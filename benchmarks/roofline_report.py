"""Render the §Dry-run / §Roofline tables from results/dryrun/*.json."""
import json
import pathlib
from typing import Dict, List

from benchmarks.common import emit

ARCH_ORDER = ["grok-1-314b", "mixtral-8x22b", "recurrentgemma-9b",
              "phi-3-vision-4.2b", "mamba2-780m", "qwen3-0.6b",
              "h2o-danube-1.8b", "gemma-7b", "h2o-danube-3-4b",
              "whisper-base"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(outdir: str = "results/dryrun") -> List[dict]:
    rows = []
    for p in sorted(pathlib.Path(outdir).glob("*.json")):
        try:
            rows.append(json.loads(p.read_text()))
        except Exception:
            pass
    return rows


def table(rows: List[dict], mesh: str = "single") -> str:
    by_key = {(r["arch"], r["shape"]): r for r in rows
              if r.get("mesh") == mesh}
    lines = ["| arch | shape | status | t_comp(ms) | t_mem(ms) | t_coll(ms) "
             "| bound | useful | temp(GB/dev) |",
             "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = by_key.get((arch, shape))
            if r is None:
                lines.append(f"| {arch} | {shape} | MISSING | | | | | | |")
                continue
            if r["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | skipped "
                             f"({r['reason'][:40]}...) | | | | | | |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | ERROR | | | | | | |")
                continue
            rl = r.get("roofline_exact") or r.get("roofline_scanned")
            mem = r.get("memory_analysis") or {}
            temp = mem.get("temp_size_in_bytes", 0) / 1e9
            useful = r.get("useful_flops_ratio")
            u = f"{useful:.2f}" if isinstance(useful, float) else "-"
            lines.append(
                f"| {arch} | {shape} | ok | {rl['t_compute']*1e3:.1f} | "
                f"{rl['t_memory']*1e3:.1f} | {rl['t_collective']*1e3:.1f} | "
                f"{rl['bottleneck']} | {u} | {temp:.1f} |")
    return "\n".join(lines)


def main(fast: bool = True) -> None:
    rows = load()
    ok = sum(r["status"] == "ok" for r in rows)
    skipped = sum(r["status"] == "skipped" for r in rows)
    err = sum(r["status"] not in ("ok", "skipped") for r in rows)
    emit("roofline.cells_ok", 0.0, f"{ok}")
    emit("roofline.cells_skipped", 0.0, f"{skipped}")
    emit("roofline.cells_error", 0.0, f"{err}")
    for r in rows:
        if r["status"] == "ok" and r.get("roofline_exact") and \
                r.get("mesh") == "single":
            rl = r["roofline_exact"]
            emit(f"roofline.{r['arch']}.{r['shape']}",
                 rl["t_bound"] * 1e6,
                 f"bound={rl['bottleneck']};useful="
                 f"{r.get('useful_flops_ratio')}")


if __name__ == "__main__":
    print(table(load(), "single"))
