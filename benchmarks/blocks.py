"""Single-Transformer-block benchmark machinery shared by the Table 1/4 and
Figure 8/9 analogues: build one block of a paper Table-2 config under
Full / LoRA / SPT, time forward+backward, and probe compiled peak memory.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.paper_blocks import blocks
from repro.core.params import init_tree
from repro.launch.dryrun import apply_variant
from repro.models import transformer
from benchmarks.common import compiled_temp_bytes, time_fn


def reduced(name: str, scale: int = 4,
            variant: str = "spt") -> configs.ModelConfig:
    """Paper block config with dims / `scale` (CPU feasibility)."""
    cfg = blocks()[name]
    cfg = dataclasses.replace(
        cfg,
        d_model=cfg.d_model // scale,
        num_heads=max(2, cfg.num_heads // scale),
        num_kv_heads=max(2, cfg.num_kv_heads // scale),
        head_dim=cfg.resolved_head_dim // 2 if scale > 2 else cfg.head_dim,
        d_ff=cfg.d_ff // scale,
        vocab_size=2048, max_position=4096)
    cfg = apply_variant(cfg, variant)
    if variant in ("full", "lora"):
        # paper-faithful baseline: attention materializes the full (n, n)
        # weight matrix (the PyTorch behavior SPT's memory claim targets)
        cfg = cfg.with_spt(chunk_q=1 << 20)
    return cfg


def block_step(cfg, module: str = "both"):
    """Returns (fn(params, x) -> scalar loss, params, x) for one block's
    forward+backward.  module: mha | ffn | both."""
    kind = cfg.pattern[0]
    defs = transformer.block_defs(cfg, kind)
    if module == "mha":
        defs.pop("ffn", None)
        defs.pop("norm_ffn", None)
    params = init_tree(defs, jax.random.PRNGKey(0))

    def fwd(p, x):
        if module == "ffn":
            from repro.models import ffn as ffn_mod
            from repro.models.layers import apply_norm
            h = apply_norm(p["norm_ffn"], x, cfg.norm)
            y, _ = ffn_mod.ffn_apply(p["ffn"], h, cfg)
            return jnp.sum((x + y.astype(x.dtype)) ** 2)
        y, _, _ = transformer.block_apply(p, x, cfg, kind, mode="train")
        return jnp.sum(y ** 2)

    def step(p, x):
        from repro.core.params import trainable_mask, partition, combine
        loss, grads = jax.value_and_grad(fwd)(p, x)
        return loss

    return step, params


def bench_block(name: str, variant: str, batch: int = 4, seq: int = 256,
                module: str = "both", scale: int = 4
                ) -> Dict[str, float]:
    cfg = reduced(name, scale, variant)
    step, params = block_step(cfg, module)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, seq, cfg.d_model)
                          ).astype(jnp.bfloat16)
    jit_step = jax.jit(step)
    us = time_fn(jit_step, params, x, iters=3, warmup=1)
    ax = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    xs = jax.ShapeDtypeStruct(x.shape, x.dtype)
    mem = compiled_temp_bytes(step, ax, xs)
    toks = batch * seq
    return {"us": us, "temp_mb": (mem or 0) / 1e6,
            "tokens_per_s": toks / (us / 1e6)}
