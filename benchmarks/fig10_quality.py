"""Figure 10 analogue: model quality (PPL on the synthetic corpus) vs
sparsity strength for sparse MHA and routed FFN."""
import dataclasses
import math

from benchmarks.common import emit
from repro import configs
from repro.data.pipeline import DataConfig, synthetic_dataset
from repro.optim.adamw import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def main(fast: bool = True) -> None:
    steps = 40 if fast else 150
    base = dataclasses.replace(
        configs.get_smoke("qwen3-0.6b"), num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512)
    grid = [
        ("dense", dict(sparse_mha=False, routed_ffn=False)),
        ("mha_1_4", dict(attn_top_fraction=0.25, routed_ffn=False)),
        ("mha_1_8", dict(attn_top_fraction=0.125, routed_ffn=False)),
        ("mha_1_16", dict(attn_top_fraction=0.0625, routed_ffn=False)),
        ("ffn_3_4", dict(sparse_mha=False, ffn_active_groups=6)),
        ("ffn_1_2", dict(sparse_mha=False, ffn_active_groups=4)),
        ("ffn_1_4", dict(sparse_mha=False, ffn_active_groups=2)),
        ("spt_default", dict(attn_top_fraction=0.125, ffn_active_groups=4)),
    ]
    for name, kw in grid:
        cfg = base.with_spt(**kw)
        data = synthetic_dataset(
            DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                       global_batch=8, branching=2, seed=7), steps=steps + 1)
        t = Trainer(cfg, OptimizerConfig(lr=3e-3, total_steps=steps),
                    TrainerConfig(total_steps=steps, log_interval=steps))
        rep = t.run(data)
        last = rep["metrics"][-1]
        emit(f"fig10.{name}", 0.0,
             f"ppl={math.exp(min(20, last['lm_loss'])):.2f};"
             f"loss={last['lm_loss']:.3f}")


if __name__ == "__main__":
    main(fast=False)
