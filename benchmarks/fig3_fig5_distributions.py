"""Figure 3 + Figure 5 analogues: (3) CDF of softmax attention weights —
the top-15% share motivates sparse MHA; (5) singular-value CDFs of the FFN
inner projection vs its output — high-rank weights / low-rank activations
motivate dynamic (not static) pruning."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit


def main(fast: bool = True) -> None:
    key = jax.random.PRNGKey(0)
    n, d = (256, 64) if fast else (512, 128)
    # correlated q/k (trained-attention stand-in)
    base = jax.random.normal(key, (n, d))
    q = base + 0.4 * jax.random.normal(jax.random.fold_in(key, 1), (n, d))
    k = base + 0.4 * jax.random.normal(jax.random.fold_in(key, 2), (n, d))
    w = jax.nn.softmax(q @ k.T / np.sqrt(d), axis=-1)
    ws = np.sort(np.asarray(w), axis=-1)[:, ::-1]
    cum = ws.cumsum(-1) / ws.sum(-1, keepdims=True)
    for frac in (0.05, 0.15, 0.25):
        share = cum[:, int(frac * n) - 1].mean()
        emit(f"fig3.top{int(frac * 100)}pct_mass", 0.0, f"{share:.3f}")

    # FFN: W_I high rank, H = relu(X W_I) low rank
    dff = 4 * d
    wi = jax.random.normal(jax.random.fold_in(key, 3), (d, dff)) / np.sqrt(d)
    x = jax.random.normal(jax.random.fold_in(key, 4), (n, d)) @ \
        jax.random.normal(jax.random.fold_in(key, 5), (d, d)) / np.sqrt(d)
    h = jax.nn.relu(x @ wi)
    sv_w = np.linalg.svd(np.asarray(wi, np.float32), compute_uv=False)
    sv_h = np.linalg.svd(np.asarray(h, np.float32), compute_uv=False)

    def top25_energy(sv):
        c = (sv ** 2).cumsum() / (sv ** 2).sum()
        return c[len(sv) // 4]

    emit("fig5.weight_top25pct_energy", 0.0, f"{top25_energy(sv_w):.3f}")
    emit("fig5.hidden_top25pct_energy", 0.0, f"{top25_energy(sv_h):.3f}")


if __name__ == "__main__":
    main(fast=False)
