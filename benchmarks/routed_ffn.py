"""Routed-FFN path benchmark: the grouped-jnp capacity fallback vs the
dense oracle vs the fused kernel path, decode-shaped and prefill-shaped.

    PYTHONPATH=src python -m benchmarks.routed_ffn \
        [--pallas] [--out BENCH_ffn.json]

Implementations timed per row (all routing-identical; see
tests/test_routed_ffn_kernel.py):

  jnp    — core.routed_ffn impl="grouped": the serving fallback (capacity
           plan + (B, G, C, d) gather + grouped einsums + scatter-add
           combine), router aux skipped as at inference
  dense  — impl="dense": the full-FFN masked oracle (no dispatch at all;
           beta times the useful FLOPs plus (1-beta) wasted)
  fused  — decode rows: kernels/routed_ffn/ref.decode_ffn_ref, the
           block-gather form the decode kernel computes (top-G' choices
           index the weight blocks directly — no plan, no dispatch
           buffer, no scatter).  On a non-TPU device this is the
           XLA-executable stand-in for the Pallas kernel's compute graph
           (same convention as benchmarks/decode_attention.py).
           Prefill rows: the grouped path as the serving prefill now
           runs it (router softmax + load-balance aux skipped); the
           in-kernel gather itself has no XLA stand-in — time it on TPU
           with --pallas.
  pallas — kernels/routed_ffn/ops.  Off-TPU it runs interpret=True, a
           CORRECTNESS mode orders of magnitude off hardware speed, so
           it is gated behind --pallas and its timing is never a speed
           claim on CPU.

Emits one JSON line per row and writes the aggregate to --out
(committed as BENCH_ffn.json at the repo root: the routed-FFN
trajectory baseline tracked per PR).
"""
import argparse
import json

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.core import lora as lora_mod
from repro.core import routed_ffn as rf
from repro.core.params import init_tree
from repro.kernels.routed_ffn import ops as rffn_ops
from repro.kernels.routed_ffn.ref import decode_ffn_ref


def _setup(d, dff, g, gp, gated, lora_on, seed=0):
    lcfg = lora_mod.LoRAConfig(rank=8, alpha=8.0, enabled=lora_on)
    rcfg = rf.RoutedFFNConfig(d_model=d, d_ff=dff, num_groups=g,
                              active_groups=gp, capacity_factor=2.0,
                              gated=gated, activation="silu")
    p = init_tree(rf.param_defs(rcfg, lcfg), jax.random.PRNGKey(seed))
    return rcfg, lcfg, p


def bench_decode_row(b, d, dff, g, gp, *, gated=True, lora_on=True,
                     run_pallas=False) -> dict:
    rcfg, lcfg, p = _setup(d, dff, g, gp, gated, lora_on)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, 1, d))
    lora_params = ({k: p[k] for k in ("lora_inner", "lora_gate",
                                     "lora_outer") if k in p}
                   if lora_on else None)

    f_jnp = jax.jit(lambda x: rf.routed_ffn(x, p, rcfg, lcfg,
                                            impl="grouped",
                                            need_aux=False)[0])
    f_dense = jax.jit(lambda x: rf.routed_ffn(x, p, rcfg, lcfg,
                                              impl="dense",
                                              need_aux=False)[0])

    def fused(x):
        choice, gate_w, _ = rf.route(x, p["router"], rcfg, need_aux=False)
        return decode_ffn_ref(x[:, 0], choice[:, 0], gate_w[:, 0],
                              p["w_inner"], p["w_outer"], p.get("w_gate"),
                              lora_params, lcfg.scale, act=rcfg.activation)

    f_fused = jax.jit(fused)
    row = {
        "shape": "decode", "b": b, "s": 1, "d": d, "d_ff": dff,
        "groups": g, "active": gp, "gated": gated, "lora": lora_on,
        "jnp_us": round(time_fn(f_jnp, x), 1),
        "dense_us": round(time_fn(f_dense, x), 1),
        "fused_us": round(time_fn(f_fused, x), 1),
    }
    row["fused_speedup"] = round(row["jnp_us"] / row["fused_us"], 2)
    if run_pallas:
        interp = jax.devices()[0].platform != "tpu"
        f_pl = lambda x: rffn_ops.routed_ffn_decode(
            x, p, rcfg, lcfg, interpret=interp)[0]
        row["pallas_us"] = round(time_fn(f_pl, x, iters=3, warmup=1), 1)
        row["pallas_interpret"] = interp
    return row


def bench_prefill_row(b, s, d, dff, g, gp, *, gated=True, lora_on=True,
                      run_pallas=False) -> dict:
    rcfg, lcfg, p = _setup(d, dff, g, gp, gated, lora_on)
    x = jax.random.normal(jax.random.PRNGKey(2), (b, s, d))

    # jnp = the pre-PR prefill path (always paid router softmax + lb aux);
    # fused stand-in = the serving prefill as this PR runs it (aux
    # skipped).  The in-kernel gather is kernel-only: --pallas times it.
    f_jnp = jax.jit(lambda x: rf.routed_ffn(x, p, rcfg, lcfg,
                                            impl="grouped")[0])
    f_dense = jax.jit(lambda x: rf.routed_ffn(x, p, rcfg, lcfg,
                                              impl="dense",
                                              need_aux=False)[0])
    f_fused = jax.jit(lambda x: rf.routed_ffn(x, p, rcfg, lcfg,
                                              impl="grouped",
                                              need_aux=False)[0])
    row = {
        "shape": "prefill", "b": b, "s": s, "d": d, "d_ff": dff,
        "groups": g, "active": gp, "gated": gated, "lora": lora_on,
        "jnp_us": round(time_fn(f_jnp, x), 1),
        "dense_us": round(time_fn(f_dense, x), 1),
        "fused_us": round(time_fn(f_fused, x), 1),
    }
    row["fused_speedup"] = round(row["jnp_us"] / row["fused_us"], 2)
    if run_pallas:
        interp = jax.devices()[0].platform != "tpu"
        f_pl = lambda x: rffn_ops.routed_ffn(x, p, rcfg, lcfg,
                                             interpret=interp,
                                             need_aux=False)[0]
        row["pallas_us"] = round(time_fn(f_pl, x, iters=3, warmup=1), 1)
        row["pallas_interpret"] = interp
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_ffn.json")
    ap.add_argument("--pallas", action="store_true",
                    help="also time the Pallas kernels (interpret mode "
                         "off-TPU: correctness only, not a speed signal)")
    args = ap.parse_args()

    platform = jax.devices()[0].platform
    note = ("fused == the kernel-equivalent XLA execution (decode rows: "
            "block-gather decode_ffn_ref, no capacity plan / dispatch "
            "buffer; prefill rows: grouped with inference aux skip).  "
            "jnp == the grouped capacity fallback serving default.  On "
            "TPU, time the kernels themselves with --pallas.")
    rows = []
    decode_shapes = [
        (8, 64, 256, 8, 2),
        (8, 64, 256, 8, 4),
        (32, 64, 256, 8, 2),
        (32, 128, 512, 8, 2),
        (64, 64, 256, 16, 4),
        (16, 128, 512, 16, 4),
    ]
    for i, (b, d, dff, g, gp) in enumerate(decode_shapes):
        row = bench_decode_row(b, d, dff, g, gp,
                               run_pallas=args.pallas and i == 0)
        rows.append(row)
        print(json.dumps(row))
    for i, (b, s, d, dff, g, gp) in enumerate([
            (2, 128, 64, 256, 8, 4),
            (4, 256, 64, 256, 8, 2)]):
        row = bench_prefill_row(b, s, d, dff, g, gp,
                                run_pallas=args.pallas and i == 0)
        rows.append(row)
        print(json.dumps(row))
    wins = sum(r["fused_us"] < r["jnp_us"] for r in rows)
    out = {"bench": "routed_ffn", "device": platform, "note": note,
           "fused_wins": f"{wins}/{len(rows)}", "rows": rows}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out} (fused beats jnp on {wins}/{len(rows)} rows)")


if __name__ == "__main__":
    main()
