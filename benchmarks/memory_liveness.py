"""Static peak-live-bytes benchmark of the serving entrypoints under
three decode-chunk donation masks — what buffer donation buys.

Unlike the timing benches this one is exact and deterministic: it runs
the liveness pass (src/repro/analysis/liveness.py) over the same traced
chunk jaxpr with (a) no donation, (b) the legacy mask that donated only
caches/page_table/astate, and (c) the HEAD mask that also donates the
per-slot decode state (tok/pos/active/n_gen/buf).  Non-donated
operands flowing into the chunk's while carry pay a copy-on-entry
surcharge (the caller's buffer stays resident alongside the loop's
working copy), so the deltas are the real resident-bytes the donation
fixes recover.  The batched ragged prefill is recorded honestly: it
builds its caches in-jit, so no operand is donatable and the row
carries no reduction.

Writes BENCH_memory.json; scripts/bench_floors.json floors the
reduction columns so a future PR that drops a donation fails
scripts/check_bench.py.
"""
from __future__ import annotations

import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

from repro.analysis import jaxpr_audit as ja          # noqa: E402
from repro.analysis import liveness as lv             # noqa: E402
from repro.serving.engine import CHUNK_DONATE_ARGNUMS  # noqa: E402

LEGACY_DONATE_ARGNUMS = (1, 2, 3)   # caches/page_table/astate only

SHAPES = {
    # the shape every other audit/baseline uses
    "tiny": dict(slots=2, max_gen=4, max_len=32),
    # serving-shaped: the per-slot decode state is KB-scale, so the
    # slot-state donation win is visible, not epsilon
    "serving": dict(slots=8, max_gen=128, max_len=256),
}


def chunk_rows():
    configs = {
        "engine.decode_chunk":
            dict(decode_attn_impl="kernel", ffn_impl="pallas"),
        "engine.decode_chunk_paged":
            dict(decode_attn_impl="kernel", attn_impl="pallas",
                 ffn_impl="pallas", kv_layout="paged", kv_page_size=16),
    }
    rows = []
    for entry, kw in configs.items():
        for shape, dims in SHAPES.items():
            cfg = ja._tiny_lm_cfg(**kw)
            closed, _, _, args = ja._engine_chunk_jaxpr(cfg, **dims)
            names = lv.arg_leaf_names(args, lv.CHUNK_ARG_NAMES)

            def peak(mask):
                rep = lv.analyze_closed(
                    closed, lv.donated_leaf_mask(args, mask), names,
                    entry)
                return rep.signature.peak_live_bytes, \
                    rep.signature.donated_bytes

            none, _ = peak(())
            legacy, _ = peak(LEGACY_DONATE_ARGNUMS)
            head, donated = peak(CHUNK_DONATE_ARGNUMS)
            rows.append({
                "kind": "chunk", "entry": entry, "shape": shape, **dims,
                "peak_no_donation": none,
                "peak_legacy_mask": legacy,
                "peak_head_mask": head,
                "donated_bytes_head": donated,
                "slot_state_reduction_bytes": legacy - head,
                "donation_reduction_bytes": none - head,
                "donation_reduction_frac": round((none - head) / none, 4),
            })
            print(f"{entry:<28} {shape:<8} none {none:>12,}  "
                  f"legacy {legacy:>12,}  head {head:>12,}  "
                  f"slot-state -{legacy - head:,} B")
    return rows


def prefill_row():
    rep = lv.memory_report("engine.prefill_ragged")
    sig = rep.signature
    print(f"{'engine.prefill_ragged':<28} {'tiny':<8} "
          f"peak {sig.peak_live_bytes:>12,}  (no donatable operands)")
    return {
        "kind": "prefill", "entry": "engine.prefill_ragged",
        "shape": "tiny",
        "peak_live_bytes": sig.peak_live_bytes,
        "donated_bytes": sig.donated_bytes,
        "note": "builds caches in-jit; no cache-sized operand exists to "
                "donate, so no reduction is claimed",
    }


def main() -> int:
    doc = {
        "note": "static liveness-model peak live bytes (exact, "
                "deterministic — no timing jitter); reductions are what "
                "the decode-chunk donation mask recovers vs no/legacy "
                "donation; regenerate with python "
                "benchmarks/memory_liveness.py",
        "rows": chunk_rows() + [prefill_row()],
    }
    out = REPO / "BENCH_memory.json"
    out.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {out.relative_to(REPO)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
