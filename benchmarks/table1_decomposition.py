"""Table 1 analogue: running-time and peak-memory decomposition of one
Transformer block into MHA and FFN for Full / LoRA / SPT (OPT-2048 family,
dims scaled for CPU; ratios are the signal)."""
from benchmarks.blocks import bench_block
from benchmarks.common import emit


def main(fast: bool = True) -> None:
    scale = 8 if fast else 4
    for variant in ("full", "lora", "spt"):
        for module in ("mha", "ffn", "both"):
            r = bench_block("opt-2048", variant, module=module, scale=scale,
                            batch=2 if fast else 4, seq=128 if fast else 256)
            emit(f"table1.{variant}.{module}", r["us"],
                 f"temp_mb={r['temp_mb']:.1f}")


if __name__ == "__main__":
    main(fast=False)
