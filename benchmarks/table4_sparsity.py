"""Table 4 analogue: MHA/FFN time + memory at different sparsity strengths
(MHA non-zero fraction 1/4 vs 1/8; FFN active fraction 3/4 vs 1/2)."""
import dataclasses

from benchmarks.blocks import bench_block, reduced
from benchmarks.common import emit
from repro.launch.dryrun import apply_variant


def main(fast: bool = True) -> None:
    scale = 8 if fast else 4
    kw = dict(scale=scale, batch=2 if fast else 4, seq=128 if fast else 256)
    r = bench_block("opt-2048", "lora", module="mha", **kw)
    emit("table4.mha.lora", r["us"], f"temp_mb={r['temp_mb']:.1f}")
    for frac, tag in ((0.25, "1_4"), (0.125, "1_8")):
        import benchmarks.blocks as B
        cfg = B.reduced("opt-2048", scale, "spt").with_spt(
            attn_top_fraction=frac)
        step, params = B.block_step(cfg, "mha")
        import jax, jax.numpy as jnp
        from benchmarks.common import compiled_temp_bytes, time_fn
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (kw["batch"], kw["seq"], cfg.d_model)
                              ).astype(jnp.bfloat16)
        us = time_fn(jax.jit(step), params, x, iters=3, warmup=1)
        ax = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
        mem = compiled_temp_bytes(step, ax,
                                  jax.ShapeDtypeStruct(x.shape, x.dtype))
        emit(f"table4.mha.spt_{tag}", us, f"temp_mb={(mem or 0) / 1e6:.1f}")
    r = bench_block("opt-2048", "lora", module="ffn", **kw)
    emit("table4.ffn.lora", r["us"], f"temp_mb={r['temp_mb']:.1f}")
    for active, tag in ((6, "3_4"), (4, "1_2")):
        import benchmarks.blocks as B
        cfg = B.reduced("opt-2048", scale, "spt").with_spt(
            ffn_active_groups=active)
        step, params = B.block_step(cfg, "ffn")
        import jax, jax.numpy as jnp
        from benchmarks.common import compiled_temp_bytes, time_fn
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (kw["batch"], kw["seq"], cfg.d_model)
                              ).astype(jnp.bfloat16)
        us = time_fn(jax.jit(step), params, x, iters=3, warmup=1)
        ax = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
        mem = compiled_temp_bytes(step, ax,
                                  jax.ShapeDtypeStruct(x.shape, x.dtype))
        emit(f"table4.ffn.spt_{tag}", us, f"temp_mb={(mem or 0) / 1e6:.1f}")


if __name__ == "__main__":
    main(fast=False)
