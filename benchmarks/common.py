"""Shared benchmark utilities: wall timing, compiled-memory probes, CSV."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall microseconds per call (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def compiled_temp_bytes(fn: Callable, *abstract_args) -> Optional[int]:
    """Peak temp bytes from the compiled module (1-device; the CPU backend
    promotes bf16 buffers to f32, so treat as an upper bound ~2x TPU)."""
    try:
        compiled = jax.jit(fn).lower(*abstract_args).compile()
        ma = compiled.memory_analysis()
        return int(ma.temp_size_in_bytes)
    except Exception:
        return None


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def scale_note() -> str:
    return ("CPU container: shapes scaled down from the paper's "
            "(batch 16, seq 512); ratios are the comparable signal")
