"""Decode sparse-attention benchmark: jnp gather fallback vs the fused
decode formulation, swept over (S, top_fraction, GQA heads).

    PYTHONPATH=src python -m benchmarks.decode_attention \
        [--pallas] [--out BENCH_decode.json]

Implementations timed per row (all selection-identical; see
tests/test_sparse_decode.py):

  jnp    — sa.sparse_mha_decode: the serving fallback (bucket_select index
           emission + grouped gather attention; GQA reshape form, no
           cache repeats)
  fused  — sa.sparse_mha_decode_masked: the fused-kernel-equivalent masked
           execution (threshold histogram -> mask on grouped dense logits;
           no index compaction, no gather).  On a non-TPU device this is
           the XLA-executable stand-in for the Pallas kernel's compute
           graph, the same convention as benchmarks/table5_kernels.py —
           the real kernel additionally skips ineligible key tiles and
           keeps the (S,) score row in VMEM.
  pallas — kernels/sparse_attention/ops.sparse_mha_decode.  Off-TPU it
           runs interpret=True, a CORRECTNESS mode orders of magnitude off
           hardware speed, so it is gated behind --pallas and its timing
           is never a speed claim on CPU.

Emits one JSON line per row and writes the aggregate to --out
(committed as BENCH_decode.json at the repo root: the decode-throughput
trajectory baseline tracked per PR).
"""
import argparse
import json

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.core import pq
from repro.core import sparse_attention as sa
from repro.core.params import init_tree
from repro.kernels.sparse_attention import ops as sa_ops


def bench_row(s: int, frac: float, hq: int, hk: int, gran: str, *,
              b: int = 4, d: int = 64, run_pallas: bool = False) -> dict:
    pcfg = pq.PQConfig(head_dim=d, code_dim=8, num_codewords=16)
    cb = init_tree(pq.param_defs(pcfg), jax.random.PRNGKey(0))["codebooks"]
    scfg = sa.SparseAttentionConfig(pq=pcfg, top_fraction=frac, min_l=16,
                                    select_granularity=gran)
    ks = jax.random.split(jax.random.PRNGKey(s), 3)
    q = jax.random.normal(ks[0], (b, hq, 1, d))
    k = jax.random.normal(ks[1], (b, hk, s, d))
    v = jax.random.normal(ks[2], (b, hk, s, d))
    codes = pq.assign(k, cb).astype(jnp.int8)
    kv_valid = jnp.ones((b, s), bool)
    scale = d ** -0.5

    f_jnp = jax.jit(lambda q, k, v, c, kv: sa.sparse_mha_decode(
        q, k, v, c, cb, scfg, scale, kv))
    f_fused = jax.jit(lambda q, k, v, c, kv: sa.sparse_mha_decode_masked(
        q, k, v, c, cb, scfg, scale, kv))
    row = {
        "s": s, "l": sa.top_l(s, scfg, None), "frac": frac, "hq": hq,
        "hk": hk, "granularity": gran, "batch": b, "head_dim": d,
        "jnp_us": round(time_fn(f_jnp, q, k, v, codes, kv_valid), 1),
        "fused_us": round(time_fn(f_fused, q, k, v, codes, kv_valid), 1),
    }
    row["fused_speedup"] = round(row["jnp_us"] / row["fused_us"], 2)
    if run_pallas:
        interp = jax.devices()[0].platform != "tpu"
        f_pl = lambda q, k, v, c, kv: sa_ops.sparse_mha_decode(
            q, k, v, c, cb, scfg, scale, kv, interpret=interp)
        row["pallas_us"] = round(
            time_fn(f_pl, q, k, v, codes, kv_valid, iters=3, warmup=1), 1)
        row["pallas_interpret"] = interp
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_decode.json")
    ap.add_argument("--pallas", action="store_true",
                    help="also time the Pallas kernel (interpret mode off-"
                         "TPU: correctness only, not a speed signal)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seqs", type=int, nargs="*",
                    default=[512, 2048, 8192])
    args = ap.parse_args()

    platform = jax.devices()[0].platform
    note = ("fused == sparse_mha_decode_masked, the kernel-equivalent XLA "
            "execution (table5 convention: the CPU/GPU stand-in for the "
            "Pallas decode kernel; on TPU, time the kernel itself with "
            "--pallas).  jnp == the gather fallback serving default.")
    rows = []
    sweeps = [(s, 0.125, 8, 2, g) for s in args.seqs for g in ("qhead",
                                                               "kvgroup")]
    sweeps += [(2048, 0.125, 8, 8, "qhead"), (2048, 0.25, 8, 2, "qhead")]
    for s, frac, hq, hk, gran in sweeps:
        row = bench_row(s, frac, hq, hk, gran, b=args.batch,
                        run_pallas=args.pallas and s == min(args.seqs))
        rows.append(row)
        print(json.dumps(row))
    out = {"bench": "decode_attention", "device": platform, "note": note,
           "rows": rows}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
