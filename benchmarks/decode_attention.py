"""Decode sparse-attention benchmark: jnp gather fallback vs the fused
decode formulation, plus the Pallas kernel tiers (one-pass fused vs the
two-pass threshold+attention pair, and paged kernel-native vs gathered
view), swept over (S, top_fraction, GQA heads).

    PYTHONPATH=src python -m benchmarks.decode_attention \
        [--out BENCH_decode.json]

Implementations timed per row (all selection-identical; see
tests/test_sparse_decode.py):

  jnp      — sa.sparse_mha_decode: the serving fallback (bucket_select
             index emission + grouped gather attention; GQA reshape form,
             no cache repeats)
  fused    — sa.sparse_mha_decode_masked: the fused-kernel-equivalent
             masked execution (threshold histogram -> mask on grouped
             dense logits; no index compaction, no gather).  On a non-TPU
             device this is the XLA-executable stand-in for the Pallas
             kernel's compute graph, the same convention as
             benchmarks/table5_kernels.py.
  onepass  — kernels ops.sparse_mha_decode fuse=True: ONE pallas_call
             whose grid prepends a histogram prologue (tiles 0..nkt-1)
             to the attention sweep (tiles nkt..2nkt-1); the (G, R, 2)
             thresholds tensor never exists in HBM.
  twopass  — the same op fuse=False: decode_topl_thresholds kernel, HBM
             thresholds round-trip, then the attention kernel (the
             bisection/fallback tier).
  paged    — ops.sparse_mha_decode_paged (kernel-native (page_id, offset)
             addressing through a scalar-prefetched page table) vs
             gather_pages + the fused kernel over the gathered view, on
             the s==2048 qhead rows.

Off-TPU the kernel tiers run interpret=True — a correctness mode orders
of magnitude off hardware speed, so their absolute us are never a speed
claim on CPU; the tier-vs-tier RATIOS are the tracked signal (both sides
pay identical interpreter overhead per grid step, so fewer dispatches +
no HBM round-trip shows up as ratio > 1).

Emits one JSON line per row and writes the aggregate to --out
(committed as BENCH_decode.json at the repo root: the decode-throughput
trajectory baseline tracked per PR; scripts/bench_floors.json records
floors over the ratio columns).
"""
import argparse
import json

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.core import pq
from repro.core import sparse_attention as sa
from repro.core.params import init_tree
from repro.kernels.sparse_attention import ops as sa_ops
from repro.serving import kv_pages as kvp

PAGE_SIZE = 256


def _to_pool(x: jax.Array, ps: int) -> jax.Array:
    """(B, Hk, MP*ps, .) contiguous cache -> (B*MP, Hk, ps, .) pool whose
    identity page table reproduces it exactly (bit-comparable views)."""
    b, hk, s, last = x.shape
    mp = s // ps
    return (x.reshape(b, hk, mp, ps, last)
            .transpose(0, 2, 1, 3, 4).reshape(b * mp, hk, ps, last))


def bench_row(s: int, frac: float, hq: int, hk: int, gran: str, *,
              b: int = 4, d: int = 64, run_paged: bool = False) -> dict:
    pcfg = pq.PQConfig(head_dim=d, code_dim=8, num_codewords=16)
    cb = init_tree(pq.param_defs(pcfg), jax.random.PRNGKey(0))["codebooks"]
    scfg = sa.SparseAttentionConfig(pq=pcfg, top_fraction=frac, min_l=16,
                                    select_granularity=gran)
    ks = jax.random.split(jax.random.PRNGKey(s), 3)
    q = jax.random.normal(ks[0], (b, hq, 1, d))
    k = jax.random.normal(ks[1], (b, hk, s, d))
    v = jax.random.normal(ks[2], (b, hk, s, d))
    codes = pq.assign(k, cb).astype(jnp.int8)
    kv_valid = jnp.ones((b, s), bool)
    scale = d ** -0.5
    interp = jax.devices()[0].platform != "tpu"

    f_jnp = jax.jit(lambda q, k, v, c, kv: sa.sparse_mha_decode(
        q, k, v, c, cb, scfg, scale, kv))
    f_fused = jax.jit(lambda q, k, v, c, kv: sa.sparse_mha_decode_masked(
        q, k, v, c, cb, scfg, scale, kv))
    f_one = lambda q, k, v, c, kv: sa_ops.sparse_mha_decode(
        q, k, v, c, cb, scfg, scale, kv, interpret=interp, fuse=True)
    f_two = lambda q, k, v, c, kv: sa_ops.sparse_mha_decode(
        q, k, v, c, cb, scfg, scale, kv, interpret=interp, fuse=False)
    row = {
        "s": s, "l": sa.top_l(s, scfg, None), "frac": frac, "hq": hq,
        "hk": hk, "granularity": gran, "batch": b, "head_dim": d,
        "jnp_us": round(time_fn(f_jnp, q, k, v, codes, kv_valid), 1),
        "fused_us": round(time_fn(f_fused, q, k, v, codes, kv_valid), 1),
        "onepass_us": round(time_fn(f_one, q, k, v, codes, kv_valid,
                                    iters=3, warmup=1), 1),
        "twopass_us": round(time_fn(f_two, q, k, v, codes, kv_valid,
                                    iters=3, warmup=1), 1),
        "kernel_interpret": interp,
    }
    row["fused_speedup"] = round(row["jnp_us"] / row["fused_us"], 2)
    row["onepass_speedup"] = round(row["twopass_us"] / row["onepass_us"], 2)
    if run_paged:
        ps = PAGE_SIZE
        ptk = ps // 2       # both routes pair tiles -> one-page-wide blocks
        kp, vp, cp = (_to_pool(x, ps) for x in (k, v, codes))
        pt = jnp.arange(b * (s // ps), dtype=jnp.int32).reshape(b, s // ps)
        f_native = lambda q, kv: sa_ops.sparse_mha_decode_paged(
            q, kp, vp, cp, cb, scfg, scale, kv, pt, tile_k=ptk,
            interpret=interp)
        f_gather = lambda q, kv: sa_ops.sparse_mha_decode(
            q, kvp.gather_pages(kp, pt), kvp.gather_pages(vp, pt),
            kvp.gather_pages(cp, pt), cb, scfg, scale, kv, tile_k=ptk,
            interpret=interp, fuse=True)
        row["page_size"] = ps
        row["paged_native_us"] = round(
            time_fn(f_native, q, kv_valid, iters=5, warmup=1), 1)
        row["paged_gather_us"] = round(
            time_fn(f_gather, q, kv_valid, iters=5, warmup=1), 1)
        row["paged_native_speedup"] = round(
            row["paged_gather_us"] / row["paged_native_us"], 2)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_decode.json")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seqs", type=int, nargs="*",
                    default=[512, 2048, 8192])
    args = ap.parse_args()

    platform = jax.devices()[0].platform
    note = ("fused == sparse_mha_decode_masked, the kernel-equivalent XLA "
            "execution (table5 convention: the CPU/GPU stand-in for the "
            "Pallas decode kernel).  jnp == the gather fallback serving "
            "default.  onepass/twopass == the Pallas kernel tiers "
            "(interpret-timed off-TPU: only their ratio is a signal).  "
            "paged == kernel-native page addressing vs gathered view, "
            "s==2048 qhead rows.")
    rows = []
    sweeps = [(s, 0.125, 8, 2, g) for s in args.seqs for g in ("qhead",
                                                               "kvgroup")]
    sweeps += [(2048, 0.125, 8, 8, "qhead"), (2048, 0.25, 8, 2, "qhead")]
    for s, frac, hq, hk, gran in sweeps:
        row = bench_row(s, frac, hq, hk, gran, b=args.batch,
                        run_paged=(s == 2048 and gran == "qhead"
                                   and s % PAGE_SIZE == 0))
        rows.append(row)
        print(json.dumps(row))
    out = {"bench": "decode_attention", "device": platform, "note": note,
           "rows": rows}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
