"""Table 5 analogue: per-operator breakdown of sparse MHA and routed FFN
(PQ assign / top-L thresholds / gather-attention / dispatch / grouped GEMM),
timed on the jnp execution path (the CPU stand-in for the CUDA kernels the
paper profiles; the Pallas kernels are the TPU-target forms)."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import dispatch, pq
from repro.core import routed_ffn as rf
from repro.core import sparse_attention as sa
from repro.core.lora import LoRAConfig
from repro.core.params import init_tree


def main(fast: bool = True) -> None:
    n, d, hq, hk, b = (256, 64, 4, 2, 2) if fast else (512, 64, 8, 4, 4)
    pcfg = pq.PQConfig(head_dim=d, code_dim=8, num_codewords=16)
    cb = init_tree(pq.param_defs(pcfg), jax.random.PRNGKey(0))["codebooks"]
    scfg = sa.SparseAttentionConfig(pq=pcfg, top_fraction=0.125, min_l=8,
                                    chunk_q=128)
    q = jax.random.normal(jax.random.PRNGKey(1), (b, hq, n, d))
    k = jax.random.normal(jax.random.PRNGKey(2), (b, hk, n, d))
    v = jax.random.normal(jax.random.PRNGKey(3), (b, hk, n, d))

    f_assign = jax.jit(lambda x: pq.assign(x, cb))
    emit("table5.mha.pq_assign", time_fn(f_assign, q))

    codes_q, codes_k = pq.assign(q, cb), pq.assign(k, cb)

    def select(cq, ck):
        s = pq.match_scores(cq.reshape(b, hq, n, -1),
                            jnp.repeat(ck, hq // hk, axis=1), 16)
        mask = sa.attention_mask(jnp.arange(n), jnp.arange(n), True, None)
        return sa.bucket_select(s, mask[None, None], sa.top_l(n, scfg, None),
                                pcfg.num_books)

    emit("table5.mha.topl_select", time_fn(jax.jit(select), codes_q, codes_k))

    full = jax.jit(lambda q, k, v: sa.sparse_mha(q, k, v, cb, scfg, d ** -0.5)[0])
    emit("table5.mha.sparse_attention_full", time_fn(full, q, k, v))
    dense = jax.jit(lambda q, k, v: sa.dense_attention(q, k, v, d ** -0.5))
    emit("table5.mha.dense_attention_ref", time_fn(dense, q, k, v))

    # routed FFN decomposition
    lcfg = LoRAConfig(rank=8, alpha=8.0)
    rcfg = rf.RoutedFFNConfig(d_model=128, d_ff=512, num_groups=8,
                              active_groups=4, capacity_factor=1.5)
    p = init_tree(rf.param_defs(rcfg, lcfg), jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5), (b, n, 128))

    route_fn = jax.jit(lambda x: rf.route(x, p["router"], rcfg)[0])
    emit("table5.ffn.router", time_fn(route_fn, x))

    def disp(x):
        choice, gate, _ = rf.route(x, p["router"], rcfg)
        cap = dispatch.capacity(n, 8, 4, 1.5)
        plan = dispatch.make_plan(choice, gate, 8, cap)
        return dispatch.gather(x, plan)

    emit("table5.ffn.dispatch_gather", time_fn(jax.jit(disp), x))
    grouped = jax.jit(lambda x: rf.routed_ffn(x, p, rcfg, lcfg,
                                              impl="grouped")[0])
    emit("table5.ffn.routed_full", time_fn(grouped, x))
    densef = jax.jit(lambda x: rf.routed_ffn(x, p, rcfg, lcfg,
                                             impl="dense")[0])
    emit("table5.ffn.dense_masked_ref", time_fn(densef, x))


if __name__ == "__main__":
    main(fast=False)
