"""Figure 8 analogue: throughput + peak memory for the 5 paper Table-2
blocks under Full / LoRA / SPT."""
from benchmarks.blocks import bench_block
from benchmarks.common import emit

BLOCKS = ("opt-1024", "opt-2048", "opt-2560", "llama-2560", "llama-4096")


def main(fast: bool = True) -> None:
    names = BLOCKS[:2] if fast else BLOCKS
    for name in names:
        rows = {}
        for variant in ("full", "lora", "spt"):
            r = bench_block(name, variant, scale=8 if fast else 4,
                            batch=2 if fast else 4,
                            seq=128 if fast else 256)
            rows[variant] = r
            emit(f"fig8.{name}.{variant}", r["us"],
                 f"tok_s={r['tokens_per_s']:.0f};temp_mb={r['temp_mb']:.1f}")
        if rows["full"]["us"]:
            emit(f"fig8.{name}.speedup_spt_vs_full", 0.0,
                 f"{rows['full']['us'] / rows['spt']['us']:.2f}x;"
                 f"mem={rows['spt']['temp_mb'] / max(rows['full']['temp_mb'], 1e-9):.2f}x")


if __name__ == "__main__":
    main(fast=False)
