"""Table 6 analogue: Naive-PQ (float-score sort / lax.top_k) vs the
bucket-sort selection.  The paper's GPU finding (4.6x slower) appears on
TPU as BOTH a time gap and an SPMD one (sort forces an all-gather of the
score tensor — EXPERIMENTS.md §Perf it4)."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import pq
from repro.core import sparse_attention as sa
from repro.core.params import init_tree


def main(fast: bool = True) -> None:
    n = 512 if fast else 1024
    l = n // 8
    pcfg = pq.PQConfig(head_dim=64, code_dim=8, num_codewords=16)
    cb = init_tree(pq.param_defs(pcfg), jax.random.PRNGKey(0))["codebooks"]
    q = jax.random.normal(jax.random.PRNGKey(1), (2, 4, n, 64))
    k = jax.random.normal(jax.random.PRNGKey(2), (2, 4, n, 64))
    codes_q, codes_k = pq.assign(q, cb), pq.assign(k, cb)
    mask = sa.attention_mask(jnp.arange(n), jnp.arange(n), True, None)

    def naive(cq, ck):
        # float approximate distances (codeword inner-product table) + sort
        cb_dots = jnp.einsum("med,mfd->mef", cb, cb)      # (M, E, E)
        s = jnp.zeros((2, 4, n, n), jnp.float32)
        for m in range(pcfg.num_books):
            s = s + cb_dots[m, cq[..., m][..., :, None],
                            ck[..., m][..., None, :]]
        return jax.lax.top_k(jnp.where(mask, s, -jnp.inf), l)[1]

    def bucket(cq, ck):
        s = pq.match_scores(cq, ck, 16)
        return sa.bucket_select(s, mask[None, None], l, pcfg.num_books)[0]

    t_naive = time_fn(jax.jit(naive), codes_q, codes_k, iters=3)
    t_bucket = time_fn(jax.jit(bucket), codes_q, codes_k, iters=3)
    emit("table6.naive_pq_sort", t_naive)
    emit("table6.bucket_select", t_bucket,
         f"cpu_ratio={t_naive / t_bucket:.2f}x (paper: 4.6x on GPU; on the "
         "TPU target the sort additionally forces an SPMD all-gather of the "
         "score tensor — EXPERIMENTS.md §Perf it4 — so bucket wins there "
         "regardless of scalar throughput)")


if __name__ == "__main__":
    main(fast=False)
