"""Table 3 analogue: end-to-end fine-tuning of a scaled-down OPT-2.7B-family
model under Full / LoRA / SPT — wall time per step, quality (loss) after a
short budget, and the max-sequence-without-blowup proxy via compiled temps."""
import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import compiled_temp_bytes, emit, time_fn
from repro.configs.paper_blocks import opt_2_7b
from repro.data.pipeline import DataConfig, synthetic_dataset
from repro.launch.dryrun import apply_variant
from repro.optim.adamw import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def _mini(variant: str):
    cfg = dataclasses.replace(
        opt_2_7b(num_layers=4), d_model=320, num_heads=4, num_kv_heads=4,
        head_dim=80, d_ff=1280, vocab_size=2048, max_position=4096)
    return apply_variant(cfg, variant)


def main(fast: bool = True) -> None:
    steps = 10 if fast else 40
    for variant in ("full", "lora", "spt"):
        cfg = _mini(variant)
        data = list(synthetic_dataset(
            DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                       global_batch=4, branching=2), steps=steps + 2))
        t = Trainer(cfg, OptimizerConfig(lr=2e-3, total_steps=steps),
                    TrainerConfig(total_steps=steps, log_interval=steps))
        import time
        t.run(iter(data[:1]))                     # compile
        t.tcfg = dataclasses.replace(t.tcfg, total_steps=steps)
        t0 = time.time()
        rep = t.run(iter(data[1:steps + 1]))
        dt = (time.time() - t0) / max(1, steps - 1) * 1e6
        last = rep["metrics"][-1] if rep["metrics"] else {"loss": float("nan")}
        emit(f"table3.{variant}", dt, f"loss={last['loss']:.3f}")


if __name__ == "__main__":
    main(fast=False)
