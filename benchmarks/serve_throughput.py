"""Serving throughput: steady-state decode tokens/s vs prefill tokens/s,
with compile/warmup reported separately (an honest split — the old
launcher folded tracing + compilation into tokens/s).

    PYTHONPATH=src python -m benchmarks.serve_throughput --requests 12

Reports, per configuration:
  compile_s       — first-run wall clock minus steady-state wall clock
  prefill_tok_s   — prompt tokens / sum of block_until_ready'd prefill calls
  decode_tok_s    — generated tokens / sum of block_until_ready'd decode
                    chunks (the continuous-batching steady state)

``--variants prefill-overlap`` runs the disaggregated-scheduler comparison
(serial batch-1 admission vs batched ragged prefill vs prefill/decode
overlap) on a bursty mixed-length workload and writes BENCH_serve.json
with time-to-first-token and tokens/s per mode.

``--paging`` additionally runs the honest KV-memory comparison at long
max_len (contiguous strip vs paged pool at equal slot counts, measured
peak pages, and the concurrent-slot count each layout supports under the
contiguous layout's memory budget) and writes BENCH_paging.json — CPU
stand-in numbers per the repo convention (compare across PRs, not
against TPU).
"""
import argparse
import dataclasses
import json
import time

import jax

from repro import configs
from repro.core.params import init_tree
from repro.launch.serve import build_requests
from repro.serving import kv_pages as kvp
from repro.serving.engine import Engine
from repro.train.state import model_defs

from benchmarks.common import scale_note


def _variant_cfg(cfg, variant: str):
    """Serving variants tracked per PR: the dense baseline, the sparse-MHA
    jnp decode fallback, the fused Pallas decode kernel path, and the
    routed-FFN decode paths (ffn = grouped capacity dispatch at (B,1,d),
    ffn-kernel = block-gather Pallas kernel, no dispatch buffer).  Kernel
    variants run interpret-mode off-TPU — compare kernel rows across PRs,
    not against the jnp rows, on CPU."""
    if variant == "dense":
        return cfg.with_spt(sparse_mha=False)
    if variant == "sparse":
        return cfg.with_spt(sparse_mha=True, decode_attn_impl="jnp")
    if variant == "sparse-kernel":
        return cfg.with_spt(sparse_mha=True, decode_attn_impl="kernel")
    if variant == "ffn":
        return cfg.with_spt(sparse_mha=False, decode_ffn_impl="jnp")
    if variant == "ffn-kernel":
        return cfg.with_spt(sparse_mha=False, decode_ffn_impl="kernel")
    raise ValueError(variant)


def bench(arch: str, requests: int, slots: int, prompt_len: int, gen: int,
          decode_chunk: int, ragged: bool, variant: str = "sparse",
          max_len: int = 0, kv_layout: str = "contiguous",
          page_size: int = 128, kv_pages=None, prefill_batch=None,
          prefill_decode_ratio: float = 0.0, trials: int = 1,
          telemetry: str = "off", trace_out=None) -> dict:
    cfg = _variant_cfg(configs.get_smoke(arch), variant)
    if trace_out:
        telemetry = "trace"
    cfg = cfg.with_spt(kv_layout=kv_layout, kv_page_size=page_size,
                       telemetry=telemetry)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    max_len = max_len or prompt_len + gen + 8
    engine = Engine(cfg, params, max_len=max_len,
                    num_slots=slots, decode_chunk=decode_chunk,
                    kv_pages=kv_pages, prefill_batch=prefill_batch,
                    prefill_decode_ratio=prefill_decode_ratio)
    reqs = build_requests(cfg, requests, prompt_len, gen, ragged)

    t0 = time.perf_counter()
    engine.run(reqs)
    first_wall = time.perf_counter() - t0

    # best of `trials` steady runs (host scheduling noise dominates the
    # tiny CPU stand-in shapes; min is the standard microbenchmark choice)
    steady_wall, s = float("inf"), None
    for _ in range(max(1, trials)):
        t0 = time.perf_counter()
        engine.run(reqs)
        wall = time.perf_counter() - t0
        if wall < steady_wall:
            steady_wall, s = wall, engine.last_stats
    row_b = kvp.kv_row_bytes(cfg)
    row = {
        "arch": cfg.name, "variant": variant, "requests": requests,
        "slots": slots, "max_len": max_len,
        "prompt_len": prompt_len, "gen": gen, "ragged": ragged,
        "compile_s": round(first_wall - steady_wall, 2),
        "steady_wall_s": round(steady_wall, 2),
        "prefill_tok_s": round(s.prefill_tok_s, 1),
        "decode_tok_s": round(s.decode_tok_s, 1),
        "decode_steps": s.decode_steps,
        "decode_tokens": s.decode_tokens,
        "ttft_avg_s": round(s.ttft_avg_s, 4),
        "ttft_max_s": round(s.ttft_s_max, 4),
        "prefill_batches": s.prefill_batches,
        "prefill_batch_occupancy": round(s.prefill_batch_occupancy, 2),
        "kv_layout": kv_layout,
    }
    if kv_layout == "paged":
        row.update({
            "page_size": s.page_size,
            "kv_pages_total": s.kv_pages_total,
            "kv_pages_peak": s.kv_pages_peak,
            "admission_stalls": s.admission_stalls,
            "kv_bytes_pool": s.kv_pages_total * s.page_size * row_b,
            "kv_bytes_peak": s.kv_pages_peak * s.page_size * row_b,
        })
    else:
        row["kv_bytes"] = slots * max_len * row_b
    if telemetry != "off":
        row["telemetry"] = telemetry
        row.update(engine.last_recorder.device_aggregates())
    if trace_out:
        from repro.serving import trace_export
        trace_export.write_trace(engine.last_recorder, trace_out)
        row["trace_out"] = trace_out
    return row


def paging_report(args) -> dict:
    """Contiguous vs paged at long max_len, equal slot counts: KV bytes
    allocated vs actually touched, and the concurrent-slot count each
    layout supports under the contiguous layout's memory budget."""
    kw = dict(requests=args.requests, slots=args.slots,
              prompt_len=args.prompt_len, gen=args.gen,
              decode_chunk=args.decode_chunk, ragged=False,
              variant="dense", max_len=args.paging_max_len)
    ps = args.page_size
    probe = _variant_cfg(configs.get_smoke(args.arch), "dense")
    if kvp.kv_row_bytes(probe) == 0:
        raise SystemExit(
            f"--paging: {args.arch} has no pageable attention cache "
            "(SWA-ring-bounded or no attention layers); the contiguous-"
            "vs-paged comparison is meaningless here")
    contig = bench(args.arch, **kw)
    # fixed budget: a quarter of the contiguous footprint — every request
    # still completes (admission stalls instead of OOMing)
    budget_pages = max(1, args.slots * kvp.num_pages(args.paging_max_len, ps)
                       // 4)
    paged = bench(args.arch, kv_layout="paged", page_size=ps,
                  kv_pages=budget_pages, **kw)
    # pages one request pins worst-case (prompt + budget rows)
    ws = kvp.num_pages(args.prompt_len + args.gen - 1, ps)
    budget_rows = args.slots * args.paging_max_len     # contiguous footprint
    slots_at_budget = budget_rows // (ws * ps)
    report = {
        "note": scale_note(),
        "config": {"arch": args.arch, "max_len": args.paging_max_len,
                   "slots": args.slots, "requests": args.requests,
                   "prompt_len": args.prompt_len, "gen": args.gen,
                   "page_size": ps},
        "contiguous": contig,
        "paged": paged,
        "kv_bytes_contiguous": contig["kv_bytes"],
        "kv_bytes_paged_peak": paged["kv_bytes_peak"],
        "kv_bytes_saved_frac": round(
            1.0 - paged["kv_bytes_peak"] / contig["kv_bytes"], 4),
        "slots_at_contiguous_budget": {"contiguous": args.slots,
                                       "paged": int(slots_at_budget)},
        "slot_ratio": round(slots_at_budget / args.slots, 1),
    }
    with open("BENCH_paging.json", "w") as f:
        json.dump(report, f, indent=1)
    return report


def prefill_overlap_report(args) -> dict:
    """Serial vs batched vs overlapped admission under a bursty
    mixed-length workload (all requests arrive at t=0, ragged prompt
    lengths in [L/2, L], more requests than slots): time-to-first-token
    and steady-state tokens/s per scheduler mode.  CPU stand-in per the
    repo convention — compare across PRs, not against TPU; the batched
    win comes from one prefill call + one cache scatter + one host sync
    per admission group instead of one of each per request."""
    kw = dict(requests=args.requests, slots=args.slots,
              prompt_len=args.prompt_len, gen=args.gen,
              decode_chunk=args.decode_chunk, ragged=True,
              variant="sparse", kv_layout=args.kv_layout,
              page_size=args.page_size, kv_pages=args.kv_pages,
              trials=5)
    modes = {
        "serial": dict(prefill_batch=1),
        "batched": dict(prefill_batch=args.slots),
        "overlapped": dict(prefill_batch=args.slots,
                           prefill_decode_ratio=args.prefill_decode_ratio),
        # batched admission with jit-pure device counters threaded
        # through the compiled chunk — the telemetry overhead row
        # (check_bench floors its decode tokens/s against 'batched')
        "telemetry": dict(prefill_batch=args.slots, telemetry="counters"),
    }
    rows = {name: bench(args.arch, **kw, **mk) for name, mk in modes.items()}
    serial = rows["serial"]
    report = {
        "note": scale_note(),
        "config": {"arch": args.arch, "slots": args.slots,
                   "requests": args.requests, "prompt_len": args.prompt_len,
                   "gen": args.gen, "decode_chunk": args.decode_chunk,
                   "prefill_decode_ratio": args.prefill_decode_ratio,
                   "workload": "bursty ragged [L/2, L], all at t=0"},
        **rows,
        "ttft_avg_speedup_vs_serial": {
            name: round(serial["ttft_avg_s"] / max(r["ttft_avg_s"], 1e-9), 2)
            for name, r in rows.items() if name != "serial"},
        "decode_tok_s_ratio_vs_serial": {
            name: round(r["decode_tok_s"]
                        / max(serial["decode_tok_s"], 1e-9), 2)
            for name, r in rows.items() if name != "serial"},
        "telemetry_decode_tok_s_ratio": round(
            rows["telemetry"]["decode_tok_s"]
            / max(rows["batched"]["decode_tok_s"], 1e-9), 3),
    }
    with open("BENCH_serve.json", "w") as f:
        json.dump(report, f, indent=1)
    return report


def load_sweep_report(args) -> dict:
    """Offered-QPS load sweep over the long-lived serve() loop: Poisson
    arrivals at each swept rate vs the same workload as one burst run(),
    reporting p50/p99 TTFT and TPOT — the SLO curve every production
    serving paper reports.  TTFT is arrival-relative, so under light
    continuous load it measures a mostly-idle engine while the burst rows
    measure queueing depth.  The 'preemptive' rows serve mixed priorities
    on a half-parity page pool, so priority preemption (evict + recompute
    re-admission) actually fires under pressure.  Wall-clock CPU stand-in
    per the repo convention — compare across PRs, not against TPU.
    Writes BENCH_slo.json."""
    from repro.serving.engine import ArrivalSchedule

    cfg = _variant_cfg(configs.get_smoke(args.arch), "sparse")
    # counters mode so every sweep row carries the device-side sparsity /
    # expert-balance aggregates next to its latency percentiles
    cfg = cfg.with_spt(kv_layout="paged", kv_page_size=args.page_size,
                       telemetry="counters")
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    # background requests generate 4x longer than interactive ones so
    # they actually HOLD their pages across many scheduling iterations —
    # short uniform requests retire within an iteration or two and the
    # pool is never saturated at the instant an interactive arrives
    bg_gen = args.gen * 4
    max_len = args.prompt_len + bg_gen + 8
    parity = args.slots * kvp.num_pages(max_len, args.page_size)
    reqs = build_requests(cfg, args.requests, args.prompt_len, args.gen,
                          ragged=True)
    # phased priorities: long background (priority 0) arrives first and
    # fills the pool, interactive (priority 1, TTFT deadline) arrives
    # mid-run — the arrival pattern that makes priority preemption fire
    # (alternating priorities never do: the priority-sorted queue would
    # drain every interactive request before a background holds a page)
    half = len(reqs) // 2
    pre_reqs = [dataclasses.replace(
        r, priority=0 if i < half else 1,
        max_new_tokens=bg_gen if i < half else r.max_new_tokens,
        deadline_s=None if i < half else 60.0)
        for i, r in enumerate(reqs)]
    qps_list = [float(q) for q in args.qps_sweep.split(",")]

    def stats_row(eng, out, wall, mode, qps):
        s = eng.last_stats
        d = s.as_dict()
        agg = eng.last_recorder.device_aggregates()
        return {
            "mode": mode, "offered_qps": qps,
            "requests": len(out), "completed": s.completed,
            "wall_s": round(wall, 2),
            "achieved_qps": round(s.completed / max(wall, 1e-9), 2),
            "ttft_p50_s": d["ttft_p50_s"], "ttft_p99_s": d["ttft_p99_s"],
            "tpot_p50_s": d["tpot_p50_s"], "tpot_p99_s": d["tpot_p99_s"],
            "preemptions": s.preemptions, "shed": s.shed,
            "admission_stalls": s.admission_stalls,
            "keep_rate": agg.get("keep_rate", 1.0),
            "expert_load_imbalance": agg.get("expert_load_imbalance", 1.0),
        }

    rows = []
    # preemptive pool: exactly one background's worst-case reservation —
    # while a background decodes, an arriving interactive cannot reserve
    # pages and the scheduler must evict (preempt + later recompute) to
    # admit it
    pool_pre = kvp.num_pages(args.prompt_len + bg_gen - 1, args.page_size)
    eng = Engine(cfg, params, max_len=max_len, num_slots=args.slots,
                 decode_chunk=args.decode_chunk, kv_pages=parity)
    eng_pre = Engine(cfg, params, max_len=max_len, num_slots=args.slots,
                     decode_chunk=args.decode_chunk, kv_pages=pool_pre)
    # warmup: a burst run traces the full-group buckets, a fast serve
    # traces the single-arrival admission + grown decode buckets — the
    # timed passes below must measure scheduling, not jit
    for e, rs in ((eng, reqs), (eng_pre, pre_reqs)):
        e.run(rs)
        e.run(rs[:1])                    # single/pair admission buckets
        e.run(rs[:2])
        e.serve(ArrivalSchedule.poisson(rs, max(qps_list), seed=0))
    t0 = time.perf_counter()
    out = eng.run(reqs)
    rows.append(stats_row(eng, out, time.perf_counter() - t0, "burst",
                          None))
    for qps in qps_list:
        t0 = time.perf_counter()
        out = eng.serve(ArrivalSchedule.poisson(reqs, qps, seed=0))
        rows.append(stats_row(eng, out, time.perf_counter() - t0,
                              "poisson", qps))
        t0 = time.perf_counter()
        out = eng_pre.serve(ArrivalSchedule.poisson(pre_reqs, qps, seed=0))
        rows.append(stats_row(eng_pre, out, time.perf_counter() - t0,
                              "preemptive", qps))
    burst = rows[0]
    low = min((r for r in rows if r["mode"] == "poisson"),
              key=lambda r: r["offered_qps"])
    report = {
        "note": scale_note(),
        "config": {"arch": cfg.name, "slots": args.slots,
                   "requests": args.requests,
                   "prompt_len": args.prompt_len, "gen": args.gen,
                   "bg_gen": bg_gen,
                   "decode_chunk": args.decode_chunk,
                   "page_size": args.page_size,
                   "kv_pages": {"poisson": parity,
                                "preemptive": pool_pre},
                   "qps_sweep": qps_list,
                   "workload": "ragged [L/2, L]; preemptive rows: "
                               "phased — long low-priority background "
                               "first, interactive (deadline) later, "
                               "pool sized for one background"},
        "rows": rows,
        "summary": {
            "all_served": float(all(r["completed"] + r["shed"]
                                    == r["requests"] for r in rows)),
            "preemptions_total": sum(r["preemptions"] for r in rows),
            "burst_over_lowqps_ttft_p99": round(
                burst["ttft_p99_s"] / max(low["ttft_p99_s"], 1e-9), 2),
        },
    }
    with open("BENCH_slo.json", "w") as f:
        json.dump(report, f, indent=1)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--decode-chunk", type=int, default=16)
    ap.add_argument("--variants", default="dense,sparse",
                    help="comma list of dense|sparse|sparse-kernel|ffn|"
                         "ffn-kernel (*-kernel = fused Pallas paths; "
                         "interpret mode off-TPU, so opt-in) or "
                         "prefill-overlap (serial vs batched vs overlapped "
                         "admission -> BENCH_serve.json)")
    ap.add_argument("--prefill-decode-ratio", type=float, default=4.0,
                    help="overlap knob for the prefill-overlap variant's "
                         "'overlapped' mode")
    ap.add_argument("--kv-layout", default="contiguous",
                    choices=("contiguous", "paged"))
    ap.add_argument("--page-size", type=int, default=128)
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="paged pool size (default contiguous parity)")
    ap.add_argument("--paging", action="store_true",
                    help="run the contiguous-vs-paged KV-memory comparison "
                         "at --paging-max-len and write BENCH_paging.json")
    ap.add_argument("--paging-max-len", type=int, default=8192)
    ap.add_argument("--load-sweep", action="store_true",
                    help="sweep offered QPS through the long-lived serve() "
                         "loop (Poisson arrivals; burst + FIFO + "
                         "priority-preemptive modes) and write the "
                         "p50/p99 TTFT/TPOT SLO curve to BENCH_slo.json")
    ap.add_argument("--qps-sweep", default="2,6,18",
                    help="comma list of offered arrival rates for "
                         "--load-sweep")
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto-loadable Chrome trace.json of "
                         "the first plain-variant bench run here (forces "
                         "telemetry=trace for that run)")
    args = ap.parse_args()

    if args.load_sweep:
        print(json.dumps(load_sweep_report(args), indent=1))
        return
    if args.paging:
        print(json.dumps(paging_report(args), indent=1))
        return

    print(json.dumps({"note": scale_note()}))
    trace_pending = args.trace_out
    for variant in args.variants.split(","):
        if variant.strip() == "prefill-overlap":
            print(json.dumps(prefill_overlap_report(args), indent=1))
            continue
        for ragged in (False, True):
            row = bench(args.arch, args.requests, args.slots,
                        args.prompt_len, args.gen, args.decode_chunk,
                        ragged, variant=variant.strip(),
                        kv_layout=args.kv_layout, page_size=args.page_size,
                        kv_pages=args.kv_pages, trace_out=trace_pending)
            trace_pending = None       # first row only
            print(json.dumps(row))


if __name__ == "__main__":
    main()
