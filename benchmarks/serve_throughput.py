"""Serving throughput: steady-state decode tokens/s vs prefill tokens/s,
with compile/warmup reported separately (an honest split — the old
launcher folded tracing + compilation into tokens/s).

    PYTHONPATH=src python -m benchmarks.serve_throughput --requests 12

Reports, per configuration:
  compile_s       — first-run wall clock minus steady-state wall clock
  prefill_tok_s   — prompt tokens / sum of block_until_ready'd prefill calls
  decode_tok_s    — generated tokens / sum of block_until_ready'd decode
                    chunks (the continuous-batching steady state)
"""
import argparse
import json
import time

import jax

from repro import configs
from repro.core.params import init_tree
from repro.launch.serve import build_requests
from repro.serving.engine import Engine
from repro.train.state import model_defs

from benchmarks.common import scale_note


def _variant_cfg(cfg, variant: str):
    """Serving variants tracked per PR: the dense baseline, the sparse-MHA
    jnp decode fallback, the fused Pallas decode kernel path, and the
    routed-FFN decode paths (ffn = grouped capacity dispatch at (B,1,d),
    ffn-kernel = block-gather Pallas kernel, no dispatch buffer).  Kernel
    variants run interpret-mode off-TPU — compare kernel rows across PRs,
    not against the jnp rows, on CPU."""
    if variant == "dense":
        return cfg.with_spt(sparse_mha=False)
    if variant == "sparse":
        return cfg.with_spt(sparse_mha=True, decode_attn_impl="jnp")
    if variant == "sparse-kernel":
        return cfg.with_spt(sparse_mha=True, decode_attn_impl="kernel")
    if variant == "ffn":
        return cfg.with_spt(sparse_mha=False, decode_ffn_impl="jnp")
    if variant == "ffn-kernel":
        return cfg.with_spt(sparse_mha=False, decode_ffn_impl="kernel")
    raise ValueError(variant)


def bench(arch: str, requests: int, slots: int, prompt_len: int, gen: int,
          decode_chunk: int, ragged: bool, variant: str = "sparse") -> dict:
    cfg = _variant_cfg(configs.get_smoke(arch), variant)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_len=prompt_len + gen + 8,
                    num_slots=slots, decode_chunk=decode_chunk)
    reqs = build_requests(cfg, requests, prompt_len, gen, ragged)

    t0 = time.perf_counter()
    engine.run(reqs)
    first_wall = time.perf_counter() - t0

    t0 = time.perf_counter()
    engine.run(reqs)
    steady_wall = time.perf_counter() - t0
    s = engine.last_stats
    return {
        "arch": cfg.name, "variant": variant, "requests": requests,
        "slots": slots,
        "prompt_len": prompt_len, "gen": gen, "ragged": ragged,
        "compile_s": round(first_wall - steady_wall, 2),
        "steady_wall_s": round(steady_wall, 2),
        "prefill_tok_s": round(s.prefill_tok_s, 1),
        "decode_tok_s": round(s.decode_tok_s, 1),
        "decode_steps": s.decode_steps,
        "decode_tokens": s.decode_tokens,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--decode-chunk", type=int, default=16)
    ap.add_argument("--variants", default="dense,sparse",
                    help="comma list of dense|sparse|sparse-kernel|ffn|"
                         "ffn-kernel (*-kernel = fused Pallas paths; "
                         "interpret mode off-TPU, so opt-in)")
    args = ap.parse_args()

    print(json.dumps({"note": scale_note()}))
    for variant in args.variants.split(","):
        for ragged in (False, True):
            row = bench(args.arch, args.requests, args.slots,
                        args.prompt_len, args.gen, args.decode_chunk,
                        ragged, variant=variant.strip())
            print(json.dumps(row))


if __name__ == "__main__":
    main()
