# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV lines.  ``--full`` uses the larger (slower) shapes.
import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args, _ = ap.parse_known_args()
    fast = not args.full

    from benchmarks import (fig3_fig5_distributions, fig8_blocks,
                            fig9_memory_vs_seq, fig10_quality,
                            roofline_report, table1_decomposition,
                            table3_end2end, table4_sparsity, table5_kernels,
                            table6_alternatives)
    suites = [
        ("table1", table1_decomposition.main),
        ("table3", table3_end2end.main),
        ("table4", table4_sparsity.main),
        ("table5", table5_kernels.main),
        ("table6", table6_alternatives.main),
        ("fig3_fig5", fig3_fig5_distributions.main),
        ("fig8", fig8_blocks.main),
        ("fig9", fig9_memory_vs_seq.main),
        ("fig10", fig10_quality.main),
        ("roofline", roofline_report.main),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            fn(fast=fast)
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"{name}.SUITE_ERROR,0,failed")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
