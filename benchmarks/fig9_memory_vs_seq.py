"""Figure 9 analogue: peak memory vs sequence length (OPT-2048 family).
Memory comes from the compiled module's memory_analysis — the quadratic
attention term is what SPT's sparse MHA removes."""
import jax

from benchmarks.blocks import block_step, reduced
from benchmarks.common import emit


def main(fast: bool = True) -> None:
    seqs = (128, 256, 512) if fast else (128, 256, 512, 1024)
    for variant in ("lora", "spt"):
        cfg = reduced("opt-2048", scale=8 if fast else 4, variant=variant)
        step, params = block_step(cfg, "both")
        ax = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
        for s in seqs:
            import jax.numpy as jnp
            xs = jax.ShapeDtypeStruct((2, s, cfg.d_model), jnp.bfloat16)
            from benchmarks.common import compiled_temp_bytes
            mem = compiled_temp_bytes(step, ax, xs)
            emit(f"fig9.{variant}.seq{s}", 0.0,
                 f"temp_mb={(mem or 0) / 1e6:.1f}")


if __name__ == "__main__":
    main(fast=False)
