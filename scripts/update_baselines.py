#!/usr/bin/env python
"""Regenerate scripts/analysis_baselines.json from the signatures at
HEAD.

The ``memory`` audit (src/repro/analysis/baselines.py) ratchets every
registered entrypoint's memory signature — peak live bytes, donated
bytes, eqn count, pallas-call count — against this file, failing CI on
regressions *and* on unrecorded improvements.  When the audit reports
``memory.stale-baseline`` (or you changed an entrypoint deliberately),
run this script and commit the diff.  ``REPRO_UPDATE_BASELINES=1
scripts/analyze.sh`` does the same before the gate runs, mirroring the
``bench_floors.json`` refresh workflow.
"""
from __future__ import annotations

import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

from repro.analysis import baselines  # noqa: E402


def main() -> int:
    entries = baselines.compute_signatures()
    old = {}
    if baselines.BASELINE_PATH.exists():
        old = baselines.load_baselines()
    doc = {
        "note": "golden memory signatures per analysis entrypoint; "
                "regenerate with scripts/update_baselines.py and commit "
                "the diff (the memory audit ratchets against this file)",
        "entries": {name: entries[name] for name in sorted(entries)},
    }
    baselines.BASELINE_PATH.write_text(
        json.dumps(doc, indent=2, sort_keys=False) + "\n")
    for name in sorted(entries):
        sig = entries[name]
        mark = " " if old.get(name) == sig else "*"
        print(f"{mark} {name:<34} peak {sig['peak_live_bytes']:>12,} B  "
              f"donated {sig['donated_bytes']:>10,} B  "
              f"eqns {sig['eqns']:>5}  pallas {sig['pallas_calls']}")
    for name in sorted(set(old) - set(entries)):
        print(f"- {name} (removed)")
    print(f"wrote {baselines.BASELINE_PATH.relative_to(REPO)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
