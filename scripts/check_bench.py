#!/usr/bin/env python
"""Compare the checked-in BENCH_*.json ratio columns against recorded
floors (scripts/bench_floors.json).

The benches themselves are too slow for CI, but their *outputs* are
checked in — so a PR that silently regresses a kernel path shows up as a
stale ratio only if someone looks.  This check makes the floors part of
CI: every floor is a claim the README/EXPERIMENTS narrative relies on
(decode FFN wins every row, paging saves >90% KV bytes, overlap improves
TTFT), and a BENCH file rewritten with worse ratios fails fast.  Floors
sit ~10-15% below recorded values, so honest container jitter at
re-measurement passes; halving a speedup does not.

Check forms (see bench_floors.json):
  rows/select/metric/agg  aggregate a metric over matching rows of a list
  path                    walk nested dicts to a scalar
Both then require  value >= floor.

Exit 0 = all floors hold; 1 = regression (or missing file/key); 2 = bad
floors file.  Stdlib only; no repo imports.
"""
from __future__ import annotations

import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
FLOORS = REPO / "scripts" / "bench_floors.json"

AGGS = {
    "min": min,
    "max": max,
    "mean": lambda xs: sum(xs) / len(xs),
}


def resolve(check: dict) -> float:
    data = json.loads((REPO / check["file"]).read_text())
    if "path" in check:
        node = data
        for key in check["path"]:
            node = node[key]
        return float(node)
    rows = data[check.get("rows", "rows")]
    select = check.get("select", {})
    picked = [r[check["metric"]] for r in rows
              if all(r.get(k) == v for k, v in select.items())
              and check["metric"] in r]
    if not picked:
        raise KeyError(f"no rows match select={select} with metric "
                       f"{check['metric']!r}")
    return float(AGGS[check.get("agg", "min")](picked))


def describe(check: dict) -> str:
    if "path" in check:
        return f"{check['file']}:{'.'.join(check['path'])}"
    sel = ",".join(f"{k}={v}" for k, v in check.get("select", {}).items())
    return (f"{check['file']}:{check.get('agg', 'min')}"
            f"({check['metric']}{'|' + sel if sel else ''})")


def main() -> int:
    try:
        floors = json.loads(FLOORS.read_text())
        checks = floors["checks"]
    except (OSError, KeyError, json.JSONDecodeError) as e:
        print(f"[bench] bad floors file {FLOORS}: {e}", file=sys.stderr)
        return 2
    failures = 0
    for check in checks:
        label = describe(check)
        try:
            value = resolve(check)
        except (OSError, KeyError, json.JSONDecodeError, TypeError) as e:
            print(f"[bench] FAIL {label}: unreadable ({e})")
            failures += 1
            continue
        floor = float(check["floor"])
        if value >= floor:
            print(f"[bench] ok   {label}: {value:g} >= floor {floor:g}")
        else:
            print(f"[bench] FAIL {label}: {value:g} < floor {floor:g}"
                  f" — {check.get('why', '')}")
            failures += 1
    if failures:
        print(f"[bench] FAILED: {failures} floor(s) broken")
        return 1
    print(f"[bench] clean: {len(checks)} floor(s) hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
