#!/usr/bin/env bash
# Static hot-path gate (runs on CPU, no benches, ~15s):
#   1. python -m repro.analysis — jaxpr budgets/primitives over the hot
#      entrypoints, Pallas VMEM/spec estimates, engine retrace
#      accounting, and source lints (src/repro/analysis/).
#   2. scripts/check_bench.py — checked-in BENCH_*.json ratio columns
#      against the recorded floors in scripts/bench_floors.json.
# scripts/ci_fast.sh runs this before pytest; REPRO_SKIP_ANALYSIS=1
# skips it there (escape hatch for iterating on a known-violating tree).
# Extra args pass through to the analysis CLI: analyze.sh --only lint
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.analysis "$@"
python scripts/check_bench.py
