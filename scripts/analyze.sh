#!/usr/bin/env bash
# Static hot-path gate (runs on CPU, no benches, ~30s):
#   1. python -m repro.analysis — jaxpr budgets/primitives over the hot
#      entrypoints, Pallas VMEM/spec estimates, engine retrace
#      accounting, source lints, memory-lifetime liveness + donation
#      audits, and the golden memory-signature ratchet against
#      scripts/analysis_baselines.json (src/repro/analysis/).
#   2. scripts/check_bench.py — checked-in BENCH_*.json ratio columns
#      against the recorded floors in scripts/bench_floors.json.
# scripts/ci_fast.sh runs this before pytest; REPRO_SKIP_ANALYSIS=1
# skips it there (escape hatch for iterating on a known-violating tree).
# REPRO_UPDATE_BASELINES=1 regenerates analysis_baselines.json before
# the gate (the memory audit then passes by construction — commit the
# diff), mirroring the bench_floors.json refresh workflow.
# Extra args pass through to the analysis CLI: analyze.sh --only lint
set -euo pipefail
cd "$(dirname "$0")/.."
if [[ "${REPRO_UPDATE_BASELINES:-0}" == "1" ]]; then
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python scripts/update_baselines.py
fi
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.analysis "$@"
python scripts/check_bench.py
