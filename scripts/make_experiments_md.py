"""Generate EXPERIMENTS.md from results/dryrun + results/hillclimb JSONs."""
import json
import pathlib
import sys

sys.path.insert(0, "src")
from repro import configs                      # noqa: E402
from repro.configs.base import SHAPES_BY_NAME  # noqa: E402
from repro.launch import roofline              # noqa: E402

ARCHS = ["grok-1-314b", "mixtral-8x22b", "recurrentgemma-9b",
         "phi-3-vision-4.2b", "mamba2-780m", "qwen3-0.6b",
         "h2o-danube-1.8b", "gemma-7b", "h2o-danube-3-4b", "whisper-base"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(d):
    out = {}
    for p in pathlib.Path(d).glob("*.json"):
        r = json.loads(p.read_text())
        out[(r["arch"], r["shape"], r["mesh"], p.stem)] = r
    return out


def useful(r):
    """Recompute MODEL_FLOPS / HLO_FLOPs with the current convention."""
    try:
        cfg = configs.get_config(r["arch"])
        shape = SHAPES_BY_NAME[r["shape"]]
        if shape.kind == "train":
            mf = roofline.model_flops(cfg, shape.global_batch * shape.seq_len)
        elif shape.kind == "prefill":
            mf = roofline.model_flops(cfg, shape.global_batch * shape.seq_len) / 3
        else:
            mf = 2.0 * roofline.active_params(cfg) * shape.global_batch
        fl = (r.get("roofline_exact") or {}).get("flops")
        return (mf / r["chips"]) / fl if fl else None
    except Exception:
        return None


def main():
    rows = load("results/dryrun")
    single = {(a, s): r for (a, s, m, _), r in rows.items() if m == "single"
              and "_lora" not in _ and "_full" not in _}
    multi = {(a, s): r for (a, s, m, _), r in rows.items() if m == "multi"}

    lines = []
    lines.append("### Baseline roofline table — single pod 16x16 = 256 chips "
                 "(per-device, per step)\n")
    lines.append("| arch | shape | t_compute | t_memory | t_collective | "
                 "bound | useful FLOPs | temp GB/dev | compile |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in SHAPES:
            r = single.get((a, s))
            if r is None:
                lines.append(f"| {a} | {s} | - | - | - | - | - | - | MISSING |")
                continue
            if r["status"] == "skipped":
                lines.append(f"| {a} | {s} | – | – | – | – | – | – | "
                             f"skipped: {r['reason'][:58]} |")
                continue
            rl = r.get("roofline_exact") or r.get("roofline_scanned")
            u = useful(r)
            us = f"{u:.2f}" if u else "-"
            temp = (r.get("memory_analysis") or {}).get(
                "temp_size_in_bytes", 0) / 1e9
            lines.append(
                f"| {a} | {s} | {rl['t_compute']*1e3:.1f} ms | "
                f"{rl['t_memory']*1e3:.0f} ms | {rl['t_collective']*1e3:.0f} ms | "
                f"{rl['bottleneck']} | {us} | {temp:.1f} | "
                f"ok ({r.get('compile_s', 0):.0f}s) |")

    lines.append("\n### Multi-pod compile proof — 2x16x16 = 512 chips\n")
    lines.append("| arch | " + " | ".join(SHAPES) + " |")
    lines.append("|---|" + "---|" * len(SHAPES))
    for a in ARCHS:
        cells = []
        for s in SHAPES:
            r = multi.get((a, s))
            if r is None:
                cells.append("MISSING")
            elif r["status"] == "ok":
                cells.append(f"ok ({r.get('compile_s', 0):.0f}s)")
            elif r["status"] == "skipped":
                cells.append("skip")
            else:
                cells.append("ERROR")
        lines.append(f"| {a} | " + " | ".join(cells) + " |")

    print("\n".join(lines))


if __name__ == "__main__":
    main()
