#!/usr/bin/env bash
# Fast CI smoke subset: skips tests marked `slow` (multi-arch smokes,
# end-to-end training, and the wide kernel interpret sweeps) so builders
# can iterate in ~1-2 min.  The Pallas decode-kernel path IS exercised
# here: tests/test_sparse_decode.py's parity cases run the fused decode
# kernels under interpret=True on CPU (only the (S, L, dtype) sweep is
# `slow`).  The tier-1 command stays the full suite:
#   PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q -m "not slow" "$@"
