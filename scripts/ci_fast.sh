#!/usr/bin/env bash
# Fast CI smoke subset: skips tests marked `slow` (multi-arch smokes,
# end-to-end training, and the wide kernel interpret sweeps) so builders
# can iterate in a few minutes.  The Pallas kernel paths ARE exercised
# here: tests/test_sparse_decode.py's parity cases run the decode
# kernel tiers under interpret=True on CPU — one-pass fused ==
# two-pass == jnp oracle — (only the (S, L, dtype) sweep is
# `slow`), tests/test_routed_ffn_kernel.py runs the fused routed-FFN
# grouped/decode kernels the same way (incl. the engine-level greedy
# kernel-on == kernel-off check), and tests/test_moe_kernel.py covers
# the MoE reuse of those kernels.  The paged-KV-cache suite
# (tests/test_kv_paging.py: allocator units + kernel-native paged
# decode == gathered view bit-identity + engine-level paged ==
# contiguous row-identity incl. the sparse decode kernel) is fast except
# the wide (page_size x variant) sweep, which is `slow`.  The
# disaggregated-prefill suite (tests/test_prefill_scheduler.py: batched
# ragged prefill == serial batch-1 row-identity across layout x sparse
# kernel variants, overlap loop, non-HOL partial admission, top-p
# nucleus sampling incl. the replayed-membership check, LM + enc-dec
# model-level ragged exactness, batched page-wise scatter) is fast
# except its (layout x sparsity) sweep, which is `slow`.  The tier-1
# command stays the full suite:
#   PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."
# Static hot-path gate first (jaxpr/Pallas/trace audits, liveness +
# donation audits, memory-signature ratchet, bench-ratio floors —
# scripts/analyze.sh): ~30s on CPU, and it fails fast on the structural
# regressions parity tests can't see (resurrected dispatch buffers,
# in-loop retraces, VMEM-busting BlockSpecs, a doubled decode-chunk live
# set, a lost donation).  The peak-live-bytes waterfall report is kept
# as a CI artifact next to the chaos trace dump (same traces the audits
# computed, so it's free).  REPRO_SKIP_ANALYSIS=1 skips it while
# iterating on a known-violating tree.
if [[ "${REPRO_SKIP_ANALYSIS:-0}" != "1" ]]; then
    REPRO_MEMORY_REPORT_OUT="${REPRO_MEMORY_REPORT_OUT:-$(mktemp -t memory_report.XXXXXX.txt)}" \
        scripts/analyze.sh
fi
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q -m "not slow" "$@"
# Fixed-seed chaos soak on the long-lived serving loop: Poisson arrivals
# + injected cancels / duplicate + oversized submissions / forced
# preemption, with slot-leak and page-conservation invariants asserted
# after every scheduling iteration (exit 1 on any violation or lost
# request).  Shorter than the pytest matrix soaks but on top of them:
# this is the exact command a builder can re-run standalone to bisect a
# scheduler leak.  --trace-out doubles as the telemetry smoke: the soak
# runs with telemetry=trace, validates the written Chrome trace against
# the schema, and fails if any submitted uid is missing a request lane.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.serving.chaos --requests 16 --seed 0 \
    --trace-out "$(mktemp -t chaos_trace.XXXXXX.json)"
