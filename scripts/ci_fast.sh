#!/usr/bin/env bash
# Fast CI smoke subset: skips tests marked `slow` (multi-arch smokes and
# end-to-end training) so builders can iterate in ~1-2 min.  The tier-1
# command stays the full suite:
#   PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q -m "not slow" "$@"
