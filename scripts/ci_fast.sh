#!/usr/bin/env bash
# Fast CI smoke subset: skips tests marked `slow` (multi-arch smokes,
# end-to-end training, and the wide kernel interpret sweeps) so builders
# can iterate in a few minutes.  The Pallas kernel paths ARE exercised
# here: tests/test_sparse_decode.py's parity cases run the fused decode
# kernels under interpret=True on CPU (only the (S, L, dtype) sweep is
# `slow`), and tests/test_routed_ffn_kernel.py runs the fused routed-FFN
# grouped/decode kernels the same way (incl. the engine-level greedy
# kernel-on == kernel-off check).  The tier-1 command stays the full
# suite:
#   PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q -m "not slow" "$@"
