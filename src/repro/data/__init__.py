from repro.data.pipeline import (DataConfig, markov_stream, pack_batches,  # noqa
                                 synthetic_dataset)
