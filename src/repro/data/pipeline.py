"""Data pipeline: deterministic synthetic corpora + packing + shard-aware
iteration.

Two sources (both host-side numpy, deterministic by seed):
  * ``markov_stream`` — a low-entropy token Markov chain.  Models can
    actually *learn* it, so fine-tuning quality experiments (paper Fig. 10
    analogue) measure real PPL movement, not noise.  This is the stand-in
    for Wikitext-103.
  * ``random`` — i.i.d. uniform tokens, matching the paper's "Random"
    dataset for micro-benchmarks.

Packing yields {tokens, labels} with labels[t] = tokens[t+1] (next-token),
-1 on the final position (ignored by the loss).  The iterator yields numpy;
the trainer places global arrays with the mesh batch sharding, so each host
only materializes its slice in multi-host deployments (single-process here).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    kind: str = "markov"          # markov | random
    seed: int = 0
    branching: int = 4            # markov out-degree (lower = easier)


def markov_stream(cfg: DataConfig, steps: int) -> Iterator[np.ndarray]:
    """Yields (global_batch, seq_len + 1) int32 token blocks."""
    rng = np.random.default_rng(cfg.seed)
    v = cfg.vocab_size
    # sparse deterministic transition table: each token -> `branching` nexts
    nexts = rng.integers(0, v, size=(v, cfg.branching), dtype=np.int64)
    probs = rng.dirichlet(np.ones(cfg.branching) * 0.5, size=v)
    state = rng.integers(0, v, size=cfg.global_batch)
    for _ in range(steps):
        out = np.empty((cfg.global_batch, cfg.seq_len + 1), dtype=np.int32)
        for t in range(cfg.seq_len + 1):
            out[:, t] = state
            choice = (rng.random(cfg.global_batch)[:, None]
                      > np.cumsum(probs[state], axis=1)).sum(axis=1)
            choice = np.minimum(choice, cfg.branching - 1)
            state = nexts[state, choice]
        yield out


def random_stream(cfg: DataConfig, steps: int) -> Iterator[np.ndarray]:
    rng = np.random.default_rng(cfg.seed)
    for _ in range(steps):
        yield rng.integers(0, cfg.vocab_size,
                           size=(cfg.global_batch, cfg.seq_len + 1),
                           dtype=np.int32)


def pack_batches(blocks: Iterator[np.ndarray]) -> Iterator[Dict[str, np.ndarray]]:
    for block in blocks:
        tokens = block[:, :-1]
        labels = block[:, 1:].copy()
        yield {"tokens": tokens, "labels": labels}


def synthetic_dataset(cfg: DataConfig, steps: int
                      ) -> Iterator[Dict[str, np.ndarray]]:
    src = markov_stream if cfg.kind == "markov" else random_stream
    return pack_batches(src(cfg, steps))
