"""Logical-axis -> mesh-axis rule tables.

The production mesh is (data, model) per pod, with a leading ``pod`` axis in
multi-pod mode used as extra data parallelism (DESIGN.md §4).  Divisibility
is checked at application time (params.spec_tree / sharding.context), so
small archs (e.g. whisper-base) degrade to replication on the axes that do
not divide instead of failing.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional

# Baseline (paper-faithful TP/DP) rule table.
RULES: Dict[str, object] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,              # sequence replicated by default (SP opts in)
    "seq_shard": "model",     # long-context KV/state sharding (decode)
    # Megatron-style sequence parallelism for the residual stream between
    # blocks: the scan-saved remat carries shrink by the model-axis size
    # (fits 64-layer grok in HBM); XLA inserts the all-gather at attention.
    "seq_sp": "model",
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "act_ffn": "model",
    # MoE expert weights: ZeRO-3/FSDP-style — sharded over data AND model so
    # a 314B MoE fits 16 GB/chip; XLA all-gathers each layer's experts on use
    "expert_ffn": ("data", "model"),
    # dispatch-buffer capacity dim (routed FFN / MoE): sharding it over
    # "model" turns the backward all-reduce of the (B,G,C,d) cotangent into
    # all-gather+reduce-scatter (Megatron-SP on the token-slot dim) — §Perf
    "dispatch_c": "model",
    # params
    "vocab": "model",
    "group": None,            # routed-FFN block axis stays whole per block
    "expert": None,           # MoE experts: ffn dim sharded instead
    "lora_rank": None,
    "layer": None,
    "codebook": None,
    "codeword": None,
    "code_dim": None,
    "conv": None,
    "state": None,
    "lru": "model",
    "lru_blocks": "model",
    "ssm_inner": "model",
    "ssm_heads": "model",
}


def rules_for_mesh(mesh) -> Dict[str, object]:
    """Attach mesh axis sizes (and drop axes the mesh doesn't have)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out: Dict[str, object] = {}
    for k, v in RULES.items():
        if v is None:
            out[k] = None
        else:
            flat = (v,) if isinstance(v, str) else tuple(v)
            kept = tuple(a for a in flat if a in sizes)
            out[k] = None if not kept else (kept[0] if len(kept) == 1 else kept)
    out["__sizes__"] = sizes
    out["__mesh__"] = mesh    # for explicit shard_map schedules (ffn_shmap)
    return out
