from repro.sharding.context import axis_rules, shard, current_rules  # noqa: F401
from repro.sharding.rules import RULES, rules_for_mesh  # noqa: F401
