"""Sharding context: logical-axis activation constraints.

Model code annotates activations with *logical* axis names via ``shard``.
When a rules context is active (set by the launcher / dry-run around
tracing), the annotation becomes ``with_sharding_constraint``; otherwise it
is a no-op, so unit tests and single-device runs are unaffected.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Mapping, Optional, Sequence, Union

import jax
from jax.sharding import PartitionSpec

_RULES: contextvars.ContextVar[Optional[Mapping[str, Any]]] = \
    contextvars.ContextVar("repro_axis_rules", default=None)


@contextlib.contextmanager
def axis_rules(rules: Mapping[str, Any]):
    token = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(token)


def current_rules() -> Optional[Mapping[str, Any]]:
    return _RULES.get()


def _resolve(dim: int, name: Optional[str], rules: Mapping[str, Any],
             used: set) -> Optional[Union[str, tuple]]:
    if name is None:
        return None
    mesh_axes = rules.get(name)
    if mesh_axes is None:
        return None
    flat = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
    sizes = rules.get("__sizes__", {})
    total = 1
    for a in flat:
        total *= int(sizes.get(a, 1))
    if total <= 0 or dim % total != 0 or any(a in used for a in flat):
        return None
    used.update(flat)
    return mesh_axes


def spec_for(shape: Sequence[int], axes: Sequence[Optional[str]],
             rules: Mapping[str, Any]) -> PartitionSpec:
    used: set = set()
    return PartitionSpec(
        *[_resolve(d, n, rules, used) for d, n in zip(shape, axes)])


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Annotate ``x`` with logical axes, e.g. shard(h, 'batch', None, 'embed')."""
    rules = _RULES.get()
    if rules is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"shard(): {len(axes)} axes for rank-{x.ndim} array")
    spec = spec_for(x.shape, axes, rules)
    return jax.lax.with_sharding_constraint(x, spec)
