"""Fault-injection + invariant harness for the long-lived serving loop.

The engine's robustness claims — pages are conserved under preemption,
slots never leak across cancel/reject/eviction, rejected requests cannot
take down a batch — are only claims until something adversarial exercises
them.  This module provides that adversary plus the referee:

* ``check_invariants(engine)`` — snapshot the live scheduler state and
  return every violated invariant (slot leaks, page-conservation breaks,
  double-completions, stale reservations).  Empty list == healthy.
* ``Watchdog`` — an ``on_iteration`` hook that asserts the invariants
  after EVERY scheduling iteration, so a leak is caught at the iteration
  that introduced it, not at the end of the run.
* ``ChaosMonkey`` — a seeded ``on_iteration`` injector: mid-stream
  cancels, forced preemption storms, duplicate-uid and oversized
  submissions (exercising rejection isolation), and page-pool "hog"
  requests that force admission stalls and pressure preemption.
* ``run_soak(engine, requests, ...)`` — wire all of the above to a
  Poisson arrival schedule on a ManualClock and serve it; returns the
  completions plus a report of what was injected and observed.

``python -m repro.serving.chaos`` runs a short fixed-seed soak on a smoke
config (used by scripts/ci_fast.sh) and exits non-zero on any invariant
violation or lost request.
"""
from __future__ import annotations

import collections
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import numpy as np

from .engine import ArrivalSchedule, Engine, ManualClock, Request

__all__ = ["check_invariants", "Watchdog", "ChaosMonkey", "compose",
           "run_soak"]


# ------------------------------------------------------------ invariants
def check_invariants(eng: Engine) -> List[str]:
    """Every violated scheduler/allocator invariant, as human-readable
    strings (empty == healthy).  Safe to call from an ``on_iteration``
    hook — reads the live ``_SchedState`` and, for paged engines, pulls
    the allocator state to host once per call."""
    st = eng._live
    bad: List[str] = []
    if st is None:
        return bad
    occ = {b for b, s in enumerate(st.slot_item) if s is not None}
    for b in range(eng.num_slots):
        if st.active[b] and b not in occ:
            bad.append(f"slot {b} active without a request (slot leak)")
    live = {it.order for it in st.queue}
    for b in occ:
        live.add(st.slot_item[b].order)
    for o in sorted(live & set(st.results)):
        bad.append(f"request order {o} is both live and completed")
    if not eng._paged:
        return bad

    astate, ptab = jax.device_get((st.astate, st.page_table))
    free, top, refs = astate["free"], int(astate["top"]), astate["refs"]
    total = eng.kv_pages
    in_use = int((refs > 0).sum())
    if top + in_use != total:
        bad.append(f"page conservation broken: free {top} + in-use "
                   f"{in_use} != pool {total}")
    flist = free[:top].tolist()
    if len(set(flist)) != top:
        bad.append("free list holds duplicate page ids")
    if top and (refs[free[:top]] > 0).any():
        bad.append("page on the free list still referenced")
    mapped: dict = {}
    for b in range(eng.num_slots):
        row = ptab[b]
        pids = row[row >= 0].tolist()
        if b not in occ and pids:
            bad.append(f"slot {b} freed but page-table row non-empty "
                       f"(page leak)")
        for p in pids:
            if refs[p] < 1:
                bad.append(f"slot {b} maps page {p} with refcount "
                           f"{int(refs[p])}")
            mapped.setdefault(p, []).append(b)
    for p, slots_ in sorted(mapped.items()):
        if len(slots_) > 1:
            bad.append(f"page {p} mapped by slots {slots_} (the serve "
                       f"loop never shares pages)")
    overlap = sorted(set(mapped) & set(flist))
    if overlap:
        bad.append(f"pages both free and mapped: {overlap[:4]}")
    leaked = sorted(p for p in np.flatnonzero(refs > 0).tolist()
                    if p not in mapped)
    if leaked:
        bad.append(f"pages referenced but mapped by no slot (leak): "
                   f"{leaked[:4]}")
    if sum(st.slot_ws) != st.reserved:
        bad.append(f"reservation ledger broken: sum(slot_ws)="
                   f"{sum(st.slot_ws)} != reserved={st.reserved}")
    for b in range(eng.num_slots):
        if b not in occ and st.slot_ws[b]:
            bad.append(f"slot {b} holds {st.slot_ws[b]} reserved pages "
                       f"after release")
    return bad


class Watchdog:
    """``on_iteration`` hook asserting the scheduler/allocator invariants
    after every scheduling iteration — a leak trips at the iteration that
    introduced it, with the full violation list in the error.

    When the engine runs with telemetry on, a trip also dumps the metrics
    snapshot and the last ``dump_events`` lifecycle events to stderr — the
    flight recorder for postmortems (what was in flight, which request
    transitions led up to the violation)."""

    def __init__(self, dump_events: int = 40) -> None:
        self.iterations = 0
        self.dump_events = dump_events

    def __call__(self, eng: Engine, iteration: int) -> None:
        self.iterations += 1
        bad = check_invariants(eng)
        if bad:
            self._dump(eng, iteration, bad)
            raise AssertionError(
                f"invariant violation at iteration {iteration}: "
                + "; ".join(bad))

    def _dump(self, eng: Engine, iteration: int, bad: List[str]) -> None:
        import json
        import sys
        st = eng._live
        dump = {"iteration": iteration, "violations": bad}
        if st is not None:
            dump["metrics"] = st.stats.snapshot().as_dict()
        rec = eng.recorder
        if rec is not None:
            dump["device"] = rec.device_aggregates()
            dump["recent_events"] = rec.recent_events(self.dump_events)
        print("WATCHDOG DUMP " + json.dumps(dump, default=str),
              file=sys.stderr)


def compose(*hooks: Optional[Callable]) -> Callable:
    """Chain ``on_iteration`` hooks (injectors run before the watchdog so
    every injected fault is checked in the same iteration)."""
    def hook(eng: Engine, iteration: int) -> None:
        for h in hooks:
            if h is not None:
                h(eng, iteration)
    return hook


# -------------------------------------------------------------- injector
class ChaosMonkey:
    """Seeded fault injector, driven as an ``on_iteration`` hook.

    Per iteration it independently rolls for: cancelling a random live
    request (queued or mid-stream), force-preempting the default victim,
    re-submitting an already-seen uid (must reject, not corrupt), an
    oversized submission (must reject), and a low-priority page-pool
    "hog" whose worst-case reservation approaches the whole pool —
    forcing admission stalls and, once higher-priority work arrives,
    pressure preemption.  ``force_preempt_at`` guarantees at least one
    successful preemption from that iteration on (retried until an
    active victim exists).  ``counts`` records what actually landed."""

    def __init__(self, seed: int = 0, *, cancel_p: float = 0.08,
                 preempt_p: float = 0.08, dup_p: float = 0.05,
                 oversized_p: float = 0.05, hog_p: float = 0.04,
                 force_preempt_at: Optional[int] = 3,
                 start_iteration: int = 2) -> None:
        self.rng = np.random.default_rng(seed)
        self.cancel_p = cancel_p
        self.preempt_p = preempt_p
        self.dup_p = dup_p
        self.oversized_p = oversized_p
        self.hog_p = hog_p
        self.force_preempt_at = force_preempt_at
        self.start_iteration = start_iteration
        self.counts: collections.Counter = collections.Counter()
        self._uid = 1_000_000                  # injector uid namespace

    def _fresh_uid(self) -> int:
        self._uid += 1
        return self._uid

    def __call__(self, eng: Engine, iteration: int) -> None:
        st = eng._live
        if st is None:
            return
        if (self.force_preempt_at is not None
                and iteration >= self.force_preempt_at
                and not self.counts["forced_preempt"]):
            if eng.preempt():
                self.counts["forced_preempt"] += 1
        if iteration < self.start_iteration:
            return
        now = st.clock()
        if self.rng.random() < self.cancel_p:
            uids = ([it.req.uid for it in st.queue]
                    + [s.req.uid for s in st.slot_item if s is not None])
            if uids:
                pick = uids[int(self.rng.integers(len(uids)))]
                if eng.cancel(pick):
                    self.counts["cancel"] += 1
        if self.rng.random() < self.preempt_p and eng.preempt():
            self.counts["preempt"] += 1
        if self.rng.random() < self.dup_p and st.seen_uids:
            seen = sorted(st.seen_uids)
            uid = seen[int(self.rng.integers(len(seen)))]
            eng.submit(Request(uid=uid, tokens=[1, 2], max_new_tokens=2),
                       now=now)
            self.counts["duplicate_submit"] += 1
        if self.rng.random() < self.oversized_p:
            eng.submit(Request(uid=self._fresh_uid(), tokens=[1, 2, 3],
                               max_new_tokens=eng.max_len + 1), now=now)
            self.counts["oversized_submit"] += 1
        if self.rng.random() < self.hog_p:
            frontend = (eng.cfg.frontend_tokens if eng.cfg.frontend
                        else 0)
            budget = max(1, eng.max_len - frontend - 2)
            eng.submit(Request(uid=self._fresh_uid(), tokens=[1, 2],
                               max_new_tokens=budget, priority=-1),
                       now=now)
            self.counts["hog_submit"] += 1


# ------------------------------------------------------------------ soak
def run_soak(eng: Engine, requests: Sequence[Request], *,
             seed: int = 0, rate_qps: Optional[float] = 4.0,
             monkey: Optional[ChaosMonkey] = None,
             watchdog: Optional[Watchdog] = None,
             temperature: float = 0.0,
             key: Optional[jax.Array] = None) -> Tuple[list, dict]:
    """Serve ``requests`` under chaos: Poisson arrivals (``rate_qps``
    None = one burst) on a ManualClock, with a seeded ChaosMonkey and the
    invariant Watchdog wired into every scheduling iteration.  Returns
    ``(completions, report)``; raises AssertionError the moment an
    invariant breaks."""
    monkey = ChaosMonkey(seed) if monkey is None else monkey
    watchdog = Watchdog() if watchdog is None else watchdog
    sched = (ArrivalSchedule.burst(list(requests)) if rate_qps is None
             else ArrivalSchedule.poisson(list(requests), rate_qps,
                                          seed=seed))
    out = eng.serve(sched, temperature=temperature, key=key,
                    clock=ManualClock(dt=1.0 / 4.0),
                    on_iteration=compose(monkey, watchdog))
    stats = eng.last_stats
    report = {
        "iterations": watchdog.iterations,
        "injected": dict(monkey.counts),
        "completions": len(out),
        "finish_reasons": dict(collections.Counter(
            c.finish_reason for c in out)),
        "preemptions": stats.preemptions,
        "rejections": stats.rejections,
        "cancelled": stats.cancelled,
        "shed": stats.shed,
        "kv_pages_peak": stats.kv_pages_peak,
    }
    return out, report


def _main() -> int:
    """Short fixed-seed chaos soak on a smoke config (ci_fast gate)."""
    import argparse
    import json

    from repro import configs
    from repro.core.params import init_tree
    from repro.train.state import model_defs

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-layout", default="paged",
                    choices=("contiguous", "paged"))
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto-loadable trace.json here "
                         "(turns telemetry=trace on for the soak)")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch).with_spt(kv_layout=args.kv_layout,
                                                kv_page_size=16)
    if args.trace_out:
        cfg = cfg.with_spt(telemetry="trace")
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed)
    reqs = [Request(uid=i,
                    tokens=rng.integers(
                        0, cfg.vocab_size, size=int(rng.integers(4, 17)),
                        dtype=np.int32).tolist(),
                    max_new_tokens=int(rng.integers(2, 9)),
                    priority=int(rng.integers(0, 3)))
            for i in range(args.requests)]
    eng = Engine(cfg, params, max_len=64, num_slots=4, decode_chunk=4,
                 kv_pages=12 if args.kv_layout == "paged" else None)
    out, report = run_soak(eng, reqs, seed=args.seed)
    lost = [i for i, c in enumerate(out) if c is None]
    ok = (not lost and report["completions"] == eng.last_stats.submitted
          and report["injected"].get("forced_preempt", 0) >= 1)
    if args.trace_out:
        from repro.serving import trace_export
        rec = eng.last_recorder
        trace = trace_export.write_trace(rec, args.trace_out)
        errs = trace_export.validate_chrome_trace(trace)
        # every submitted uid (soak requests AND injected ones) must own
        # a lane in the trace — a missing lane is a lost request the
        # completion count could still hide
        submitted = {c.uid for c in out}
        missing = sorted(submitted - trace_export.trace_uids(trace))
        report["trace_events"] = len(trace["traceEvents"])
        report["trace_schema_errors"] = errs
        report["trace_missing_uids"] = missing
        ok = ok and not errs and not missing
    report["metrics"] = eng.last_stats.snapshot().as_dict()
    print(json.dumps({"ok": ok, **report}, indent=1))
    return 0 if ok else 1


if __name__ == "__main__":
    import sys
    sys.exit(_main())
