"""Paged KV cache: page allocator, slot->page tables, and paged views.

The serving engine's contiguous layout reserves a full ``max_len`` KV strip
per decode slot, so one long-context request pins as much cache memory as
dozens of short chats.  This module pages the cache into fixed-size blocks
(``page_size`` rows each) drawn from a shared pool:

  * the **pool** replaces each attention layer's per-slot ``(B, size, ...)``
    cache with a global ``(num_pages, page_size, ...)`` tensor;
  * the **page table** ``(num_slots, max_pages)`` int32 maps each slot's
    logical page j to a physical page id (-1 = unallocated);
  * the **allocator** is a free-list *stack* held in device arrays
    (``{"free": (P,) int32, "top": () int32}``) with alloc/free as pure
    functions, so page growth can ride inside the engine's compiled
    ``lax.while_loop`` decode chunk (a slot crossing a page boundary
    allocates its next page in-loop, no host round-trip).

Exhaustion never corrupts state: a failed alloc returns page id -1, and
every paged write routes -1 ids out of bounds under ``mode="drop"``.  The
engine's admission control ("free slot AND pages available") reserves each
request's worst-case page count up front, which makes in-loop allocation
infallible by construction — the free list can only run dry if reservation
accounting is violated.

Everything here is pure jax + ints; no model imports (models/attention.py
imports *this* for the paged gather/scatter views).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

AllocState = Dict[str, jax.Array]


# ------------------------------------------------------------ shape math
def num_pages(rows: int, page_size: int) -> int:
    """Pages needed to back ``rows`` cache rows (host-side, static)."""
    return max(1, -(-int(rows) // int(page_size)))


def pool_pages(num_slots: int, max_len: int, page_size: int) -> int:
    """Default pool size: parity with the contiguous layout's footprint
    (every slot could still grow to max_len).  Callers shrink this to an
    actual memory budget to realize the paging win."""
    return num_slots * num_pages(max_len, page_size)


def view_len(max_len: int, page_size: int) -> int:
    """Length of the per-slot gathered view: max_pages * page_size
    (>= max_len; the overhang is never valid)."""
    return num_pages(max_len, page_size) * page_size


# ------------------------------------------------------------- allocator
def init_state(total_pages: int) -> AllocState:
    """Fresh allocator: all pages free.  ``free[0:top]`` hold the free ids
    (a stack; alloc pops from ``free[top-1]``, free pushes back).

    ``refs`` is a per-page reference count: alloc sets it to 1, ``add_ref``
    bumps it for sharing (prefix caching / copy-on-write pages map the
    same physical page into several page tables), and ``free_slot_pages``
    decrements — a page returns to the free stack only when its count
    hits zero.  The serving invariant the chaos watchdog asserts is
    conservation: ``top + count(refs > 0) == total_pages`` after every
    scheduling iteration."""
    return {"free": jnp.arange(total_pages, dtype=jnp.int32),
            "top": jnp.asarray(total_pages, jnp.int32),
            "refs": jnp.zeros(total_pages, jnp.int32)}


def init_page_table(num_slots: int, max_pages: int) -> jax.Array:
    return jnp.full((num_slots, max_pages), -1, jnp.int32)


def alloc_masked(state: AllocState, want: jax.Array
                 ) -> Tuple[AllocState, jax.Array, jax.Array]:
    """Pop one page per True entry of ``want`` (any shape, vectorized).

    Returns (state', page_ids, ok) with page_ids == -1 (and ok False)
    where ``want`` is False or the pool is exhausted.  Pure; safe inside
    lax.while_loop bodies."""
    free, top = state["free"], state["top"]
    p = free.shape[0]
    w = want.astype(jnp.int32)
    rank = jnp.cumsum(w.reshape(-1)).reshape(w.shape) - w   # 0-based
    idx = top - 1 - rank
    ok = want & (idx >= 0)
    pid = jnp.where(ok, free[jnp.clip(idx, 0, p - 1)], jnp.int32(-1))
    new_top = top - jnp.sum(ok.astype(jnp.int32))
    dest = jnp.where(ok, pid, jnp.int32(p)).reshape(-1)   # OOB -> drop
    refs = state["refs"].at[dest].set(1, mode="drop")
    return {"free": free, "top": new_top, "refs": refs}, pid, ok


def alloc_slot_pages(state: AllocState, page_table: jax.Array,
                     slot: jax.Array, n: jax.Array
                     ) -> Tuple[AllocState, jax.Array]:
    """Allocate the first ``n`` (traced scalar) pages of ``slot``'s row,
    replacing the whole row (so a recycled slot starts clean).  One
    compiled shape serves every n."""
    mp = page_table.shape[1]
    want = jnp.arange(mp, dtype=jnp.int32) < jnp.asarray(n, jnp.int32)
    state, pid, _ = alloc_masked(state, want)
    return state, page_table.at[slot].set(pid)


def alloc_rows_pages(state: AllocState, page_table: jax.Array,
                     slots: jax.Array, npages: jax.Array
                     ) -> Tuple[AllocState, jax.Array]:
    """Group admission: allocate the first ``npages[i]`` pages for each row
    of a batched prefill in ONE call (slots (Bp,) int32, -1 = bucket-pad
    dummy row -> nothing allocated, page-table write dropped).  Each real
    slot's page-table row is replaced wholesale (clean recycle), exactly
    like alloc_slot_pages does for one slot."""
    mp = page_table.shape[1]
    npages = jnp.asarray(npages, jnp.int32)
    want = ((jnp.arange(mp, dtype=jnp.int32)[None, :] < npages[:, None])
            & (slots >= 0)[:, None])                      # (Bp, MP)
    state, pid, _ = alloc_masked(state, want)
    dest = jnp.where(slots >= 0, slots, jnp.int32(page_table.shape[0]))
    return state, page_table.at[dest].set(pid, mode="drop")


def free_slot_pages(state: AllocState, page_table: jax.Array,
                    slot: jax.Array) -> Tuple[AllocState, jax.Array]:
    """Drop one reference on each of ``slot``'s allocated pages, push the
    pages whose count hits zero back on the free stack, and clear the
    slot's page-table row.  This is the engine's retire AND preemption
    path: with no sharing every page's count is 1, so this reclaims the
    whole row; once prefix-cached pages are shared (``add_ref``) the
    shared pages survive until their last mapping drops."""
    free, top, refs = state["free"], state["top"], state["refs"]
    p = free.shape[0]
    row = page_table[slot]                                # (MP,)
    valid = row >= 0
    rdest = jnp.where(valid, row, jnp.int32(p))
    refs = refs.at[rdest].add(-1, mode="drop")
    reclaim = valid & (refs[jnp.clip(row, 0, p - 1)] <= 0)
    v = reclaim.astype(jnp.int32)
    rank = jnp.cumsum(v) - v
    dest = jnp.where(reclaim, top + rank, jnp.int32(p))   # p -> dropped
    free = free.at[dest].set(row, mode="drop")
    top = top + jnp.sum(v)
    refs = refs.at[rdest].max(0, mode="drop")             # clamp at zero
    return ({"free": free, "top": top, "refs": refs},
            page_table.at[slot].set(jnp.int32(-1)))


def add_ref(state: AllocState, pages: jax.Array) -> AllocState:
    """Bump the reference count of ``pages`` (any shape int32; -1 entries
    ignored).  The hook future prefix-caching uses to map one physical
    page into several slots' tables; today only tests exercise it."""
    p = state["free"].shape[0]
    dest = jnp.where(pages >= 0, pages, jnp.int32(p)).reshape(-1)
    return {"free": state["free"], "top": state["top"],
            "refs": state["refs"].at[dest].add(1, mode="drop")}


def pages_in_use(state: AllocState) -> jax.Array:
    return jnp.asarray(state["free"].shape[0], jnp.int32) - state["top"]


# ----------------------------------------------------------- paged views
def gather_pages(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """Materialize per-slot contiguous views from a page pool.

    pool (P, Hk, ps, X) -> (B, Hk, MP*ps, X);  pool (P, ps) -> (B, MP*ps).
    Unallocated entries (-1) clamp to page 0 — callers MUST mask those
    rows via ``occupancy`` (or an engine kv_valid that includes it); the
    clamped reads are garbage-but-finite, never NaN."""
    pt = jnp.maximum(page_table, 0)
    g = jnp.take(pool, pt, axis=0)                        # (B, MP, ...)
    if pool.ndim == 2:
        b, mp, ps = g.shape
        return g.reshape(b, mp * ps)
    b, mp, hk, ps, x = g.shape
    return g.transpose(0, 2, 1, 3, 4).reshape(b, hk, mp * ps, x)


def occupancy(page_table: jax.Array, page_size: int) -> jax.Array:
    """(B, MP*ps) bool — view row is backed by an allocated page.

    Broadcast+reshape instead of jnp.repeat: the repeat count is static,
    so the mask expands with zero data movement (analysis/lint.py bans
    jnp.repeat in serving/ — on cache-adjacent shapes it materializes the
    expansion)."""
    b, mp = page_table.shape
    alloc = (page_table >= 0)[:, :, None]                 # (B, MP, 1)
    return jnp.broadcast_to(alloc, (b, mp, page_size)).reshape(
        b, mp * page_size)


def scatter_row(pool: jax.Array, page_table: jax.Array, pos: jax.Array,
                val: jax.Array, page_size: int) -> jax.Array:
    """Write one row per slot at absolute position ``pos`` (B,).

    pool (P, Hk, ps, X) takes val (B, Hk, X); pool (P, ps) takes val (B,)
    (broadcastable).  Rows whose page is unallocated are dropped."""
    p = pool.shape[0]
    pj = pos // page_size
    row = pos % page_size
    pid = jnp.take_along_axis(page_table, pj[:, None], axis=1)[:, 0]
    dest = jnp.where(pid >= 0, pid, jnp.int32(p))         # OOB -> drop
    if pool.ndim == 2:
        return pool.at[dest, row].set(val, mode="drop")
    return pool.at[dest, :, row].set(val.astype(pool.dtype), mode="drop")


def scatter_prefill(pool: jax.Array, page_table_row: jax.Array,
                    seq: jax.Array, page_size: int,
                    pad_value=0) -> jax.Array:
    """Scatter a contiguous batch-1 prefill row into ``slot``'s pages.

    pool (P, Hk, ps, X) takes seq (Hk, L, X); pool (P, ps) takes seq (L,).
    L is zero-padded (``pad_value`` for slot_pos) up to a page multiple;
    pages beyond the slot's allocation (-1 ids, e.g. bucketed right-pad
    overhang) are dropped — those rows are never read before decode
    overwrites them."""
    p, ps = pool.shape[0], page_size
    l = seq.shape[-2] if pool.ndim == 4 else seq.shape[-1]
    npg = num_pages(l, ps)
    pad = npg * ps - l
    ids = page_table_row[:npg]
    dest = jnp.where(ids >= 0, ids, jnp.int32(p))
    if pool.ndim == 2:
        rows = jnp.pad(seq, (0, pad), constant_values=pad_value)
        return pool.at[dest].set(rows.reshape(npg, ps), mode="drop")
    hk, _, x = seq.shape
    rows = jnp.pad(seq, ((0, 0), (0, pad), (0, 0)))
    rows = rows.reshape(hk, npg, ps, x).transpose(1, 0, 2, 3)
    return pool.at[dest].set(rows.astype(pool.dtype), mode="drop")


def scatter_prefill_rows(pool: jax.Array, page_tables: jax.Array,
                         seqs: jax.Array, page_size: int,
                         pad_value=0) -> jax.Array:
    """Batched scatter_prefill: every row of a prefill group in one call.

    pool (P, Hk, ps, X) takes seqs (B, Hk, L, X); pool (P, ps) takes seqs
    (B, L).  page_tables: (B, MP) — -1 ids (bucketed-pad overhang, or a
    dummy row's all -1) route out of bounds and drop.  Page ids are unique
    across rows, so destinations never conflict."""
    p, ps = pool.shape[0], page_size
    l = seqs.shape[-2] if pool.ndim == 4 else seqs.shape[-1]
    npg = num_pages(l, ps)
    pad = npg * ps - l
    ids = page_tables[:, :npg]                            # (B, npg)
    dest = jnp.where(ids >= 0, ids, jnp.int32(p)).reshape(-1)
    if pool.ndim == 2:
        rows = jnp.pad(seqs, ((0, 0), (0, pad)), constant_values=pad_value)
        return pool.at[dest].set(rows.reshape(-1, ps), mode="drop")
    b, hk, _, x = seqs.shape
    rows = jnp.pad(seqs, ((0, 0), (0, 0), (0, pad), (0, 0)))
    rows = rows.reshape(b, hk, npg, ps, x).transpose(0, 2, 1, 3, 4)
    return pool.at[dest].set(rows.reshape(b * npg, hk, ps, x)
                             .astype(pool.dtype), mode="drop")


# ------------------------------------------------------ memory accounting
def kv_row_bytes(cfg) -> int:
    """Bytes of attention-cache state per cache row per slot, summed over
    the layers the paged layout covers (attn blocks without a SWA ring).
    Used by benchmarks for the honest contiguous-vs-paged comparison:
      contiguous bytes = num_slots * max_len * kv_row_bytes
      paged bytes      = num_pages * page_size * kv_row_bytes
    cfg is a ModelConfig (duck-typed; no model imports here)."""
    if cfg.window is not None:
        return 0
    n_attn = sum(1 for kind in cfg.layer_types() if kind == "attn")
    if n_attn == 0:                 # pure-SSM/recurrent: nothing to page
        return 0
    hk, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    itemsize = jnp.dtype(cfg.dtype).itemsize
    per_row = 2 * hk * hd * itemsize + 4                  # K + V + slot_pos
    spt = cfg.spt
    if spt.sparse_mha and hd % spt.pq_code_dim == 0:
        per_row += hk * (hd // spt.pq_code_dim)           # int8 PQ codes
    return n_attn * per_row
