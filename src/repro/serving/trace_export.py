"""Trace export: Chrome-trace/Perfetto ``trace.json`` and JSONL event
logs from a ``TelemetryRecorder``.

The Chrome trace event format (the JSON Perfetto's legacy importer and
chrome://tracing both load) is an object ``{"traceEvents": [...]}`` whose
events carry ``ph`` (phase), ``ts``/``dur`` (microseconds), ``pid``/
``tid`` lanes, and ``args``.  We emit:

  * pid 1 ("scheduler"): one "X" (complete) event per scheduler span
    (group formation, pressure preemption, prefill batch, decode chunk,
    drain) and "C" (counter) tracks for the per-iteration gauges (queue
    depth, active slots, free pages).
  * pid 2 ("requests"): one tid lane per request uid, an "i" (instant)
    event per lifecycle transition plus derived "X" spans for the queued
    wait (submit -> admit/reject/shed) and the generation phase (first
    token -> terminal event) so lanes read at a glance.

``validate_chrome_trace`` is the schema check the tests (and the chaos
CLI) run over the written file — it enforces the subset of the format we
rely on rather than trusting "it loaded once in Perfetto".
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from repro.serving.telemetry import TelemetryRecorder

SCHED_PID = 1
REQ_PID = 2

# lifecycle events that end a request's lane
TERMINAL_EVENTS = ("retired", "shed", "rejected", "cancelled")


def _us(recorder: TelemetryRecorder, t: float) -> float:
    return max(0.0, (t - recorder.time_origin)) * 1e6


def chrome_trace(recorder: TelemetryRecorder) -> Dict[str, Any]:
    """Build the Chrome-trace object (host data only; json-serializable)."""
    ev: List[dict] = []
    ev.append({"ph": "M", "pid": SCHED_PID, "tid": 0,
               "name": "process_name", "args": {"name": "scheduler"}})
    ev.append({"ph": "M", "pid": REQ_PID, "tid": 0,
               "name": "process_name", "args": {"name": "requests"}})

    for sp in recorder.spans:
        ev.append({"ph": "X", "pid": SCHED_PID, "tid": 0, "name": sp.name,
                   "ts": _us(recorder, sp.t0),
                   "dur": max(0.0, (sp.t1 - sp.t0) * 1e6),
                   "args": {"iteration": sp.iteration, **sp.args}})
    for name, track in recorder.gauge_tracks.items():
        for t, v in track:
            ev.append({"ph": "C", "pid": SCHED_PID, "tid": 0, "name": name,
                       "ts": _us(recorder, t), "args": {"value": v}})

    for uid, timeline in sorted(recorder.timelines.items()):
        ev.append({"ph": "M", "pid": REQ_PID, "tid": uid,
                   "name": "thread_name", "args": {"name": f"req {uid}"}})
        submit_t: Optional[float] = None
        first_tok_t: Optional[float] = None
        for e in timeline:
            args = {k: v for k, v in e.items()
                    if k not in ("t", "uid", "event")}
            ev.append({"ph": "i", "pid": REQ_PID, "tid": uid,
                       "name": e["event"], "ts": _us(recorder, e["t"]),
                       "s": "t", "args": args})
            name, t = e["event"], e["t"]
            if name == "submit":
                submit_t = t
            elif name == "first_token":
                first_tok_t = t
            if submit_t is not None and (
                    name in ("admitted", "resumed") or
                    name in TERMINAL_EVENTS):
                ev.append({"ph": "X", "pid": REQ_PID, "tid": uid,
                           "name": "queued", "ts": _us(recorder, submit_t),
                           "dur": max(0.0, (t - submit_t) * 1e6),
                           "args": {}})
                submit_t = None
            if name == "preempted":
                submit_t = t                 # re-queued wait restarts
            if first_tok_t is not None and name in TERMINAL_EVENTS:
                ev.append({"ph": "X", "pid": REQ_PID, "tid": uid,
                           "name": "generate", "ts": _us(recorder,
                                                         first_tok_t),
                           "dur": max(0.0, (t - first_tok_t) * 1e6),
                           "args": {"finish": name}})
                first_tok_t = None
    return {"traceEvents": ev, "displayTimeUnit": "ms"}


def write_trace(recorder: TelemetryRecorder, path: str) -> Dict[str, Any]:
    trace = chrome_trace(recorder)
    with open(path, "w") as f:
        json.dump(trace, f)
    return trace


def write_events_jsonl(recorder: TelemetryRecorder, path: str) -> int:
    """Append-free JSONL dump of the (bounded) global event log."""
    n = 0
    with open(path, "w") as f:
        for e in recorder.events:
            f.write(json.dumps(e) + "\n")
            n += 1
    return n


# ------------------------------------------------------------ validation
_ALLOWED_PH = {"X", "B", "E", "i", "I", "C", "M"}


def validate_chrome_trace(trace: Any) -> List[str]:
    """Check the subset of the Chrome trace event schema we emit.
    Returns a list of problems (empty = valid)."""
    errs: List[str] = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["top level must be an object with a traceEvents array"]
    evs = trace["traceEvents"]
    if not isinstance(evs, list):
        return ["traceEvents must be an array"]
    for i, e in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            errs.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if ph not in _ALLOWED_PH:
            errs.append(f"{where}: bad ph {ph!r}")
            continue
        if not isinstance(e.get("name"), str):
            errs.append(f"{where}: missing name")
        for key in ("pid", "tid"):
            if not isinstance(e.get(key), int):
                errs.append(f"{where}: missing integer {key}")
        if ph != "M":
            ts = e.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                errs.append(f"{where}: missing nonneg ts")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"{where}: X event missing nonneg dur")
        if ph == "C" and not isinstance(e.get("args"), dict):
            errs.append(f"{where}: C event missing args")
    return errs


def trace_uids(trace: Dict[str, Any]) -> set:
    """Every request uid with a lane in the trace (tid of pid-2 events)."""
    return {e["tid"] for e in trace.get("traceEvents", ())
            if e.get("pid") == REQ_PID and e.get("ph") != "M"}
