"""Serving observability: bounded reservoirs, metrics snapshots, and the
host-side telemetry recorder.

Three layers (ISSUE 9 / ROADMAP "observability"):

  * ``Reservoir`` — bounded uniform sample (Algorithm R, deterministic
    seed) with an exact running mean, replacing the unbounded
    ``ServeStats.ttft_samples`` / ``tpot_samples`` lists so week-long
    ``serve()`` runs don't leak host memory.
  * ``MetricsSnapshot`` — a point-in-time counters/gauges/histograms
    view; ``ServeStats.as_dict`` delegates to it, and the chaos watchdog
    dumps it on invariant failures.
  * ``TelemetryRecorder`` — per-request lifecycle timelines (submit ->
    queued -> admitted/stalled -> prefill -> first token -> preempt/
    resume -> retire/shed/rejected/cancelled), per-iteration scheduler
    spans and gauges, and aggregation of the jit-pure device counters
    (tel_* trees) the engine drains once per scheduling iteration.

This module is engine-agnostic: it never imports ``serving.engine`` and
holds no jax arrays — the engine hands it host data (floats / numpy)
exactly once per scheduling iteration, so nothing here can add a device
sync to the hot path (lint.host-sync covers this file).
"""
from __future__ import annotations

import collections
import dataclasses
import random
from typing import Any, Deque, Dict, Iterator, List, Optional, Tuple

import numpy as np

# keep at most this many host-side span/gauge/event records; old entries
# roll off (the per-request timelines stay complete — their length is
# bounded by the request's own lifecycle, not the run's)
MAX_HOST_RECORDS = 65536


class Reservoir:
    """Bounded uniform sample over a stream (Vitter's Algorithm R).

    Deterministic for a given (cap, seed, stream): item i <= cap is kept;
    after that item i replaces a random slot with probability cap/i.  The
    mean is exact (running total over every item seen); percentiles are
    computed over the retained sample, so they carry sampling error only
    once the stream exceeds ``cap``.  API is list-compatible where the
    engine's stats code needs it (append / len / iteration / truthiness).
    """

    __slots__ = ("cap", "_rng", "_items", "n_seen", "_total")

    def __init__(self, cap: int = 2048, seed: int = 0):
        assert cap > 0
        self.cap = cap
        self._rng = random.Random(seed)
        self._items: List[float] = []
        self.n_seen = 0
        self._total = 0.0

    def append(self, x: float) -> None:
        x = float(x)
        self.n_seen += 1
        self._total += x
        if len(self._items) < self.cap:
            self._items.append(x)
        else:
            j = self._rng.randrange(self.n_seen)
            if j < self.cap:
                self._items[j] = x

    add = append

    def extend(self, xs) -> None:
        for x in xs:
            self.append(x)

    @property
    def values(self) -> List[float]:
        return list(self._items)

    @property
    def mean(self) -> float:
        return self._total / self.n_seen if self.n_seen else 0.0

    @property
    def total(self) -> float:
        return self._total

    def percentile(self, q: float) -> float:
        if not self._items:
            return 0.0
        return float(np.percentile(np.array(self._items), q))

    def __len__(self) -> int:
        return len(self._items)

    def __bool__(self) -> bool:
        return bool(self._items)

    def __iter__(self) -> Iterator[float]:
        return iter(self._items)


@dataclasses.dataclass
class MetricsSnapshot:
    """Point-in-time metrics view: monotonic counters, instantaneous
    gauges, and histogram summaries (from bounded reservoirs).

    ``legacy_order`` preserves the exact key order `ServeStats.as_dict`
    has always produced (benchmarks and tests consume it); keys not in
    the legacy set (device-counter aggregates like ``keep_rate``) are
    appended after it, sorted, so telemetry=off output is byte-identical
    to the pre-telemetry engine.
    """

    counters: Dict[str, float] = dataclasses.field(default_factory=dict)
    gauges: Dict[str, float] = dataclasses.field(default_factory=dict)
    histograms: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)
    legacy_order: Tuple[str, ...] = ()

    def flat(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        out.update(self.counters)
        out.update(self.gauges)
        for name, h in self.histograms.items():
            for stat, v in h.items():
                out[f"{name}_{stat}"] = v
        return out

    def as_dict(self) -> Dict[str, Any]:
        flat = self.flat()
        d: Dict[str, Any] = {}
        for k in self.legacy_order:
            if k in flat:
                d[k] = flat[k]
        for k in sorted(flat):
            if k not in d:
                d[k] = flat[k]
        return d


# ------------------------------------------------------------- recorder
@dataclasses.dataclass
class Span:
    """One scheduler phase within a scheduling iteration."""
    name: str
    t0: float
    t1: float
    iteration: int
    args: Dict[str, float] = dataclasses.field(default_factory=dict)


class TelemetryRecorder:
    """Host-side recorder for one engine run (reset per ``serve()``).

    mode "counters" keeps only device-counter aggregation; mode "trace"
    additionally records request timelines, scheduler spans, and gauges.
    Every method takes host scalars — the engine calls them strictly at
    scheduling-iteration boundaries, never inside compiled code.
    """

    def __init__(self, mode: str = "trace", time_origin: float = 0.0):
        self.mode = mode
        self.trace = mode == "trace"
        self.time_origin = time_origin
        self.timelines: Dict[int, List[dict]] = {}
        self.events: Deque[dict] = collections.deque(maxlen=MAX_HOST_RECORDS)
        self.spans: Deque[Span] = collections.deque(maxlen=MAX_HOST_RECORDS)
        self.gauge_tracks: Dict[str, Deque[Tuple[float, float]]] = {}
        # device-counter accumulators (all host floats / numpy)
        self.attn_kept = 0.0
        self.attn_elig = 0.0
        self.expert_load: Optional[np.ndarray] = None
        self.expert_dropped = 0.0
        self.pages_allocated = 0.0
        self.sampled_tokens = 0.0
        self.counted_decode_tokens = 0.0
        self.counter_drains = 0

    # ---------------------------------------------------- trace events
    def event(self, uid: Optional[int], name: str, t: float, **fields
              ) -> None:
        """One lifecycle event.  uid None = scheduler-lane instant."""
        if not self.trace:
            return
        ev = {"t": float(t), "uid": uid, "event": name}
        if fields:
            ev.update(fields)
        if uid is not None:
            self.timelines.setdefault(uid, []).append(ev)
        self.events.append(ev)

    def span(self, name: str, t0: float, t1: float, iteration: int,
             **args) -> None:
        if not self.trace:
            return
        self.spans.append(Span(name, float(t0), float(t1), iteration,
                               {k: float(v) for k, v in args.items()}))

    def gauge(self, name: str, t: float, value: float) -> None:
        if not self.trace:
            return
        track = self.gauge_tracks.setdefault(
            name, collections.deque(maxlen=MAX_HOST_RECORDS))
        track.append((float(t), float(value)))

    def recent_events(self, n: int = 50) -> List[dict]:
        evs = list(self.events)
        return evs[-n:]

    def timeline(self, uid: int) -> List[dict]:
        return list(self.timelines.get(uid, ()))

    # ------------------------------------------------- device counters
    def drain_counters(self, ctr: Optional[Dict[str, Any]]) -> None:
        """Fold one host-fetched counter tree (numpy leaves) into the run
        accumulators.  Called once per scheduling iteration with the tree
        the compiled chunk / prefill threaded through its carry."""
        if not ctr:
            return
        self.counter_drains += 1
        for k, v in ctr.items():
            a = np.array(v, dtype=np.float64)
            if k == "tel_attn_kept":
                self.attn_kept += float(a.sum())
            elif k == "tel_attn_elig":
                self.attn_elig += float(a.sum())
            elif k == "tel_expert_load":
                per = a.reshape(-1, a.shape[-1]).sum(axis=0)   # (G,)
                if self.expert_load is None:
                    self.expert_load = per
                else:
                    self.expert_load = self.expert_load + per
            elif k == "tel_expert_drop":
                self.expert_dropped += float(a.sum())
            elif k == "pages_allocated":
                self.pages_allocated += float(a.sum())
            elif k == "sampled_tokens":
                self.sampled_tokens += float(a.sum())
            elif k == "decode_tokens":
                self.counted_decode_tokens += float(a.sum())

    def device_aggregates(self) -> Dict[str, float]:
        """Run-level aggregates of the drained device counters — merged
        into ``ServeStats.as_dict`` (only when telemetry is on, so the
        off-mode dict stays byte-identical to the legacy engine)."""
        out: Dict[str, float] = {}
        if self.attn_elig > 0:
            out["keep_rate"] = round(self.attn_kept / self.attn_elig, 4)
        if self.expert_load is not None:
            total = float(self.expert_load.sum())
            mean = total / self.expert_load.size
            if mean > 0:
                out["expert_load_imbalance"] = round(
                    float(self.expert_load.max()) / mean, 3)
            out["expert_tokens_routed"] = total
            out["expert_dropped"] = round(self.expert_dropped, 1)
        if self.pages_allocated:
            out["pages_allocated_in_loop"] = self.pages_allocated
        if self.sampled_tokens:
            out["sampled_tokens"] = self.sampled_tokens
        if self.counted_decode_tokens:
            out["counted_decode_tokens"] = self.counted_decode_tokens
        return out

    def expert_load_vector(self) -> Optional[List[float]]:
        if self.expert_load is None:
            return None
        return [float(x) for x in self.expert_load]
