"""Serving: prefill/decode step builders and a continuous-batching engine.

serve_step semantics for the dry-run cells:
  prefill_32k  — lower `prefill_step` over (B, S) prompts
  decode_32k / long_500k — lower `decode_step`: one new token per sequence
                 against a KV cache of seq_len (the cache is a donated input)

The `Engine` runs **continuous batching** over a fixed number of decode
slots (vLLM-style, in JAX):

  * requests queue up and are admitted into free slots as they open;
  * admission is **disaggregated and batched**: up to `prefill_batch`
    queued requests drain through ONE batched ragged prefill call — rows
    are right-padded to a joint (Bp, S) power-of-2 bucket (per-row lengths
    are threaded into sparse-MHA top-L budgets and routed-FFN/MoE dispatch
    capacities, so every row's output is identical to a batch-1
    exact-length prefill) and ALL resulting cache rows scatter into their
    slots in one jit call (one page allocation + one page-wise scatter in
    the paged layout) instead of one host round-trip per admission; the
    scatter replaces whole rows, which doubles as slot recycling.
    Non-right-paddable stacks (recurrent/SSM states, SWA rings) batch
    equal-length rows only;
  * with `prefill_decode_ratio > 0` the scheduler **overlaps** admission
    with decode: while decodes are in flight, each scheduling iteration
    admits at most ratio * decode_chunk * active_slots prompt tokens
    before running the next decode chunk, so a burst of arrivals no
    longer pauses every in-flight generation until the queue drains;
    `ServeStats` reports time-to-first-token and prefill-batch occupancy
    so the overlap is measurable;
  * admission never head-of-line-blocks on the page pool: a request whose
    worst case does not fit is counted as a stall and skipped, while
    later requests that do fit are admitted (the stalled one retries
    every iteration);
  * decode runs in jit-compiled `lax.while_loop` chunks with per-slot
    positions, so the whole generation traces ONCE instead of per token;
    the loop exits a chunk early when every slot has finished;
  * each decode step lowers through the fused Pallas kernel paths when
    the config selects them (`core/dispatch.py`): sparse-MHA decode
    attention, and the routed-FFN block-gather kernel — at (B, 1, d)
    the latter indexes weight blocks by the scalar-prefetched top-G'
    choices directly, so no (B, G, C, d) dispatch buffer is built and
    the router's softmax/load-balance aux is skipped (inference mode);
  * slots retire on EOS or on their per-request token budget, freeing the
    slot for the next queued request;
  * with `SPTConfig.kv_layout="paged"` the attention caches are pools of
    fixed-size pages shared across slots (serving/kv_pages.py): admission
    requires a free slot AND pages for the request's worst case, pages
    grow on demand *inside* the compiled chunk (pure allocator state in
    the while_loop carry), and retirement frees them — so short requests
    no longer pin max_len-sized strips and long-context max_len stops
    capping the slot count;
  * per-request sampling (Request.temperature / top_k / top_p nucleus
    truncation via a per-slot sorted cumsum) runs inside the chunk via
    per-slot arrays; greedy decoding remains the bit-identical default.

Timing is honest: prefill and decode are accumulated separately with
`block_until_ready` at each boundary, and reported via `ServeStats` so
callers can separate compile/warmup (first run) from steady state.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import dispatch as kdispatch
from repro.models import attention, encdec, ffn, transformer
from repro.serving import kv_pages as kvp


def build_prefill_step(cfg: ModelConfig, max_len: int) -> Callable:
    if cfg.family == "audio":
        def prefill(params, batch):
            return encdec.encdec_prefill(params, cfg, batch, max_len)
    else:
        def prefill(params, batch):
            return transformer.lm_prefill(params, cfg, batch, max_len)
    return prefill


def build_decode_step(cfg: ModelConfig) -> Callable:
    if cfg.family == "audio":
        def decode(params, caches, token, pos):
            return encdec.encdec_decode_step(params, cfg, caches, token, pos)
    else:
        def decode(params, caches, token, pos):
            return transformer.lm_decode_step(params, cfg, caches, token, pos)
    return decode


def abstract_decode_caches(cfg: ModelConfig, batch: int, cache_len: int,
                           kv_pages: Optional[int] = None):
    if cfg.family == "audio":
        fn = lambda: encdec.init_dec_caches(cfg, batch, cache_len,
                                            cfg.frontend_tokens)
    else:
        fn = lambda: transformer.init_caches(cfg, batch, cache_len,
                                             kv_pages=kv_pages)
    shapes = jax.eval_shape(fn)
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), shapes)


def decode_cache_axes(cfg: ModelConfig, kv_paged: bool = False):
    if cfg.family == "audio":
        return encdec.cache_axes(cfg)
    return transformer.cache_axes(cfg, kv_paged=kv_paged)


# ---------------------------------------------------------------- requests
@dataclasses.dataclass
class Request:
    """One generation request for the continuous-batching engine."""
    uid: int
    tokens: Sequence[int]                  # prompt token ids
    max_new_tokens: int = 16
    frontend_embeds: Optional[Any] = None  # (F, d) for VLM-style frontends
    # per-request sampling (applied inside the compiled decode chunk):
    # temperature None = inherit run()'s temperature; <= 0 = greedy.
    # top_k 0 = no truncation; 1 = deterministic argmax sampling.
    # top_p in (0, 1) keeps the smallest nucleus with that much probability
    # mass (0 or >= 1 = off); composes with top_k (intersection).
    temperature: Optional[float] = None
    top_k: int = 0
    top_p: float = 0.0


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: List[int]                      # generated ids (EOS included)
    finish_reason: str                     # "eos" | "length"
    prompt_len: int


@dataclasses.dataclass
class ServeStats:
    """Wall-clock split of one `Engine.run` (block_until_ready-bounded)."""
    prefill_s: float = 0.0
    decode_s: float = 0.0
    prefill_tokens: int = 0                # prompt tokens processed
    decode_tokens: int = 0                 # tokens produced by decode steps
    decode_steps: int = 0                  # batch-wide while_loop trips
    admitted: int = 0
    completed: int = 0
    # disaggregated batched prefill
    prefill_batches: int = 0               # batched prefill calls issued
    ttft_s_sum: float = 0.0                # sum over admitted requests of
    ttft_s_max: float = 0.0                # (first token ready - run start)
    # paged KV cache (zeros when kv_layout="contiguous")
    page_size: int = 0
    kv_pages_total: int = 0                # pool capacity in pages
    kv_pages_peak: int = 0                 # peak pages in use
    admission_stalls: int = 0              # free slot but no pages

    @property
    def prefill_tok_s(self) -> float:
        return self.prefill_tokens / self.prefill_s if self.prefill_s else 0.0

    @property
    def decode_tok_s(self) -> float:
        return self.decode_tokens / self.decode_s if self.decode_s else 0.0

    @property
    def ttft_avg_s(self) -> float:
        """Mean time-to-first-token (the first token comes out of prefill,
        so this is prefill latency + any queueing behind earlier groups)."""
        return self.ttft_s_sum / self.admitted if self.admitted else 0.0

    @property
    def prefill_batch_occupancy(self) -> float:
        """Mean admitted rows per batched prefill call (1.0 == the old
        serial batch-1 admission)."""
        return (self.admitted / self.prefill_batches
                if self.prefill_batches else 0.0)

    def as_dict(self) -> Dict[str, float]:
        return {"prefill_s": round(self.prefill_s, 4),
                "decode_s": round(self.decode_s, 4),
                "prefill_tokens": self.prefill_tokens,
                "decode_tokens": self.decode_tokens,
                "decode_steps": self.decode_steps,
                "prefill_tok_s": round(self.prefill_tok_s, 1),
                "decode_tok_s": round(self.decode_tok_s, 1),
                "admitted": self.admitted, "completed": self.completed,
                "prefill_batches": self.prefill_batches,
                "prefill_batch_occupancy": round(
                    self.prefill_batch_occupancy, 2),
                "ttft_avg_s": round(self.ttft_avg_s, 4),
                "ttft_max_s": round(self.ttft_s_max, 4),
                **({"page_size": self.page_size,
                    "kv_pages_total": self.kv_pages_total,
                    "kv_pages_peak": self.kv_pages_peak,
                    "admission_stalls": self.admission_stalls}
                   if self.kv_pages_total else {})}


@dataclasses.dataclass
class GenerationResult:
    tokens: List[List[int]]
    steps: int


# ---------------------------------------------------------------- engine
class Engine:
    """Continuous-batching serving engine over `num_slots` decode slots.

    `run(requests)` is the native API (queue admission, EOS/budget exits,
    ragged prompts).  `generate(batch, steps)` keeps the legacy fixed-batch
    API used by the benchmarks and system tests; for greedy decoding it is
    routed through the slot engine, whose outputs are row-for-row identical
    to the old per-token Python loop.
    """

    def __init__(self, cfg: ModelConfig, params: dict, max_len: int = 512,
                 jit: bool = True, *, num_slots: int = 8,
                 eos_id: Optional[int] = None, decode_chunk: int = 16,
                 pad_id: int = 0, kv_pages: Optional[int] = None,
                 prefill_batch: Optional[int] = None,
                 prefill_decode_ratio: float = 0.0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.num_slots = num_slots
        self.eos_id = eos_id
        self.decode_chunk = max(1, decode_chunk)
        self.pad_id = pad_id
        self.last_stats: Optional[ServeStats] = None
        self._use_jit = jit
        # disaggregated prefill scheduler: up to prefill_batch queued
        # requests drain through ONE batched ragged prefill call per
        # admission group (prefill_batch=1 == the old serial admission).
        # prefill_decode_ratio > 0 interleaves prefill micro-batches with
        # decode chunks instead of filling every free slot first: per
        # scheduling iteration at most ratio * decode_chunk * active_slots
        # prompt tokens are admitted while decodes are in flight (always
        # at least one request, so admission cannot starve); 0 = admit
        # greedily into all free slots before each decode chunk.
        self.prefill_batch = max(1, min(num_slots, num_slots
                                        if prefill_batch is None
                                        else prefill_batch))
        self.prefill_decode_ratio = max(0.0, prefill_decode_ratio)
        # paged KV cache: pool of kv_pages fixed-size pages shared across
        # slots (cfg.spt.kv_layout="paged"); kv_pages=None defaults to the
        # contiguous footprint — pass a smaller pool to serve under a
        # fixed cache-memory budget.
        self._paged = (kdispatch.use_paged_kv(cfg)
                       and transformer.paged_applicable(cfg))
        self.page_size = cfg.spt.kv_page_size if self._paged else 0
        if self._paged:
            self.max_pages_per_slot = kvp.num_pages(max_len, self.page_size)
            self.kv_pages = (num_slots * self.max_pages_per_slot
                             if kv_pages is None else int(kv_pages))
        else:
            self.kv_pages = 0
        # legacy per-token step fns (audio family + sampled generate())
        self._prefill = build_prefill_step(cfg, max_len)
        self._decode = build_decode_step(cfg)
        if jit:
            self._prefill = jax.jit(self._prefill)
            self._decode = jax.jit(self._decode, donate_argnums=(1,))
        self._prefill_one: Optional[Callable] = None
        self._chunk_cache: Dict[Any, Callable] = {}
        if self._paged:
            def _ws(caches, rows, slots, page_table):
                return transformer.write_slot_caches_paged_rows(
                    caches, rows, slots, page_table, cfg)
            self._write_rows = (jax.jit(_ws, donate_argnums=(0,))
                                if jit else _ws)
            self._alloc_rows = (
                jax.jit(kvp.alloc_rows_pages, donate_argnums=(0, 1))
                if jit else kvp.alloc_rows_pages)
            self._free_slot = (
                jax.jit(kvp.free_slot_pages, donate_argnums=(0, 1))
                if jit else kvp.free_slot_pages)
        else:
            self._write_rows = (
                jax.jit(transformer.write_slot_caches_rows,
                        donate_argnums=(0,))
                if jit else transformer.write_slot_caches_rows)

    # ------------------------------------------------------------ prefill
    def _pad_invariant(self) -> bool:
        """True when right-padding alone (no per-row length threading)
        provably cannot change real-token outputs.  That requires: a
        pure-attention stack (padding corrupts recurrent states), no
        sliding-window ring cache (padding displaces real KV), dense
        attention (sparse MHA's top-L budget counts the padded keys), and
        dense FFN (routed-FFN/MoE capacity dispatch lets pad tokens compete
        with real ones for slots)."""
        cfg = self.cfg
        return (transformer.supports_ragged_prefill(cfg)
                and cfg.window is None
                and not transformer.length_sensitive(cfg))

    def _ragged_batchable(self) -> bool:
        """True when ragged rows may be right-padded to a common bucket:
        pure-attention stacks without a SWA ring (padding would displace
        real KV from the window-sized ring buffer).  Length-sensitive
        configs (sparse MHA / routed FFN / MoE) stay exact because
        lm_prefill_ragged threads the per-row lengths into selection
        budgets and dispatch capacities.  Everything else (rec/ssd states)
        batches equal-length rows only."""
        return (transformer.supports_ragged_prefill(self.cfg)
                and self.cfg.window is None)

    def _pad_len(self, n: int) -> int:
        """Prompt-length bucket: ragged-batchable configs pad right to a
        power of two (cache slots past the real length are invalidated),
        bounding jit retraces to O(log L); everything else prefills at
        exact length so outputs stay identical to the per-token
        reference."""
        n = max(1, n)
        if not self._ragged_batchable():
            return n
        p = 8
        while p < n:
            p <<= 1
        frontend = self.cfg.frontend_tokens if self.cfg.frontend else 0
        return max(n, min(p, self.max_len - frontend))

    @staticmethod
    def _pad_rows(n: int) -> int:
        """Row-count bucket (power of two), so the (Bp, S) prefill shapes
        stay O(log Bp * log S) and retraces stay bounded."""
        p = 1
        while p < n:
            p <<= 1
        return p

    def _get_prefill(self) -> Callable:
        if self._prefill_one is None:
            cfg, max_len = self.cfg, self.max_len

            def fn(params, batch, lengths):
                return transformer.lm_prefill_ragged(params, cfg, batch,
                                                     lengths, max_len)
            self._prefill_one = jax.jit(fn) if self._use_jit else fn
        return self._prefill_one

    def _prefill_group(self, group: Sequence[Request]):
        """ONE batched ragged prefill over an admission group: rows are
        right-padded to a joint (Bp, S) bucket (dummy rows fill the Bp
        bucket; their results are discarded and their cache rows dropped
        by the scatter).  Returns (cache_rows, logits (Bpb, 1, V), Bpb)."""
        cfg = self.cfg
        frontend = cfg.frontend_tokens if cfg.frontend else 0
        p = self._pad_len(max(len(r.tokens) for r in group))
        bpb = self._pad_rows(len(group))
        toks = np.full((bpb, p), self.pad_id, np.int32)
        lens = np.ones(bpb, np.int32)                  # dummies: length 1
        for i, r in enumerate(group):
            toks[i, :len(r.tokens)] = np.asarray(r.tokens, np.int32)
            lens[i] = len(r.tokens)
        batch = {"tokens": jnp.asarray(toks)}
        if frontend:
            fe = np.zeros((bpb, frontend, cfg.d_model), np.float32)
            for i, r in enumerate(group):
                fe[i] = np.asarray(r.frontend_embeds).reshape(
                    frontend, cfg.d_model)
            batch["frontend_embeds"] = jnp.asarray(fe)
        lengths = jnp.asarray(frontend + lens, jnp.int32)
        rows, logits = self._get_prefill()(self.params, batch, lengths)
        return rows, logits, bpb

    # ------------------------------------------------------------- decode
    def _get_chunk(self, slots: int, max_gen: int, greedy: bool,
                   eos_id: Optional[int], use_topp: bool = False
                   ) -> Callable:
        key = (slots, max_gen, greedy, eos_id, use_topp)
        fn = self._chunk_cache.get(key)
        if fn is not None:
            return fn
        cfg, chunk_steps = self.cfg, self.decode_chunk
        cache_len = self.max_len
        paged, ps = self._paged, self.page_size
        if paged:
            view = kvp.view_len(self.max_len, ps)

        def sample_fn(keys, n, lg, temps, topks, topps):
            """Per-slot temperature + top-k + top-p sampling; slots with
            temp <= 0 fall back to argmax (mixed batches share one compiled
            chunk).  Both truncations are computed on the temperature-
            scaled logits and intersected.  The nucleus pass only compiles
            in when some request in the run actually set top_p (use_topp is
            static in the chunk cache key) — runs without it pay nothing."""
            kb = jax.vmap(jax.random.fold_in)(keys, n)
            vocab = lg.shape[-1]

            def draw(k, l, tmp, tk, tp):
                scaled = l / jnp.maximum(tmp, 1e-6)
                srt = -jnp.sort(-scaled)                  # descending
                thr_k = srt[jnp.clip(tk - 1, 0, vocab - 1)]
                masked = jnp.where((tk > 0) & (scaled < thr_k),
                                   -jnp.inf, scaled)
                if use_topp:
                    # nucleus: smallest sorted prefix with mass >= tp (a
                    # token is kept iff the mass strictly before it is
                    # < tp, so the top-1 token always survives)
                    probs = jax.nn.softmax(srt)
                    cum = jnp.cumsum(probs)
                    kcnt = jnp.clip(jnp.sum(((cum - probs) < tp)
                                            .astype(jnp.int32)), 1, vocab)
                    thr_p = srt[kcnt - 1]
                    masked = jnp.where((tp > 0.0) & (tp < 1.0)
                                       & (scaled < thr_p), -jnp.inf, masked)
                return jax.random.categorical(k, masked).astype(jnp.int32)

            sampled = jax.vmap(draw)(kb, lg, temps, topks, topps)
            return jnp.where(temps > 0.0, sampled,
                             jnp.argmax(lg, axis=-1).astype(jnp.int32))

        def chunk(params, caches, page_table, astate, tok, pos, active, n,
                  limit, buf, keys, temps, topks, topps):
            def cond(c):
                return (c[0] < chunk_steps) & jnp.any(c[6])

            def body(c):
                t, caches, page_table, astate, tok, pos, active, n, buf = c
                if paged:
                    # grow pages in-loop: a slot writing the first row of a
                    # new page pops one from the free list (admission
                    # reserved the worst case, so the pop cannot fail)
                    needs = active & (pos % ps == 0)
                    astate, pid, ok = kvp.alloc_masked(astate, needs)
                    bidx = jnp.arange(slots, dtype=jnp.int32)
                    pj = jnp.clip(pos // ps, 0, page_table.shape[1] - 1)
                    page_table = page_table.at[bidx, pj].set(
                        jnp.where(ok, pid, page_table[bidx, pj]))
                    caches = transformer.reset_page_slots(caches, cfg,
                                                          pid, ok)
                    # validity = engine positions AND page occupancy
                    kv_valid = (
                        (jnp.arange(view, dtype=jnp.int32)[None, :]
                         <= pos[:, None])
                        & kvp.occupancy(page_table, ps))
                    caches, logits = transformer.lm_decode_step(
                        params, cfg, caches, tok, pos, kv_valid=kv_valid,
                        page_table=page_table)
                else:
                    # slot validity from the engine's per-slot positions,
                    # built ONCE per step and shared by every attention
                    # layer (slots fill in position order, so slot j is
                    # live iff j <= pos; ring-buffer SWA layers recompute
                    # their own window mask)
                    kv_valid = (jnp.arange(cache_len,
                                           dtype=jnp.int32)[None, :]
                                <= pos[:, None])
                    caches, logits = transformer.lm_decode_step(
                        params, cfg, caches, tok, pos, kv_valid=kv_valid)
                lg = logits[:, -1].astype(jnp.float32)          # (B, V)
                if greedy:
                    nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                else:
                    nxt = sample_fn(keys, n, lg, temps, topks, topps)
                bidx = jnp.arange(slots, dtype=jnp.int32)
                col = jnp.clip(n, 0, max_gen - 1)
                buf = buf.at[bidx, col].set(
                    jnp.where(active, nxt, buf[bidx, col]))
                step = active.astype(jnp.int32)
                n = n + step
                pos = pos + step
                done = n >= limit
                if eos_id is not None:
                    done |= nxt == eos_id
                tok = jnp.where(active, nxt, tok)
                active = active & ~done
                return (t + 1, caches, page_table, astate, tok, pos,
                        active, n, buf)

            (t, caches, page_table, astate, tok, pos, active, n,
             buf) = jax.lax.while_loop(
                cond, body,
                (jnp.zeros((), jnp.int32), caches, page_table, astate, tok,
                 pos, active, n, buf))
            return caches, page_table, astate, tok, pos, active, n, buf, t

        if self._use_jit:
            chunk = jax.jit(chunk, donate_argnums=(1, 2, 3))
        self._chunk_cache[key] = chunk
        return chunk

    # ---------------------------------------------------------- scheduler
    def run(self, requests: Sequence[Request], *, temperature: float = 0.0,
            key: Optional[jax.Array] = None,
            eos_id: Any = "engine-default") -> List[Completion]:
        """Serve `requests` (any count vs. `num_slots`) to completion.

        Returns completions in request order; wall-clock split is left in
        `self.last_stats`."""
        cfg = self.cfg
        if cfg.family == "audio":
            raise NotImplementedError(
                "continuous batching covers decoder-only LMs; use "
                "generate() for the enc-dec audio family")
        if eos_id == "engine-default":
            eos_id = self.eos_id
        uids = [r.uid for r in requests]
        if len(set(uids)) != len(uids):
            raise ValueError("duplicate request uids")
        frontend = cfg.frontend_tokens if cfg.frontend else 0
        ps = self.page_size

        def pages_ws(r: Request) -> int:
            """Worst-case pages this request can ever hold: one per page of
            rows [0, prompt_end + max_new - 1) — the last decode write
            lands at position prompt_end + max_new - 2."""
            rows = frontend + len(r.tokens) + r.max_new_tokens - 1
            return kvp.num_pages(max(1, rows), ps)

        for r in requests:
            if r.max_new_tokens < 1:
                raise ValueError(f"request {r.uid}: max_new_tokens < 1")
            if frontend and r.frontend_embeds is None:
                raise ValueError(
                    f"request {r.uid}: {cfg.name} has a {cfg.frontend} "
                    f"frontend; frontend_embeds is required")
            need = frontend + len(r.tokens) + r.max_new_tokens
            if need > self.max_len:
                raise ValueError(
                    f"request {r.uid} needs {need} positions > "
                    f"max_len={self.max_len}")
            if self._paged and pages_ws(r) > self.kv_pages:
                raise ValueError(
                    f"request {r.uid} needs {pages_ws(r)} KV pages > "
                    f"pool size {self.kv_pages}")

        slots = self.num_slots
        eff_temp = {r.uid: (temperature if r.temperature is None
                            else r.temperature) for r in requests}
        sampling = key is not None and any(t > 0.0 for t in eff_temp.values())
        greedy = not sampling
        base_key = key if key is not None else jax.random.PRNGKey(0)
        max_gen = max((r.max_new_tokens for r in requests), default=1)
        stats = ServeStats(page_size=ps, kv_pages_total=self.kv_pages)
        queue: List[Request] = list(requests)
        completions: Dict[int, Completion] = {}

        caches = transformer.init_caches(
            cfg, slots, self.max_len,
            kv_pages=self.kv_pages if self._paged else None)
        if self._paged:
            page_table = kvp.init_page_table(slots, self.max_pages_per_slot)
            astate = kvp.init_state(self.kv_pages)
        else:                       # inert placeholders riding the carry
            page_table = kvp.init_page_table(slots, 1)
            astate = kvp.init_state(1)
        reserved = 0                            # host-side page accounting
        slot_ws = [0] * slots
        tok = np.zeros(slots, np.int32)
        pos = np.zeros(slots, np.int32)
        active = np.zeros(slots, bool)
        n_gen = np.zeros(slots, np.int32)
        limit = np.ones(slots, np.int32)
        buf = np.zeros((slots, max_gen), np.int32)
        keys = np.zeros((slots, 2), np.uint32)
        temps = np.zeros(slots, np.float32)
        topks = np.zeros(slots, np.int32)
        topps = np.zeros(slots, np.float32)
        slot_req: List[Optional[Request]] = [None] * slots
        use_topp = sampling and any(0.0 < r.top_p < 1.0 for r in requests)
        chunk_fn = self._get_chunk(slots, max_gen, greedy, eos_id, use_topp)
        ragged_ok = self._ragged_batchable()
        t_run0 = time.perf_counter()

        def retire(b: int):
            nonlocal astate, page_table, reserved
            r = slot_req[b]
            toks = buf[b, :n_gen[b]].tolist()
            reason = ("eos" if eos_id is not None and toks
                      and toks[-1] == eos_id else "length")
            completions[r.uid] = Completion(
                uid=r.uid, tokens=toks, finish_reason=reason,
                prompt_len=len(r.tokens))
            slot_req[b] = None
            active[b] = False
            stats.completed += 1
            if self._paged:
                astate, page_table = self._free_slot(astate, page_table,
                                                     jnp.int32(b))
                reserved -= slot_ws[b]
                slot_ws[b] = 0

        def track_peak():
            if self._paged:
                used = self.kv_pages - int(jax.device_get(astate["top"]))
                stats.kv_pages_peak = max(stats.kv_pages_peak, used)

        def form_group(stalled_seen: set) -> List[Request]:
            """Scan the queue IN ORDER for the next admission group: up to
            prefill_batch requests that have a free slot and (paged) a
            worst-case page reservation.  A request that does not fit the
            page pool is counted as a stall (once per scheduling iteration
            — `stalled_seen` dedups across the admission loop's passes)
            and SKIPPED — it must not head-of-line-block later rows that
            do fit; it is retried every iteration and admits once retiring
            slots release their reservations.  Non-ragged-batchable stacks
            (rec/ssd states, SWA rings) group equal-length rows only (no
            right-padding).  With overlap enabled and decodes in flight,
            the group is bounded by the prefill token budget (always >= 1
            request, so admission cannot starve)."""
            free = sum(1 for s in slot_req if s is None)
            if not free or not queue:
                return []
            budget = None
            if self.prefill_decode_ratio > 0 and active.any():
                budget = max(1, int(self.prefill_decode_ratio
                                    * self.decode_chunk
                                    * int(active.sum())))
            group: List[Request] = []
            picked: List[int] = []
            group_ws = group_tokens = 0
            for qi, r in enumerate(queue):
                if len(group) == min(free, self.prefill_batch):
                    break
                if (budget is not None and group
                        and group_tokens + len(r.tokens) > budget):
                    break
                if (not ragged_ok and group
                        and len(r.tokens) != len(group[0].tokens)):
                    continue
                if (self._paged
                        and pages_ws(r) > self.kv_pages - reserved
                        - group_ws):
                    if r.uid not in stalled_seen:
                        stalled_seen.add(r.uid)
                        stats.admission_stalls += 1
                    continue
                group.append(r)
                picked.append(qi)
                group_ws += pages_ws(r) if self._paged else 0
                group_tokens += len(r.tokens)
            for qi in reversed(picked):
                del queue[qi]
            return group

        def admit(group: List[Request]):
            """ONE batched prefill + ONE jit scatter (and, paged, ONE page
            allocation) admits the whole group — the serial engine paid a
            host round-trip per request."""
            nonlocal caches, page_table, astate, reserved
            t0 = time.perf_counter()
            rows, logits, bpb = self._prefill_group(group)
            slot_vec = np.full(bpb, -1, np.int32)   # -1 rows: dummies, drop
            assigned: List[int] = []
            for i, r in enumerate(group):
                b = next(j for j, s in enumerate(slot_req) if s is None)
                slot_req[b] = r
                assigned.append(b)
                slot_vec[i] = b
            if self._paged:
                npages = np.zeros(bpb, np.int32)
                for i, r in enumerate(group):
                    reserved += pages_ws(r)
                    slot_ws[assigned[i]] = pages_ws(r)
                    npages[i] = kvp.num_pages(frontend + len(r.tokens), ps)
                astate, page_table = self._alloc_rows(
                    astate, page_table, jnp.asarray(slot_vec),
                    jnp.asarray(npages))
                caches = self._write_rows(caches, rows,
                                          jnp.asarray(slot_vec), page_table)
            else:
                caches = self._write_rows(caches, rows,
                                          jnp.asarray(slot_vec))
            logits = jax.block_until_ready(logits)
            jax.block_until_ready(caches)
            now = time.perf_counter()
            stats.prefill_s += now - t0
            ttft = now - t_run0
            stats.ttft_s_sum += ttft * len(group)
            stats.ttft_s_max = max(stats.ttft_s_max, ttft)
            stats.prefill_batches += 1
            stats.prefill_tokens += sum(len(r.tokens) for r in group)
            stats.admitted += len(group)
            for i, r in enumerate(group):
                b = assigned[i]
                lg = np.asarray(logits[i, -1], np.float32)
                skey = jax.random.fold_in(base_key, r.uid)
                t_r = eff_temp[r.uid]
                if greedy or t_r <= 0.0:
                    first = int(lg.argmax())
                else:
                    scaled = lg / max(t_r, 1e-6)
                    if r.top_k > 0:
                        thr = np.sort(scaled)[::-1][
                            min(r.top_k, scaled.size) - 1]
                        scaled = np.where(scaled < thr, -np.inf, scaled)
                    if 0.0 < r.top_p < 1.0:
                        srt = np.sort(lg / max(t_r, 1e-6))[::-1]
                        e = np.exp(srt - srt[0])
                        probs = e / e.sum()
                        cum = np.cumsum(probs)
                        kcnt = max(1, int(((cum - probs)
                                           < r.top_p).sum()))
                        scaled = np.where(scaled < srt[kcnt - 1],
                                          -np.inf, scaled)
                    first = int(jax.random.categorical(
                        jax.random.fold_in(skey, 0), jnp.asarray(scaled)))
                keys[b] = np.asarray(skey, np.uint32)
                temps[b] = t_r
                topks[b] = r.top_k
                topps[b] = r.top_p
                tok[b] = first
                pos[b] = frontend + len(r.tokens)
                n_gen[b] = 1
                limit[b] = r.max_new_tokens
                buf[b] = 0
                buf[b, 0] = first
                done_now = (r.max_new_tokens <= 1
                            or (eos_id is not None and first == eos_id))
                active[b] = not done_now
                if done_now:
                    retire(b)

        while queue or any(s is not None for s in slot_req):
            # -------- admission: batched-prefill groups, interleaved with
            # decode chunks under the overlap budget instead of pausing
            # decode until every free slot is filled
            stalled_seen: set = set()
            while True:
                group = form_group(stalled_seen)
                if not group:
                    break
                admit(group)
                if self.prefill_decode_ratio > 0 and active.any():
                    break       # overlap: hand control back to decode
            track_peak()
            if not active.any():
                continue            # all admitted work finished; drain queue
            # -------- one decode chunk (compiled once per shape)
            t0 = time.perf_counter()
            out = chunk_fn(self.params, caches, page_table, astate,
                           jnp.asarray(tok), jnp.asarray(pos),
                           jnp.asarray(active), jnp.asarray(n_gen),
                           jnp.asarray(limit), jnp.asarray(buf),
                           jnp.asarray(keys), jnp.asarray(temps),
                           jnp.asarray(topks), jnp.asarray(topps))
            out = jax.block_until_ready(out)
            (caches, page_table, astate, tok_d, pos_d, act_d, n_d, buf_d,
             steps) = out
            stats.decode_s += time.perf_counter() - t0
            track_peak()
            prev_total = int(n_gen.sum())
            # writable host mirrors (np.asarray of a jax array is read-only)
            tok = np.array(tok_d)
            pos = np.array(pos_d)
            act_new = np.array(act_d)
            n_gen = np.array(n_d)
            buf = np.array(buf_d)
            stats.decode_steps += int(steps)
            stats.decode_tokens += int(n_gen.sum()) - prev_total
            # -------- retire slots that finished inside the chunk
            for b in range(slots):
                if slot_req[b] is not None and active[b] and not act_new[b]:
                    active[b] = False
                    retire(b)
            active = act_new

        self.last_stats = stats
        return [completions[r.uid] for r in requests]

    # ------------------------------------------------------------- legacy
    def generate(self, batch: Dict[str, jax.Array], steps: int,
                 temperature: float = 0.0,
                 key: Optional[jax.Array] = None) -> GenerationResult:
        """Fixed-batch generation (legacy API).  Greedy LM decoding runs on
        the continuous-batching engine; the enc-dec audio family,
        temperature sampling (its key schedule is batch-shaped and is
        preserved bit-for-bit), and rolling-cache workloads where
        prompt + steps exceed max_len keep the original per-token loop."""
        frontend = (self.cfg.frontend_tokens
                    if self.cfg.frontend and self.cfg.family != "audio" else 0)
        need = frontend + batch["tokens"].shape[1] + steps
        if (self.cfg.family == "audio"
                or (temperature > 0.0 and key is not None)
                or need > self.max_len):
            return self._generate_per_token(batch, steps, temperature, key)
        rows = np.asarray(batch["tokens"])
        fes = batch.get("frontend_embeds")
        reqs = [Request(uid=i, tokens=rows[i].tolist(), max_new_tokens=steps,
                        frontend_embeds=None if fes is None else fes[i])
                for i in range(rows.shape[0])]
        outs = self.run(reqs, temperature=0.0, eos_id=None)
        return GenerationResult(tokens=[c.tokens for c in outs], steps=steps)

    def _generate_per_token(self, batch, steps, temperature, key):
        caches, logits = self._prefill(self.params, batch)
        pos0 = batch["tokens"].shape[1]
        if self.cfg.frontend and self.cfg.family != "audio":
            pos0 += self.cfg.frontend_tokens
        outs = []
        tok = self._sample(logits[:, -1], temperature, key, 0)
        outs.append(tok)
        for t in range(1, steps):
            caches, logits = self._decode(
                self.params, caches, tok, jnp.asarray(pos0 + t - 1, jnp.int32))
            tok = self._sample(logits[:, -1], temperature, key, t)
            outs.append(tok)
        toks = jnp.stack(outs, axis=1)
        return GenerationResult(tokens=toks.tolist(), steps=steps)

    def _sample(self, logits, temperature, key, t):
        if temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        k = jax.random.fold_in(key, t)
        return jax.random.categorical(
            k, logits.astype(jnp.float32) / temperature, axis=-1
        ).astype(jnp.int32)
