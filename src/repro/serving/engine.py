"""Serving: prefill + decode step builders and a batched-request engine.

serve_step semantics for the dry-run cells:
  prefill_32k  — lower `prefill_step` over (B, S) prompts
  decode_32k / long_500k — lower `decode_step`: one new token per sequence
                 against a KV cache of seq_len (the cache is a donated input)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer


def build_prefill_step(cfg: ModelConfig, max_len: int) -> Callable:
    if cfg.family == "audio":
        def prefill(params, batch):
            return encdec.encdec_prefill(params, cfg, batch, max_len)
    else:
        def prefill(params, batch):
            return transformer.lm_prefill(params, cfg, batch, max_len)
    return prefill


def build_decode_step(cfg: ModelConfig) -> Callable:
    if cfg.family == "audio":
        def decode(params, caches, token, pos):
            return encdec.encdec_decode_step(params, cfg, caches, token, pos)
    else:
        def decode(params, caches, token, pos):
            return transformer.lm_decode_step(params, cfg, caches, token, pos)
    return decode


def abstract_decode_caches(cfg: ModelConfig, batch: int, cache_len: int):
    if cfg.family == "audio":
        fn = lambda: encdec.init_dec_caches(cfg, batch, cache_len,
                                            cfg.frontend_tokens)
    else:
        fn = lambda: transformer.init_caches(cfg, batch, cache_len)
    shapes = jax.eval_shape(fn)
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), shapes)


def decode_cache_axes(cfg: ModelConfig):
    if cfg.family == "audio":
        return encdec.cache_axes(cfg)
    return transformer.cache_axes(cfg)


@dataclasses.dataclass
class GenerationResult:
    tokens: List[List[int]]
    steps: int


class Engine:
    """Minimal batched serving engine: greedy/temperature sampling over a
    fixed slot batch; used by examples/serve_batch.py and the benchmarks."""

    def __init__(self, cfg: ModelConfig, params: dict, max_len: int = 512,
                 jit: bool = True):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = build_prefill_step(cfg, max_len)
        self._decode = build_decode_step(cfg)
        if jit:
            self._prefill = jax.jit(self._prefill)
            self._decode = jax.jit(self._decode, donate_argnums=(1,))

    def generate(self, batch: Dict[str, jax.Array], steps: int,
                 temperature: float = 0.0,
                 key: Optional[jax.Array] = None) -> GenerationResult:
        caches, logits = self._prefill(self.params, batch)
        pos0 = batch["tokens"].shape[1]
        if self.cfg.frontend and self.cfg.family != "audio":
            pos0 += self.cfg.frontend_tokens
        outs = []
        tok = self._sample(logits[:, -1], temperature, key, 0)
        outs.append(tok)
        for t in range(1, steps):
            caches, logits = self._decode(
                self.params, caches, tok, jnp.asarray(pos0 + t - 1, jnp.int32))
            tok = self._sample(logits[:, -1], temperature, key, t)
            outs.append(tok)
        toks = jnp.stack(outs, axis=1)
        return GenerationResult(tokens=toks.tolist(), steps=steps)

    def _sample(self, logits, temperature, key, t):
        if temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        k = jax.random.fold_in(key, t)
        return jax.random.categorical(
            k, logits.astype(jnp.float32) / temperature, axis=-1
        ).astype(jnp.int32)
