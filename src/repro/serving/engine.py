"""Serving: prefill/decode step builders and a continuous-batching engine.

serve_step semantics for the dry-run cells:
  prefill_32k  — lower `prefill_step` over (B, S) prompts
  decode_32k / long_500k — lower `decode_step`: one new token per sequence
                 against a KV cache of seq_len (the cache is a donated input)

The `Engine` runs **continuous batching** over a fixed number of decode
slots (vLLM-style, in JAX):

  * requests queue up and are admitted into free slots as they open;
  * admission is **disaggregated and batched**: up to `prefill_batch`
    queued requests drain through ONE batched ragged prefill call — rows
    are right-padded to a joint (Bp, S) power-of-2 bucket (per-row lengths
    are threaded into sparse-MHA top-L budgets and routed-FFN/MoE dispatch
    capacities, so every row's output is identical to a batch-1
    exact-length prefill) and ALL resulting cache rows scatter into their
    slots in one jit call (one page allocation + one page-wise scatter in
    the paged layout) instead of one host round-trip per admission; the
    scatter replaces whole rows, which doubles as slot recycling.
    Non-right-paddable stacks (recurrent/SSM states, SWA rings) batch
    equal-length rows only;
  * with `prefill_decode_ratio > 0` the scheduler **overlaps** admission
    with decode: while decodes are in flight, each scheduling iteration
    admits at most ratio * decode_chunk * active_slots prompt tokens
    before running the next decode chunk, so a burst of arrivals no
    longer pauses every in-flight generation until the queue drains;
    `ServeStats` reports time-to-first-token and prefill-batch occupancy
    so the overlap is measurable;
  * admission never head-of-line-blocks on the page pool: a request whose
    worst case does not fit is counted as a stall and skipped, while
    later requests that do fit are admitted (the stalled one retries
    every iteration);
  * decode runs in jit-compiled `lax.while_loop` chunks with per-slot
    positions, so the whole generation traces ONCE instead of per token;
    the loop exits a chunk early when every slot has finished;
  * each decode step lowers through the fused Pallas kernel paths when
    the config selects them (`core/dispatch.py`): sparse-MHA decode
    attention, and the routed-FFN block-gather kernel — at (B, 1, d)
    the latter indexes weight blocks by the scalar-prefetched top-G'
    choices directly, so no (B, G, C, d) dispatch buffer is built and
    the router's softmax/load-balance aux is skipped (inference mode);
  * slots retire on EOS or on their per-request token budget, freeing the
    slot for the next queued request;
  * with `SPTConfig.kv_layout="paged"` the attention caches are pools of
    fixed-size pages shared across slots (serving/kv_pages.py): admission
    requires a free slot AND pages for the request's worst case, pages
    grow on demand *inside* the compiled chunk (pure allocator state in
    the while_loop carry), and retirement frees them — so short requests
    no longer pin max_len-sized strips and long-context max_len stops
    capping the slot count;
  * per-request sampling (Request.temperature / top_k / top_p nucleus
    truncation via a per-slot sorted cumsum) runs inside the chunk via
    per-slot arrays; greedy decoding remains the bit-identical default.

Timing is honest: prefill and decode are accumulated separately with
`block_until_ready` at each boundary, and reported via `ServeStats` so
callers can separate compile/warmup (first run) from steady state.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import dispatch as kdispatch
from repro.models import attention, encdec, ffn, transformer
from repro.serving import kv_pages as kvp
from repro.serving.telemetry import (MetricsSnapshot, Reservoir,
                                     TelemetryRecorder)


def build_prefill_step(cfg: ModelConfig, max_len: int) -> Callable:
    if cfg.family == "audio":
        def prefill(params, batch):
            return encdec.encdec_prefill(params, cfg, batch, max_len)
    else:
        def prefill(params, batch):
            return transformer.lm_prefill(params, cfg, batch, max_len)
    return prefill


def build_decode_step(cfg: ModelConfig) -> Callable:
    if cfg.family == "audio":
        def decode(params, caches, token, pos):
            return encdec.encdec_decode_step(params, cfg, caches, token, pos)
    else:
        def decode(params, caches, token, pos):
            return transformer.lm_decode_step(params, cfg, caches, token, pos)
    return decode


def abstract_decode_caches(cfg: ModelConfig, batch: int, cache_len: int,
                           kv_pages: Optional[int] = None):
    if cfg.family == "audio":
        fn = lambda: encdec.init_dec_caches(cfg, batch, cache_len,
                                            cfg.frontend_tokens)
    else:
        fn = lambda: transformer.init_caches(cfg, batch, cache_len,
                                             kv_pages=kv_pages)
    shapes = jax.eval_shape(fn)
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), shapes)


def decode_cache_axes(cfg: ModelConfig, kv_paged: bool = False):
    if cfg.family == "audio":
        return encdec.cache_axes(cfg)
    return transformer.cache_axes(cfg, kv_paged=kv_paged)


# Donated positions of the compiled decode chunk
# chunk(params, caches, page_table, astate, tok, pos, active, n, limit,
#       buf, keys, temps, topks, topps): everything the chunk returns
# updated with an identical aval — caches/page_table/astate plus the
# per-slot decode state (tok, pos, active, n_gen, buf).  The scheduler
# passes fresh device arrays from its numpy mirrors each call and copies
# the outputs back, so the donated buffers are never re-read on the
# host.  limit/keys/temps/topks/topps are read-only inputs (not chunk
# outputs) and must NOT be donated — XLA would warn and silently copy.
# analysis/liveness.py and analysis/donation.py key on this constant, so
# the audit and the jit site cannot drift apart.
CHUNK_DONATE_ARGNUMS = (1, 2, 3, 4, 5, 6, 7, 9)


# ---------------------------------------------------------------- arrivals
class ManualClock:
    """Deterministic serve clock: ``clock()`` reads virtual time, and the
    serve loop calls ``advance()`` once per scheduling iteration.  The
    chaos/robustness suites drive arrivals, deadlines, and preemption off
    this clock so every run is a pure function of the seed — no wall-clock
    flake.  Production serving uses the default wall clock instead."""

    def __init__(self, dt: float = 1.0):
        self.now = 0.0
        self.dt = float(dt)

    def __call__(self) -> float:
        return self.now

    def advance(self) -> None:
        self.now += self.dt


class ArrivalSchedule:
    """An arrival process feeding ``Engine.serve``: (t_s, Request) events
    in time order, popped as the serve clock passes each arrival time.

    Build one from a Poisson process (``poisson``), an explicit trace
    (``from_trace``), or an all-at-t=0 burst (``burst`` — equivalent to
    the legacy ``Engine.run`` workload)."""

    def __init__(self, events: Sequence[Tuple[float, Request]]):
        self._events = sorted(events, key=lambda e: e[0])      # stable
        self._i = 0

    @classmethod
    def burst(cls, requests: Sequence[Request],
              at: float = 0.0) -> "ArrivalSchedule":
        return cls([(at, r) for r in requests])

    @classmethod
    def poisson(cls, requests: Sequence[Request], rate_qps: float,
                seed: int = 0) -> "ArrivalSchedule":
        """Seeded Poisson arrivals at ``rate_qps`` mean offered load."""
        rng = np.random.default_rng(seed)
        t, events = 0.0, []
        for r in requests:
            t += float(rng.exponential(1.0 / max(rate_qps, 1e-9)))
            events.append((t, r))
        return cls(events)

    @classmethod
    def from_trace(cls, pairs: Sequence[Tuple[float, Request]]
                   ) -> "ArrivalSchedule":
        return cls(list(pairs))

    @property
    def exhausted(self) -> bool:
        return self._i >= len(self._events)

    def next_time(self) -> Optional[float]:
        return None if self.exhausted else self._events[self._i][0]

    def due(self, now: float) -> List[Request]:
        out = []
        while (self._i < len(self._events)
               and self._events[self._i][0] <= now):
            out.append(self._events[self._i][1])
            self._i += 1
        return out


# ---------------------------------------------------------------- requests
@dataclasses.dataclass
class Request:
    """One generation request for the continuous-batching engine."""
    uid: int
    tokens: Sequence[int]                  # prompt token ids
    max_new_tokens: int = 16
    frontend_embeds: Optional[Any] = None  # (F, d) for VLM-style frontends
    # per-request sampling (applied inside the compiled decode chunk):
    # temperature None = inherit run()'s temperature; <= 0 = greedy.
    # top_k 0 = no truncation; 1 = deterministic argmax sampling.
    # top_p in (0, 1) keeps the smallest nucleus with that much probability
    # mass (0 or >= 1 = off); composes with top_k (intersection).
    temperature: Optional[float] = None
    top_k: int = 0
    top_p: float = 0.0
    # long-lived serving (Engine.serve):
    # priority — higher admits first; under slot/page pressure a queued
    #   request may preempt a strictly-lower-priority running one.
    # deadline_s — TTFT target in serve-clock seconds after arrival; a
    #   queued request whose deadline lapses before its first token is
    #   shed (finish_reason="shed") instead of occupying the queue, and a
    #   deadline at >= 50% of its budget makes the request "urgent"
    #   (may preempt deadline-free peers of equal priority).
    # on_token — per-token streaming callback (uid, token_id, done),
    #   called from the host scheduler as tokens leave each decode chunk.
    priority: int = 0
    deadline_s: Optional[float] = None
    on_token: Optional[Callable[[int, int, bool], None]] = None


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: List[int]                      # generated ids (EOS included)
    finish_reason: str     # "eos"|"length"|"rejected"|"cancelled"|"shed"
    prompt_len: int
    detail: str = ""                       # reject/shed reason, else ""
    preemptions: int = 0                   # evict+resume count for this uid


@dataclasses.dataclass
class ServeStats:
    """Wall-clock split of one `Engine.run` (block_until_ready-bounded)."""
    prefill_s: float = 0.0
    decode_s: float = 0.0
    prefill_tokens: int = 0                # prompt tokens processed
    decode_tokens: int = 0                 # tokens produced by decode steps
    decode_steps: int = 0                  # batch-wide while_loop trips
    admitted: int = 0
    completed: int = 0
    # disaggregated batched prefill
    prefill_batches: int = 0               # batched prefill calls issued
    ttft_s_sum: float = 0.0                # sum over admitted requests of
    ttft_s_max: float = 0.0                # (first token ready - run start)
    # long-lived serving (zeros for plain burst runs)
    submitted: int = 0                     # requests offered (incl. rejects)
    preemptions: int = 0                   # slot evictions under pressure
    rejections: int = 0                    # invalid requests isolated
    cancelled: int = 0                     # cancel() mid-queue/mid-stream
    shed: int = 0                          # TTFT deadline lapsed in queue
    # per-request latency samples: bounded reservoirs (Algorithm R,
    # deterministic seeds) so week-long serve() runs don't grow host
    # memory — the mean stays exact, percentiles carry sampling error
    # only past the cap (serving/telemetry.py)
    ttft_samples: Reservoir = dataclasses.field(
        default_factory=lambda: Reservoir(cap=2048, seed=17))
    tpot_samples: Reservoir = dataclasses.field(
        default_factory=lambda: Reservoir(cap=2048, seed=29))
    # paged KV cache (zeros when kv_layout="contiguous")
    page_size: int = 0
    kv_pages_total: int = 0                # pool capacity in pages
    kv_pages_peak: int = 0                 # peak pages in use
    admission_stalls: int = 0              # free slot but no pages
    # device-counter aggregates (keep_rate, expert_load_imbalance, ...)
    # merged in by the telemetry recorder — empty when telemetry is off,
    # so as_dict stays byte-identical to the pre-telemetry engine
    device: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def prefill_tok_s(self) -> float:
        return self.prefill_tokens / self.prefill_s if self.prefill_s else 0.0

    @property
    def decode_tok_s(self) -> float:
        return self.decode_tokens / self.decode_s if self.decode_s else 0.0

    @property
    def ttft_avg_s(self) -> float:
        """Mean time-to-first-token (the first token comes out of prefill,
        so this is prefill latency + any queueing behind earlier groups).
        Exact over every sample seen, not just the retained reservoir."""
        return self.ttft_samples.mean

    @staticmethod
    def _pctl(xs, q: float) -> float:
        vals = xs.values if isinstance(xs, Reservoir) else list(xs)
        return float(np.percentile(np.array(vals), q)) if vals else 0.0

    @property
    def ttft_p50_s(self) -> float:
        return self._pctl(self.ttft_samples, 50)

    @property
    def ttft_p99_s(self) -> float:
        return self._pctl(self.ttft_samples, 99)

    @property
    def tpot_p50_s(self) -> float:
        """Median per-request time-per-output-token (completion wall time
        after the first token, over tokens generated after it)."""
        return self._pctl(self.tpot_samples, 50)

    @property
    def tpot_p99_s(self) -> float:
        return self._pctl(self.tpot_samples, 99)

    @property
    def prefill_batch_occupancy(self) -> float:
        """Mean admitted rows per batched prefill call (1.0 == the old
        serial batch-1 admission)."""
        return (self.admitted / self.prefill_batches
                if self.prefill_batches else 0.0)

    # as_dict key order the benchmarks/tests have always consumed —
    # snapshot() keys not in this tuple (device-counter aggregates)
    # append after it, sorted
    LEGACY_ORDER = (
        "prefill_s", "decode_s", "prefill_tokens", "decode_tokens",
        "decode_steps", "prefill_tok_s", "decode_tok_s", "admitted",
        "completed", "prefill_batches", "prefill_batch_occupancy",
        "ttft_avg_s", "ttft_max_s", "ttft_p50_s", "ttft_p99_s",
        "tpot_p50_s", "tpot_p99_s", "preemptions", "rejections",
        "cancelled", "shed", "page_size", "kv_pages_total",
        "kv_pages_peak", "admission_stalls")

    def snapshot(self) -> MetricsSnapshot:
        """Point-in-time counters/gauges/histograms view — what as_dict
        flattens, what the chaos watchdog dumps on invariant failures."""
        counters: Dict[str, float] = {
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "decode_steps": self.decode_steps,
            "admitted": self.admitted, "completed": self.completed,
            "prefill_batches": self.prefill_batches,
            "preemptions": self.preemptions,
            "rejections": self.rejections,
            "cancelled": self.cancelled, "shed": self.shed}
        gauges: Dict[str, float] = {
            "prefill_s": round(self.prefill_s, 4),
            "decode_s": round(self.decode_s, 4),
            "prefill_tok_s": round(self.prefill_tok_s, 1),
            "decode_tok_s": round(self.decode_tok_s, 1),
            "prefill_batch_occupancy": round(
                self.prefill_batch_occupancy, 2)}
        if self.kv_pages_total:
            gauges.update(page_size=self.page_size,
                          kv_pages_total=self.kv_pages_total,
                          kv_pages_peak=self.kv_pages_peak,
                          admission_stalls=self.admission_stalls)
        hists = {
            "ttft": {"avg_s": round(self.ttft_avg_s, 4),
                     "max_s": round(self.ttft_s_max, 4),
                     "p50_s": round(self.ttft_p50_s, 4),
                     "p99_s": round(self.ttft_p99_s, 4)},
            "tpot": {"p50_s": round(self.tpot_p50_s, 5),
                     "p99_s": round(self.tpot_p99_s, 5)}}
        counters.update(self.device)
        return MetricsSnapshot(counters=counters, gauges=gauges,
                               histograms=hists,
                               legacy_order=self.LEGACY_ORDER)

    def as_dict(self) -> Dict[str, float]:
        return self.snapshot().as_dict()


@dataclasses.dataclass
class GenerationResult:
    tokens: List[List[int]]
    steps: int


# ------------------------------------------------------- scheduler state
@dataclasses.dataclass
class _QItem:
    """A request's live scheduling record: queued, running in a slot, or
    re-queued after preemption (``done`` holds the tokens generated before
    eviction; re-admission recomputes their KV via the batched ragged
    prefill and forces the last one as the resume token, so the stream
    continues bit-identically)."""
    req: Request
    order: int                             # submission order (stable key)
    arrival_s: float                       # serve-clock arrival time
    temp: float                            # resolved sampling temperature
    done: List[int] = dataclasses.field(default_factory=list)
    arrival_wall: float = 0.0              # wall clock at submit (TTFT base)
    first_tok_wall: Optional[float] = None
    preemptions: int = 0

    def prefill_tokens(self) -> List[int]:
        """Tokens to (re)compute through prefill: the prompt, plus — when
        resuming — every generated token except the last (which becomes
        the pending decode input, exactly like a fresh admission's
        prefill-sampled first token)."""
        if self.done:
            return list(self.req.tokens) + self.done[:-1]
        return list(self.req.tokens)


@dataclasses.dataclass
class _SchedState:
    """Mutable state of one serve()/run() — held on ``Engine._live`` so
    submit()/cancel()/preempt() and the chaos watchdog can reach it
    mid-loop."""
    stats: ServeStats
    clock: Callable[[], float]
    eos_id: Optional[int]
    greedy: bool
    use_topp: bool
    base_key: jax.Array
    max_gen: int
    caches: Any
    page_table: Any
    astate: Any
    reserved: int
    slot_ws: List[int]
    tok: Any
    pos: Any
    active: Any
    n_gen: Any
    limit: Any
    buf: Any
    keys: Any
    temps: Any
    topks: Any
    topps: Any
    slot_item: List[Optional[_QItem]]
    queue: List[_QItem]
    results: Dict[int, Completion]
    seen_uids: set
    default_temp: float
    order: int = 0
    iteration: int = 0
    t0_wall: float = 0.0


def _queue_key(it: _QItem) -> Tuple[int, int]:
    """Admission order: priority descending, then submission order (a
    preempted request keeps its original order, so it re-admits ahead of
    later arrivals of its priority class)."""
    return (-it.req.priority, it.order)


# ---------------------------------------------------------------- engine
class Engine:
    """Continuous-batching serving engine over `num_slots` decode slots.

    `run(requests)` is the native API (queue admission, EOS/budget exits,
    ragged prompts).  `generate(batch, steps)` keeps the legacy fixed-batch
    API used by the benchmarks and system tests; for greedy decoding it is
    routed through the slot engine, whose outputs are row-for-row identical
    to the old per-token Python loop.
    """

    def __init__(self, cfg: ModelConfig, params: dict, max_len: int = 512,
                 jit: bool = True, *, num_slots: int = 8,
                 eos_id: Optional[int] = None, decode_chunk: int = 16,
                 pad_id: int = 0, kv_pages: Optional[int] = None,
                 prefill_batch: Optional[int] = None,
                 prefill_decode_ratio: float = 0.0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.num_slots = num_slots
        self.eos_id = eos_id
        self.decode_chunk = max(1, decode_chunk)
        self.pad_id = pad_id
        self.last_stats: Optional[ServeStats] = None
        self._use_jit = jit
        # live scheduler state while serve()/run() is on the stack —
        # submit()/cancel()/preempt() and the chaos watchdog read it
        self._live: Optional[_SchedState] = None
        # observability (serving/telemetry.py): "off" adds nothing to the
        # compiled chunk (jaxpr.telemetry-cost audit); "counters" threads
        # the jit-pure device-counter tree through the chunk carry and
        # drains it once per scheduling iteration; "trace" additionally
        # records the host-side request/scheduler event timeline
        self._tel_mode = kdispatch.telemetry_mode(cfg)
        self._tel_counters = kdispatch.use_telemetry_counters(cfg)
        self.recorder: Optional[TelemetryRecorder] = None      # live run
        self.last_recorder: Optional[TelemetryRecorder] = None
        # disaggregated prefill scheduler: up to prefill_batch queued
        # requests drain through ONE batched ragged prefill call per
        # admission group (prefill_batch=1 == the old serial admission).
        # prefill_decode_ratio > 0 interleaves prefill micro-batches with
        # decode chunks instead of filling every free slot first: per
        # scheduling iteration at most ratio * decode_chunk * active_slots
        # prompt tokens are admitted while decodes are in flight (always
        # at least one request, so admission cannot starve); 0 = admit
        # greedily into all free slots before each decode chunk.
        self.prefill_batch = max(1, min(num_slots, num_slots
                                        if prefill_batch is None
                                        else prefill_batch))
        self.prefill_decode_ratio = max(0.0, prefill_decode_ratio)
        # paged KV cache: pool of kv_pages fixed-size pages shared across
        # slots (cfg.spt.kv_layout="paged"); kv_pages=None defaults to the
        # contiguous footprint — pass a smaller pool to serve under a
        # fixed cache-memory budget.
        self._paged = (kdispatch.use_paged_kv(cfg)
                       and transformer.paged_applicable(cfg))
        self.page_size = cfg.spt.kv_page_size if self._paged else 0
        if self._paged:
            self.max_pages_per_slot = kvp.num_pages(max_len, self.page_size)
            self.kv_pages = (num_slots * self.max_pages_per_slot
                             if kv_pages is None else int(kv_pages))
        else:
            self.kv_pages = 0
        # legacy per-token step fns (audio family + sampled generate())
        self._prefill = build_prefill_step(cfg, max_len)
        self._decode = build_decode_step(cfg)
        if jit:
            # no-donate: lm_prefill builds its caches in-jit (no
            # cache-sized operand); batch tokens alias nothing.
            self._prefill = jax.jit(self._prefill)
            self._decode = jax.jit(self._decode, donate_argnums=(1,))
        self._prefill_one: Optional[Callable] = None
        self._chunk_cache: Dict[Any, Callable] = {}
        if self._paged:
            def _ws(caches, rows, slots, page_table):
                return transformer.write_slot_caches_paged_rows(
                    caches, rows, slots, page_table, cfg)
            self._write_rows = (jax.jit(_ws, donate_argnums=(0,))
                                if jit else _ws)
            self._alloc_rows = (
                jax.jit(kvp.alloc_rows_pages, donate_argnums=(0, 1))
                if jit else kvp.alloc_rows_pages)
            self._free_slot = (
                jax.jit(kvp.free_slot_pages, donate_argnums=(0, 1))
                if jit else kvp.free_slot_pages)
        else:
            self._write_rows = (
                jax.jit(transformer.write_slot_caches_rows,
                        donate_argnums=(0,))
                if jit else transformer.write_slot_caches_rows)

    # ------------------------------------------------------------ prefill
    def _pad_invariant(self) -> bool:
        """True when right-padding alone (no per-row length threading)
        provably cannot change real-token outputs.  That requires: a
        pure-attention stack (padding corrupts recurrent states), no
        sliding-window ring cache (padding displaces real KV), dense
        attention (sparse MHA's top-L budget counts the padded keys), and
        dense FFN (routed-FFN/MoE capacity dispatch lets pad tokens compete
        with real ones for slots)."""
        cfg = self.cfg
        return (transformer.supports_ragged_prefill(cfg)
                and cfg.window is None
                and not transformer.length_sensitive(cfg))

    def _ragged_batchable(self) -> bool:
        """True when ragged rows may be right-padded to a common bucket:
        pure-attention stacks without a SWA ring (padding would displace
        real KV from the window-sized ring buffer).  Length-sensitive
        configs (sparse MHA / routed FFN / MoE) stay exact because
        lm_prefill_ragged threads the per-row lengths into selection
        budgets and dispatch capacities.  Everything else (rec/ssd states)
        batches equal-length rows only."""
        return (transformer.supports_ragged_prefill(self.cfg)
                and self.cfg.window is None)

    def _pad_len(self, n: int) -> int:
        """Prompt-length bucket: ragged-batchable configs pad right to a
        power of two (cache slots past the real length are invalidated),
        bounding jit retraces to O(log L); everything else prefills at
        exact length so outputs stay identical to the per-token
        reference."""
        n = max(1, n)
        if not self._ragged_batchable():
            return n
        p = 8
        while p < n:
            p <<= 1
        frontend = self.cfg.frontend_tokens if self.cfg.frontend else 0
        return max(n, min(p, self.max_len - frontend))

    @staticmethod
    def _pad_rows(n: int) -> int:
        """Row-count bucket (power of two), so the (Bp, S) prefill shapes
        stay O(log Bp * log S) and retraces stay bounded."""
        p = 1
        while p < n:
            p <<= 1
        return p

    def _get_prefill(self) -> Callable:
        if self._prefill_one is None:
            cfg, max_len = self.cfg, self.max_len
            tel_on = self._tel_counters

            def fn(params, batch, lengths):
                return transformer.lm_prefill_ragged(
                    params, cfg, batch, lengths, max_len,
                    return_counters=tel_on)
            # no-donate: ragged prefill also inits its cache rows in-jit;
            # tokens/lengths are read-only and alias no output.
            self._prefill_one = jax.jit(fn) if self._use_jit else fn
        return self._prefill_one

    def _prefill_group(self, group: Sequence["_QItem"]):
        """ONE batched ragged prefill over an admission group: rows are
        right-padded to a joint (Bp, S) bucket (dummy rows fill the Bp
        bucket; their results are discarded and their cache rows dropped
        by the scatter).  Resumed (preempted) rows prefill prompt +
        regenerated tokens — the recompute path.  Returns (cache_rows,
        logits (Bpb, 1, V), Bpb, tel-counter tree or None)."""
        cfg = self.cfg
        frontend = cfg.frontend_tokens if cfg.frontend else 0
        rows_toks = [it.prefill_tokens() for it in group]
        p = self._pad_len(max(len(t) for t in rows_toks))
        bpb = self._pad_rows(len(group))
        toks = np.full((bpb, p), self.pad_id, np.int32)
        lens = np.ones(bpb, np.int32)                  # dummies: length 1
        for i, t in enumerate(rows_toks):
            toks[i, :len(t)] = np.asarray(t, np.int32)
            lens[i] = len(t)
        batch = {"tokens": jnp.asarray(toks)}
        if frontend:
            fe = np.zeros((bpb, frontend, cfg.d_model), np.float32)
            for i, it in enumerate(group):
                fe[i] = np.asarray(it.req.frontend_embeds).reshape(
                    frontend, cfg.d_model)
            batch["frontend_embeds"] = jnp.asarray(fe)
        lengths = jnp.asarray(frontend + lens, jnp.int32)
        out = self._get_prefill()(self.params, batch, lengths)
        if self._tel_counters:
            rows, logits, tel = out
        else:
            (rows, logits), tel = out, None
        return rows, logits, bpb, tel

    # ------------------------------------------------------------- decode
    def _counter_shapes(self, slots: int) -> Dict[str, Any]:
        """Abstract tel_* counter tree ONE decode step emits for this
        engine's exact cache layout — derived via eval_shape of the same
        lm_decode_step call the compiled chunk makes, so the chunk carry's
        counter block never drifts from the model's emission."""
        cfg = self.cfg
        caches = abstract_decode_caches(
            cfg, slots, self.max_len,
            kv_pages=self.kv_pages if self._paged else None)
        tok = jax.ShapeDtypeStruct((slots,), jnp.int32)
        pos = jax.ShapeDtypeStruct((slots,), jnp.int32)
        if self._paged:
            view = kvp.view_len(self.max_len, self.page_size)
            kvv = jax.ShapeDtypeStruct((slots, view), jnp.bool_)
            pt = jax.ShapeDtypeStruct(
                (slots, self.max_pages_per_slot), jnp.int32)
            _, _, tel = jax.eval_shape(
                lambda p, c, t, q, m, g: transformer.lm_decode_step(
                    p, cfg, c, t, q, kv_valid=m, page_table=g,
                    return_counters=True),
                self.params, caches, tok, pos, kvv, pt)
        else:
            kvv = jax.ShapeDtypeStruct((slots, self.max_len), jnp.bool_)
            _, _, tel = jax.eval_shape(
                lambda p, c, t, q, m: transformer.lm_decode_step(
                    p, cfg, c, t, q, kv_valid=m, return_counters=True),
                self.params, caches, tok, pos, kvv)
        return tel

    def _get_chunk(self, slots: int, max_gen: int, greedy: bool,
                   eos_id: Optional[int], use_topp: bool = False
                   ) -> Callable:
        key = (slots, max_gen, greedy, eos_id, use_topp)
        fn = self._chunk_cache.get(key)
        if fn is not None:
            return fn
        cfg, chunk_steps = self.cfg, self.decode_chunk
        cache_len = self.max_len
        paged, ps = self._paged, self.page_size
        if paged:
            view = kvp.view_len(self.max_len, ps)
        # telemetry counters ride the while_loop carry (appended at the
        # tuple END so cond's c[0]/c[6] indexing is unchanged) and drain
        # to host ONCE per chunk; tel_off traces the byte-identical
        # pre-telemetry chunk (jaxpr.telemetry-cost audit)
        tel_on = self._tel_counters
        tel_shapes = self._counter_shapes(slots) if tel_on else {}

        def sample_fn(keys, n, lg, temps, topks, topps):
            """Per-slot temperature + top-k + top-p sampling; slots with
            temp <= 0 fall back to argmax (mixed batches share one compiled
            chunk).  Both truncations are computed on the temperature-
            scaled logits and intersected.  The nucleus pass only compiles
            in when some request in the run actually set top_p (use_topp is
            static in the chunk cache key) — runs without it pay nothing."""
            kb = jax.vmap(jax.random.fold_in)(keys, n)
            vocab = lg.shape[-1]

            def draw(k, l, tmp, tk, tp):
                scaled = l / jnp.maximum(tmp, 1e-6)
                srt = -jnp.sort(-scaled)                  # descending
                thr_k = srt[jnp.clip(tk - 1, 0, vocab - 1)]
                masked = jnp.where((tk > 0) & (scaled < thr_k),
                                   -jnp.inf, scaled)
                if use_topp:
                    # nucleus: smallest sorted prefix with mass >= tp (a
                    # token is kept iff the mass strictly before it is
                    # < tp, so the top-1 token always survives)
                    probs = jax.nn.softmax(srt)
                    cum = jnp.cumsum(probs)
                    kcnt = jnp.clip(jnp.sum(((cum - probs) < tp)
                                            .astype(jnp.int32)), 1, vocab)
                    thr_p = srt[kcnt - 1]
                    masked = jnp.where((tp > 0.0) & (tp < 1.0)
                                       & (scaled < thr_p), -jnp.inf, masked)
                return jax.random.categorical(k, masked).astype(jnp.int32)

            sampled = jax.vmap(draw)(kb, lg, temps, topks, topps)
            return jnp.where(temps > 0.0, sampled,
                             jnp.argmax(lg, axis=-1).astype(jnp.int32))

        def chunk(params, caches, page_table, astate, tok, pos, active, n,
                  limit, buf, keys, temps, topks, topps):
            def cond(c):
                return (c[0] < chunk_steps) & jnp.any(c[6])

            def body(c):
                (t, caches, page_table, astate, tok, pos, active, n,
                 buf) = c[:9]
                ctr = c[9] if tel_on else None
                ok = None
                if paged:
                    # grow pages in-loop: a slot writing the first row of a
                    # new page pops one from the free list (admission
                    # reserved the worst case, so the pop cannot fail)
                    needs = active & (pos % ps == 0)
                    astate, pid, ok = kvp.alloc_masked(astate, needs)
                    bidx = jnp.arange(slots, dtype=jnp.int32)
                    pj = jnp.clip(pos // ps, 0, page_table.shape[1] - 1)
                    page_table = page_table.at[bidx, pj].set(
                        jnp.where(ok, pid, page_table[bidx, pj]))
                    caches = transformer.reset_page_slots(caches, cfg,
                                                          pid, ok)
                    # validity = engine positions AND page occupancy
                    kv_valid = (
                        (jnp.arange(view, dtype=jnp.int32)[None, :]
                         <= pos[:, None])
                        & kvp.occupancy(page_table, ps))
                    step_out = transformer.lm_decode_step(
                        params, cfg, caches, tok, pos, kv_valid=kv_valid,
                        page_table=page_table, return_counters=tel_on)
                else:
                    # slot validity from the engine's per-slot positions,
                    # built ONCE per step and shared by every attention
                    # layer (slots fill in position order, so slot j is
                    # live iff j <= pos; ring-buffer SWA layers recompute
                    # their own window mask)
                    kv_valid = (jnp.arange(cache_len,
                                           dtype=jnp.int32)[None, :]
                                <= pos[:, None])
                    step_out = transformer.lm_decode_step(
                        params, cfg, caches, tok, pos, kv_valid=kv_valid,
                        return_counters=tel_on)
                if tel_on:
                    caches, logits, tel = step_out
                else:
                    caches, logits = step_out
                    tel = None
                lg = logits[:, -1].astype(jnp.float32)          # (B, V)
                if greedy:
                    nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                else:
                    nxt = sample_fn(keys, n, lg, temps, topks, topps)
                if tel_on:
                    # accumulate this step's counters, weighting per-slot
                    # leaves by the CURRENT active mask (retired slots
                    # decode dead air inside a chunk — their counts would
                    # pollute keep-rate / expert-load aggregates)
                    amask = active.astype(jnp.float32)

                    def _acc(c0, v):
                        v = v.astype(jnp.float32)
                        if v.ndim >= 2 and v.shape[1] == slots:
                            w = amask.reshape(
                                (1, slots) + (1,) * (v.ndim - 2))
                            v = v * w
                        return c0 + v
                    ctr = dict(ctr)
                    for k, v in tel.items():
                        ctr[k] = _acc(ctr[k], v)
                    ctr["decode_tokens"] = ctr["decode_tokens"] + amask.sum()
                    if paged:
                        ctr["pages_allocated"] = (
                            ctr["pages_allocated"]
                            + ok.astype(jnp.float32).sum())
                    if not greedy:
                        ctr["sampled_tokens"] = (
                            ctr["sampled_tokens"]
                            + (active & (temps > 0.0))
                            .astype(jnp.float32).sum())
                bidx = jnp.arange(slots, dtype=jnp.int32)
                col = jnp.clip(n, 0, max_gen - 1)
                buf = buf.at[bidx, col].set(
                    jnp.where(active, nxt, buf[bidx, col]))
                step = active.astype(jnp.int32)
                n = n + step
                pos = pos + step
                done = n >= limit
                if eos_id is not None:
                    done |= nxt == eos_id
                tok = jnp.where(active, nxt, tok)
                active = active & ~done
                base = (t + 1, caches, page_table, astate, tok, pos,
                        active, n, buf)
                return base + ((ctr,) if tel_on else ())

            init = (jnp.zeros((), jnp.int32), caches, page_table, astate,
                    tok, pos, active, n, buf)
            if tel_on:
                ctr0 = {k: jnp.zeros(s.shape, jnp.float32)
                        for k, s in tel_shapes.items()}
                ctr0["decode_tokens"] = jnp.zeros((), jnp.float32)
                if paged:
                    ctr0["pages_allocated"] = jnp.zeros((), jnp.float32)
                if not greedy:
                    ctr0["sampled_tokens"] = jnp.zeros((), jnp.float32)
                init = init + (ctr0,)
            out = jax.lax.while_loop(cond, body, init)
            (t, caches, page_table, astate, tok, pos, active, n,
             buf) = out[:9]
            res = (caches, page_table, astate, tok, pos, active, n, buf, t)
            return res + ((out[9],) if tel_on else ())

        if self._use_jit:
            chunk = jax.jit(chunk, donate_argnums=CHUNK_DONATE_ARGNUMS)
        self._chunk_cache[key] = chunk
        return chunk

    # ---------------------------------------------------------- scheduler
    def _pages_ws(self, req: Request) -> int:
        """Worst-case pages ``req`` can ever hold: one per page of rows
        [0, prompt_end + max_new - 1) — the last decode write lands at
        position prompt_end + max_new - 2.  Identical for a resumed item
        (regenerated tokens refill the same decode rows)."""
        frontend = self.cfg.frontend_tokens if self.cfg.frontend else 0
        rows = frontend + len(req.tokens) + req.max_new_tokens - 1
        return kvp.num_pages(max(1, rows), self.page_size)

    def _validate(self, req: Request, seen: set) -> Optional[str]:
        """Reason ``req`` must be rejected, or None.  Failure isolation:
        a bad request becomes Completion(finish_reason="rejected") while
        the rest of the workload keeps serving (the pre-PR-8 engine
        raised ValueError and aborted every other request)."""
        cfg = self.cfg
        frontend = cfg.frontend_tokens if cfg.frontend else 0
        if req.uid in seen:
            return f"duplicate request uid {req.uid}"
        if req.max_new_tokens < 1:
            return "max_new_tokens < 1"
        if frontend and req.frontend_embeds is None:
            return (f"{cfg.name} has a {cfg.frontend} frontend; "
                    "frontend_embeds is required")
        need = frontend + len(req.tokens) + req.max_new_tokens
        if need > self.max_len:
            return f"needs {need} positions > max_len={self.max_len}"
        if self._paged and self._pages_ws(req) > self.kv_pages:
            return (f"needs {self._pages_ws(req)} KV pages > pool size "
                    f"{self.kv_pages}")
        return None

    # ------------------------------------------------- long-lived API
    def submit(self, req: Request, now: Optional[float] = None) -> bool:
        """Queue ``req`` into the live serve()/run() loop — callable from
        arrival schedules, chaos injectors, or streaming callbacks while
        the loop runs.  Returns False when the request is rejected; the
        rejection is a Completion in the results, never an exception."""
        st = self._live
        if st is None:
            raise RuntimeError("submit() requires a live serve()/run()")
        if now is None:
            now = st.clock()
        order = st.order
        st.order += 1
        st.stats.submitted += 1
        rec = self.recorder
        wall = time.perf_counter()
        if rec is not None:
            rec.event(req.uid, "submit", wall, prompt_len=len(req.tokens),
                      priority=req.priority)
        why = self._validate(req, st.seen_uids)
        if why is not None:
            st.stats.rejections += 1
            if rec is not None:
                rec.event(req.uid, "rejected", wall, detail=why)
            st.results[order] = Completion(
                uid=req.uid, tokens=[], finish_reason="rejected",
                prompt_len=len(req.tokens), detail=why)
            return False
        st.seen_uids.add(req.uid)
        if rec is not None:
            rec.event(req.uid, "queued", wall)
        temp = (st.default_temp if req.temperature is None
                else req.temperature)
        if (not st.greedy) and 0.0 < req.top_p < 1.0:
            st.use_topp = True
        self._grow_gen(req.max_new_tokens)
        st.queue.append(_QItem(req=req, order=order, arrival_s=now,
                               temp=temp,
                               arrival_wall=time.perf_counter()))
        st.queue.sort(key=_queue_key)
        return True

    def cancel(self, uid: int) -> bool:
        """Cancel a queued or in-flight request: frees its slot/pages and
        finishes it as Completion(finish_reason="cancelled") carrying the
        tokens generated so far.  False when the uid is not live."""
        st = self._live
        if st is None:
            return False
        rec = self.recorder
        for qi, it in enumerate(st.queue):
            if it.req.uid == uid:
                del st.queue[qi]
                st.stats.cancelled += 1
                if rec is not None:
                    rec.event(uid, "cancelled", time.perf_counter(),
                              detail="while queued")
                st.results[it.order] = Completion(
                    uid=uid, tokens=list(it.done),
                    finish_reason="cancelled",
                    prompt_len=len(it.req.tokens),
                    detail="cancelled while queued",
                    preemptions=it.preemptions)
                return True
        for b, it in enumerate(st.slot_item):
            if it is not None and it.req.uid == uid:
                st.stats.cancelled += 1
                if rec is not None:
                    rec.event(uid, "cancelled", time.perf_counter(),
                              detail="mid-stream",
                              n_gen=int(st.n_gen[b]))
                st.results[it.order] = Completion(
                    uid=uid, tokens=st.buf[b, :st.n_gen[b]].tolist(),
                    finish_reason="cancelled",
                    prompt_len=len(it.req.tokens),
                    detail="cancelled mid-stream",
                    preemptions=it.preemptions)
                self._release_slot(b)
                return True
        return False

    def preempt(self, uid: Optional[int] = None) -> bool:
        """Force-preempt an active request (chaos harness / external
        policy): saves its progress, frees its slot and pages, and
        re-queues it for recompute re-admission.  ``uid`` None picks the
        default victim (lowest priority, most recently admitted).
        Returns False when nothing matches."""
        st = self._live
        if st is None:
            return False
        if uid is None:
            b = self._pick_victim(None, False)
            if b is None:
                return False
            self._preempt_slot(b)
            return True
        for b, it in enumerate(st.slot_item):
            if it is not None and it.req.uid == uid and st.active[b]:
                self._preempt_slot(b)
                return True
        return False

    # ------------------------------------------------ slot-state plumbing
    def _grow_gen(self, need: int) -> None:
        """Grow the per-slot output buffer to a power-of-2 token-budget
        bucket, so chunk retraces stay O(log max_gen) as arrivals raise
        the budget mid-serve (burst run() presizes the exact maximum and
        never grows — the PR 5 trace behavior)."""
        st = self._live
        if need <= st.max_gen:
            return
        new = max(8, st.max_gen)
        while new < need:
            new <<= 1
        st.buf = np.pad(st.buf, ((0, 0), (0, new - st.buf.shape[1])))
        st.max_gen = new

    def _release_slot(self, b: int) -> None:
        """Return slot b to the free pool — retire, cancel, and preempt
        all land here: paged pages go back through the refcount-aware
        free path and the host-side worst-case reservation is dropped."""
        st = self._live
        st.slot_item[b] = None
        st.active[b] = False
        if self._paged:
            st.astate, st.page_table = self._free_slot(
                st.astate, st.page_table, jnp.int32(b))
            st.reserved -= st.slot_ws[b]
            st.slot_ws[b] = 0

    def _retire(self, b: int) -> None:
        st = self._live
        it = st.slot_item[b]
        toks = st.buf[b, :st.n_gen[b]].tolist()
        reason = ("eos" if st.eos_id is not None and toks
                  and toks[-1] == st.eos_id else "length")
        now_wall = time.perf_counter()
        if self.recorder is not None:
            self.recorder.event(it.req.uid, "retired", now_wall,
                                finish=reason, n_gen=int(st.n_gen[b]))
        if it.first_tok_wall is not None and int(st.n_gen[b]) > 1:
            st.stats.tpot_samples.append(
                (now_wall - it.first_tok_wall) / (int(st.n_gen[b]) - 1))
        st.results[it.order] = Completion(
            uid=it.req.uid, tokens=toks, finish_reason=reason,
            prompt_len=len(it.req.tokens), preemptions=it.preemptions)
        st.stats.completed += 1
        self._release_slot(b)

    def _track_peak(self) -> None:
        st = self._live
        if self._paged:
            used = self.kv_pages - int(jax.device_get(st.astate["top"]))
            st.stats.kv_pages_peak = max(st.stats.kv_pages_peak, used)
            if self.recorder is not None:
                self.recorder.gauge("kv_pages_used", time.perf_counter(),
                                    used)

    def _preempt_slot(self, b: int) -> None:
        """Evict slot b: save its generated tokens on the queue item,
        free its pages/slot, and re-queue it — re-admission recomputes
        the KV through the batched ragged prefill (prefill_tokens) and
        resumes the token stream bit-identically."""
        st = self._live
        it = st.slot_item[b]
        it.done = st.buf[b, :st.n_gen[b]].tolist()
        it.preemptions += 1
        st.stats.preemptions += 1
        if self.recorder is not None:
            self.recorder.event(it.req.uid, "preempted",
                                time.perf_counter(), slot=b,
                                n_gen=int(st.n_gen[b]))
        self._release_slot(b)
        st.queue.append(it)
        st.queue.sort(key=_queue_key)

    def _pick_victim(self, cand: Optional[_QItem],
                     urgent: bool) -> Optional[int]:
        """Lowest-priority, most-recently-admitted active slot that
        ``cand`` may evict: strictly lower priority, or — when cand's
        TTFT deadline is at risk (urgent) — a deadline-free peer of
        equal priority.  cand None (forced preemption) matches any
        active slot."""
        st = self._live
        best = None
        for b, it in enumerate(st.slot_item):
            if it is None or not st.active[b]:
                continue
            if cand is not None:
                lower = it.req.priority < cand.req.priority
                peer = (urgent and it.req.priority == cand.req.priority
                        and it.req.deadline_s is None)
                if not (lower or peer):
                    continue
            key = (it.req.priority, -it.order)
            if best is None or key < best[0]:
                best = (key, b)
        return None if best is None else best[1]

    def _shed_expired(self, now: float) -> None:
        """Drop queued requests whose TTFT deadline already lapsed — they
        cannot meet their SLO, so shedding them protects the requests
        that still can (resumed items already produced their first token
        and are never shed)."""
        st = self._live
        keep = []
        for it in st.queue:
            d = it.req.deadline_s
            if (d is not None and it.first_tok_wall is None
                    and now - it.arrival_s > d):
                st.stats.shed += 1
                if self.recorder is not None:
                    self.recorder.event(it.req.uid, "shed",
                                        time.perf_counter(),
                                        deadline_s=d)
                st.results[it.order] = Completion(
                    uid=it.req.uid, tokens=[], finish_reason="shed",
                    prompt_len=len(it.req.tokens),
                    detail=f"TTFT deadline {d}s lapsed in queue",
                    preemptions=it.preemptions)
            else:
                keep.append(it)
        st.queue = keep

    def _pressure_preempt(self, now: float) -> None:
        """Slot / page-pool pressure: when the head-of-queue request
        cannot fit, evict strictly-lower-priority victims (or, for a
        deadline-at-risk head, deadline-free equal-priority peers) until
        it fits or no eligible victim remains.  Uniform-priority burst
        workloads never trigger this, so run() stays bit-identical to
        the PR 5 scheduler."""
        st = self._live
        if not st.queue:
            return
        cand = st.queue[0]

        def blocked() -> bool:
            if not any(s is None for s in st.slot_item):
                return True
            return (self._paged and self._pages_ws(cand.req)
                    > self.kv_pages - st.reserved)

        d = cand.req.deadline_s
        urgent = (d is not None and cand.first_tok_wall is None
                  and now - cand.arrival_s >= 0.5 * d)
        guard = 0
        while blocked() and guard < self.num_slots:
            b = self._pick_victim(cand, urgent)
            if b is None:
                break
            self._preempt_slot(b)
            guard += 1
        if guard:
            # the eviction was FOR cand: re-queued victims of equal
            # priority carry an older submission order and would outrank
            # it at admission (starvation thrash — evict, re-admit the
            # victim, repeat until cand sheds), so cand keeps the head.
            st.queue.remove(cand)
            st.queue.insert(0, cand)

    # -------------------------------------------------- admission + decode
    def _form_group(self, stalled_seen: set) -> List[_QItem]:
        """Scan the queue IN ORDER (priority-major, then submission) for
        the next admission group: up to prefill_batch requests that have
        a free slot and (paged) a worst-case page reservation.  A request
        that does not fit the page pool is counted as a stall (once per
        scheduling iteration — ``stalled_seen`` dedups across the
        admission loop's passes) and SKIPPED — it must not
        head-of-line-block later rows that do fit; it retries every
        iteration and admits once retiring slots release their
        reservations.  Non-ragged-batchable stacks (rec/ssd states, SWA
        rings) group equal-length rows only (no right-padding).  With
        overlap enabled and decodes in flight, the group is bounded by
        the prefill token budget (always >= 1 request, so admission
        cannot starve)."""
        st = self._live
        free = sum(1 for s in st.slot_item if s is None)
        if not free or not st.queue:
            return []
        budget = None
        if self.prefill_decode_ratio > 0 and st.active.any():
            budget = max(1, int(self.prefill_decode_ratio
                                * self.decode_chunk
                                * int(st.active.sum())))
        ragged_ok = self._ragged_batchable()
        group: List[_QItem] = []
        picked: List[int] = []
        group_ws = group_tokens = 0
        for qi, it in enumerate(st.queue):
            if len(group) == min(free, self.prefill_batch):
                break
            ptoks = len(it.prefill_tokens())
            if (budget is not None and group
                    and group_tokens + ptoks > budget):
                break
            if (not ragged_ok and group
                    and ptoks != len(group[0].prefill_tokens())):
                continue
            if (self._paged
                    and self._pages_ws(it.req) > self.kv_pages
                    - st.reserved - group_ws):
                if it.req.uid not in stalled_seen:
                    stalled_seen.add(it.req.uid)
                    st.stats.admission_stalls += 1
                continue
            group.append(it)
            picked.append(qi)
            group_ws += self._pages_ws(it.req) if self._paged else 0
            group_tokens += ptoks
        for qi in reversed(picked):
            del st.queue[qi]
        return group

    def _stream(self, it: _QItem, toks: Sequence[int], done: bool) -> None:
        cb = it.req.on_token
        if cb is None:
            return
        for j, t in enumerate(toks):
            cb(it.req.uid, int(t), done and j == len(toks) - 1)

    def _admit(self, group: List[_QItem]) -> None:
        """ONE batched prefill + ONE jit scatter (and, paged, ONE page
        allocation) admits the whole group — the serial engine paid a
        host round-trip per request.  Resumed (preempted) rows force
        their last generated token as the pending decode input instead
        of sampling from the prefill logits."""
        st = self._live
        cfg = self.cfg
        frontend = cfg.frontend_tokens if cfg.frontend else 0
        ps = self.page_size
        t0 = time.perf_counter()
        rows, logits, bpb, tel = self._prefill_group(group)
        slot_vec = np.full(bpb, -1, np.int32)   # -1 rows: dummies, drop
        assigned: List[int] = []
        for i, it in enumerate(group):
            b = next(j for j, s in enumerate(st.slot_item) if s is None)
            st.slot_item[b] = it
            assigned.append(b)
            slot_vec[i] = b
        if self._paged:
            npages = np.zeros(bpb, np.int32)
            for i, it in enumerate(group):
                ws = self._pages_ws(it.req)
                st.reserved += ws
                st.slot_ws[assigned[i]] = ws
                npages[i] = kvp.num_pages(
                    frontend + len(it.prefill_tokens()), ps)
            st.astate, st.page_table = self._alloc_rows(
                st.astate, st.page_table, jnp.asarray(slot_vec),
                jnp.asarray(npages))
            st.caches = self._write_rows(st.caches, rows,
                                         jnp.asarray(slot_vec),
                                         st.page_table)
        else:
            st.caches = self._write_rows(st.caches, rows,
                                         jnp.asarray(slot_vec))
        logits = jax.block_until_ready(logits)
        jax.block_until_ready(st.caches)
        now_wall = time.perf_counter()
        rec = self.recorder
        if rec is not None and tel is not None:
            # trim dummy bucket rows before folding: real rows are the
            # first len(group) of the Bpb padding bucket
            ng = len(group)
            rec.drain_counters({
                k: (v[:, :ng] if getattr(v, "ndim", 0) >= 2
                    and v.shape[1] == bpb else v)
                for k, v in jax.device_get(tel).items()})
        if rec is not None:
            rec.span("prefill_batch", t0, now_wall, st.iteration,
                     group=len(group), bucket_rows=bpb)
        st.stats.prefill_s += now_wall - t0
        st.stats.prefill_batches += 1
        st.stats.prefill_tokens += sum(
            len(it.prefill_tokens()) for it in group)
        st.stats.admitted += len(group)
        for i, it in enumerate(group):
            b = assigned[i]
            r = it.req
            skey = jax.random.fold_in(st.base_key, r.uid)
            st.keys[b] = np.asarray(skey, np.uint32)
            st.temps[b] = it.temp
            st.topks[b] = r.top_k
            st.topps[b] = r.top_p
            st.limit[b] = r.max_new_tokens
            st.buf[b] = 0
            if it.done:                         # resume after preemption
                nd = len(it.done)
                st.buf[b, :nd] = it.done
                st.tok[b] = it.done[-1]
                st.pos[b] = frontend + len(it.prefill_tokens())
                st.n_gen[b] = nd
                if rec is not None:
                    rec.event(r.uid, "resumed", now_wall, slot=b,
                              regenerated=nd)
                done_now = (nd >= r.max_new_tokens
                            or (st.eos_id is not None
                                and it.done[-1] == st.eos_id))
                st.active[b] = not done_now
                if done_now:
                    self._retire(b)
                continue
            if rec is not None:
                rec.event(r.uid, "admitted", now_wall, slot=b,
                          prompt_len=len(r.tokens))
            lg = np.asarray(logits[i, -1], np.float32)
            if st.greedy or it.temp <= 0.0:
                first = int(lg.argmax())
            else:
                scaled = lg / max(it.temp, 1e-6)
                if r.top_k > 0:
                    thr = np.sort(scaled)[::-1][
                        min(r.top_k, scaled.size) - 1]
                    scaled = np.where(scaled < thr, -np.inf, scaled)
                if 0.0 < r.top_p < 1.0:
                    srt = np.sort(lg / max(it.temp, 1e-6))[::-1]
                    e = np.exp(srt - srt[0])
                    probs = e / e.sum()
                    cum = np.cumsum(probs)
                    kcnt = max(1, int(((cum - probs) < r.top_p).sum()))
                    scaled = np.where(scaled < srt[kcnt - 1],
                                      -np.inf, scaled)
                first = int(jax.random.categorical(
                    jax.random.fold_in(skey, 0), jnp.asarray(scaled)))
            # TTFT is arrival-relative: for a burst every arrival_wall is
            # the serve start (the legacy semantics); under continuous
            # arrivals a late request is not charged for time it did not
            # wait.
            ttft = now_wall - it.arrival_wall
            st.stats.ttft_s_sum += ttft
            st.stats.ttft_s_max = max(st.stats.ttft_s_max, ttft)
            st.stats.ttft_samples.append(ttft)
            it.first_tok_wall = now_wall
            if rec is not None:
                rec.event(r.uid, "first_token", now_wall,
                          ttft_s=round(ttft, 6))
            st.tok[b] = first
            st.pos[b] = frontend + len(r.tokens)
            st.n_gen[b] = 1
            st.buf[b, 0] = first
            done_now = (r.max_new_tokens <= 1
                        or (st.eos_id is not None and first == st.eos_id))
            st.active[b] = not done_now
            self._stream(it, [first], done_now)
            if done_now:
                self._retire(b)

    def _decode_once(self) -> None:
        """One decode chunk (compiled once per shape bucket), then stream
        fresh tokens and retire slots that finished inside the chunk."""
        st = self._live
        chunk_fn = self._get_chunk(self.num_slots, st.max_gen, st.greedy,
                                   st.eos_id, st.use_topp)
        n_prev = st.n_gen.copy()
        # capture the pre-chunk active mask BEFORE handing the device
        # copies to the jit call: the chunk donates the slot-state
        # buffers (CHUNK_DONATE_ARGNUMS), so no donated mirror may be
        # read between the call and its reassignment below
        was_active = st.active.copy()
        t0 = time.perf_counter()
        out = chunk_fn(self.params, st.caches, st.page_table, st.astate,
                       jnp.asarray(st.tok), jnp.asarray(st.pos),
                       jnp.asarray(st.active), jnp.asarray(st.n_gen),
                       jnp.asarray(st.limit), jnp.asarray(st.buf),
                       jnp.asarray(st.keys), jnp.asarray(st.temps),
                       jnp.asarray(st.topks), jnp.asarray(st.topps))
        out = jax.block_until_ready(out)
        (st.caches, st.page_table, st.astate, tok_d, pos_d, act_d, n_d,
         buf_d, steps) = out[:9]
        t1 = time.perf_counter()
        st.stats.decode_s += t1 - t0
        rec = self.recorder
        if rec is not None and self._tel_counters:
            # ONE host fetch per chunk, inside the already-synced region
            rec.drain_counters(jax.device_get(out[9]))
            t2 = time.perf_counter()
            rec.span("drain", t1, t2, st.iteration)
        if rec is not None:
            rec.span("decode_chunk", t0, t1, st.iteration,
                     steps=int(steps),
                     active=int(np.array(act_d).sum()))
        self._track_peak()
        prev_total = int(n_prev.sum())
        # writable host mirrors (np.asarray of a jax array is read-only)
        st.tok = np.array(tok_d)
        st.pos = np.array(pos_d)
        act_new = np.array(act_d)
        st.n_gen = np.array(n_d)
        st.buf = np.array(buf_d)
        st.stats.decode_steps += int(steps)
        st.stats.decode_tokens += int(st.n_gen.sum()) - prev_total
        st.active = act_new
        for b in range(self.num_slots):
            it = st.slot_item[b]
            if it is None or not was_active[b]:
                continue
            finished = not act_new[b]
            fresh = st.buf[b, n_prev[b]:st.n_gen[b]]
            if len(fresh):
                self._stream(it, fresh.tolist(), finished)
            if finished:
                self._retire(b)

    # ------------------------------------------------------ loop drivers
    def _start(self, *, temperature, key, eos_id, clock, greedy,
               use_topp, max_gen) -> _SchedState:
        cfg = self.cfg
        if cfg.family == "audio":
            raise NotImplementedError(
                "continuous batching covers decoder-only LMs; use "
                "generate() for the enc-dec audio family")
        if self._live is not None:
            raise RuntimeError("engine already has a live serve()/run()")
        if eos_id == "engine-default":
            eos_id = self.eos_id
        slots = self.num_slots
        caches = transformer.init_caches(
            cfg, slots, self.max_len,
            kv_pages=self.kv_pages if self._paged else None)
        if self._paged:
            page_table = kvp.init_page_table(slots, self.max_pages_per_slot)
            astate = kvp.init_state(self.kv_pages)
        else:                   # inert placeholders riding the carry
            page_table = kvp.init_page_table(slots, 1)
            astate = kvp.init_state(1)
        t0 = time.perf_counter()
        st = _SchedState(
            stats=ServeStats(page_size=self.page_size,
                             kv_pages_total=self.kv_pages),
            clock=(clock if clock is not None
                   else (lambda: time.perf_counter() - t0)),
            eos_id=eos_id, greedy=greedy, use_topp=use_topp,
            base_key=key if key is not None else jax.random.PRNGKey(0),
            max_gen=max_gen,
            caches=caches, page_table=page_table, astate=astate,
            reserved=0, slot_ws=[0] * slots,
            tok=np.zeros(slots, np.int32),
            pos=np.zeros(slots, np.int32),
            active=np.zeros(slots, bool),
            n_gen=np.zeros(slots, np.int32),
            limit=np.ones(slots, np.int32),
            buf=np.zeros((slots, max(0, max_gen)), np.int32),
            keys=np.zeros((slots, 2), np.uint32),
            temps=np.zeros(slots, np.float32),
            topks=np.zeros(slots, np.int32),
            topps=np.zeros(slots, np.float32),
            slot_item=[None] * slots, queue=[], results={},
            seen_uids=set(), default_temp=temperature, t0_wall=t0)
        if self._tel_mode != "off":
            rec = TelemetryRecorder(
                mode=("trace" if self._tel_mode == "trace"
                      else "counters"),
                time_origin=t0)
            self.recorder = rec
            self.last_recorder = rec
        self._live = st
        return st

    def _iterate(self, schedule: Optional[ArrivalSchedule],
                 on_iteration: Optional[Callable]) -> bool:
        """One scheduling iteration: arrivals -> deadline shedding ->
        pressure preemption -> batched admission -> one decode chunk
        (streaming + retirement inside) -> the on_iteration hook (chaos
        injection / invariant watchdog).  Returns True when a decode
        chunk ran."""
        st = self._live
        rec = self.recorder
        now = st.clock()
        if schedule is not None:
            for r in schedule.due(now):
                self.submit(r, now=now)
        self._shed_expired(now)
        tp0 = time.perf_counter()
        pre_before = st.stats.preemptions
        self._pressure_preempt(now)
        if rec is not None and st.stats.preemptions > pre_before:
            rec.span("pressure_preempt", tp0, time.perf_counter(),
                     st.iteration,
                     evicted=st.stats.preemptions - pre_before)
        ta0 = time.perf_counter()
        admitted_before = st.stats.admitted
        stalled_seen: set = set()
        while True:
            group = self._form_group(stalled_seen)
            if not group:
                break
            self._admit(group)
            if self.prefill_decode_ratio > 0 and st.active.any():
                break           # overlap: hand control back to decode
        if rec is not None and st.stats.admitted > admitted_before:
            rec.span("admission", ta0, time.perf_counter(), st.iteration,
                     admitted=st.stats.admitted - admitted_before,
                     stalled=len(stalled_seen))
        self._track_peak()
        stepped = False
        if st.active.any():
            self._decode_once()
            stepped = True
        if rec is not None:
            tg = time.perf_counter()
            rec.gauge("queue_depth", tg, len(st.queue))
            rec.gauge("active_slots", tg, int(st.active.sum()))
        st.iteration += 1
        if on_iteration is not None:
            on_iteration(self, st.iteration)
        if hasattr(st.clock, "advance"):
            st.clock.advance()
        return stepped

    def serve(self, schedule: ArrivalSchedule, *,
              temperature: float = 0.0, key: Optional[jax.Array] = None,
              eos_id: Any = "engine-default",
              clock: Optional[Callable[[], float]] = None,
              on_iteration: Optional[Callable] = None,
              _greedy: Optional[bool] = None,
              _use_topp: Optional[bool] = None,
              _max_gen: int = 0) -> List[Completion]:
        """Long-lived serving loop over an ``ArrivalSchedule``.

        Requests arrive mid-run per the schedule (plus any submit() from
        callbacks); the loop runs until the schedule is exhausted and
        every submitted request reached a terminal state (completed,
        rejected, cancelled, or shed).  Completions return in submission
        order; the wall-clock split is left in ``self.last_stats``.

        ``clock`` reads serve time in seconds (default: wall clock since
        serve start; pass a ManualClock for deterministic tests —
        arrivals and deadlines then advance per scheduling iteration).
        ``on_iteration(engine, i)`` fires after every scheduling
        iteration — the chaos-injection / invariant-watchdog hook.  The
        underscore knobs let run() pin the compiled-chunk bucket exactly
        as the PR 5 burst scheduler did."""
        greedy = (key is None) if _greedy is None else _greedy
        st = self._start(temperature=temperature, key=key, eos_id=eos_id,
                         clock=clock, greedy=greedy,
                         use_topp=bool(_use_topp), max_gen=_max_gen)
        try:
            while True:
                stepped = self._iterate(schedule, on_iteration)
                idle = (not stepped and not st.queue
                        and not st.active.any())
                if (schedule.exhausted and idle
                        and all(s is None for s in st.slot_item)):
                    break
                if idle and not schedule.exhausted:
                    nxt = schedule.next_time()
                    wait = (nxt - st.clock()) if nxt is not None else 0.0
                    if wait > 0 and not hasattr(st.clock, "advance"):
                        time.sleep(min(wait, 0.05))
        finally:
            rec = self.recorder
            if rec is not None:
                st.stats.device.update(rec.device_aggregates())
            self.last_stats = st.stats
            self.recorder = None        # last_recorder keeps the handle
            self._live = None
        return [st.results[i] for i in range(st.order)]

    def run(self, requests: Sequence[Request], *, temperature: float = 0.0,
            key: Optional[jax.Array] = None,
            eos_id: Any = "engine-default",
            on_iteration: Optional[Callable] = None) -> List[Completion]:
        """Serve a burst of `requests` (any count vs. `num_slots`) to
        completion — the one-shot API, now a burst-schedule wrapper over
        the long-lived loop (same admission order, chunking, and greedy
        outputs as the PR 5 scheduler).  Invalid requests (oversized,
        duplicate uid, missing frontend) finish as rejected Completions
        instead of raising.  Returns completions in request order;
        wall-clock split is left in `self.last_stats`."""
        eff = [(temperature if r.temperature is None else r.temperature)
               for r in requests]
        sampling = key is not None and any(t > 0.0 for t in eff)
        use_topp = sampling and any(0.0 < r.top_p < 1.0 for r in requests)
        max_gen = max([r.max_new_tokens for r in requests] + [1])
        return self.serve(ArrivalSchedule.burst(requests),
                          temperature=temperature, key=key, eos_id=eos_id,
                          on_iteration=on_iteration, _greedy=not sampling,
                          _use_topp=use_topp, _max_gen=max_gen)

    # ------------------------------------------------------------- legacy
    def generate(self, batch: Dict[str, jax.Array], steps: int,
                 temperature: float = 0.0,
                 key: Optional[jax.Array] = None) -> GenerationResult:
        """Fixed-batch generation (legacy API).  Greedy LM decoding runs on
        the continuous-batching engine; the enc-dec audio family,
        temperature sampling (its key schedule is batch-shaped and is
        preserved bit-for-bit), and rolling-cache workloads where
        prompt + steps exceed max_len keep the original per-token loop."""
        frontend = (self.cfg.frontend_tokens
                    if self.cfg.frontend and self.cfg.family != "audio" else 0)
        need = frontend + batch["tokens"].shape[1] + steps
        if (self.cfg.family == "audio"
                or (temperature > 0.0 and key is not None)
                or need > self.max_len):
            return self._generate_per_token(batch, steps, temperature, key)
        rows = np.asarray(batch["tokens"])
        fes = batch.get("frontend_embeds")
        reqs = [Request(uid=i, tokens=rows[i].tolist(), max_new_tokens=steps,
                        frontend_embeds=None if fes is None else fes[i])
                for i in range(rows.shape[0])]
        outs = self.run(reqs, temperature=0.0, eos_id=None)
        return GenerationResult(tokens=[c.tokens for c in outs], steps=steps)

    def _generate_per_token(self, batch, steps, temperature, key):
        caches, logits = self._prefill(self.params, batch)
        pos0 = batch["tokens"].shape[1]
        if self.cfg.frontend and self.cfg.family != "audio":
            pos0 += self.cfg.frontend_tokens
        outs = []
        tok = self._sample(logits[:, -1], temperature, key, 0)
        outs.append(tok)
        for t in range(1, steps):
            caches, logits = self._decode(
                self.params, caches, tok, jnp.asarray(pos0 + t - 1, jnp.int32))
            tok = self._sample(logits[:, -1], temperature, key, t)
            outs.append(tok)
        toks = jnp.stack(outs, axis=1)
        return GenerationResult(tokens=toks.tolist(), steps=steps)

    def _sample(self, logits, temperature, key, t):
        if temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        k = jax.random.fold_in(key, t)
        return jax.random.categorical(
            k, logits.astype(jnp.float32) / temperature, axis=-1
        ).astype(jnp.int32)
