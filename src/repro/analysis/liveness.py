"""Layer 5: memory-lifetime analysis of hot-entrypoint jaxprs.

The paper's headline claim is *peak memory* (sparse MHA cuts peak
consumption by up to 50%), and the serving stack's value proposition is
resident-bytes-per-session — but per-eqn byte budgets (layer 1) cannot
see *when* buffers die, whether donated inputs actually alias, or
whether a change silently doubled the live set of the decode chunk.
This layer runs a backward liveness pass over the (nested) jaxpr of
every registered memory entrypoint and derives:

  * a **peak-live-bytes waterfall** — for each top-level program point,
    the bytes resident under the model below;
  * the **top-k live-set contributors** at the peak point, with
    provenance (arg tree path for inputs, primitive + source line for
    intermediates);
  * a **memory signature** (peak live bytes, donated bytes, eqn count,
    pallas-call count) — the unit the golden-baseline ratchet in
    ``analysis/baselines.py`` diffs against ``scripts/
    analysis_baselines.json``.

The residency model (an upper bound, but a *consistent* one — the
ratchet cares about drift, not absolute truth):

  * non-donated top-level invars and consts are **pinned**: the caller
    holds them for the whole call (params, read-only operands);
  * donated invars die at their last use — donation is how the decode
    chunk's caches/slot-state stop counting twice;
  * an intermediate is resident from the eqn that defines it through its
    last use; at eqn ``i`` the resident set is pinned ∪ live-after(i) ∪
    the eqn's own operands and results;
  * ``while``/``scan``/``cond``/``pjit``/``custom_*`` bodies are
    analyzed recursively: the sub-jaxpr invar is treated as donated iff
    the outer operand dies at the eqn, so a donated cache flowing
    through the while carry is counted once; a while/scan carry whose
    outer operand does NOT die (non-donated, or still read later) pays
    a copy-on-entry surcharge — the caller's buffer stays resident
    alongside the loop's working copy, which is exactly the cost
    donation buys back; in-place cache updates (``scatter*`` /
    ``dynamic_update_slice`` whose operand dies) alias their output;
  * a ``pallas_call`` contributes its operands/results plus kernel
    scratch (VMEM scratch_shapes), never its internal ref vars.

``python -m repro.analysis --memory-report`` prints the waterfalls;
the ``liveness`` audit registered here only sanity-checks that every
entrypoint traces and that entries expected to donate actually report
donated bytes (rules ``liveness.trace-failure``, ``liveness.empty``,
``liveness.donation-unused``).  Regression gating lives in the
``memory`` audit (baselines.py).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
from jax import core as jcore
from jax._src import source_info_util

from repro.analysis import jaxpr_audit as ja
from repro.analysis.registry import Violation, audit

# ------------------------------------------------------------- byte sizes
def aval_bytes(aval) -> int:
    """Static byte size of an abstract value (0 when unknown/dynamic)."""
    aval = getattr(aval, "inner_aval", aval)
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    size = 1
    for dim in shape:
        if not isinstance(dim, int):
            return 0
        size *= dim
    return size * jnp.dtype(dtype).itemsize


def _aval_str(aval) -> str:
    aval = getattr(aval, "inner_aval", aval)
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return "?"
    return f"{jnp.dtype(dtype).name}{tuple(shape)}"


def _src(eqn) -> str:
    try:
        return source_info_util.summarize(eqn.source_info)
    except Exception:
        return "?"


# --------------------------------------------------------------- results
@dataclasses.dataclass(frozen=True)
class Contributor:
    nbytes: int
    aval: str         # dtype + shape
    label: str        # arg tree path or defining "prim @ file:line"


@dataclasses.dataclass(frozen=True)
class PeakInfo:
    nbytes: int
    at: str                                # program point description
    contributors: Tuple[Contributor, ...]  # sorted desc, truncated


@dataclasses.dataclass(frozen=True)
class MemorySignature:
    peak_live_bytes: int
    donated_bytes: int
    eqns: int
    pallas_calls: int


@dataclasses.dataclass(frozen=True)
class MemoryReport:
    entry: str
    signature: MemorySignature
    timeline: Tuple[Tuple[str, int], ...]  # top-level (label, live bytes)
    peak: PeakInfo


# ------------------------------------------------------- liveness engine
# primitives that update an operand in place when it is dead: output
# aliases operand 0 (XLA's in-place scatter/DUS path)
_INPLACE_PRIMS = frozenset({
    "scatter", "scatter-add", "scatter-mul", "scatter-min", "scatter-max",
    "dynamic_update_slice",
})
_CONTRIB_KEEP = 12


def _bytes_of(vs) -> int:
    return sum(aval_bytes(v.aval) for v in vs)


def _contributors(vs, labels) -> Tuple[Contributor, ...]:
    cs = [Contributor(aval_bytes(v.aval), _aval_str(v.aval),
                      labels.get(v, "intermediate"))
          for v in vs if aval_bytes(v.aval) > 0]
    cs.sort(key=lambda c: (-c.nbytes, c.label))
    return tuple(cs[:_CONTRIB_KEEP])


def _pallas_scratch_bytes(eqn) -> int:
    try:
        gm = eqn.params["grid_mapping"]
        num = int(gm.num_scratch_operands)
    except Exception:
        return 0
    if not num:
        return 0
    kernel = eqn.params.get("jaxpr")
    if kernel is None:
        return 0
    if isinstance(kernel, jcore.ClosedJaxpr):
        kernel = kernel.jaxpr
    return sum(aval_bytes(v.aval) for v in kernel.invars[-num:])


def _sub_closed(val):
    if isinstance(val, jcore.ClosedJaxpr):
        return val
    if isinstance(val, jcore.Jaxpr):
        return jcore.ClosedJaxpr(val, ())
    return None


def _analyze(jaxpr: jcore.Jaxpr, donated: Sequence[bool],
             labels: Dict) -> Tuple[List[Tuple[str, int]], PeakInfo]:
    """Backward-liveness walk of one jaxpr level.  ``donated[k]`` says
    invar k dies at last use (else pinned for the whole program).
    Returns (timeline of top-level program points, peak info)."""
    eqns = list(jaxpr.eqns)
    invars = list(jaxpr.invars)
    donated = list(donated) + [False] * (len(invars) - len(donated))
    pinned: Set = set(jaxpr.constvars)
    for v in jaxpr.constvars:
        labels.setdefault(v, "const")
    don: Set = set()
    for k, v in enumerate(invars):
        labels.setdefault(v, f"arg{k}")
        (don if donated[k] else pinned).add(v)

    # backward pass: live_after[i] = vars defined at or before eqn i that
    # some later eqn (or the outputs) still needs
    live = {v for v in jaxpr.outvars if isinstance(v, jcore.Var)}
    live_after: List[Set] = [set()] * len(eqns)
    for i in range(len(eqns) - 1, -1, -1):
        live_after[i] = set(live)
        for v in eqns[i].outvars:
            live.discard(v)
        for v in eqns[i].invars:
            if isinstance(v, jcore.Var):
                live.add(v)
    live_entry = live

    def point(resident: Set, extra: int, at: str) -> Tuple[int, PeakInfo]:
        nbytes = _bytes_of(resident) + extra
        return nbytes, PeakInfo(nbytes, at, _contributors(resident, labels))

    entry_resident = pinned | (don & live_entry)
    nbytes, best = point(entry_resident, 0, "entry")
    timeline: List[Tuple[str, int]] = [("entry", nbytes)]

    for i, eqn in enumerate(eqns):
        prim = eqn.primitive.name
        here_in = [v for v in eqn.invars if isinstance(v, jcore.Var)]
        here = set(here_in) | set(eqn.outvars)
        rest = (pinned | live_after[i]) - here

        def dead(v) -> bool:
            return (isinstance(v, jcore.Var) and v not in pinned
                    and v not in live_after[i])

        cost: Optional[int] = None
        info: Optional[PeakInfo] = None
        sub_specs = _call_sub_specs(eqn, dead, labels)
        if prim == "pallas_call":
            extra = _pallas_scratch_bytes(eqn)
            cost, info = point(rest | here, extra, f"{prim} @ {_src(eqn)}")
        elif sub_specs:
            rest_bytes = _bytes_of(rest)
            results = [(_analyze(sub.jaxpr, mask, sub_labels), extra)
                       for sub, mask, sub_labels, extra in sub_specs]
            (sub_tl, inner_best), extra_outer = max(
                results, key=lambda r: r[0][1].nbytes + r[1])
            cost = rest_bytes + inner_best.nbytes + extra_outer
            info = PeakInfo(
                cost, f"{prim} @ {_src(eqn)} -> {inner_best.at}",
                tuple(sorted(
                    _contributors(rest, labels) + inner_best.contributors,
                    key=lambda c: -c.nbytes))[:_CONTRIB_KEEP])
            # splice the body's program points into the waterfall so
            # loop-heavy entrypoints aren't a single opaque bar
            timeline.extend(
                (f"{prim}:{lbl}", rest_bytes + v + extra_outer)
                for lbl, v in sub_tl)
        else:
            save = 0
            if prim in _INPLACE_PRIMS and here_in and eqn.outvars:
                op0, out0 = eqn.invars[0], eqn.outvars[0]
                if (dead(op0) and aval_bytes(op0.aval)
                        == aval_bytes(out0.aval)):
                    save = aval_bytes(out0.aval)
            cost, info = point(rest | here, -save,
                               f"{prim} @ {_src(eqn)}")
        if not sub_specs or prim == "pallas_call":
            timeline.append((prim, cost))
        if cost > best.nbytes:
            best = info
        for v in eqn.outvars:
            labels.setdefault(v, f"{prim} @ {_src(eqn)}")

    exit_resident = pinned | {v for v in jaxpr.outvars
                              if isinstance(v, jcore.Var)}
    nbytes, exit_info = point(exit_resident, 0, "exit")
    timeline.append(("exit", nbytes))
    if nbytes > best.nbytes:
        best = exit_info
    return timeline, best


def _call_sub_specs(eqn, dead, labels):
    """For call-like eqns, yield (ClosedJaxpr, donated mask, sub label
    map, extra outer bytes) per body to recurse into.  The mask marks a
    sub invar donated iff the outer operand dies at this eqn, so donated
    buffers flowing into while carries / pjit bodies count once."""
    prim = eqn.primitive.name
    if prim == "pallas_call":       # kernel body vars are refs, not HBM
        return []

    def lbl(v):
        return labels.get(v) if isinstance(v, jcore.Var) else None

    def names_for(sub_invars, outer_ops):
        out = {}
        for sv, ov in zip(sub_invars, outer_ops):
            name = lbl(ov)
            if name is not None:
                out[sv] = name
        return out

    def carry_copy_bytes(carry):
        # a loop carry updated in place needs its own buffer; when the
        # outer operand does NOT die here (non-donated, or still used
        # later) the caller's buffer ALSO stays resident for the whole
        # loop — this surcharge is exactly what donating the operand
        # buys back
        return sum(aval_bytes(v.aval) for v in carry
                   if isinstance(v, jcore.Var) and not dead(v))

    if prim == "while":
        cn = eqn.params["cond_nconsts"]
        bn = eqn.params["body_nconsts"]
        cond_j = _sub_closed(eqn.params["cond_jaxpr"])
        body_j = _sub_closed(eqn.params["body_jaxpr"])
        ops = list(eqn.invars)
        cconsts, bconsts = ops[:cn], ops[cn:cn + bn]
        carry = ops[cn + bn:]
        copies = carry_copy_bytes(carry)
        # cond reads the carry the body still needs — never donated there
        cond_mask = [dead(v) for v in cconsts] + [False] * len(carry)
        body_mask = [dead(v) for v in bconsts] + [dead(v) for v in carry]
        return [
            (cond_j, cond_mask, names_for(cond_j.jaxpr.invars,
                                          cconsts + carry), copies),
            (body_j, body_mask, names_for(body_j.jaxpr.invars,
                                          bconsts + carry), copies),
        ]
    if prim == "scan":
        closed = _sub_closed(eqn.params["jaxpr"])
        nc = eqn.params["num_consts"]
        ncar = eqn.params["num_carry"]
        ops = list(eqn.invars)
        lead, xs = ops[:nc + ncar], ops[nc + ncar:]
        sub_in = closed.jaxpr.invars
        mask = [dead(v) for v in lead]
        mask += [True] * (len(sub_in) - len(mask))  # per-iter xs slices
        names = names_for(sub_in, lead)
        for sv, ov in zip(sub_in[nc + ncar:], xs):
            name = lbl(ov)
            if name is not None:
                names[sv] = name + "[iter]"
        # full xs stay resident for the whole scan, the stacked ys
        # outputs fill up while it runs, and non-dead carries are copied
        # on entry (see carry_copy_bytes)
        ys = list(eqn.outvars[ncar:])
        extra = (_bytes_of({v for v in xs if isinstance(v, jcore.Var)})
                 + _bytes_of(ys)
                 + carry_copy_bytes(ops[nc:nc + ncar]))
        return [(closed, mask, names, extra)]
    if prim == "cond":
        branches = [_sub_closed(b) for b in eqn.params["branches"]]
        ops = list(eqn.invars[1:])          # invars[0] is the predicate
        mask = [dead(v) for v in ops]
        return [(b, mask, names_for(b.jaxpr.invars, ops), 0)
                for b in branches if b is not None]
    # generic call-like (pjit, closed_call, custom_jvp/vjp, remat):
    # accept any single ClosedJaxpr param whose invars match 1:1
    for val in eqn.params.values():
        closed = _sub_closed(val)
        if closed is None:
            continue
        if len(closed.jaxpr.invars) == len(eqn.invars):
            mask = [dead(v) for v in eqn.invars]
            return [(closed, mask,
                     names_for(closed.jaxpr.invars, eqn.invars), 0)]
    return []


# ------------------------------------------------------- report assembly
def arg_leaf_names(args, prefixes: Sequence[str]) -> List[str]:
    names = []
    for prefix, arg in zip(prefixes, args):
        leaves, _ = jax.tree_util.tree_flatten_with_path(arg)
        if not leaves:
            continue
        for path, _ in leaves:
            names.append(prefix + jax.tree_util.keystr(path))
    return names


def donated_leaf_mask(args, donate_argnums: Sequence[int]) -> List[bool]:
    mask = []
    for i, arg in enumerate(args):
        n = len(jax.tree_util.tree_leaves(arg))
        mask.extend([i in donate_argnums] * n)
    return mask


def analyze_closed(closed: jcore.ClosedJaxpr,
                   donated: Optional[Sequence[bool]] = None,
                   arg_names: Optional[Sequence[str]] = None,
                   entry: str = "jaxpr") -> MemoryReport:
    jaxpr = closed.jaxpr
    donated = list(donated or [False] * len(jaxpr.invars))
    labels: Dict = {}
    if arg_names:
        for v, name in zip(jaxpr.invars, arg_names):
            labels[v] = name
    timeline, peak = _analyze(jaxpr, donated, labels)
    donated_bytes = sum(aval_bytes(v.aval)
                        for v, d in zip(jaxpr.invars, donated) if d)
    sig = MemorySignature(
        peak_live_bytes=peak.nbytes,
        donated_bytes=donated_bytes,
        eqns=sum(1 for _ in ja.iter_eqns(closed)),
        pallas_calls=ja.pallas_call_count(closed))
    return MemoryReport(entry=entry, signature=sig,
                        timeline=tuple(timeline), peak=peak)


# --------------------------------------------------- entrypoint registry
MEMORY_ENTRYPOINTS: Dict[str, Callable[[], MemoryReport]] = {}
# entries whose jit site declares donation — donated_bytes == 0 there
# means the audit's mask plumbing silently broke
_EXPECT_DONATION = set()
_REPORT_CACHE: Dict[str, MemoryReport] = {}


def memory_entrypoint(name: str, expect_donation: bool = False):
    def register(fn):
        if name in MEMORY_ENTRYPOINTS:
            raise ValueError(f"duplicate memory entrypoint {name!r}")
        MEMORY_ENTRYPOINTS[name] = fn
        if expect_donation:
            _EXPECT_DONATION.add(name)
        return fn
    return register


def memory_report(name: str) -> MemoryReport:
    """Compute (and memoize — baselines, the liveness audit, and
    --memory-report all reuse one trace) the report for one entry."""
    if name not in _REPORT_CACHE:
        _REPORT_CACHE[name] = MEMORY_ENTRYPOINTS[name]()
    return _REPORT_CACHE[name]


def all_reports() -> Dict[str, MemoryReport]:
    return {name: memory_report(name) for name in MEMORY_ENTRYPOINTS}


CHUNK_ARG_NAMES = ("params", "caches", "page_table", "astate", "tok",
                   "pos", "active", "n_gen", "limit", "buf", "keys",
                   "temps", "topks", "topps")


def _chunk_report(entry: str, cfg, donate_argnums=None) -> MemoryReport:
    from repro.serving.engine import CHUNK_DONATE_ARGNUMS
    if donate_argnums is None:
        donate_argnums = CHUNK_DONATE_ARGNUMS
    closed, _, _, args = ja._engine_chunk_jaxpr(cfg)
    return analyze_closed(
        closed, donated=donated_leaf_mask(args, donate_argnums),
        arg_names=arg_leaf_names(args, CHUNK_ARG_NAMES), entry=entry)


@memory_entrypoint("engine.decode_chunk", expect_donation=True)
def _mem_decode_chunk() -> MemoryReport:
    cfg = ja._tiny_lm_cfg(decode_attn_impl="kernel", ffn_impl="pallas")
    return _chunk_report("engine.decode_chunk", cfg)


@memory_entrypoint("engine.decode_chunk_kernels_off",
                   expect_donation=True)
def _mem_decode_chunk_off() -> MemoryReport:
    prev = os.environ.get("REPRO_DISABLE_KERNELS")
    os.environ["REPRO_DISABLE_KERNELS"] = "1"
    try:
        cfg = ja._tiny_lm_cfg(decode_attn_impl="kernel",
                              ffn_impl="pallas")
        return _chunk_report("engine.decode_chunk_kernels_off", cfg)
    finally:
        if prev is None:
            os.environ.pop("REPRO_DISABLE_KERNELS", None)
        else:
            os.environ["REPRO_DISABLE_KERNELS"] = prev


@memory_entrypoint("engine.decode_chunk_paged", expect_donation=True)
def _mem_decode_chunk_paged() -> MemoryReport:
    cfg = ja._tiny_lm_cfg(decode_attn_impl="kernel", attn_impl="pallas",
                          ffn_impl="pallas", kv_layout="paged",
                          kv_page_size=16)
    return _chunk_report("engine.decode_chunk_paged", cfg)


@memory_entrypoint("engine.prefill_ragged")
def _mem_prefill_ragged() -> MemoryReport:
    from repro.models import transformer
    cfg = ja._tiny_lm_cfg(ffn_impl="pallas")
    params = ja._lm_params(cfg)
    bpb, s, max_len = 2, 16, 32
    batch = {"tokens": jax.ShapeDtypeStruct((bpb, s), jnp.int32)}
    lengths = jax.ShapeDtypeStruct((bpb,), jnp.int32)
    closed = jax.make_jaxpr(
        lambda p, b, ln: transformer.lm_prefill_ragged(p, cfg, b, ln,
                                                       max_len)
    )(params, batch, lengths)
    args = (params, batch, lengths)
    return analyze_closed(
        closed,
        arg_names=arg_leaf_names(args, ("params", "batch", "lengths")),
        entry="engine.prefill_ragged")


@memory_entrypoint("ops.sparse_mha_decode")
def _mem_sparse_mha_decode() -> MemoryReport:
    from repro.kernels.sparse_attention import ops as sa_ops
    (b, hq, hk, s, d), scfg, cb, q, k, v, codes, kv_valid = \
        ja._sparse_decode_operands()
    closed = jax.make_jaxpr(
        lambda q, k, v, c, cb, kv: sa_ops.sparse_mha_decode(
            q, k, v, c, cb, scfg, d ** -0.5, kv, interpret=True,
            fuse=True)
    )(q, k, v, codes, cb, kv_valid)
    args = (q, k, v, codes, cb, kv_valid)
    return analyze_closed(
        closed,
        arg_names=arg_leaf_names(args, ("q", "k", "v", "codes",
                                        "codebooks", "kv_valid")),
        entry="ops.sparse_mha_decode")


@memory_entrypoint("ops.routed_ffn_decode")
def _mem_routed_ffn_decode() -> MemoryReport:
    from repro.core import lora as lora_mod
    from repro.core import routed_ffn as rf
    from repro.core.params import init_tree
    from repro.kernels.routed_ffn import ops as rffn_ops
    b, d, dff, g, gp = 4, 64, 128, 8, 2
    lcfg = lora_mod.LoRAConfig(rank=4, alpha=4.0, enabled=True)
    rcfg = rf.RoutedFFNConfig(d_model=d, d_ff=dff, num_groups=g,
                              active_groups=gp, capacity_factor=4.0,
                              gated=True, activation="gelu")
    p = jax.eval_shape(lambda: init_tree(rf.param_defs(rcfg, lcfg),
                                         jax.random.PRNGKey(0)))
    x = jax.ShapeDtypeStruct((b, 1, d), jnp.float32)
    closed = jax.make_jaxpr(
        lambda p, x: rffn_ops.routed_ffn_decode(x, p, rcfg, lcfg,
                                                interpret=True)[0])(p, x)
    return analyze_closed(
        closed, arg_names=arg_leaf_names((p, x), ("params", "x")),
        entry="ops.routed_ffn_decode")


@memory_entrypoint("models.moe_decode")
def _mem_moe_decode() -> MemoryReport:
    from repro import configs
    from repro.core.params import init_tree
    from repro.models import moe
    cfg = configs.get_smoke("grok-1-314b").with_spt(ffn_impl="pallas")
    p = jax.eval_shape(lambda: init_tree(moe.moe_defs(cfg),
                                         jax.random.PRNGKey(0)))
    x = jax.ShapeDtypeStruct((4, 1, cfg.d_model), jnp.float32)
    closed = jax.make_jaxpr(
        lambda p, x: moe.moe_apply(p, x, cfg, mode="decode")[0])(p, x)
    return analyze_closed(
        closed, arg_names=arg_leaf_names((p, x), ("params", "x")),
        entry="models.moe_decode")


@memory_entrypoint("kv_pages.alloc_free", expect_donation=True)
def _mem_kv_pages_alloc_free() -> MemoryReport:
    from repro.serving import kv_pages as kvp
    slots, pages_per, pool = 4, 4, 16

    def roundtrip(state, page_table, rows, num_pages):
        state, page_table = kvp.alloc_rows_pages(state, page_table,
                                                 rows, num_pages)
        return kvp.free_slot_pages(state, page_table, jnp.int32(0))

    state = ja._abstract(kvp.init_state(pool))
    pt = ja._abstract(kvp.init_page_table(slots, pages_per))
    rows = jax.ShapeDtypeStruct((slots,), jnp.int32)
    npages = jax.ShapeDtypeStruct((slots,), jnp.int32)
    closed = jax.make_jaxpr(roundtrip)(state, pt, rows, npages)
    args = (state, pt, rows, npages)
    return analyze_closed(
        closed, donated=donated_leaf_mask(args, (0, 1)),
        arg_names=arg_leaf_names(args, ("astate", "page_table", "rows",
                                        "num_pages")),
        entry="kv_pages.alloc_free")


# ----------------------------------------------------------- the reports
_BLOCKS = " ▁▂▃▄▅▆▇█"


def waterfall(timeline: Sequence[Tuple[str, int]], width: int = 60) -> str:
    """Sampled sparkline of live bytes over program points (max per
    bucket, scaled to the peak)."""
    vals = [v for _, v in timeline]
    if not vals:
        return ""
    peak = max(vals) or 1
    width = min(width, len(vals))
    cells = []
    for c in range(width):
        lo = c * len(vals) // width
        hi = max(lo + 1, (c + 1) * len(vals) // width)
        frac = max(vals[lo:hi]) / peak
        cells.append(_BLOCKS[min(len(_BLOCKS) - 1,
                                 int(round(frac * (len(_BLOCKS) - 1))))])
    return "".join(cells)


def format_memory_report(top_k: int = 6, width: int = 60) -> str:
    lines = ["memory-lifetime report (liveness model: pinned params + "
             "donated-dies-at-last-use; see analysis/liveness.py)"]
    for name in MEMORY_ENTRYPOINTS:
        rep = memory_report(name)
        sig = rep.signature
        lines.append("")
        lines.append(
            f"{name}: peak {sig.peak_live_bytes:,} B  "
            f"donated {sig.donated_bytes:,} B  eqns {sig.eqns}  "
            f"pallas {sig.pallas_calls}")
        lines.append("  live |" + waterfall(rep.timeline, width) + "|")
        lines.append(f"  peak at {rep.peak.at}")
        for c in rep.peak.contributors[:top_k]:
            lines.append(f"    {c.nbytes:>12,} B  {c.aval:<18} {c.label}")
    return "\n".join(lines)


# --------------------------------------------------------------- audit
def entry_violations(name: str,
                     builder: Callable[[], MemoryReport]
                     ) -> List[Violation]:
    try:
        rep = builder()
    except Exception as e:               # trace failure IS the finding
        return [Violation("liveness.trace-failure", name,
                          f"{type(e).__name__}: {e}")]
    out = []
    if rep.signature.peak_live_bytes <= 0:
        out.append(Violation(
            "liveness.empty", name,
            "peak live bytes is zero — the analyzer saw no resident "
            "buffers (trace or model bug)"))
    if name in _EXPECT_DONATION and rep.signature.donated_bytes <= 0:
        out.append(Violation(
            "liveness.donation-unused", name,
            "the jit site declares donation but the analyzer saw no "
            "donated invars — the donated-mask plumbing broke"))
    return out


@audit("liveness")
def _liveness_audit() -> List[Violation]:
    out: List[Violation] = []
    for name in MEMORY_ENTRYPOINTS:
        out.extend(entry_violations(name, lambda n=name: memory_report(n)))
    return out
