"""Layer 6: buffer-donation audit over the serving jit sites.

Donation is the serving stack's only defense against paying for every
cache twice: a jit entrypoint that carries a cache/pool/slot-state
operand without ``donate_argnums`` holds both the input and the output
buffer live across the call, and a donated operand whose aval matches
no output cannot alias — XLA warns once and silently copies.  Both
failure modes are invisible to parity tests, so this layer checks them
statically:

  donation.missing       a non-donated operand leaf (outside the exempt
                         argnums — params are engine-owned and shared
                         across calls) aval-matches an output leaf that
                         no donated operand claimed: it should be
                         donated so XLA can reuse the buffer in place
  donation.cannot-alias  a donated operand leaf matches no output aval —
                         the donation is a silent copy (dtype/shape
                         drifted, or the output was dropped)
  donation.jit-site      source lint: a ``jax.jit`` call in ``serving/``
                         passes neither ``donate_argnums`` nor
                         ``donate_argnames`` and carries no explicit
                         ``# no-donate: <reason>`` marker within the two
                         lines above it

The structural checks lower each engine jit site (contiguous and paged
layouts) over the same abstract operands the scheduler passes and read
the donation flags back from ``jitted.lower(...).args_info`` — so the
audit sees exactly what XLA sees, not what the source claims.
Donated leaves claim matching outputs *first*; only leftovers can flag
a non-donated operand, which keeps read-only operands that merely
share an aval with an already-claimed output (e.g. the chunk's
``limit`` vs the returned ``tok``/``pos``/``n_gen``) out of the report.
"""
from __future__ import annotations

import ast
import collections
import pathlib
from typing import Iterable, List, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import jaxpr_audit as ja
from repro.analysis.registry import Violation, audit

_SERVING_DIR = pathlib.Path(__file__).resolve().parents[1] / "serving"


# ------------------------------------------------------ structural audit
def _fmt(aval) -> str:
    return f"{jnp.dtype(aval.dtype).name}{tuple(aval.shape)}"


def _aval_key(aval) -> Tuple:
    return (tuple(aval.shape), jnp.dtype(aval.dtype).str)


def _leaf_infos(args_info):
    """Flatten ``lowered.args_info`` to (path label, argnum, aval,
    donated) rows."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(args_info)
    rows = []
    for path, info in leaves:
        aval = getattr(info, "aval", None)
        if aval is None:
            aval = info._aval
        # args_info wraps the positional args tuple one level deep
        # ((args, kwargs)-shaped), so the argnum is the SECOND path key
        path = tuple(path)
        if len(path) > 1 and getattr(path[0], "idx", None) == 0:
            path = path[1:]
        argnum = getattr(path[0], "idx", None)
        rows.append((jax.tree_util.keystr(path), argnum, aval,
                     bool(info.donated)))
    return rows


def donation_violations(entry: str, jitted, args,
                        exempt_argnums: Iterable[int] = ()
                        ) -> List[Violation]:
    """Lower ``jitted`` over abstract ``args`` and check every operand
    leaf's donation flag against the output avals."""
    exempt = frozenset(exempt_argnums)
    lowered = jitted.lower(*args)
    outs = jax.tree_util.tree_leaves(jax.eval_shape(jitted, *args))
    pool = collections.Counter(_aval_key(o) for o in outs)
    rows = _leaf_infos(lowered.args_info)
    out: List[Violation] = []
    for name, argnum, aval, donated in rows:     # donated claim first
        if not donated:
            continue
        key = _aval_key(aval)
        if pool[key] > 0:
            pool[key] -= 1
        else:
            out.append(Violation(
                "donation.cannot-alias", entry,
                f"donated operand {name} {_fmt(aval)} matches no output "
                "aval — XLA cannot alias it and silently copies"))
    for name, argnum, aval, donated in rows:
        if donated or argnum in exempt:
            continue
        key = _aval_key(aval)
        if pool[key] > 0:
            pool[key] -= 1
            out.append(Violation(
                "donation.missing", entry,
                f"operand {name} {_fmt(aval)} aval-matches an unclaimed "
                "output but is not donated — the input buffer stays "
                "live across the whole call"))
    return out


def _tiny_engine(paged: bool):
    from repro.serving.engine import Engine
    spt = {"kv_layout": "paged", "kv_page_size": 16} if paged else {}
    cfg = ja._tiny_lm_cfg(**spt)
    params = ja._lm_params(cfg)
    eng = Engine(cfg, params, max_len=32, jit=True, num_slots=2,
                 decode_chunk=4)
    return cfg, params, eng


def engine_donation_violations() -> List[Violation]:
    """Every jit site ``Engine.__init__`` / ``_get_prefill`` /
    ``_get_chunk`` builds, lowered over scheduler-shaped abstract
    operands, for both KV layouts."""
    from repro.serving import kv_pages as kvp
    from repro.serving.engine import abstract_decode_caches

    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    out: List[Violation] = []

    cfg, params, eng = _tiny_engine(paged=False)
    out += donation_violations(
        "engine._prefill", eng._prefill,
        (params, {"tokens": i32(1, 8)}), exempt_argnums=(0,))
    out += donation_violations(
        "engine._decode", eng._decode,
        (params, abstract_decode_caches(cfg, 1, 32), i32(1), i32()),
        exempt_argnums=(0,))
    out += donation_violations(
        "engine._prefill_one", eng._get_prefill(),
        (params, {"tokens": i32(2, 8)}, i32(2)), exempt_argnums=(0,))
    out += donation_violations(
        "engine._write_rows", eng._write_rows,
        (abstract_decode_caches(cfg, 2, 32),
         abstract_decode_caches(cfg, 1, 32), i32(1)))
    out += donation_violations(
        "engine.decode_chunk",
        eng._get_chunk(2, 4, greedy=True, eos_id=None),
        ja.engine_chunk_args(eng, 2, 4), exempt_argnums=(0,))

    cfgp, paramsp, engp = _tiny_engine(paged=True)
    astate = ja._abstract(kvp.init_state(engp.kv_pages))
    pt = ja._abstract(kvp.init_page_table(2, engp.max_pages_per_slot))
    out += donation_violations(
        "engine._alloc_rows[paged]", engp._alloc_rows,
        (astate, pt, i32(1), i32(1)))
    out += donation_violations(
        "engine._free_slot[paged]", engp._free_slot, (astate, pt, i32()))
    out += donation_violations(
        "engine._write_rows[paged]", engp._write_rows,
        (abstract_decode_caches(cfgp, 2, 32, kv_pages=engp.kv_pages),
         abstract_decode_caches(cfgp, 1, 32), i32(1), pt))
    out += donation_violations(
        "engine.decode_chunk[paged]",
        engp._get_chunk(2, 4, greedy=True, eos_id=None),
        ja.engine_chunk_args(engp, 2, 4), exempt_argnums=(0,))
    return out


# ----------------------------------------------------------- source lint
def jit_site_violations(source: str, rel: str) -> List[Violation]:
    """Every ``jax.jit(...)`` call in a serving module must either pass
    donate_argnums/donate_argnames or carry a ``# no-donate: <reason>``
    marker on the call line or the two lines above it."""
    try:
        tree = ast.parse(source)
    except SyntaxError:
        return []
    lines = source.splitlines()
    out: List[Violation] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if not (isinstance(fn, ast.Attribute) and fn.attr == "jit"
                and isinstance(fn.value, ast.Name)
                and fn.value.id == "jax"):
            continue
        kwargs = {kw.arg for kw in node.keywords}
        if kwargs & {"donate_argnums", "donate_argnames"}:
            continue
        window = lines[max(0, node.lineno - 3):node.lineno]
        if any("no-donate:" in ln for ln in window):
            continue
        out.append(Violation(
            "donation.jit-site", f"{rel}:{node.lineno}",
            "jax.jit without donate_argnums/donate_argnames — donate "
            "the dead operands or mark the site `# no-donate: <reason>`"))
    return out


def run_jit_site_lint() -> List[Violation]:
    out: List[Violation] = []
    for path in sorted(_SERVING_DIR.glob("*.py")):
        out += jit_site_violations(path.read_text(),
                                   f"serving/{path.name}")
    return out


@audit("donation")
def _donation_audit() -> List[Violation]:
    return engine_donation_violations() + run_jit_site_lint()
