"""Layer 4: stdlib-``ast`` lints over ``src/repro``.

Four rules, each encoding a hot-path invariant the jaxpr/Pallas audits
can't see because it lives in *source* convention rather than in any one
traced artifact:

  lint.jnp-repeat        models/ + serving/ must not call ``jnp.repeat``
                         — on cache-adjacent shapes it materializes a
                         (B, Hq, S, d)-class expansion; GQA paths pack
                         heads on the sublane axis instead and paging
                         masks broadcast+reshape (core/ keeps its
                         documented jnp fallback oracles, which ARE the
                         gather formulation the kernels replace).
  lint.host-sync         hot modules (models/, kernels/, core/, and
                         serving/ — the telemetry layer included) must
                         not call ``.item()`` or ``np.asarray`` — either
                         one is a device sync inside code that the
                         serving loop jits (the engine's host *scheduler*
                         in serving/engine.py syncs at chunk boundaries
                         by design and is exempt; telemetry.py /
                         trace_export.py are NOT, so observability can
                         never add a sync to the hot path).
  lint.interpret-default kernels/: every function with a defaulted
                         ``interpret`` parameter must default to None
                         ("derive from backend", kernels.resolve_interpret)
                         so no wrapper hard-codes a platform.
  lint.dispatch-routing  models/ + serving/ must not import
                         jax.experimental.pallas nor read the
                         REPRO_DISABLE_KERNELS env var — kernel gating
                         routes exclusively through core/dispatch.py's
                         ``use_*_kernel`` switches, and the kernel
                         wrappers own every pallas_call.
  lint.paged-gather      models/ + serving/ must not call
                         ``gather_pages`` — kernel-native page indexing
                         reads (page_id, offset) tiles straight from the
                         pool, so a per-step gather of the per-slot view
                         must never creep back onto the decode hot path
                         (models/paged_fallback.py, the designated
                         gathered-view fallback tier, is exempt).

Each rule is (id, applies-to-path predicate, AST checker) in ``RULES`` —
adding a rule is appending a tuple.  ``lint_source`` lints one buffer
(used by tests/test_analysis.py's violating fixtures); ``run_lint`` walks
the tree.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Callable, List, Tuple

from repro.analysis.registry import Violation, audit

SRC_ROOT = Path(__file__).resolve().parents[1]          # .../src/repro

KILL_SWITCH = "REPRO_DISABLE_KERNELS"


def _in(*dirs: str) -> Callable[[str], bool]:
    def applies(rel: str) -> bool:
        return any(rel.startswith(d + "/") for d in dirs)
    return applies


def _is_name_attr(node: ast.AST, base: str, attr: str) -> bool:
    return (isinstance(node, ast.Attribute) and node.attr == attr
            and isinstance(node.value, ast.Name) and node.value.id == base)


# ------------------------------------------------------------ rule bodies
def _check_jnp_repeat(rel: str, tree: ast.AST) -> List[Violation]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_name_attr(
                node.func, "jnp", "repeat"):
            out.append(Violation(
                "lint.jnp-repeat", f"{rel}:{node.lineno}",
                "jnp.repeat in models//serving/ — pack GQA heads on the "
                "sublane axis or broadcast+reshape a static expansion"))
    return out


def _check_host_sync(rel: str, tree: ast.AST) -> List[Violation]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr == "item" and not node.args):
            out.append(Violation(
                "lint.host-sync", f"{rel}:{node.lineno}",
                ".item() forces a device->host sync in a hot module"))
        if (_is_name_attr(node.func, "np", "asarray")
                or _is_name_attr(node.func, "numpy", "asarray")):
            out.append(Violation(
                "lint.host-sync", f"{rel}:{node.lineno}",
                "np.asarray() forces a device->host sync in a hot module "
                "(use jnp.asarray for device-side casts)"))
    return out


def _check_interpret_default(rel: str, tree: ast.AST) -> List[Violation]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        a = node.args
        # (arg, default) pairs for positional-or-kw and kw-only params;
        # positionals without defaults pair with None (pass-through args
        # like _forward(..., interpret, ...) are exempt — only a *default*
        # can hard-code a platform).
        pos = a.posonlyargs + a.args
        pairs = list(zip(reversed(pos), reversed(a.defaults)))
        pairs += [(arg, d) for arg, d in zip(a.kwonlyargs, a.kw_defaults)
                  if d is not None]
        for arg, default in pairs:
            if arg.arg != "interpret":
                continue
            if not (isinstance(default, ast.Constant)
                    and default.value is None):
                out.append(Violation(
                    "lint.interpret-default",
                    f"{rel}:{node.lineno}",
                    f"def {node.name}: interpret must default to None "
                    "(backend-derived via kernels.resolve_interpret), "
                    f"not {ast.unparse(default)}"))
    return out


def _check_dispatch_routing(rel: str, tree: ast.AST) -> List[Violation]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.startswith("jax.experimental.pallas"):
                    out.append(Violation(
                        "lint.dispatch-routing", f"{rel}:{node.lineno}",
                        "direct pallas import outside kernels/ — lower "
                        "through a kernels/ wrapper gated by "
                        "core/dispatch.py"))
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            names = {a.name for a in node.names}
            if (mod.startswith("jax.experimental.pallas")
                    or (mod == "jax.experimental" and "pallas" in names)):
                out.append(Violation(
                    "lint.dispatch-routing", f"{rel}:{node.lineno}",
                    "direct pallas import outside kernels/ — lower "
                    "through a kernels/ wrapper gated by core/dispatch.py"))
        elif (isinstance(node, ast.Constant)
              and node.value == KILL_SWITCH):
            out.append(Violation(
                "lint.dispatch-routing", f"{rel}:{node.lineno}",
                f"reads {KILL_SWITCH} directly — the kill switch is "
                "owned by core/dispatch.py (kernels_disabled())"))
    return out


def _check_paged_gather(rel: str, tree: ast.AST) -> List[Violation]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        named = (isinstance(fn, ast.Name) and fn.id == "gather_pages")
        attred = (isinstance(fn, ast.Attribute)
                  and fn.attr == "gather_pages")
        if named or attred:
            out.append(Violation(
                "lint.paged-gather", f"{rel}:{node.lineno}",
                "gather_pages in models//serving/ — decode reads the KV "
                "pool kernel-natively (scalar-prefetched page table); "
                "gathered views live only in models/paged_fallback.py"))
    return out


RULES: List[Tuple[str, Callable[[str], bool],
                  Callable[[str, ast.AST], List[Violation]]]] = [
    ("lint.jnp-repeat", _in("models", "serving"), _check_jnp_repeat),
    ("lint.host-sync", _in("models", "kernels", "core", "serving"),
     _check_host_sync),
    ("lint.interpret-default", _in("kernels"), _check_interpret_default),
    ("lint.dispatch-routing", _in("models", "serving"),
     _check_dispatch_routing),
    ("lint.paged-gather", _in("models", "serving"), _check_paged_gather),
]

# serving/engine.py is the host scheduler: np mirrors of slot state are
# its job.  models/paged_fallback.py is the designated gathered-view
# fallback tier for paged decode (jnp oracle / kill switch / bisection) —
# the one place a per-slot gather is allowed.  Nothing else is exempt
# from anything.
EXEMPT = {("lint.host-sync", "serving/engine.py"),
          ("lint.paged-gather", "models/paged_fallback.py")}


def lint_source(source: str, rel: str) -> List[Violation]:
    """Lint one buffer as if it lived at ``rel`` (posix, repro-relative,
    e.g. "models/foo.py").  Rule applicability follows the path."""
    tree = ast.parse(source, filename=rel)
    out: List[Violation] = []
    for rule_id, applies, check in RULES:
        if not applies(rel) or (rule_id, rel) in EXEMPT:
            continue
        out.extend(check(rel, tree))
    return out


def run_lint(root: Path = SRC_ROOT) -> List[Violation]:
    out: List[Violation] = []
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        out.extend(lint_source(path.read_text(), rel))
    return out


@audit("lint")
def _lint_audit() -> List[Violation]:
    return run_lint()
