"""Layer 7: golden memory-signature baselines with ratchet semantics.

The liveness pass (layer 5) reduces every registered memory entrypoint
to a four-number **memory signature** — peak live bytes, donated bytes,
eqn count, pallas-call count.  This layer diffs the signatures computed
at HEAD against the golden copies committed to
``scripts/analysis_baselines.json`` and fails CI on drift:

  memory.regression       peak live bytes grew, or donated bytes shrank
                          — the change made an entrypoint more
                          memory-hungry (or lost a donation)
  memory.stale-baseline   peak shrank or donated grew — an
                          *improvement* the baseline doesn't record yet;
                          refresh with ``scripts/update_baselines.py``
                          so the win is ratcheted in and can't silently
                          regress later
  memory.signature-drift  pallas-call count changed, or eqn count moved
                          more than ±10% — the program's shape changed
                          enough that the baseline no longer describes
                          it; re-baseline deliberately
  memory.baseline-missing the baseline file or an entry is absent —
                          run ``scripts/update_baselines.py``

Both directions fail on purpose (mirroring ``bench_floors.json``):
a gate that only catches regressions lets improvements evaporate
unrecorded, and the next regression hides inside the headroom.  The
refresh workflow is ``REPRO_UPDATE_BASELINES=1 scripts/analyze.sh`` or
``python scripts/update_baselines.py`` directly; commit the diff.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List

from repro.analysis import liveness
from repro.analysis.registry import Violation, audit

BASELINE_PATH = (pathlib.Path(__file__).resolve().parents[3]
                 / "scripts" / "analysis_baselines.json")
_REFRESH = "refresh: python scripts/update_baselines.py (commit the diff)"
# eqn counts wobble with jax version / fusion details; ±10% is shape
# drift worth a deliberate re-baseline, below that is noise
_EQN_DRIFT_FRAC = 0.10
_FIELDS = ("peak_live_bytes", "donated_bytes", "eqns", "pallas_calls")


def compute_signatures() -> Dict[str, Dict[str, int]]:
    """Signature dict per registered entrypoint, in registry order
    (reuses the memoized liveness traces)."""
    return {
        name: {f: getattr(rep.signature, f) for f in _FIELDS}
        for name, rep in liveness.all_reports().items()
    }


def load_baselines(path: pathlib.Path = BASELINE_PATH
                   ) -> Dict[str, Dict[str, int]]:
    data = json.loads(path.read_text())
    return data["entries"]


def diff_signatures(current: Dict[str, Dict[str, int]],
                    golden: Dict[str, Dict[str, int]]
                    ) -> List[Violation]:
    """Pure ratchet: compare signatures at HEAD against the golden
    copies.  Separated from I/O and tracing so tests can inject
    synthetic regressions."""
    out: List[Violation] = []
    for name in sorted(set(current) | set(golden)):
        if name not in golden:
            out.append(Violation(
                "memory.baseline-missing", name,
                f"entrypoint has no golden signature — {_REFRESH}"))
            continue
        if name not in current:
            out.append(Violation(
                "memory.baseline-missing", name,
                "golden signature exists but the entrypoint is no "
                f"longer registered — {_REFRESH}"))
            continue
        cur, gold = current[name], golden[name]

        peak_c, peak_g = cur["peak_live_bytes"], gold["peak_live_bytes"]
        if peak_c > peak_g:
            out.append(Violation(
                "memory.regression", name,
                f"peak live bytes {peak_g:,} -> {peak_c:,} "
                f"(+{peak_c - peak_g:,}) — the live set grew"))
        elif peak_c < peak_g:
            out.append(Violation(
                "memory.stale-baseline", name,
                f"peak live bytes {peak_g:,} -> {peak_c:,} "
                f"(-{peak_g - peak_c:,}) — improvement; {_REFRESH}"))

        don_c, don_g = cur["donated_bytes"], gold["donated_bytes"]
        if don_c < don_g:
            out.append(Violation(
                "memory.regression", name,
                f"donated bytes {don_g:,} -> {don_c:,} — a donation "
                "was lost (the input buffer now counts twice)"))
        elif don_c > don_g:
            out.append(Violation(
                "memory.stale-baseline", name,
                f"donated bytes {don_g:,} -> {don_c:,} — more donation; "
                f"{_REFRESH}"))

        pc_c, pc_g = cur["pallas_calls"], gold["pallas_calls"]
        if pc_c != pc_g:
            out.append(Violation(
                "memory.signature-drift", name,
                f"pallas-call count {pc_g} -> {pc_c} — a kernel was "
                f"added or dropped; {_REFRESH}"))

        eq_c, eq_g = cur["eqns"], gold["eqns"]
        if abs(eq_c - eq_g) > _EQN_DRIFT_FRAC * eq_g:
            out.append(Violation(
                "memory.signature-drift", name,
                f"eqn count {eq_g} -> {eq_c} (more than ±"
                f"{_EQN_DRIFT_FRAC:.0%}) — program shape changed; "
                f"{_REFRESH}"))
    return out


@audit("memory")
def _memory_audit() -> List[Violation]:
    if not BASELINE_PATH.exists():
        return [Violation(
            "memory.baseline-missing", str(BASELINE_PATH),
            f"golden baseline file not found — {_REFRESH}")]
    return diff_signatures(compute_signatures(), load_baselines())
