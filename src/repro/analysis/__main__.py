"""CLI: ``python -m repro.analysis`` — run every registered audit, print
violations, exit nonzero if any fired.  ``--only jaxpr,lint`` selects
layers; ``--list`` shows what's registered; ``--memory-report`` prints
the liveness waterfalls instead of auditing (``--out`` saves a copy).
When ``REPRO_MEMORY_REPORT_OUT`` is set, a normal audit run also writes
the report there (reusing the traces the audits already computed) so CI
keeps it as an artifact.  Wired into CI via ``scripts/analyze.sh``
(which ``scripts/ci_fast.sh`` runs before pytest).
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import List, Optional

from repro.analysis import registry
# importing the layers registers their audits
from repro.analysis import jaxpr_audit    # noqa: F401
from repro.analysis import lint           # noqa: F401
from repro.analysis import pallas_audit   # noqa: F401
from repro.analysis import trace_guard    # noqa: F401
from repro.analysis import liveness       # noqa: F401
from repro.analysis import donation       # noqa: F401
from repro.analysis import baselines      # noqa: F401


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static hot-path audits: jaxpr budgets/primitives, "
                    "Pallas VMEM & specs, engine retrace accounting, "
                    "source lints.")
    ap.add_argument("--only", metavar="NAMES",
                    help="comma-separated audit names (default: all)")
    ap.add_argument("--list", action="store_true", dest="list_audits",
                    help="list registered audits and exit")
    ap.add_argument("--memory-report", action="store_true",
                    dest="memory_report",
                    help="print the peak-live-bytes waterfalls and "
                         "top contributors per entrypoint, then exit")
    ap.add_argument("--out", metavar="PATH",
                    help="with --memory-report: also write the report "
                         "to PATH")
    args = ap.parse_args(argv)

    if args.list_audits:
        for name in registry.AUDITS:
            print(name)
        return 0

    if args.memory_report:
        text = liveness.format_memory_report()
        print(text)
        if args.out:
            with open(args.out, "w") as f:
                f.write(text + "\n")
            print(f"[analysis] memory report written to {args.out}")
        return 0

    names = ([n.strip() for n in args.only.split(",") if n.strip()]
             if args.only else None)

    def report(name: str, vs: List[registry.Violation]) -> None:
        status = "ok" if not vs else f"{len(vs)} violation(s)"
        print(f"[analysis] {name:<8} {status}", flush=True)
        for v in vs:
            print(f"  FAIL {v}", flush=True)

    t0 = time.perf_counter()
    try:
        violations = registry.run_audits(names, report)
    except KeyError as e:
        print(f"[analysis] {e}", file=sys.stderr)
        return 2
    dt = time.perf_counter() - t0
    artifact = os.environ.get("REPRO_MEMORY_REPORT_OUT")
    if artifact:
        # liveness/memory audits already traced everything; this just
        # formats the memoized reports
        try:
            with open(artifact, "w") as f:
                f.write(liveness.format_memory_report() + "\n")
            print(f"[analysis] memory report artifact: {artifact}")
        except Exception as e:     # artifact is best-effort, not a gate
            print(f"[analysis] memory report artifact failed: {e}",
                  file=sys.stderr)
    if violations:
        print(f"[analysis] FAILED: {len(violations)} violation(s) "
              f"in {dt:.1f}s")
        return 1
    print(f"[analysis] clean: {len(registry.AUDITS) if names is None else len(names)} "
          f"audit(s) in {dt:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
