"""Static analysis over the repo's compiled artifacts and source.

SPT's value proposition is what the hot path *doesn't* do: sparse MHA
never stores large attention weights, routed FFN never builds the dense
dispatch tensor, the serving loop never retraces or syncs per token.
Parity tests cannot see those properties — a refactor that resurrects a
``(B, G, C, d)`` dispatch buffer or an in-loop retrace keeps every test
green while the paper's memory/speed claims quietly evaporate.  This
package makes the claims machine-checked on CPU, no TPU needed:

  * ``jaxpr_audit``  — walks the ClosedJaxpr of registered hot
    entrypoints: per-eqn intermediate-size budgets, dispatch-buffer and
    cache-repeat shape patterns, forbidden host-callback primitives,
    f32-accumulator policy inside Pallas kernels, and expected
    pallas_call presence/absence per ``core/dispatch.py`` switch state.
  * ``pallas_audit`` — static VMEM-residency estimates from BlockSpecs +
    grid + scratch shapes against the per-platform budget, tile
    divisibility, and scalar-prefetch operand arity.
  * ``trace_guard``  — runtime context manager counting retraces of the
    engine's jitted functions (one trace per shape bucket over a full
    ``Engine.run()``), plus an opt-in ``jax.transfer_guard`` wrapper.
  * ``lint``         — stdlib-``ast`` rules over ``src/``: no
    ``jnp.repeat`` in models//serving/, no host syncs in hot modules,
    ``interpret=None`` defaults on kernel wrappers, kernel dispatch
    routed through ``core/dispatch.py``.

CLI: ``python -m repro.analysis`` (or ``scripts/analyze.sh``) runs every
registered audit and exits nonzero on violations.  Rules register via
``registry.audit``; hot entrypoints via ``jaxpr_audit.hot_entrypoint``.
"""
from repro.analysis.registry import AUDITS, Violation, audit, run_audits

__all__ = ["AUDITS", "Violation", "audit", "run_audits"]
