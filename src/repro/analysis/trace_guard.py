"""Layer 3: runtime retrace accounting for the serving engine.

The engine's whole performance story rests on "the generation traces
ONCE": decode runs in jit-compiled while_loop chunks keyed by a small
static tuple, prefill shapes are bucketed to powers of two, and host
state stays in numpy mirrors.  A one-line slip — passing a python scalar
one iteration and a () array the next (weak-type flip), keying a chunk
on a per-request value, rebuilding a jit object per scheduling iteration
— silently multiplies compiles while every output stays correct.

``TraceGuard`` wraps the engine's jitted callables, buckets each call's
signature by (treedef, leaf shapes/dtypes) — python scalars bucket like
() arrays of their result dtype precisely so weak-type flip-flops land
in ONE bucket while jit treats them as two — and afterwards compares
each function's jit-cache growth against the number of distinct buckets:

  trace.retrace            more new traces than distinct signature
                           buckets (weak-type churn, non-hashable-static
                           churn, donation mismatches)
  trace.per-iteration-jit  one logical callable backed by >1 jit objects
                           (a jax.jit rebuilt inside the serving loop —
                           every call compiles from scratch)

``guard_engine(engine)`` instruments a live Engine (chunk + prefill
builders and the cache-row writers) for the duration of a ``with``
block and raises on violations at exit.  ``no_implicit_transfers()`` is
the opt-in strict mode: it turns silent device<->host transfers inside
the block into errors via ``jax.transfer_guard`` (opt-in because the
engine's host scheduler legitimately syncs at chunk boundaries).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.registry import Violation, audit


def _canon_leaf(x: Any) -> Tuple:
    """Signature atom: arrays by (shape, dtype); python scalars as the
    () array jit would weakly promote them to; everything else by value
    (static args participate in the jit cache key by equality)."""
    if isinstance(x, (jax.Array, np.ndarray)):
        return ("arr", tuple(x.shape), jnp.dtype(x.dtype).name)
    if isinstance(x, (bool, int, float, complex)):
        return ("arr", (), jnp.dtype(jnp.result_type(x)).name)
    return ("static", repr(x))


def call_signature(args: Tuple, kwargs: Dict) -> Tuple:
    leaves, treedef = jax.tree_util.tree_flatten((args, kwargs))
    return (str(treedef),) + tuple(_canon_leaf(x) for x in leaves)


def _cache_size(fn) -> Optional[int]:
    probe = getattr(fn, "_cache_size", None)
    try:
        return int(probe()) if callable(probe) else None
    except Exception:
        return None


@dataclasses.dataclass
class _Tracked:
    name: str
    fn: Callable
    baseline: Optional[int]
    sigs: Set[Tuple] = dataclasses.field(default_factory=set)
    calls: int = 0


class TraceGuard:
    """Call-signature and jit-cache bookkeeping over tracked callables."""

    def __init__(self) -> None:
        self._by_id: Dict[int, _Tracked] = {}
        self._names: Dict[str, Set[int]] = {}

    def track(self, name: str, fn: Callable,
              unique: bool = False) -> Callable:
        """Return ``fn`` wrapped to record each call.  Tracking the same
        underlying object twice reuses one record.  ``unique=True``
        declares that this logical name must always resolve to the same
        jit object — a second object under the name is a
        per-iteration-jit violation even if each one traces once."""
        rec = self._by_id.get(id(fn))
        if rec is None:
            rec = _Tracked(name=name, fn=fn, baseline=_cache_size(fn))
            self._by_id[id(fn)] = rec
            key = name if unique else f"{name}#{len(self._by_id)}"
            self._names.setdefault(key, set()).add(id(fn))

        def wrapped(*args, **kwargs):
            rec.calls += 1
            rec.sigs.add(call_signature(args, kwargs))
            return fn(*args, **kwargs)

        return wrapped

    def violations(self) -> List[Violation]:
        out: List[Violation] = []
        for rec in self._by_id.values():
            size = _cache_size(rec.fn)
            if size is None or rec.baseline is None or not rec.calls:
                continue
            traces = size - rec.baseline
            buckets = len(rec.sigs)
            if traces > buckets:
                out.append(Violation(
                    "trace.retrace", rec.name,
                    f"{traces} new traces over {rec.calls} calls in only "
                    f"{buckets} signature bucket(s) — something "
                    "non-shape (weak type? unhashable static?) is "
                    "churning the jit cache"))
        for name, ids in self._names.items():
            if len(ids) > 1:
                recs = [self._by_id[i] for i in ids]
                out.append(Violation(
                    "trace.per-iteration-jit", name,
                    f"{len(ids)} distinct jit objects served this "
                    f"callable ({sum(r.calls for r in recs)} calls) — "
                    "the jit wrapper is being rebuilt instead of reused"))
        return out


@contextlib.contextmanager
def guard_engine(engine, raise_on_violation: bool = True):
    """Instrument a live ``serving.engine.Engine`` for the with-block:
    every jitted chunk/prefill the scheduler fetches and every cache-row
    writer call is tracked; at exit, retrace violations raise (or are
    left on ``guard.violations()`` with ``raise_on_violation=False``)."""
    guard = TraceGuard()
    saved = {}

    def hook_getter(attr: str, label: str):
        orig = getattr(engine, attr)
        saved[attr] = orig

        def getter(*args, **kwargs):
            fn = orig(*args, **kwargs)
            # the static key IS the args tuple: fetching the same key must
            # hand back the same jit object, so track it as unique
            return guard.track(f"{label}{args}" if args else label, fn,
                               unique=True)
        setattr(engine, attr, getter)

    def hook_fn(attr: str):
        fn = getattr(engine, attr, None)
        if fn is None:
            return
        saved[attr] = fn
        setattr(engine, attr, guard.track(attr.lstrip("_"), fn,
                                          unique=True))

    hook_getter("_get_chunk", "decode_chunk")
    hook_getter("_get_prefill", "prefill")
    for attr in ("_write_rows", "_alloc_rows", "_free_slot"):
        hook_fn(attr)
    try:
        yield guard
    finally:
        for attr, fn in saved.items():
            setattr(engine, attr, fn)
    if raise_on_violation:
        vs = guard.violations()
        if vs:
            raise RuntimeError(
                "trace guard violations:\n  "
                + "\n  ".join(str(v) for v in vs))


@contextlib.contextmanager
def no_implicit_transfers():
    """Strict mode: any implicit device<->host transfer in the block
    raises (jax.transfer_guard("disallow")).  Opt-in — the engine's host
    scheduler syncs by design, so apply this to pure device code only."""
    with jax.transfer_guard("disallow"):
        yield


@audit("trace")
def _trace_audit() -> List[Violation]:
    """Serve a tiny run end-to-end under the guard: the compiled chunk
    must trace once per (slots, max_gen, ...) bucket and the batched
    prefill once per (Bp, S) bucket.  Then drive the LONG-LIVED loop —
    continuous Poisson arrivals on a virtual clock, a mid-stream cancel,
    and a forced preemption whose victim re-admits by recompute — under
    the same guard: per-arrival scheduling must reuse the already-traced
    buckets, not compile per event."""
    from repro import configs
    import dataclasses as dc
    from repro.core.params import init_tree
    from repro.serving.engine import (ArrivalSchedule, Engine,
                                      ManualClock, Request)
    from repro.train.state import model_defs

    cfg = dc.replace(
        configs.get_smoke("qwen3-0.6b"), num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256, dtype=jnp.float32).with_spt(
            ffn_capacity_factor=8.0)
    params = jax.tree_util.tree_map(
        lambda a: a.astype(jnp.float32),
        init_tree(model_defs(cfg), jax.random.PRNGKey(0)))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, tokens=rng.integers(0, 256, size=ln).tolist(),
                    max_new_tokens=4)
            for i, ln in enumerate([5, 9, 12])]
    arrivals = [Request(uid=10 + i, priority=i % 2,
                        tokens=rng.integers(0, 256, size=ln).tolist(),
                        max_new_tokens=6)
                for i, ln in enumerate([4, 7, 11, 6, 9, 13])]
    fired = {"preempt": False}

    def chaos(e, iteration):
        if iteration == 3:
            e.cancel(12)
        if iteration >= 4 and not fired["preempt"]:
            fired["preempt"] = e.preempt()

    eng = Engine(cfg, params, max_len=32, num_slots=2, decode_chunk=4)
    with guard_engine(eng, raise_on_violation=False) as guard:
        eng.run(reqs)
        eng.serve(ArrivalSchedule.poisson(arrivals, 4.0, seed=0),
                  clock=ManualClock(dt=0.25), on_iteration=chaos)
    return guard.violations()
