"""Layer 2: static Pallas-kernel audits — VMEM residency, tile
divisibility, scalar-prefetch arity.

Kernels are captured by tracing their public wrapper ops with
``jax.make_jaxpr`` and reading each ``pallas_call`` eqn's GridMapping —
no monkeypatching, no execution, and (critically) no pollution of the
module-level jit caches the wrappers sit behind.  From the BlockSpecs +
grid + scratch shapes we bound what one grid step keeps resident in
VMEM; on TPU, blowing that budget is a *compile-time* failure, so this
audit is the CPU-side tripwire for a BlockSpec edit that would brick the
TPU build.

Rules:
  pallas.vmem-budget       2x (double-buffered) per-step block bytes +
                           scratch bytes > VMEM_BUDGET_BYTES
  pallas.tile-divisibility a grid-blocked operand dim is not a multiple
                           of its block dim (the wrappers zero-pad every
                           operand to tile multiples *before* the
                           pallas_call; a non-dividing shape here means a
                           padding precondition was dropped)
  pallas.scalar-prefetch   a kernel's scalar-prefetch operand count
                           drifted from its contract (grouped FFN
                           prefetches the plan index; decode FFN
                           prefetches choices + gates; everything else
                           prefetches nothing)
  pallas.no-kernel         a registered entry traced zero pallas_calls
                           (the audit itself went vacuous)

Representative shapes are serving-scale (d_model 1024, S 512, d_ff
3072) so the VMEM estimate reflects deployment tiles, not smoke tests.
New kernels register with ``@kernel_entry("name")`` returning
``(fn, args, expectations)``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.jaxpr_audit import iter_eqns
from repro.analysis.registry import Violation, audit

# Per-core VMEM on current TPU generations (see the Pallas guide); one
# grid step's working set must fit with room for double buffering.
VMEM_BUDGET_BYTES = 16 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class BlockInfo:
    block_shape: Tuple[Optional[int], ...]
    array_shape: Tuple[int, ...]
    dtype: str
    itemsize: int
    any_space: bool            # ANY-space operands stay in HBM

    @property
    def block_bytes(self) -> int:
        size = 1
        for bdim, adim in zip(self.block_shape, self.array_shape):
            size *= adim if bdim is None else int(bdim)
        return size * self.itemsize


@dataclasses.dataclass(frozen=True)
class PallasCallInfo:
    name: str                  # kernel fn name (+ src line)
    grid: Tuple[int, ...]
    num_index_operands: int    # scalar-prefetch operands
    num_scratch_operands: int
    blocks: Tuple[BlockInfo, ...]   # inputs then outputs
    scratch_bytes: int

    @property
    def short_name(self) -> str:
        return str(self.name).split(" ")[0]

    @property
    def vmem_bytes(self) -> int:
        """One grid step's VMEM residency bound: every non-ANY in/out
        block double-buffered (the pipeline overlaps the next step's
        copies) plus all scratch."""
        blocks = sum(b.block_bytes for b in self.blocks if not b.any_space)
        return 2 * blocks + self.scratch_bytes


def _scratch_nbytes(kernel_jaxpr, num_scratch: int) -> int:
    if not num_scratch:
        return 0
    total = 0
    for var in kernel_jaxpr.invars[-num_scratch:]:
        aval = getattr(var.aval, "inner_aval", var.aval)
        shape = getattr(aval, "shape", ())
        dtype = getattr(aval, "dtype", jnp.float32)
        size = 1
        for dim in shape:
            size *= int(dim)
        total += size * jnp.dtype(dtype).itemsize
    return total


def collect_pallas_calls(fn: Callable, *args) -> List[PallasCallInfo]:
    """Trace ``fn(*args)`` (ShapeDtypeStructs welcome) and decode every
    pallas_call eqn, however deeply nested."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    out = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        gm = eqn.params["grid_mapping"]
        blocks = []
        for bm in gm.block_mappings:
            asd = bm.array_shape_dtype
            blocks.append(BlockInfo(
                block_shape=tuple(bm.block_shape),
                array_shape=tuple(asd.shape),
                dtype=jnp.dtype(asd.dtype).name,
                itemsize=jnp.dtype(asd.dtype).itemsize,
                any_space="any" in str(bm.block_aval).lower()))
        out.append(PallasCallInfo(
            name=str(eqn.params.get("name_and_src_info", "pallas_call")),
            grid=tuple(int(g) for g in gm.grid),
            num_index_operands=int(gm.num_index_operands),
            num_scratch_operands=int(gm.num_scratch_operands),
            blocks=tuple(blocks),
            scratch_bytes=_scratch_nbytes(eqn.params["jaxpr"],
                                          gm.num_scratch_operands)))
    return out


# ------------------------------------------------------------ rule bodies
def vmem_violations(calls: Sequence[PallasCallInfo], entry: str,
                    budget: int = VMEM_BUDGET_BYTES) -> List[Violation]:
    out = []
    for c in calls:
        if c.vmem_bytes > budget:
            out.append(Violation(
                "pallas.vmem-budget", entry,
                f"{c.short_name}: ~{c.vmem_bytes} B resident per grid "
                f"step (2x blocks + {c.scratch_bytes} B scratch) > "
                f"budget {budget} B"))
    return out


def tile_divisibility_violations(calls: Sequence[PallasCallInfo],
                                 entry: str) -> List[Violation]:
    out = []
    for c in calls:
        for i, b in enumerate(c.blocks):
            for bdim, adim in zip(b.block_shape, b.array_shape):
                if bdim is None or not isinstance(adim, int):
                    continue
                if int(bdim) <= 0 or adim % int(bdim) != 0:
                    out.append(Violation(
                        "pallas.tile-divisibility", entry,
                        f"{c.short_name} operand {i}: array "
                        f"{b.array_shape} not a multiple of block "
                        f"{b.block_shape} — a zero-pad precondition "
                        "was dropped"))
    return out


def scalar_prefetch_violations(calls: Sequence[PallasCallInfo], entry: str,
                               expected: Dict[str, int]) -> List[Violation]:
    """expected: substring of the kernel's name+src info -> required
    num_index_operands (kernels not matched by any key must prefetch
    nothing)."""
    out = []
    for c in calls:
        want = 0
        for key, n in expected.items():
            if key in c.name:
                want = n
                break
        if c.num_index_operands != want:
            out.append(Violation(
                "pallas.scalar-prefetch", entry,
                f"{c.short_name} prefetches {c.num_index_operands} "
                f"scalar operand(s), contract says {want}"))
    return out


def audit_calls(calls: Sequence[PallasCallInfo], entry: str,
                prefetch: Optional[Dict[str, int]] = None,
                budget: int = VMEM_BUDGET_BYTES) -> List[Violation]:
    if not calls:
        return [Violation("pallas.no-kernel", entry,
                          "entry traced zero pallas_calls — the audit "
                          "is vacuous (wrapper stopped lowering?)")]
    return (vmem_violations(calls, entry, budget)
            + tile_divisibility_violations(calls, entry)
            + scalar_prefetch_violations(calls, entry, prefetch or {}))


# --------------------------------------------------------- kernel entries
KERNEL_ENTRIES: Dict[str, Callable[[], List[Violation]]] = {}


def kernel_entry(name: str):
    def register(fn):
        if name in KERNEL_ENTRIES:
            raise ValueError(f"duplicate kernel entry {name!r}")
        KERNEL_ENTRIES[name] = fn
        return fn
    return register


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _pq_setup(d: int = 64, m: int = 8):
    from repro.core import pq
    from repro.core.params import init_tree
    pcfg = pq.PQConfig(head_dim=d, code_dim=m, num_codewords=16)
    cb = jax.eval_shape(lambda: init_tree(
        pq.param_defs(pcfg), jax.random.PRNGKey(0)))["codebooks"]
    return pcfg, cb


@kernel_entry("sparse_attention.prefill")
def _entry_sparse_prefill() -> List[Violation]:
    from repro.core import sparse_attention as sa
    from repro.kernels.sparse_attention import ops as sa_ops
    entry = "kernels.sparse_mha[prefill b2 h8/2 s512 d64]"
    b, hq, hk, s, d = 2, 8, 2, 512, 64
    pcfg, cb = _pq_setup(d)
    scfg = sa.SparseAttentionConfig(pq=pcfg, top_fraction=0.25, min_l=8)
    calls = collect_pallas_calls(
        lambda q, k, v, cb: sa_ops.sparse_mha(q, k, v, cb, scfg,
                                              d ** -0.5, causal=True,
                                              interpret=True)[0],
        _f32(b, hq, s, d), _f32(b, hk, s, d), _f32(b, hk, s, d), cb)
    return audit_calls(calls, entry)


@kernel_entry("sparse_attention.decode")
def _entry_sparse_decode() -> List[Violation]:
    """Both decode tiers at serving scale: the fused one-pass kernel
    (histogram scratch rides the same grid — nothing prefetched) and the
    two-pass bisection pair."""
    from repro.core import sparse_attention as sa
    from repro.kernels.sparse_attention import ops as sa_ops
    b, hq, hk, s, d, m = 4, 8, 2, 1024, 64, 8
    pcfg, cb = _pq_setup(d, m)
    scfg = sa.SparseAttentionConfig(pq=pcfg, top_fraction=0.25, min_l=8)
    args = (_f32(b, hq, 1, d), _f32(b, hk, s, d), _f32(b, hk, s, d),
            jax.ShapeDtypeStruct((b, hk, s, d // m), jnp.int8), cb,
            jax.ShapeDtypeStruct((b, s), jnp.bool_))
    out = []
    for fuse, tag in ((True, "fused"), (False, "two-pass")):
        entry = f"kernels.sparse_mha_decode[{tag} b4 h8/2 s1024 d64]"
        calls = collect_pallas_calls(
            lambda q, k, v, c, cb, kv: sa_ops.sparse_mha_decode(
                q, k, v, c, cb, scfg, d ** -0.5, kv, interpret=True,
                fuse=fuse), *args)
        out += audit_calls(calls, entry)
        want = 1 if fuse else 2
        if len(calls) != want:
            out.append(Violation(
                "pallas.no-kernel", entry,
                f"expected {want} pallas_call(s), traced {len(calls)}"))
    return out


@kernel_entry("sparse_attention.decode_paged")
def _entry_sparse_decode_paged() -> List[Violation]:
    """Kernel-native paged decode: sparse and dense kernels must each
    prefetch exactly ONE scalar operand (the clamped page table driving
    the pool index_maps), tile within page bounds, and stay inside the
    VMEM budget at serving-scale page counts."""
    from repro.core import sparse_attention as sa
    from repro.kernels.sparse_attention import ops as sa_ops
    b, hq, hk, d, m = 4, 8, 2, 64, 8
    ps, mp, pool = 128, 8, 64                 # view 1024 rows/slot
    pcfg, cb = _pq_setup(d, m)
    scfg = sa.SparseAttentionConfig(pq=pcfg, top_fraction=0.25, min_l=8)
    pt = jax.ShapeDtypeStruct((b, mp), jnp.int32)
    kvv = jax.ShapeDtypeStruct((b, mp * ps), jnp.bool_)
    entry = f"kernels.sparse_mha_decode_paged[b4 h8/2 ps{ps} mp{mp} d64]"
    calls = collect_pallas_calls(
        lambda q, k, v, c, cb, kv, pt: sa_ops.sparse_mha_decode_paged(
            q, k, v, c, cb, scfg, d ** -0.5, kv, pt, interpret=True),
        _f32(b, hq, 1, d), _f32(pool, hk, ps, d), _f32(pool, hk, ps, d),
        jax.ShapeDtypeStruct((pool, hk, ps, d // m), jnp.int8), cb,
        kvv, pt)
    out = audit_calls(calls, entry, prefetch={"sparse_attention.py": 1})
    entry_d = f"kernels.dense_mha_decode_paged[b4 h8/2 ps{ps} mp{mp} d64]"
    calls_d = collect_pallas_calls(
        lambda q, k, v, kv, pt: sa_ops.dense_mha_decode_paged(
            q, k, v, d ** -0.5, kv, pt, interpret=True),
        _f32(b, hq, 1, d), _f32(pool, hk, ps, d), _f32(pool, hk, ps, d),
        kvv, pt)
    out += audit_calls(calls_d, entry_d, prefetch={"sparse_attention.py": 1})
    return out


@kernel_entry("routed_ffn.grouped")
def _entry_routed_grouped() -> List[Violation]:
    from repro.core import lora as lora_mod
    from repro.core import routed_ffn as rf
    from repro.core.params import init_tree
    from repro.kernels.routed_ffn import ops as rffn_ops
    entry = "kernels.routed_ffn[grouped b2 s256 d1024 f3072 g8]"
    lcfg = lora_mod.LoRAConfig(rank=8, alpha=8.0, enabled=True)
    rcfg = rf.RoutedFFNConfig(d_model=1024, d_ff=3072, num_groups=8,
                              active_groups=2, capacity_factor=2.0,
                              gated=True, activation="gelu")
    p = jax.eval_shape(lambda: init_tree(rf.param_defs(rcfg, lcfg),
                                         jax.random.PRNGKey(0)))
    calls = collect_pallas_calls(
        lambda p, x: rffn_ops.routed_ffn(x, p, rcfg, lcfg,
                                         interpret=True)[0],
        p, _f32(2, 256, 1024))
    # the grouped kernel scalar-prefetches the (B, G, C) plan index
    return audit_calls(calls, entry, prefetch={"routed_ffn.py": 1})


@kernel_entry("routed_ffn.decode")
def _entry_routed_decode() -> List[Violation]:
    from repro.core import lora as lora_mod
    from repro.core import routed_ffn as rf
    from repro.core.params import init_tree
    from repro.kernels.routed_ffn import ops as rffn_ops
    entry = "kernels.routed_ffn_decode[b8 d1024 f3072 g8]"
    lcfg = lora_mod.LoRAConfig(rank=8, alpha=8.0, enabled=True)
    rcfg = rf.RoutedFFNConfig(d_model=1024, d_ff=3072, num_groups=8,
                              active_groups=2, capacity_factor=2.0,
                              gated=True, activation="gelu")
    p = jax.eval_shape(lambda: init_tree(rf.param_defs(rcfg, lcfg),
                                         jax.random.PRNGKey(0)))
    calls = collect_pallas_calls(
        lambda p, x: rffn_ops.routed_ffn_decode(x, p, rcfg, lcfg,
                                                interpret=True)[0],
        p, _f32(8, 1, 1024))
    # block-gather decode kernel scalar-prefetches choices AND gates
    return audit_calls(calls, entry, prefetch={"routed_ffn.py": 2})


@kernel_entry("pq_quantize.assign")
def _entry_pq_assign() -> List[Violation]:
    from repro.kernels.pq_quantize import ops as pq_ops
    entry = "kernels.pq_assign[b2 h8 s512 d64]"
    _, cb = _pq_setup(64)
    calls = collect_pallas_calls(
        lambda x, cb: pq_ops.pq_assign(x, cb, interpret=True),
        _f32(2, 8, 512, 64), cb)
    return audit_calls(calls, entry)


@audit("pallas")
def _pallas_audit() -> List[Violation]:
    out: List[Violation] = []
    for name in KERNEL_ENTRIES:
        out.extend(KERNEL_ENTRIES[name]())
    return out
