"""Declarative rule registry shared by every analysis layer.

An *audit* is a zero-arg callable returning a list of ``Violation``s.
Layers register theirs with the ``@audit("name")`` decorator at import
time; the CLI (``python -m repro.analysis``) imports the layer modules
and runs the registry.  Keeping the registry dumb (name -> callable)
means a new rule family is one decorated function away — no CLI or CI
changes needed.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional

AuditFn = Callable[[], List["Violation"]]

AUDITS: Dict[str, AuditFn] = {}


@dataclasses.dataclass(frozen=True)
class Violation:
    """One rule failure.

    rule:   dotted rule id, e.g. "jaxpr.dispatch-buffer" — stable names
            that tests and suppressions can key on.
    entry:  what was audited (hot entrypoint, kernel call, file:line).
    detail: human-readable specifics (shapes, bytes, primitive names).
    """
    rule: str
    entry: str
    detail: str

    def __str__(self) -> str:
        return f"{self.rule} @ {self.entry}: {self.detail}"


def audit(name: str) -> Callable[[AuditFn], AuditFn]:
    """Register ``fn`` as the audit called ``name`` (one per name)."""
    def register(fn: AuditFn) -> AuditFn:
        if name in AUDITS:
            raise ValueError(f"duplicate audit {name!r}")
        AUDITS[name] = fn
        return fn
    return register


def run_audits(names: Optional[Iterable[str]] = None,
               report: Optional[Callable[[str, List[Violation]], None]]
               = None) -> List[Violation]:
    """Run the selected audits (all when ``names`` is None) in
    registration order; ``report(name, violations)`` fires after each so
    the CLI can stream progress."""
    picked = list(AUDITS) if names is None else list(names)
    unknown = [n for n in picked if n not in AUDITS]
    if unknown:
        raise KeyError(f"unknown audits {unknown}; have {sorted(AUDITS)}")
    out: List[Violation] = []
    for name in picked:
        vs = AUDITS[name]()
        if report is not None:
            report(name, vs)
        out.extend(vs)
    return out
