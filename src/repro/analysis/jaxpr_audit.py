"""Layer 1: structural audits of hot-entrypoint jaxprs.

The paper's efficiency claims are *absences* — no dense dispatch buffer
at decode, no repeated GQA cache, no host callback inside the compiled
chunk — and absences don't fail parity tests.  Here each registered hot
entrypoint is traced with ``jax.make_jaxpr`` over ShapeDtypeStructs (no
FLOPs, no device buffers) and the resulting program is checked eqn by
eqn.  Rules (stable ids tests key on):

  jaxpr.dispatch-buffer     a decode-shaped call materializes a
                            (B, G/E, C, ·) capacity buffer
  jaxpr.cache-repeat        a decode attention path materializes a
                            (B, Hq, S, ·) tensor with Hq > Hk — the GQA
                            cache was expanded instead of packed
  jaxpr.paged-gather        a paged decode chunk materializes a gathered
                            per-slot (B, Hk, S, ·) view of the KV pool —
                            the kernel-native route reads (page_id,
                            offset) tiles directly
  jaxpr.intermediate-budget an eqn output exceeds a byte budget (rule +
                            ``auto_budget`` kept for tests/ad-hoc use;
                            at HEAD the per-entry byte gate is the
                            liveness-derived memory-signature ratchet in
                            analysis/liveness.py + analysis/baselines.py)
  jaxpr.forbidden-primitive host callbacks / prints inside a hot path
  jaxpr.accum-dtype         a dot/exp inside a Pallas kernel body does
                            not accumulate in float32
  jaxpr.kernel-missing      a dispatch switch says "Pallas" but no
                            pallas_call lowered
  jaxpr.kernel-present      the kill switch (or an impl=jnp override)
                            says "no kernels" but a pallas_call lowered

Helper predicates are importable on their own — tests/test_moe_kernel.py
and tests/test_routed_ffn_kernel.py assert their kernel-shape properties
through them, so the test suite and ``python -m repro.analysis`` enforce
the same definitions.  New entrypoints register with
``@hot_entrypoint("name")``.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, Iterator, List, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import core as jcore

from repro.analysis.registry import Violation, audit

# Host round-trips that must never appear inside a servable entrypoint.
FORBIDDEN_PRIMITIVES = frozenset({
    "io_callback", "pure_callback", "callback", "debug_callback",
    "debug_print",
})


# ----------------------------------------------------------- jaxpr walking
def iter_eqns(jaxpr) -> Iterator:
    """Every eqn of ``jaxpr`` and of any jaxpr nested in eqn params
    (pjit/while/scan/cond bodies, custom_vjp calls, pallas_call kernels)."""
    if isinstance(jaxpr, jcore.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _param_jaxprs(eqn):
            yield from iter_eqns(sub)


def _param_jaxprs(eqn) -> Iterator:
    for val in eqn.params.values():
        for item in (val if isinstance(val, (list, tuple)) else (val,)):
            if isinstance(item, (jcore.Jaxpr, jcore.ClosedJaxpr)):
                yield item
            elif hasattr(item, "jaxpr") and isinstance(
                    getattr(item, "jaxpr"), (jcore.Jaxpr, jcore.ClosedJaxpr)):
                yield item.jaxpr


def _eqn_site(eqn) -> str:
    return str(eqn.primitive.name)


def count_primitive(jaxpr, name: str) -> int:
    return sum(1 for eqn in iter_eqns(jaxpr) if eqn.primitive.name == name)


def pallas_call_count(jaxpr) -> int:
    return count_primitive(jaxpr, "pallas_call")


def _out_shapes(eqn):
    for v in eqn.outvars:
        shape = getattr(v.aval, "shape", None)
        if shape is not None:
            yield v, shape


# ------------------------------------------------------------ rule bodies
def dispatch_buffer_violations(jaxpr, batch: int, groups: int,
                               entry: str = "jaxpr") -> List[Violation]:
    """Any 4-d intermediate (batch, groups, ·, ·) is a resurrected
    capacity dispatch buffer — decode-shaped calls index weight blocks
    directly and must never build one (PR-3 acceptance property)."""
    out = []
    for eqn in iter_eqns(jaxpr):
        for v, shape in _out_shapes(eqn):
            if len(shape) == 4 and shape[0] == batch and shape[1] == groups:
                out.append(Violation(
                    "jaxpr.dispatch-buffer", entry,
                    f"{eqn.primitive.name} builds dispatch-shaped "
                    f"intermediate {tuple(shape)} (B={batch}, G={groups})"))
    return out


def cache_repeat_violations(jaxpr, num_q_heads: int, num_kv_heads: int,
                            min_seq: int, entry: str = "jaxpr"
                            ) -> List[Violation]:
    """A (B, Hq, S, ·) intermediate with Hq > Hk and S at cache length
    means the GQA KV cache (or its code cache) was expanded to the query
    heads — exactly the materialization the fused decode path avoids by
    packing the head group on the sublane axis."""
    if num_q_heads <= num_kv_heads:
        return []
    out = []
    for eqn in iter_eqns(jaxpr):
        for v, shape in _out_shapes(eqn):
            if (len(shape) == 4 and shape[1] == num_q_heads
                    and shape[2] >= min_seq):
                out.append(Violation(
                    "jaxpr.cache-repeat", entry,
                    f"{eqn.primitive.name} expands a cache to "
                    f"{tuple(shape)} (Hq={num_q_heads} > Hk="
                    f"{num_kv_heads}, S>={min_seq})"))
    return out


def paged_gather_violations(jaxpr, batch: int, num_kv_heads: int,
                            view: int, page_size: int, max_pages: int,
                            entry: str = "jaxpr") -> List[Violation]:
    """A (B, Hk, >=view, ·) — or pre-transpose (B, MP, Hk, ps, ·) —
    intermediate inside a paged decode chunk is a materialized per-slot
    gather of the KV pool: the kernel-native route addresses (page_id,
    offset) tiles straight from the pool and must never build one.
    (The MP dim in the 5-d form is required so layer-stacked pool
    carries (L, P, Hk, ps, ·) of scan/while bodies don't alias it.)"""
    out = []
    for eqn in iter_eqns(jaxpr):
        for v, shape in _out_shapes(eqn):
            gathered = (len(shape) == 4 and shape[0] == batch
                        and shape[1] == num_kv_heads
                        and isinstance(shape[2], int) and shape[2] >= view)
            pre_t = (len(shape) == 5 and shape[0] == batch
                     and shape[1] == max_pages
                     and shape[2] == num_kv_heads and shape[3] == page_size)
            if gathered or pre_t:
                out.append(Violation(
                    "jaxpr.paged-gather", entry,
                    f"{eqn.primitive.name} materializes a gathered "
                    f"per-slot KV view {tuple(shape)} (B={batch}, "
                    f"Hk={num_kv_heads}, view={view}) in a paged decode "
                    "chunk"))
    return out


def big_intermediate_violations(jaxpr, max_bytes: int,
                                entry: str = "jaxpr") -> List[Violation]:
    out = []
    for eqn in iter_eqns(jaxpr):
        for v, shape in _out_shapes(eqn):
            dtype = getattr(v.aval, "dtype", None)
            if dtype is None:
                continue
            size = 1
            for dim in shape:
                if not isinstance(dim, int):
                    size = 0      # dynamic dim — can't bound statically
                    break
                size *= dim
            nbytes = size * jnp.dtype(dtype).itemsize
            if nbytes > max_bytes:
                out.append(Violation(
                    "jaxpr.intermediate-budget", entry,
                    f"{eqn.primitive.name} builds {tuple(shape)} "
                    f"{jnp.dtype(dtype).name} = {nbytes} B "
                    f"(budget {max_bytes} B)"))
    return out


def forbidden_primitive_violations(
        jaxpr, entry: str = "jaxpr",
        forbidden: frozenset = FORBIDDEN_PRIMITIVES) -> List[Violation]:
    out = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name in forbidden:
            out.append(Violation(
                "jaxpr.forbidden-primitive", entry,
                f"{eqn.primitive.name} (host round-trip) inside a hot "
                "entrypoint"))
    return out


def accum_dtype_violations(jaxpr, entry: str = "jaxpr") -> List[Violation]:
    """Inside every pallas_call kernel body: dots and exp must produce
    f32 (the online-softmax state and FFN combine accumulate there even
    for bf16 operands — preferred_element_type=f32 policy)."""
    out = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        kernel = eqn.params.get("jaxpr")
        if kernel is None:
            continue
        name = eqn.params.get("name_and_src_info", "pallas_call")
        for keqn in iter_eqns(kernel):
            if keqn.primitive.name not in ("dot_general", "exp"):
                continue
            for v, shape in _out_shapes(keqn):
                dtype = getattr(v.aval, "dtype", None)
                if dtype is not None and jnp.dtype(dtype) != jnp.float32:
                    out.append(Violation(
                        "jaxpr.accum-dtype", entry,
                        f"{keqn.primitive.name} in kernel "
                        f"{str(name).split(' ')[0]} accumulates in "
                        f"{jnp.dtype(dtype).name}, not float32"))
    return out


def kernel_count_violations(jaxpr, entry: str, expect: str,
                            exact: Optional[int] = None) -> List[Violation]:
    """expect: "some" (dispatch switches selected Pallas), "none" (kill
    switch / jnp override active), or "exact" with ``exact`` calls."""
    n = pallas_call_count(jaxpr)
    if expect == "some" and n == 0:
        return [Violation("jaxpr.kernel-missing", entry,
                          "dispatch selected the Pallas path but no "
                          "pallas_call lowered")]
    if expect == "none" and n > 0:
        return [Violation("jaxpr.kernel-present", entry,
                          f"{n} pallas_call(s) lowered with kernels "
                          "switched off")]
    if expect == "exact" and n != exact:
        return [Violation(
            "jaxpr.kernel-missing" if n < (exact or 0)
            else "jaxpr.kernel-present", entry,
            f"expected exactly {exact} pallas_call(s), found {n}")]
    return []


def auto_budget(*trees, factor: float = 1.5) -> int:
    """Byte budget from the traced call's own operands: ``factor`` x the
    largest param/input/cache leaf.  A decode step that allocates beyond
    every operand is materializing something the paper says it avoids."""
    biggest = 0
    for tree in trees:
        for leaf in jax.tree_util.tree_leaves(tree):
            shape = getattr(leaf, "shape", None)
            dtype = getattr(leaf, "dtype", None)
            if shape is None or dtype is None:
                continue
            size = 1
            for dim in shape:
                size *= int(dim)
            biggest = max(biggest, size * jnp.dtype(dtype).itemsize)
    return int(biggest * factor)


def _abstract(tree):
    """Concrete/initializer tree -> ShapeDtypeStructs (trace-only)."""
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


# --------------------------------------------------------- hot entrypoints
ENTRYPOINTS: Dict[str, Callable[[], List[Violation]]] = {}


def hot_entrypoint(name: str):
    def register(fn):
        if name in ENTRYPOINTS:
            raise ValueError(f"duplicate hot entrypoint {name!r}")
        ENTRYPOINTS[name] = fn
        return fn
    return register


def _tiny_lm_cfg(**spt):
    from repro import configs
    cfg = dataclasses.replace(
        configs.get_smoke("qwen3-0.6b"), num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256, dtype=jnp.float32)
    return cfg.with_spt(ffn_capacity_factor=8.0, **spt)


def _lm_params(cfg):
    from repro.core.params import init_tree
    from repro.train.state import model_defs
    return jax.eval_shape(
        lambda: init_tree(model_defs(cfg), jax.random.PRNGKey(0)))


def engine_chunk_args(eng, slots: int = 2, max_gen: int = 4):
    """Abstract decode-chunk operands exactly as ``Engine._decode_once``
    passes them (contiguous or paged placeholders, following the
    engine's kv_layout).  Shared by the jaxpr trace here, the liveness
    analyzer, and the donation auditor so all three see one signature."""
    from repro.serving import kv_pages as kvp
    from repro.serving.engine import abstract_decode_caches

    cfg, max_len = eng.cfg, eng.max_len
    params = _abstract(eng.params)
    if eng._paged:
        caches = abstract_decode_caches(cfg, slots, max_len,
                                        kv_pages=eng.kv_pages)
        page_table = _abstract(
            kvp.init_page_table(slots, eng.max_pages_per_slot))
        astate = _abstract(kvp.init_state(eng.kv_pages))
    else:
        caches = abstract_decode_caches(cfg, slots, max_len)
        page_table = _abstract(kvp.init_page_table(slots, 1))
        astate = _abstract(kvp.init_state(1))
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    return (params, caches, page_table, astate,
            i32(slots), i32(slots),                       # tok, pos
            jax.ShapeDtypeStruct((slots,), jnp.bool_),    # active
            i32(slots), i32(slots),                       # n_gen, limit
            i32(slots, max_gen),                          # buf
            jax.ShapeDtypeStruct((slots, 2), jnp.uint32),  # keys
            f32(slots), i32(slots), f32(slots))           # temps/topks/topps


def _engine_chunk_jaxpr(cfg, slots: int = 2, max_gen: int = 4,
                        max_len: int = 32):
    """Trace the engine's compiled greedy decode chunk exactly as
    ``Engine.run`` builds it."""
    from repro.serving.engine import Engine

    params = _lm_params(cfg)
    eng = Engine(cfg, params, max_len=max_len, jit=False,
                 num_slots=slots, decode_chunk=4)
    chunk = eng._get_chunk(slots, max_gen, greedy=True, eos_id=None)
    args = engine_chunk_args(eng, slots, max_gen)
    caches = args[1]
    return jax.make_jaxpr(chunk)(*args), params, caches, args


@hot_entrypoint("engine.decode_chunk")
def _audit_decode_chunk() -> List[Violation]:
    entry = "engine.decode_chunk[kernel]"
    cfg = _tiny_lm_cfg(decode_attn_impl="kernel", ffn_impl="pallas")
    slots, max_len = 2, 32
    jaxpr, params, caches, _ = _engine_chunk_jaxpr(cfg, slots=slots,
                                                   max_len=max_len)
    out = []
    out += forbidden_primitive_violations(jaxpr, entry)
    out += kernel_count_violations(jaxpr, entry, "some")
    out += dispatch_buffer_violations(jaxpr, slots, cfg.spt.ffn_groups,
                                      entry)
    out += cache_repeat_violations(jaxpr, cfg.num_heads, cfg.num_kv_heads,
                                   max_len, entry)
    out += accum_dtype_violations(jaxpr, entry)
    return out


@hot_entrypoint("engine.decode_chunk_kernels_off")
def _audit_decode_chunk_disabled() -> List[Violation]:
    """REPRO_DISABLE_KERNELS=1 must demote the same chunk to pure jnp —
    no pallas_call may survive the kill switch (trace-time check, so the
    env var is set only around the trace)."""
    entry = "engine.decode_chunk[kernels-off]"
    prev = os.environ.get("REPRO_DISABLE_KERNELS")
    os.environ["REPRO_DISABLE_KERNELS"] = "1"
    try:
        cfg = _tiny_lm_cfg(decode_attn_impl="kernel", ffn_impl="pallas")
        jaxpr, _, _, _ = _engine_chunk_jaxpr(cfg)
    finally:
        if prev is None:
            os.environ.pop("REPRO_DISABLE_KERNELS", None)
        else:
            os.environ["REPRO_DISABLE_KERNELS"] = prev
    return (kernel_count_violations(jaxpr, entry, "none")
            + forbidden_primitive_violations(jaxpr, entry))


@hot_entrypoint("engine.decode_chunk_telemetry")
def _audit_decode_chunk_telemetry() -> List[Violation]:
    """telemetry="off" must be zero-cost: the compiled decode chunk's
    jaxpr is eqn-for-eqn identical to a build that never heard of
    telemetry (the default config), with the legacy 9-output carry.
    telemetry="counters" must actually thread the counter tree (more
    eqns, more outputs) — a silent no-op counter path would report
    zeros as real keep rates."""
    entry = "engine.decode_chunk[telemetry]"
    base = _tiny_lm_cfg()
    jaxpr_default, _, _, _ = _engine_chunk_jaxpr(base)
    jaxpr_off, _, _, _ = _engine_chunk_jaxpr(base.with_spt(telemetry="off"))
    jaxpr_ctr, _, _, _ = _engine_chunk_jaxpr(
        base.with_spt(telemetry="counters"))
    out: List[Violation] = []
    n_default = sum(1 for _ in iter_eqns(jaxpr_default))
    n_off = sum(1 for _ in iter_eqns(jaxpr_off))
    n_ctr = sum(1 for _ in iter_eqns(jaxpr_ctr))
    if n_off != n_default:
        out.append(Violation(
            "jaxpr.telemetry-cost", entry,
            f"telemetry=off chunk has {n_off} eqns vs {n_default} for the "
            "default config — the off path must be zero-cost"))
    n_out_default = len(jaxpr_default.jaxpr.outvars)   # flattened leaves
    n_out_off = len(jaxpr_off.jaxpr.outvars)
    if n_out_off != n_out_default:
        out.append(Violation(
            "jaxpr.telemetry-cost", entry,
            f"telemetry=off chunk returns {n_out_off} output leaves vs "
            f"{n_out_default} for the default config — the off carry "
            "must match the legacy 9-tuple"))
    if n_ctr <= n_off or len(jaxpr_ctr.jaxpr.outvars) <= n_out_off:
        out.append(Violation(
            "jaxpr.telemetry-cost", entry,
            "telemetry=counters chunk is indistinguishable from off — "
            "the counter tree is not riding the carry"))
    return out


@hot_entrypoint("engine.prefill_ragged")
def _audit_prefill_ragged() -> List[Violation]:
    """Batched ragged prefill: admission-path trace must stay free of
    host callbacks and must lower the fused grouped FFN kernel when
    ffn_impl="pallas".  (No byte budget: prefill legitimately builds
    (B, G, C, d) capacity buffers and SxS score tiles.)"""
    from repro.models import transformer
    entry = "engine.prefill_ragged"
    cfg = _tiny_lm_cfg(ffn_impl="pallas")
    params = _lm_params(cfg)
    bpb, s, max_len = 2, 16, 32
    batch = {"tokens": jax.ShapeDtypeStruct((bpb, s), jnp.int32)}
    lengths = jax.ShapeDtypeStruct((bpb,), jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda p, b, ln: transformer.lm_prefill_ragged(p, cfg, b, ln,
                                                       max_len)
    )(params, batch, lengths)
    return (forbidden_primitive_violations(jaxpr, entry)
            + kernel_count_violations(jaxpr, entry, "some")
            + accum_dtype_violations(jaxpr, entry))


def _sparse_decode_operands():
    from repro.core import pq
    from repro.core import sparse_attention as sa
    from repro.core.params import init_tree

    b, hq, hk, s, d, m = 4, 8, 2, 256, 64, 8
    pcfg = pq.PQConfig(head_dim=d, code_dim=m, num_codewords=16)
    cb = jax.eval_shape(lambda: init_tree(
        pq.param_defs(pcfg), jax.random.PRNGKey(0)))["codebooks"]
    scfg = sa.SparseAttentionConfig(pq=pcfg, top_fraction=0.25, min_l=4)
    f32 = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.float32)
    q, k, v = f32(b, hq, 1, d), f32(b, hk, s, d), f32(b, hk, s, d)
    codes = jax.ShapeDtypeStruct((b, hk, s, d // m), jnp.int8)
    kv_valid = jax.ShapeDtypeStruct((b, s), jnp.bool_)
    return (b, hq, hk, s, d), scfg, cb, q, k, v, codes, kv_valid


@hot_entrypoint("ops.sparse_mha_decode")
def _audit_sparse_mha_decode() -> List[Violation]:
    """The one-pass decode attention op at serving-representative shape:
    exactly ONE kernel (histogram prologue + attention in a single
    pallas_call — the thresholds tensor never reaches HBM), nothing bigger
    than the V cache, and no GQA expansion."""
    from repro.kernels.sparse_attention import ops as sa_ops

    entry = "ops.sparse_mha_decode[fused]"
    (b, hq, hk, s, d), scfg, cb, q, k, v, codes, kv_valid = \
        _sparse_decode_operands()
    jaxpr = jax.make_jaxpr(
        lambda q, k, v, c, cb, kv: sa_ops.sparse_mha_decode(
            q, k, v, c, cb, scfg, d ** -0.5, kv, interpret=True, fuse=True)
    )(q, k, v, codes, cb, kv_valid)
    return (kernel_count_violations(jaxpr, entry, "exact", exact=1)
            + forbidden_primitive_violations(jaxpr, entry)
            + cache_repeat_violations(jaxpr, hq, hk, s, entry)
            + accum_dtype_violations(jaxpr, entry))


@hot_entrypoint("ops.sparse_mha_decode_two_pass")
def _audit_sparse_mha_decode_two_pass() -> List[Violation]:
    """The bisection tier: fuse=False still lowers the original
    threshold + attention kernel pair (exactly two pallas_calls), with
    the same byte/shape discipline."""
    from repro.kernels.sparse_attention import ops as sa_ops

    entry = "ops.sparse_mha_decode[two-pass]"
    (b, hq, hk, s, d), scfg, cb, q, k, v, codes, kv_valid = \
        _sparse_decode_operands()
    jaxpr = jax.make_jaxpr(
        lambda q, k, v, c, cb, kv: sa_ops.sparse_mha_decode(
            q, k, v, c, cb, scfg, d ** -0.5, kv, interpret=True, fuse=False)
    )(q, k, v, codes, cb, kv_valid)
    return (kernel_count_violations(jaxpr, entry, "exact", exact=2)
            + forbidden_primitive_violations(jaxpr, entry)
            + cache_repeat_violations(jaxpr, hq, hk, s, entry)
            + accum_dtype_violations(jaxpr, entry))


@hot_entrypoint("engine.decode_chunk_paged")
def _audit_decode_chunk_paged() -> List[Violation]:
    """Paged layout with the kernel tier on: the decode chunk must read
    the KV pool kernel-natively — no gathered per-slot (B, Hk, view, ·)
    view (or its pre-transpose 5-d form) anywhere in the chunk, and no
    intermediate bigger than the pool itself."""
    entry = "engine.decode_chunk[paged-native]"
    cfg = _tiny_lm_cfg(decode_attn_impl="kernel", attn_impl="pallas",
                       ffn_impl="pallas", kv_layout="paged",
                       kv_page_size=16)
    slots, max_len = 2, 32
    jaxpr, params, caches, _ = _engine_chunk_jaxpr(cfg, slots=slots,
                                                   max_len=max_len)
    ps = cfg.spt.kv_page_size
    from repro.serving import kv_pages as kvp
    view = kvp.view_len(max_len, ps)
    out = []
    out += forbidden_primitive_violations(jaxpr, entry)
    out += kernel_count_violations(jaxpr, entry, "some")
    out += paged_gather_violations(jaxpr, slots, cfg.num_kv_heads, view,
                                   ps, kvp.num_pages(max_len, ps), entry)
    out += cache_repeat_violations(jaxpr, cfg.num_heads, cfg.num_kv_heads,
                                   view, entry)
    out += accum_dtype_violations(jaxpr, entry)
    return out


@hot_entrypoint("ops.routed_ffn_decode")
def _audit_routed_ffn_decode() -> List[Violation]:
    """Block-gather decode FFN: one kernel, no (B, G, C, d) dispatch
    buffer at any width (the PR-3 acceptance property, now enforced at
    HEAD instead of only in one test fixture)."""
    from repro.core import lora as lora_mod
    from repro.core import routed_ffn as rf
    from repro.core.params import init_tree
    from repro.kernels.routed_ffn import ops as rffn_ops

    entry = "ops.routed_ffn_decode"
    b, d, dff, g, gp = 4, 64, 128, 8, 2
    lcfg = lora_mod.LoRAConfig(rank=4, alpha=4.0, enabled=True)
    rcfg = rf.RoutedFFNConfig(d_model=d, d_ff=dff, num_groups=g,
                              active_groups=gp, capacity_factor=4.0,
                              gated=True, activation="gelu")
    p = jax.eval_shape(lambda: init_tree(rf.param_defs(rcfg, lcfg),
                                         jax.random.PRNGKey(0)))
    x = jax.ShapeDtypeStruct((b, 1, d), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda p, x: rffn_ops.routed_ffn_decode(x, p, rcfg, lcfg,
                                                interpret=True)[0])(p, x)
    return (kernel_count_violations(jaxpr, entry, "exact", exact=1)
            + dispatch_buffer_violations(jaxpr, b, g, entry)
            + forbidden_primitive_violations(jaxpr, entry)
            + accum_dtype_violations(jaxpr, entry))


@hot_entrypoint("models.moe_decode")
def _audit_moe_decode() -> List[Violation]:
    """MoE decode through the shared block-gather kernel: expert ids
    index weight blocks directly — no (B, E, C, d) capacity buffer."""
    from repro import configs
    from repro.core.params import init_tree
    from repro.models import moe

    entry = "models.moe_decode"
    cfg = configs.get_smoke("grok-1-314b").with_spt(ffn_impl="pallas")
    p = jax.eval_shape(lambda: init_tree(moe.moe_defs(cfg),
                                         jax.random.PRNGKey(0)))
    b = 4
    x = jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda p, x: moe.moe_apply(p, x, cfg, mode="decode")[0])(p, x)
    return (dispatch_buffer_violations(jaxpr, b, cfg.num_experts, entry)
            + kernel_count_violations(jaxpr, entry, "some")
            + forbidden_primitive_violations(jaxpr, entry))


@audit("jaxpr")
def _jaxpr_audit() -> List[Violation]:
    out: List[Violation] = []
    for name in ENTRYPOINTS:
        out.extend(ENTRYPOINTS[name]())
    return out
