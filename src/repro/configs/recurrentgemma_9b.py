"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attention, 1 attn : 2 recurrent.
[arXiv:2402.19427; unverified]"""
import dataclasses

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
        head_dim=256, d_ff=12288, vocab_size=256000,
        pattern=("rec", "rec", "attn"), activation="gelu", gated_ffn=True,
        norm="rmsnorm", rope_theta=10000.0, window=2048,
        lru_width=4096, conv_width=4,
        tie_embeddings=True, scale_embed=True,
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=5, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=256, lru_width=64, window=16,
    )
