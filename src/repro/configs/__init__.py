"""Config registry: assigned architectures + the paper's own blocks."""
from __future__ import annotations

from typing import Callable, Dict, Tuple

from repro.configs import (base, gemma_7b, grok_1_314b, h2o_danube_1_8b,
                           h2o_danube_3_4b, mamba2_780m, mixtral_8x22b,
                           paper_blocks, phi_3_vision_4_2b, qwen3_0_6b,
                           recurrentgemma_9b, whisper_base)
from repro.configs.base import (SHAPES, SHAPES_BY_NAME, ModelConfig,
                                ShapeSpec, SPTConfig)

_MODULES = {
    "grok-1-314b": grok_1_314b,
    "mixtral-8x22b": mixtral_8x22b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "phi-3-vision-4.2b": phi_3_vision_4_2b,
    "mamba2-780m": mamba2_780m,
    "qwen3-0.6b": qwen3_0_6b,
    "h2o-danube-1.8b": h2o_danube_1_8b,
    "gemma-7b": gemma_7b,
    "h2o-danube-3-4b": h2o_danube_3_4b,
    "whisper-base": whisper_base,
}

ARCH_NAMES: Tuple[str, ...] = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name in _MODULES:
        return _MODULES[name].config()
    pb = paper_blocks.blocks()
    if name in pb:
        return pb[name]
    if name == "opt-2.7b":
        return paper_blocks.opt_2_7b()
    if name == "llama-2.7b":
        return paper_blocks.llama_2_7b()
    raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")


def get_smoke(name: str) -> ModelConfig:
    return _MODULES[name].smoke()


# (arch, shape) applicability: long_500k needs a sub-quadratic path —
# SSM state, RG-LRU+local window, or SWA-bounded KV (DESIGN.md §5).
_LONG_OK = {"mamba2-780m", "recurrentgemma-9b", "mixtral-8x22b",
            "h2o-danube-1.8b", "h2o-danube-3-4b"}


def cell_supported(arch: str, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and arch not in _LONG_OK:
        return False, ("pure full-attention arch: 500k dense KV decode is "
                       "architecturally unsupported (no window/state)")
    return True, ""
