"""h2o-danube-1.8b [dense]: 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000 — llama+mistral mix, SWA.  [arXiv:2401.16818; hf]"""
import dataclasses

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b", family="dense",
        num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
        head_dim=80, d_ff=6912, vocab_size=32000,
        pattern=("attn",), activation="silu", gated_ffn=True,
        norm="rmsnorm", rope_theta=10000.0, window=4096,
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256, window=32,
    )
