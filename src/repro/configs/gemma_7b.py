"""gemma-7b [dense]: 28L d_model=3072 16H (GQA kv=16) d_ff=24576
vocab=256000 — GeGLU, head_dim=256.  [arXiv:2403.08295; hf]"""
import dataclasses

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b", family="dense",
        num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16,
        head_dim=256, d_ff=24576, vocab_size=256000,
        pattern=("attn",), activation="gelu", gated_ffn=True,
        norm="rmsnorm", rope_theta=10000.0,
        tie_embeddings=True, scale_embed=True,
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=256,
    )
