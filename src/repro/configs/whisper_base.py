"""whisper-base [audio]: 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865 —
encoder-decoder; conv frontend is a STUB (input_specs provides precomputed
frame embeddings).  [arXiv:2212.04356; unverified]

Shape-cell notes: seq_len applies to the DECODER; the encoder consumes the
fixed 1500-frame (30 s) window.  long_500k is skipped (pure full attention,
bounded encoder context — DESIGN.md §Arch-applicability)."""
import dataclasses

from repro.configs.base import ModelConfig

ENCODER_FRAMES = 1500


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="audio",
        num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
        head_dim=64, d_ff=2048, vocab_size=51865,
        pattern=("attn",), activation="gelu", gated_ffn=False,
        norm="layernorm", rope_theta=None, positional="learned",
        max_position=65536,
        encoder_layers=6, cross_attention=True,
        frontend="audio", frontend_tokens=ENCODER_FRAMES,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
        max_position=512, frontend_tokens=12,
    )
