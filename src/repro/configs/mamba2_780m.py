"""mamba2-780m [ssm]: 48L d_model=1536 (attention-free) d_ff=0 vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]

SPT applicability: attention-free and FFN-free, so sparse MHA and routed FFN
are inapplicable (DESIGN.md §Arch-applicability); SPT degenerates to LoRA on
the SSM in/out projections."""
import dataclasses

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280,
        pattern=("ssd",), norm="rmsnorm", rope_theta=None,
        positional="none",                  # SSM: conv carries position
        ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
        conv_width=4, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, vocab_size=256,
        ssm_state=16, ssm_headdim=16, ssm_chunk=16,
    )
