"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064 — phi3-mini backbone + CLIP frontend (stub: input_specs provides
precomputed patch embeddings).  [hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
import dataclasses

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", family="vlm",
        num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
        head_dim=96, d_ff=8192, vocab_size=32064,
        pattern=("attn",), activation="silu", gated_ffn=True,
        norm="rmsnorm", rope_theta=10000.0,
        frontend="vision", frontend_tokens=576,   # 24x24 CLIP patch grid
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=256, frontend_tokens=8,
    )
