"""The paper's Table 2 Transformer-block configurations, used by the
benchmarks that mirror Tables 1/4/5/6 and Figures 8/9.

| Name       | d_model | d_head | d_ffn  | source model          |
| OPT-1024   | 1024    | 64     | 4096   | GPT2-medium, OPT-350M |
| OPT-2048   | 2048    | 64     | 8192   | OPT-1.3B              |
| OPT-2560   | 2560    | 80     | 10240  | OPT-2.7B              |
| LLaMA-2560 | 2560    | 128    | 6912   | Sheared-LLaMA-2.7B    |
| LLaMA-4096 | 4096    | 128    | 11008  | Open-LLaMA-7B         |

OPT blocks: ReLU FFN, LayerNorm, learned positions (paper §6.1).
LLaMA blocks: SwiGLU, RMSNorm, RoPE.
``num_layers=1`` — the paper benchmarks single blocks.
"""
import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig


def _opt(name: str, d_model: int, d_head: int, d_ffn: int) -> ModelConfig:
    return ModelConfig(
        name=name, family="dense", num_layers=1,
        d_model=d_model, num_heads=d_model // d_head,
        num_kv_heads=d_model // d_head, head_dim=d_head, d_ff=d_ffn,
        vocab_size=50272, pattern=("attn",), activation="relu",
        gated_ffn=False, norm="layernorm", rope_theta=None,
        positional="learned", max_position=8192,
    )


def _llama(name: str, d_model: int, d_head: int, d_ffn: int) -> ModelConfig:
    return ModelConfig(
        name=name, family="dense", num_layers=1,
        d_model=d_model, num_heads=d_model // d_head,
        num_kv_heads=d_model // d_head, head_dim=d_head, d_ff=d_ffn,
        vocab_size=32000, pattern=("attn",), activation="silu",
        gated_ffn=True, norm="rmsnorm", rope_theta=10000.0,
    )


def blocks() -> Dict[str, ModelConfig]:
    return {
        "opt-1024": _opt("opt-1024", 1024, 64, 4096),
        "opt-2048": _opt("opt-2048", 2048, 64, 8192),
        "opt-2560": _opt("opt-2560", 2560, 80, 10240),
        "llama-2560": _llama("llama-2560", 2560, 128, 6912),
        "llama-4096": _llama("llama-4096", 4096, 128, 11008),
    }


def opt_2_7b(num_layers: int = 32) -> ModelConfig:
    """OPT-2.7B (paper's end-to-end model): 32 x OPT-2560 blocks."""
    return dataclasses.replace(_opt("opt-2.7b", 2560, 80, 10240),
                               num_layers=num_layers)


def llama_2_7b(num_layers: int = 32) -> ModelConfig:
    """Sheared-LLaMA-2.7B (paper's end-to-end model): 32 x LLaMA-2560."""
    return dataclasses.replace(_llama("llama-2.7b", 2560, 128, 6912),
                               num_layers=num_layers)
