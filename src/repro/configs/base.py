"""Config dataclasses: model architecture + SPT (paper technique) knobs.

Every assigned architecture is an instance of ModelConfig; the SPT features
(sparse MHA / routed FFN / LoRA) are orthogonal switches in SPTConfig so any
arch can run Full / LoRA / SPT — mirroring the paper's baselines.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.lora import LoRAConfig


@dataclasses.dataclass(frozen=True)
class SPTConfig:
    """Paper-technique configuration (defaults = paper defaults)."""
    sparse_mha: bool = True
    routed_ffn: bool = True
    lora: LoRAConfig = LoRAConfig(rank=16, alpha=16.0, enabled=True)
    # sparse MHA (§4.1): keep top-L = top_fraction * n attention weights
    attn_top_fraction: float = 0.125
    attn_min_l: int = 16
    attn_pad_l_to: int = 1          # set 128 on TPU for MXU alignment
    pq_code_dim: int = 8            # d' (paper §5.1)
    pq_codewords: int = 16          # E (paper §5.1)
    pq_update_interval: int = 20    # codebook refresh cadence (paper §5.1)
    select_granularity: str = "qhead"   # "kvgroup" = GQA-shared selection opt
    chunk_q: int = 256
    attn_impl: str = "sparse_jnp"   # sparse_jnp | dense | pallas
    # decode-time sparse attention path: "kernel" = fused Pallas decode
    # kernel, "jnp" = sa.sparse_mha_decode fallback, "auto" = follow
    # attn_impl ("pallas" -> kernel).  REPRO_DISABLE_KERNELS=1 forces jnp.
    decode_attn_impl: str = "auto"  # auto | kernel | jnp
    # kernel-tier shape of that decode path: "fused" = one-pass kernel
    # (threshold histogram as a prologue phase of the attention grid, no
    # thresholds tensor in HBM), "two_pass" = the original threshold +
    # attention kernel pair (bisection/fallback tier, bit-identical
    # output), "auto" = fused.  Only consulted when the kernel tier is on.
    decode_attn_fuse: str = "auto"  # auto | fused | two_pass
    # paged-pool decode addressing: "kernel" = decode kernels read K/V/code
    # tiles straight from the page pools via a scalar-prefetched page
    # table (no gathered per-slot view), "gather" = materialize the
    # gathered view first (fallback tier), "auto" = follow the decode
    # attention kernel tier.  REPRO_DISABLE_KERNELS=1 forces gather.
    kv_paged_native: str = "auto"   # auto | kernel | gather
    # routed FFN (§4.2): G groups, G' active (beta = G'/G)
    ffn_groups: int = 8
    ffn_active_groups: int = 4
    ffn_capacity_factor: float = 1.25
    dispatch_pad: int = 8           # 128 => capacity dim shardable (perf)
    # "pallas" = fused grouped-GEMM kernel with in-kernel (scalar-prefetch)
    # token dispatch; "grouped" = jnp BSpMV fallback; "dense" = masked
    # oracle.  REPRO_DISABLE_KERNELS=1 demotes "pallas" to "grouped".
    ffn_impl: str = "grouped"       # grouped | dense | grouped_shmap | pallas
    # serving-decode routed-FFN path at (B, 1, d): "kernel" = block-gather
    # Pallas kernel (scalar-prefetched top-G' choices index the weight
    # blocks directly — no capacity plan, no dispatch buffer, no scatter),
    # "jnp" = the grouped capacity path, "auto" = follow ffn_impl
    # ("pallas" -> kernel).  REPRO_DISABLE_KERNELS=1 forces jnp.
    decode_ffn_impl: str = "auto"   # auto | kernel | jnp
    # serving KV-cache layout: "contiguous" = one max_len strip per decode
    # slot; "paged" = fixed-size pages from a shared pool, mapped per slot
    # by a page table (serving/kv_pages.py) so long and short requests
    # share cache memory.  Engages only in the slot engine's decode path
    # (prefill rows stay contiguous and are scattered into pages); ring-
    # buffer SWA caches and recurrent states are never paged.
    kv_layout: str = "contiguous"   # contiguous | paged
    kv_page_size: int = 128         # rows per KV page (TPU lane-friendly)
    routed_ffn_in_experts: bool = False  # sub-route inside MoE experts
    lb_loss_weight: float = 0.01
    qerr_loss_weight: float = 0.0
    # serving observability (serving/telemetry.py): "off" = zero-cost (the
    # compiled decode chunk is eqn-identical to a telemetry-free build),
    # "counters" = jit-pure device counters (sparse-MHA kept/eligible
    # slots, routed-FFN/MoE expert loads and drops, in-loop page allocs)
    # threaded through the chunk carry and drained once per scheduling
    # iteration, "trace" = counters + host-side request lifecycle events
    # and scheduler spans (Chrome-trace/Perfetto export).  Outputs are
    # bit-identical across all three modes.
    telemetry: str = "off"          # off | counters | trace

    def disabled(self) -> "SPTConfig":
        return dataclasses.replace(self, sparse_mha=False, routed_ffn=False)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // num_heads
    pattern: Tuple[str, ...] = ("attn",)   # block types, cycled over layers
    activation: str = "silu"
    gated_ffn: bool = True         # SwiGLU/GeGLU
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    qk_norm: bool = False
    rope_theta: Optional[float] = 10000.0
    positional: str = "rope"       # rope | learned | none
    max_position: int = 1 << 20    # learned-pos table size
    window: Optional[int] = None   # sliding-window attention
    logits_softcap: Optional[float] = None
    tie_embeddings: bool = False
    scale_embed: bool = False      # gemma-style sqrt(d) embedding scale
    # MoE
    num_experts: int = 0
    experts_per_token: int = 2
    moe_capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 128
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 64
    conv_width: int = 4
    # recurrent (RG-LRU)
    lru_width: int = 0             # 0 => d_model
    # enc-dec (whisper)
    encoder_layers: int = 0
    cross_attention: bool = False
    # modality frontend (stub): number of prepended embedding tokens
    frontend: Optional[str] = None         # None | vision | audio
    frontend_tokens: int = 0
    # numerics
    dtype: object = jnp.bfloat16
    # the paper's technique
    spt: SPTConfig = SPTConfig()

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def d_inner(self) -> int:      # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so TP-16 and MXU lanes divide."""
        return -(-self.vocab_size // 256) * 256

    def layer_types(self) -> Tuple[str, ...]:
        reps = -(-self.num_layers // len(self.pattern))
        return tuple((self.pattern * reps)[: self.num_layers])

    def with_spt(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, spt=dataclasses.replace(self.spt, **kw))


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One dry-run cell: an input-shape regime for an arch."""
    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int

SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", "train", 4096, 256),
    ShapeSpec("prefill_32k", "prefill", 32768, 32),
    ShapeSpec("decode_32k", "decode", 32768, 128),
    ShapeSpec("long_500k", "decode", 524288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
