"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, SWA.  [arXiv:2401.04088; hf]"""
import dataclasses

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
        head_dim=128, d_ff=16384, vocab_size=32768,
        pattern=("attn",), activation="silu", gated_ffn=True,
        norm="rmsnorm", rope_theta=1000000.0, window=4096,
        num_experts=8, experts_per_token=2,
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256, num_experts=4, window=32,
    )
