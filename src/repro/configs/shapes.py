"""input_specs(): ShapeDtypeStruct stand-ins for every model input of a
(arch x shape) cell — weak-type-correct, shardable, no device allocation.

train/prefill  -> token batch (+ stub frontend embeddings)
decode         -> one new token per sequence + the KV/state caches
                  (cache specs come from the model's abstract cache fns)
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec


def _i32(*shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                batch_override: int = 0) -> Dict[str, Any]:
    b = batch_override or shape.global_batch
    s = shape.seq_len
    d = cfg.d_model
    fe = cfg.frontend_tokens
    emb = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        specs: Dict[str, Any] = {}
        if cfg.family == "audio":        # enc-dec: seq applies to decoder
            specs["frontend_embeds"] = emb((b, fe, d), jnp.bfloat16)
            specs["tokens"] = _i32(b, s)
        elif cfg.frontend:               # vlm: patches + text share seq_len
            text = max(1, s - fe)
            specs["frontend_embeds"] = emb((b, fe, d), jnp.bfloat16)
            specs["tokens"] = _i32(b, text)
        else:
            specs["tokens"] = _i32(b, s)
        if shape.kind == "train":
            specs["labels"] = _i32(*specs["tokens"].shape)
        return specs
    if shape.kind == "decode":
        return {"token": _i32(b), "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    raise ValueError(shape.kind)


def materialize(specs: Dict[str, Any], key: jax.Array,
                vocab: int) -> Dict[str, jax.Array]:
    """Random concrete inputs matching the specs (for smoke tests/benches)."""
    out = {}
    for name, spec in specs.items():
        k = jax.random.fold_in(key, hash(name) % (1 << 30))
        if jnp.issubdtype(spec.dtype, jnp.integer):
            if name == "pos":
                out[name] = jnp.zeros((), jnp.int32)
            else:
                out[name] = jax.random.randint(k, spec.shape, 0, vocab,
                                               dtype=jnp.int32)
        else:
            out[name] = jax.random.normal(k, spec.shape, jnp.float32
                                          ).astype(spec.dtype)
    return out
