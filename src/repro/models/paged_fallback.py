"""Gathered-view fallback tier for paged-pool decode attention.

The default paged decode route (models/attention.py) is kernel-native: the
Pallas decode kernels address K/V/code tiles straight out of the global
page pools through a scalar-prefetched page table, so no per-slot gathered
view ever materializes.  This module is the OTHER tier — it builds the
gathered (B, Hk, MP*page_size, .) views with ``kv_pages.gather_pages`` and
runs the contiguous decode paths over them.  It exists for three callers:

- the jnp oracle (``attn_impl != "pallas"`` / ``kv_paged_native="gather"``),
- the ``REPRO_DISABLE_KERNELS=1`` kill switch and kernel-vs-jnp bisection,
- direct ``lm_decode_step`` callers that did not hand in the engine's
  view-coordinate validity mask (the kernels require it; the fallback can
  reconstruct validity from the gathered ``slot_pos``).

It is deliberately the ONE models/serving module allowed to call
``gather_pages`` at decode time — ``analysis/lint.py`` bans the call
everywhere else so the O(S) gather cannot quietly creep back onto the
default hot path.  Only the views a path actually reads are gathered:
K/V always, cached PQ codes only on the sparse route, ``slot_pos`` only
when the engine validity mask is absent.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import dispatch as kdispatch
from repro.core import sparse_attention as sa
from repro.serving import kv_pages


def decode_attend_gathered(p: dict, cfg, q: jax.Array, cache: dict,
                           page_table: jax.Array, pos_b: jax.Array,
                           kv_valid: Optional[jax.Array],
                           scale: float) -> jax.Array:
    """Single-token decode attention over a paged cache via gathered views.

    q: (B, Hq, 1, d); cache: paged pool dict (k/v/codes: (P, Hk, ps, .),
    slot_pos: (P, ps)); page_table: (B, MP) int32; pos_b: (B,) absolute
    positions; kv_valid: optional engine-tracked (B, MP*ps) mask.
    Selection and masking are exactly the contiguous path's — the gathered
    view is what the pre-kernel-native route always read, so this tier is
    the bit-reference for the paged kernels (at equal tile size).
    """
    from repro.models import attention as mattn
    ps = cache["k"].shape[2]
    k_view = kv_pages.gather_pages(cache["k"], page_table)
    v_view = kv_pages.gather_pages(cache["v"], page_table)
    s_view = k_view.shape[2]
    if kv_valid is not None and kv_valid.shape[-1] == s_view:
        valid = kv_valid                              # engine-tracked
    else:
        # self-derived: slot_pos visibility AND page-table occupancy
        # (clamped gathers of unallocated pages read garbage rows)
        sp = kv_pages.gather_pages(cache["slot_pos"], page_table)
        valid = ((sp >= 0) & (sp <= pos_b[:, None])
                 & kv_pages.occupancy(page_table, ps))
    if mattn.sparse_applicable(cfg):
        codes_view = kv_pages.gather_pages(cache["codes"], page_table)
        if kdispatch.use_sparse_decode_kernel(cfg):
            from repro.kernels.sparse_attention import ops as sa_ops
            return sa_ops.sparse_mha_decode(
                q, k_view, v_view, codes_view, p["pq"]["codebooks"],
                mattn._sa_config(cfg), scale, valid,
                fuse=kdispatch.use_fused_decode_attn(cfg))
        return sa.sparse_mha_decode(
            q, k_view, v_view, codes_view, p["pq"]["codebooks"],
            mattn._sa_config(cfg), scale, valid)
    return sa.dense_attention(q, k_view, v_view, scale, causal=False,
                              kv_valid=valid, chunk_q=1)
