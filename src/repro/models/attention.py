"""Attention layer: GQA/MQA, RoPE, qk-norm, SWA — with the paper's sparse
MHA as a drop-in execution mode (SPTConfig.sparse_mha).

Modes:
  train    — full-sequence causal (or bidirectional for encoders)
  prefill  — train-mode compute + populate the KV(+PQ-codes) cache
  decode   — one token against the cache; sparse MHA selects top-L over the
             cached keys' PQ codes (paper Alg. 1 applied at serving time)

The KV cache stores absolute slot positions so a plain causal cache and a
ring-buffer sliding-window cache share one code path.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import dispatch as kdispatch
from repro.core import lora, pq
from repro.core import sparse_attention as sa
from repro.core.params import ParamDef
from repro.models import layers
from repro.sharding import shard


def _pq_config(cfg: ModelConfig) -> pq.PQConfig:
    return pq.PQConfig(head_dim=cfg.resolved_head_dim,
                       code_dim=cfg.spt.pq_code_dim,
                       num_codewords=cfg.spt.pq_codewords,
                       update_interval=cfg.spt.pq_update_interval)


def _sa_config(cfg: ModelConfig) -> sa.SparseAttentionConfig:
    return sa.SparseAttentionConfig(
        pq=_pq_config(cfg),
        top_fraction=cfg.spt.attn_top_fraction,
        min_l=cfg.spt.attn_min_l,
        pad_l_to=cfg.spt.attn_pad_l_to,
        chunk_q=cfg.spt.chunk_q,
        select_granularity=cfg.spt.select_granularity,
        qerr_loss_weight=cfg.spt.qerr_loss_weight)


def sparse_applicable(cfg: ModelConfig) -> bool:
    return cfg.spt.sparse_mha and cfg.resolved_head_dim % cfg.spt.pq_code_dim == 0


def attn_defs(cfg: ModelConfig) -> dict:
    d, hq, hk = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    lc = cfg.spt.lora
    defs = {
        "wq": lora.linear_defs(d, hq * hd, lc, "embed", "heads"),
        "wk": lora.linear_defs(d, hk * hd, lc, "embed", "kv_heads"),
        "wv": lora.linear_defs(d, hk * hd, lc, "embed", "kv_heads"),
        "wo": lora.linear_defs(hq * hd, d, lc, "heads", "embed"),
    }
    if cfg.qk_norm:
        defs["q_norm"] = layers.norm_defs(hd, "rmsnorm", None)
        defs["k_norm"] = layers.norm_defs(hd, "rmsnorm", None)
    if sparse_applicable(cfg):
        defs["pq"] = pq.param_defs(_pq_config(cfg))
    return defs


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               window: Optional[int] = None) -> Dict[str, jax.Array]:
    """Cache sized to the SWA window when present (ring buffer)."""
    size = max_len if window is None else min(max_len, window)
    hk, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    cache = {
        "k": jnp.zeros((batch, hk, size, hd), cfg.dtype),
        "v": jnp.zeros((batch, hk, size, hd), cfg.dtype),
        "slot_pos": jnp.full((batch, size), -1, jnp.int32),
    }
    if sparse_applicable(cfg):
        m = _pq_config(cfg).num_books
        cache["codes"] = jnp.zeros((batch, hk, size, m), jnp.int8)
    return cache


def init_paged_cache(cfg: ModelConfig, num_pages: int) -> Dict[str, jax.Array]:
    """Paged pool layout (serving/kv_pages.py): the per-slot (B, size, ...)
    strips become a global (num_pages, page_size, ...) pool addressed via
    the engine's slot->page table.  Same keys as init_cache so the rest of
    the layer code walks both layouts."""
    ps = cfg.spt.kv_page_size
    hk, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    cache = {
        "k": jnp.zeros((num_pages, hk, ps, hd), cfg.dtype),
        "v": jnp.zeros((num_pages, hk, ps, hd), cfg.dtype),
        "slot_pos": jnp.full((num_pages, ps), -1, jnp.int32),
    }
    if sparse_applicable(cfg):
        m = _pq_config(cfg).num_books
        cache["codes"] = jnp.zeros((num_pages, hk, ps, m), jnp.int8)
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int,
                   window: Optional[int] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        jax.eval_shape(lambda: init_cache(cfg, batch, max_len, window)))


def _project(p: dict, x: jax.Array, lc, heads: int, hd: int,
             axis: str) -> jax.Array:
    y = lora.linear(x, p, lc)
    b, s, _ = y.shape
    y = shard(y, "batch", None, axis)
    y = y.reshape(b, s, heads, hd).transpose(0, 2, 1, 3)
    return shard(y, "batch", axis, None, None)


def _qkv(p: dict, x: jax.Array, kv_x: jax.Array, cfg: ModelConfig,
         pos_q: jax.Array, pos_k: jax.Array, rope: bool
         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    lc = cfg.spt.lora
    hd = cfg.resolved_head_dim
    q = _project(p["wq"], x, lc, cfg.num_heads, hd, "heads")
    k = _project(p["wk"], kv_x, lc, cfg.num_kv_heads, hd, "kv_heads")
    v = _project(p["wv"], kv_x, lc, cfg.num_kv_heads, hd, "kv_heads")
    if cfg.qk_norm:
        q = layers.apply_norm(p["q_norm"], q, "rmsnorm")
        k = layers.apply_norm(p["k_norm"], k, "rmsnorm")
    if rope and cfg.rope_theta is not None:
        q = layers.apply_rope(q, pos_q, cfg.rope_theta)
        k = layers.apply_rope(k, pos_k, cfg.rope_theta)
    return q, k, v


def write_cache(cache: dict, cfg: ModelConfig, p: dict, k: jax.Array,
                v: jax.Array, pos_k: jax.Array) -> dict:
    """pos_k: (S_new,) shared positions, or (B, S_new) per-sequence positions
    (continuous-batching decode, where slots sit at ragged depths)."""
    size = cache["k"].shape[2]
    s_new = k.shape[2]
    if pos_k.ndim == 2:
        b = cache["k"].shape[0]
        slots = (pos_k % size).astype(jnp.int32)          # (B, S_new)
        bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
        new = dict(cache)
        # advanced-index scatter: target view is (B, S_new, Hk, hd)
        new["k"] = cache["k"].at[bidx, :, slots].set(
            k.transpose(0, 2, 1, 3).astype(cache["k"].dtype))
        new["v"] = cache["v"].at[bidx, :, slots].set(
            v.transpose(0, 2, 1, 3).astype(cache["v"].dtype))
        new["slot_pos"] = cache["slot_pos"].at[bidx, slots].set(
            pos_k.astype(jnp.int32))
        if "codes" in cache:
            codes = pq.assign(k, p["pq"]["codebooks"])    # (B, Hk, S_new, M)
            new["codes"] = cache["codes"].at[bidx, :, slots].set(
                codes.transpose(0, 2, 1, 3).astype(jnp.int8))
        return new
    if s_new > size:
        k, v, pos_k = k[:, :, -size:], v[:, :, -size:], pos_k[-size:]
        s_new = size
    slots = (pos_k % size).astype(jnp.int32)
    new = dict(cache)
    new["k"] = cache["k"].at[:, :, slots].set(k.astype(cache["k"].dtype))
    new["v"] = cache["v"].at[:, :, slots].set(v.astype(cache["v"].dtype))
    b = cache["slot_pos"].shape[0]
    new["slot_pos"] = cache["slot_pos"].at[:, slots].set(
        jnp.broadcast_to(pos_k[None], (b, s_new)).astype(jnp.int32))
    if "codes" in cache:
        codes = pq.assign(k, p["pq"]["codebooks"])        # (B, Hk, S_new, M)
        new["codes"] = cache["codes"].at[:, :, slots].set(
            codes.astype(jnp.int8))
    return new


def write_cache_paged(cache: dict, cfg: ModelConfig, p: dict, k: jax.Array,
                      v: jax.Array, pos: jax.Array,
                      page_table: jax.Array) -> dict:
    """Decode-time paged scatter: one new token per slot at absolute
    position ``pos`` (B,), routed to physical page page_table[b, pos//ps]
    row pos%ps.  Slots whose page is unallocated (retired slots decoding
    dead air inside a chunk) drop the write."""
    from repro.serving import kv_pages
    ps = cache["k"].shape[2]
    pos = pos.astype(jnp.int32)
    new = dict(cache)
    new["k"] = kv_pages.scatter_row(cache["k"], page_table, pos,
                                    k[:, :, 0], ps)
    new["v"] = kv_pages.scatter_row(cache["v"], page_table, pos,
                                    v[:, :, 0], ps)
    new["slot_pos"] = kv_pages.scatter_row(cache["slot_pos"], page_table,
                                           pos, pos, ps)
    if "codes" in cache:
        codes = pq.assign(k, p["pq"]["codebooks"])        # (B, Hk, 1, M)
        new["codes"] = kv_pages.scatter_row(cache["codes"], page_table,
                                            pos, codes[:, :, 0], ps)
    return new


def kv_valid_mask(cache: dict, q_pos: jax.Array,
                  window: Optional[int]) -> jax.Array:
    """(B, S) — slot holds a token visible to a query at q_pos (per batch)."""
    sp = cache["slot_pos"]                                # (B, S)
    q = jnp.reshape(q_pos, (-1, 1))
    ok = (sp >= 0) & (sp <= q)
    if window is not None:
        ok &= sp > q - window
    return ok


def attend(p: dict, cfg: ModelConfig, q: jax.Array, k: jax.Array,
           v: jax.Array, causal: bool, window: Optional[int],
           q_offset: int = 0, seq_lengths: Optional[jax.Array] = None
           ) -> Tuple[jax.Array, dict]:
    """Full-sequence attention (train/prefill), sparse or dense.

    seq_lengths: per-row real lengths (B,) for batched ragged prefill —
    sparse MHA then selects with each row's exact-length top-L budget
    (always via the jnp gather path; per-row budgets inside the fused
    prefill kernel are a follow-on).  Dense attention needs nothing: the
    causal mask already hides right-pad keys from every real query."""
    scale = cfg.resolved_head_dim ** -0.5
    aux: dict = {}
    if sparse_applicable(cfg):
        scfg = _sa_config(cfg)
        impl = cfg.spt.attn_impl
        if impl == "pallas" and kdispatch.kernels_disabled():
            impl = "sparse_jnp"                  # REPRO_DISABLE_KERNELS=1
        if seq_lengths is not None:
            out, aux = sa.sparse_mha(q, k, v, p["pq"]["codebooks"], scfg,
                                     scale, causal=causal, window=window,
                                     q_offset=q_offset,
                                     seq_lengths=seq_lengths)
        elif impl == "pallas":
            from repro.kernels.sparse_attention import ops as sa_ops
            out, aux = sa_ops.sparse_mha(q, k, v, p["pq"]["codebooks"], scfg,
                                         scale, causal=causal, window=window,
                                         q_offset=q_offset)
        elif impl == "sparse_masked":
            out, aux = sa.sparse_mha_masked(q, k, v, p["pq"]["codebooks"],
                                            scfg, scale, causal=causal,
                                            window=window, q_offset=q_offset)
        else:
            out, aux = sa.sparse_mha(q, k, v, p["pq"]["codebooks"], scfg,
                                     scale, causal=causal, window=window,
                                     q_offset=q_offset)
    else:
        out = sa.dense_attention(q, k, v, scale, causal=causal, window=window,
                                 q_offset=q_offset, chunk_q=cfg.spt.chunk_q)
    return out, aux


def _tel_decode_counters(cfg: ModelConfig, valid: jax.Array) -> dict:
    """Jit-pure sparsity counters for one decode step (telemetry layer).

    Derived from the validity mask alone — the decode paths select
    top-L = top_l(mask_width) slots out of the valid ones, so per row
    kept = min(L, n_valid) and eligible = n_valid.  No scores are
    recomputed; cost is one mask reduction per attention layer."""
    n_valid = valid.sum(axis=-1).astype(jnp.float32)          # (B,)
    l = sa.top_l(valid.shape[-1], _sa_config(cfg), None)
    return {"tel_attn_kept": jnp.minimum(float(l), n_valid),
            "tel_attn_elig": n_valid}


def attn_apply(p: dict, x: jax.Array, cfg: ModelConfig, *,
               mode: str = "train", causal: bool = True,
               window: Optional[int] = None,
               cache: Optional[dict] = None,
               pos: Optional[jax.Array] = None,
               kv_x: Optional[jax.Array] = None,
               rope: bool = True,
               kv_valid: Optional[jax.Array] = None,
               page_table: Optional[jax.Array] = None,
               seq_lengths: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, Optional[dict], dict]:
    """Returns (y, new_cache, aux).  x: (B, S, d_model).

    pos: absolute position of x[:, 0] — a scalar when batches are aligned,
    or a (B,) vector when serving slots sit at ragged depths.
    seq_lengths: train/prefill only — per-row real lengths (B,) of a
    right-padded ragged batch (see ``attend``).
    kv_x: source for K/V (cross-attention); defaults to x.
    kv_valid: decode-mode only — a caller-tracked (B, cache_size) slot
    validity mask (the serving engine derives it once per step from slot
    positions); when absent, or for ring-buffer SWA caches whose slot
    semantics the caller can't see, it is recomputed from the cache's
    slot_pos.
    page_table: decode-mode only — (B, max_pages) int32 slot->page map
    signalling that ``cache`` is a paged pool (serving/kv_pages.py).
    Ring-buffer SWA caches ignore it (they are already window-bounded).
    """
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    lc = cfg.spt.lora
    start = jnp.asarray(0 if pos is None else pos, jnp.int32)
    if start.ndim == 1:
        pos_q = start[:, None] + jnp.arange(s, dtype=jnp.int32)   # (B, s)
    else:
        pos_q = start + jnp.arange(s, dtype=jnp.int32)            # (s,)
    kv_src = x if kv_x is None else kv_x
    pos_k = (jnp.arange(kv_src.shape[1], dtype=jnp.int32)
             if kv_x is not None else pos_q)
    q, k, v = _qkv(p, x, kv_src, cfg, pos_q, pos_k, rope)
    aux: dict = {}
    new_cache = cache

    if mode in ("train", "prefill"):
        out, aux = attend(p, cfg, q, k, v, causal, window,
                          seq_lengths=seq_lengths)
        if mode == "prefill":
            assert cache is not None
            new_cache = write_cache(cache, cfg, p, k, v, pos_k)
    elif mode == "decode" and page_table is not None and window is None:
        # paged pool: scatter the new token into its slot's page, then
        # attend.  Kernel tier: the decode kernels address (page_id,
        # offset) tiles straight out of the pools via the scalar-
        # prefetched page table — no gathered per-slot view exists.
        # Fallback tier (jnp oracle, kill switch, or a caller without a
        # view-coordinate validity mask): models/paged_fallback.py
        # gathers the view and runs the contiguous decode paths over it.
        from repro.models import paged_fallback
        assert cache is not None and pos is not None
        pos_b = jnp.broadcast_to(start, (b,)).astype(jnp.int32)
        new_cache = write_cache_paged(cache, cfg, p, k, v, pos_b, page_table)
        ps = new_cache["k"].shape[2]
        s_view = page_table.shape[1] * ps
        scale = hd ** -0.5
        sparse = sparse_applicable(cfg)
        engine_valid = kv_valid is not None and kv_valid.shape[-1] == s_view
        if sparse and engine_valid and kdispatch.use_telemetry_counters(cfg):
            aux.update(_tel_decode_counters(cfg, kv_valid))
        native = (engine_valid and kdispatch.use_paged_native_decode(cfg)
                  and (not sparse or kdispatch.use_sparse_decode_kernel(cfg)))
        if native:
            from repro.kernels.sparse_attention import ops as sa_ops
            if sparse:
                out = sa_ops.sparse_mha_decode_paged(
                    q, new_cache["k"], new_cache["v"], new_cache["codes"],
                    p["pq"]["codebooks"], _sa_config(cfg), scale, kv_valid,
                    page_table)
            else:
                out = sa_ops.dense_mha_decode_paged(
                    q, new_cache["k"], new_cache["v"], scale, kv_valid,
                    page_table)
        else:
            out = paged_fallback.decode_attend_gathered(
                p, cfg, q, new_cache, page_table, pos_b, kv_valid, scale)
    elif mode == "decode":
        assert cache is not None and pos is not None
        new_cache = write_cache(cache, cfg, p, k, v, pos_q)
        size = new_cache["k"].shape[2]
        if (kv_valid is not None and window is None
                and kv_valid.shape[-1] == size):
            valid = kv_valid                              # engine-tracked
        else:
            valid = kv_valid_mask(new_cache, start, window)   # (B, S_cache)
        scale = hd ** -0.5
        if sparse_applicable(cfg):
            if kdispatch.use_telemetry_counters(cfg):
                aux.update(_tel_decode_counters(cfg, valid))
            if kdispatch.use_sparse_decode_kernel(cfg):
                from repro.kernels.sparse_attention import ops as sa_ops
                out = sa_ops.sparse_mha_decode(
                    q, new_cache["k"], new_cache["v"], new_cache["codes"],
                    p["pq"]["codebooks"], _sa_config(cfg), scale, valid,
                    fuse=kdispatch.use_fused_decode_attn(cfg))
            else:
                out = sa.sparse_mha_decode(
                    q, new_cache["k"], new_cache["v"], new_cache["codes"],
                    p["pq"]["codebooks"], _sa_config(cfg), scale, valid)
        else:
            out = sa.dense_attention(q, new_cache["k"], new_cache["v"], scale,
                                     causal=False, kv_valid=valid, chunk_q=1)
    else:
        raise ValueError(mode)

    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.num_heads * hd)
    out = shard(out, "batch", None, "heads")
    y = lora.linear(out, p["wo"], lc)
    return shard(y, "batch", None, None), new_cache, aux
