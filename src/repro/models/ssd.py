"""Mamba-2 block via SSD (state-space duality), chunked matmul form.

The chunked dual form keeps training compute on the MXU:
  * intra-chunk: (Q x Q) masked-decay attention-like matmuls
  * inter-chunk: per-chunk states carried by a short lax.scan

Decode is the O(1) recurrent update  h <- h * exp(dt A) + dt B (x)  ;
y = C h + D x.

SPT applicability (DESIGN.md §Arch-applicability): mamba2 is attention-free
and has no FFN (d_ff = 0), so sparse MHA and routed FFN are inapplicable —
SPT degenerates to LoRA on in/out projections.  This module still carries
full LoRA support so the arch participates in the fine-tuning framework.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import lora
from repro.core.params import ParamDef
from repro.models.layers import apply_norm, norm_defs
from repro.sharding import shard


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    h = cfg.ssm_heads
    n = cfg.ssm_state
    conv_dim = di + 2 * n
    proj_out = 2 * di + 2 * n + h   # z, x, B, C, dt
    return di, h, n, conv_dim, proj_out


def ssd_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, h, n, conv_dim, proj_out = _dims(cfg)
    lc = cfg.spt.lora
    return {
        "in_proj": lora.linear_defs(d, proj_out, lc, "embed", "ssm_inner"),
        "out_proj": lora.linear_defs(di, d, lc, "ssm_inner", "embed"),
        "conv": ParamDef((cfg.conv_width, conv_dim), jnp.float32,
                         ("conv", None), init="normal:0.1", trainable=False),
        "a_log": ParamDef((h,), jnp.float32, (None,), init="zeros",
                          trainable=False),
        "d_skip": ParamDef((h,), jnp.float32, (None,), init="ones",
                           trainable=False),
        "dt_bias": ParamDef((h,), jnp.float32, (None,), init="zeros",
                            trainable=False),
        "norm": norm_defs(di, "rmsnorm", None),
    }


def init_ssm_cache(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    di, h, n, conv_dim, _ = _dims(cfg)
    p = cfg.ssm_headdim
    return {
        "h": jnp.zeros((batch, h, p, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), jnp.float32),
    }


def _causal_conv(x, kernel, state):
    k = kernel.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * kernel[i].astype(x.dtype)
            for i in range(k))
    return jax.nn.silu(y), xp[:, -(k - 1):]


def _segsum(dA: jax.Array) -> jax.Array:
    """dA: (..., Q) -> (..., Q, Q) lower-triangular exp-arg differences:
    out[i, j] = sum_{j < t <= i} dA[t]  (=-inf above diagonal)."""
    q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]            # (.., i, j)
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, bm: jax.Array,
             cm: jax.Array, chunk: int,
             h0: Optional[jax.Array] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.  x: (B,S,H,P), dt: (B,S,H) (>=0), a: (H,) (<0),
    bm/cm: (B,S,N).  Returns (y (B,S,H,P), h_last (B,H,P,N))."""
    b, s, h, p = x.shape
    n = bm.shape[-1]
    q = min(chunk, s)
    if s % q != 0:
        q = s
    nc = s // q
    xr = x.reshape(b, nc, q, h, p)
    dtr = dt.reshape(b, nc, q, h)
    br = bm.reshape(b, nc, q, n)
    cr = cm.reshape(b, nc, q, n)
    da = dtr * a[None, None, None, :]                     # (B,NC,Q,H)
    da_h = jnp.moveaxis(da, -1, 2)                        # (B,NC,H,Q)
    seg = _segsum(da_h)                                   # (B,NC,H,Q,Q)
    l_mat = jnp.exp(seg)
    xdt = xr * dtr[..., None]                             # (B,NC,Q,H,P)
    # intra-chunk (quadratic within chunk, matmul form)
    cb = jnp.einsum("bcin,bcjn->bcij", cr, br,
                    preferred_element_type=jnp.float32)   # (B,NC,Q,Q)
    scores = cb[:, :, None] * l_mat                       # (B,NC,H,Q,Q)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", scores.astype(x.dtype), xdt)
    # chunk states
    da_cs = jnp.cumsum(da, axis=2)                        # (B,NC,Q,H)
    decay_tail = jnp.exp(da_cs[:, :, -1:, :] - da_cs)     # (B,NC,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", br,
                        decay_tail.astype(x.dtype), xdt)  # (B,NC,H,P,N)
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])             # (B,NC,H)

    def step(hprev, inp):
        st, dec = inp
        hnew = hprev * dec[..., None, None] + st.astype(jnp.float32)
        return hnew, hprev

    init = (jnp.zeros((b, h, p, n), jnp.float32) if h0 is None
            else h0.astype(jnp.float32))
    from repro.core.chunking import maybe_scan
    h_last, h_prevs = maybe_scan(
        step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                 # (B,NC,H,P,N)
    decay_in = jnp.exp(da_cs)                             # (B,NC,Q,H)
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp", cr.astype(x.dtype),
                         decay_in.astype(x.dtype), h_prevs.astype(x.dtype))
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, h_last


def ssd_step(x: jax.Array, dt: jax.Array, a: jax.Array, bm: jax.Array,
             cm: jax.Array, hst: jax.Array
             ) -> Tuple[jax.Array, jax.Array]:
    """One decode step.  x: (B,H,P), dt: (B,H), bm/cm: (B,N), h: (B,H,P,N)."""
    da = jnp.exp(dt * a[None, :])[..., None, None]        # (B,H,1,1)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt, bm, x)
    h_new = hst * da + upd.astype(jnp.float32)
    y = jnp.einsum("bhpn,bn->bhp", h_new.astype(x.dtype), cm.astype(x.dtype))
    return y, h_new


def ssd_apply(p: dict, x: jax.Array, cfg: ModelConfig, *,
              mode: str = "train",
              cache: Optional[dict] = None
              ) -> Tuple[jax.Array, Optional[dict], dict]:
    """Mamba-2 block.  x: (B, S, d_model)."""
    lc = cfg.spt.lora
    di, h, n, conv_dim, _ = _dims(cfg)
    phead = cfg.ssm_headdim
    bsz, s, _ = x.shape
    zxbcdt = lora.linear(x, p["in_proj"], lc)
    z, xc, bm, cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xc, bm, cm], axis=-1)
    conv_state = None if cache is None else cache["conv"]
    conv_out, new_conv = _causal_conv(conv_in, p["conv"], conv_state)
    xc, bm, cm = jnp.split(conv_out, [di, di + n], axis=-1)
    xc = shard(xc, "batch", None, "ssm_inner")
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    xh = xc.reshape(bsz, s, h, phead)
    new_cache = cache
    if mode in ("train", "prefill"):
        y, h_last = ssd_scan(xh, dt, a, bm, cm, cfg.ssm_chunk,
                             None if cache is None else cache["h"])
        if mode == "prefill":
            new_cache = {"h": h_last, "conv": new_conv.astype(jnp.float32)}
    elif mode == "decode":
        assert cache is not None
        y1, h_new = ssd_step(xh[:, 0], dt[:, 0], a, bm[:, 0], cm[:, 0],
                             cache["h"])
        new_cache = {"h": h_new, "conv": new_conv.astype(jnp.float32)}
        y = y1[:, None]
    else:
        raise ValueError(mode)
    y = y + xh * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(bsz, s, di)
    y = apply_norm(p["norm"], y * jax.nn.silu(z), "rmsnorm")
    out = lora.linear(y, p["out_proj"], lc)
    return shard(out, "batch", None, None), new_cache, {}
