"""FFN layer: dense (Full/LoRA baseline) or the paper's routed FFN.

Routed-FFN execution paths (selection in core/dispatch.py):

  * ``spt.ffn_impl="pallas"`` — fused Pallas grouped-GEMM kernel with
    in-kernel scalar-prefetch dispatch (kernels/routed_ffn); falls back
    to "grouped" under REPRO_DISABLE_KERNELS=1.
  * ``mode="decode"`` at (B, 1, d) — block-gather decode kernel (no
    capacity plan, no dispatch buffer) when
    ``dispatch.use_decode_ffn_kernel(cfg)`` says so.
  * ``"grouped"`` / ``"dense"`` / ``"grouped_shmap"`` — the jnp paths.

``mode`` ("train" | "prefill" | "decode") also gates the router aux:
inference skips the full-group softmax and load-balance loss.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import dispatch, lora, routed_ffn
from repro.core.params import ParamDef
from repro.models.layers import norm_defs
from repro.sharding import shard


def _routed_cfg(cfg: ModelConfig) -> routed_ffn.RoutedFFNConfig:
    return routed_ffn.RoutedFFNConfig(
        d_model=cfg.d_model, d_ff=cfg.d_ff,
        num_groups=cfg.spt.ffn_groups,
        active_groups=cfg.spt.ffn_active_groups,
        capacity_factor=cfg.spt.ffn_capacity_factor,
        capacity_pad=cfg.spt.dispatch_pad,
        activation=cfg.activation, gated=cfg.gated_ffn,
        lb_loss_weight=cfg.spt.lb_loss_weight)


def routed_applicable(cfg: ModelConfig) -> bool:
    return (cfg.spt.routed_ffn and cfg.d_ff > 0
            and cfg.d_ff % cfg.spt.ffn_groups == 0)


def ffn_defs(cfg: ModelConfig) -> dict:
    lc = cfg.spt.lora
    if routed_applicable(cfg):
        return routed_ffn.param_defs(_routed_cfg(cfg), lc)
    d, f = cfg.d_model, cfg.d_ff
    defs = {
        "wi": lora.linear_defs(d, f, lc, "embed", "ffn"),
        "wo": lora.linear_defs(f, d, lc, "ffn", "embed"),
    }
    if cfg.gated_ffn:
        defs["wg"] = lora.linear_defs(d, f, lc, "embed", "ffn")
    return defs


def _routed_apply(p: dict, x: jax.Array, cfg: ModelConfig, mode: str,
                  seq_lengths=None) -> Tuple[jax.Array, dict]:
    lc = cfg.spt.lora
    rcfg = _routed_cfg(cfg)
    need_aux = mode == "train"
    if mode == "decode" and x.ndim == 3 and x.shape[1] == 1:
        if dispatch.use_decode_ffn_kernel(cfg):
            from repro.kernels.routed_ffn import ops as rffn_ops
            return rffn_ops.routed_ffn_decode(x, p, rcfg, lc)
        if cfg.spt.decode_ffn_impl == "jnp":
            # explicit per-path override: grouped jnp at decode even when
            # ffn_impl="pallas" keeps the train/prefill kernel on
            return routed_ffn.routed_ffn(x, p, rcfg, lc, impl="grouped",
                                         need_aux=False)
    impl = cfg.spt.ffn_impl
    if impl == "pallas":
        if dispatch.use_routed_ffn_kernel(cfg):
            from repro.kernels.routed_ffn import ops as rffn_ops
            return rffn_ops.routed_ffn(x, p, rcfg, lc, need_aux=need_aux,
                                       seq_lengths=seq_lengths)
        impl = "grouped"                       # REPRO_DISABLE_KERNELS=1
    if impl == "grouped_shmap":
        from repro.core import ffn_shmap
        from repro.sharding import current_rules
        rules = current_rules() or {}
        mesh = rules.get("__mesh__")
        if (x.ndim == 3 and seq_lengths is None and ffn_shmap.applicable(
                mesh, rcfg, cfg.d_ff, x.shape[1], x.shape[0])):
            return ffn_shmap.routed_ffn_shmap(x, p, rcfg, lc, mesh,
                                              need_aux=need_aux)
        impl = "grouped"
    return routed_ffn.routed_ffn(x, p, rcfg, lc, impl=impl,
                                 need_aux=need_aux, seq_lengths=seq_lengths)


def ffn_apply(p: dict, x: jax.Array, cfg: ModelConfig, mode: str = "train",
              seq_lengths=None) -> Tuple[jax.Array, dict]:
    """seq_lengths: per-row real lengths (B,) for batched ragged prefill —
    threads the exact-length dispatch capacity into the routed paths (the
    dense FFN is per-token, so it ignores them)."""
    lc = cfg.spt.lora
    if routed_applicable(cfg):
        return _routed_apply(p, x, cfg, mode, seq_lengths=seq_lengths)
    act = routed_ffn.ACTIVATIONS[cfg.activation]
    up = lora.linear(x, p["wi"], lc)
    up = shard(up, "batch", None, "ffn")
    if cfg.gated_ffn:
        gate = lora.linear(x, p["wg"], lc)
        h = act(gate) * up
    else:
        h = act(up)
    y = lora.linear(h, p["wo"], lc)
    return shard(y, "batch", None, None), {}
