"""FFN layer: dense (Full/LoRA baseline) or the paper's routed FFN.

Routed-FFN execution paths (selection in core/dispatch.py):

  * ``spt.ffn_impl="pallas"`` — fused Pallas grouped-GEMM kernel with
    in-kernel scalar-prefetch dispatch (kernels/routed_ffn); falls back
    to "grouped" under REPRO_DISABLE_KERNELS=1.
  * ``mode="decode"`` at (B, 1, d) — block-gather decode kernel (no
    capacity plan, no dispatch buffer) when
    ``dispatch.use_decode_ffn_kernel(cfg)`` says so.
  * ``"grouped"`` / ``"dense"`` / ``"grouped_shmap"`` — the jnp paths.

``mode`` ("train" | "prefill" | "decode") also gates the router aux:
inference skips the full-group softmax and load-balance loss.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import dispatch, lora, routed_ffn
from repro.core.params import ParamDef
from repro.models.layers import norm_defs
from repro.sharding import shard


def _routed_cfg(cfg: ModelConfig) -> routed_ffn.RoutedFFNConfig:
    return routed_ffn.RoutedFFNConfig(
        d_model=cfg.d_model, d_ff=cfg.d_ff,
        num_groups=cfg.spt.ffn_groups,
        active_groups=cfg.spt.ffn_active_groups,
        capacity_factor=cfg.spt.ffn_capacity_factor,
        capacity_pad=cfg.spt.dispatch_pad,
        activation=cfg.activation, gated=cfg.gated_ffn,
        lb_loss_weight=cfg.spt.lb_loss_weight)


def routed_applicable(cfg: ModelConfig) -> bool:
    return (cfg.spt.routed_ffn and cfg.d_ff > 0
            and cfg.d_ff % cfg.spt.ffn_groups == 0)


def ffn_defs(cfg: ModelConfig) -> dict:
    lc = cfg.spt.lora
    if routed_applicable(cfg):
        return routed_ffn.param_defs(_routed_cfg(cfg), lc)
    d, f = cfg.d_model, cfg.d_ff
    defs = {
        "wi": lora.linear_defs(d, f, lc, "embed", "ffn"),
        "wo": lora.linear_defs(f, d, lc, "ffn", "embed"),
    }
    if cfg.gated_ffn:
        defs["wg"] = lora.linear_defs(d, f, lc, "embed", "ffn")
    return defs


def _tel_expert_load(choice: jax.Array, num_groups: int, x: jax.Array,
                     seq_lengths) -> jax.Array:
    """(B, G) per-row token->expert load from the router's top-k choices
    (telemetry layer).  Right-pad rows of a ragged prefill batch are
    masked out so loads count real tokens only."""
    oh = jax.nn.one_hot(choice, num_groups, dtype=jnp.float32)  # (B,S,k,G)
    if seq_lengths is not None:
        valid = (jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
                 < seq_lengths[:, None]).astype(jnp.float32)    # (B, S)
        oh = oh * valid[:, :, None, None]
    return oh.sum(axis=(1, 2))


def _routed_apply(p: dict, x: jax.Array, cfg: ModelConfig, mode: str,
                  seq_lengths=None) -> Tuple[jax.Array, dict]:
    lc = cfg.spt.lora
    rcfg = _routed_cfg(cfg)
    need_aux = mode == "train"
    y = aux = None
    if mode == "decode" and x.ndim == 3 and x.shape[1] == 1:
        if dispatch.use_decode_ffn_kernel(cfg):
            from repro.kernels.routed_ffn import ops as rffn_ops
            y, aux = rffn_ops.routed_ffn_decode(x, p, rcfg, lc)
        elif cfg.spt.decode_ffn_impl == "jnp":
            # explicit per-path override: grouped jnp at decode even when
            # ffn_impl="pallas" keeps the train/prefill kernel on
            y, aux = routed_ffn.routed_ffn(x, p, rcfg, lc, impl="grouped",
                                           need_aux=False)
    if y is None:
        impl = cfg.spt.ffn_impl
        if impl == "pallas":
            if dispatch.use_routed_ffn_kernel(cfg):
                from repro.kernels.routed_ffn import ops as rffn_ops
                y, aux = rffn_ops.routed_ffn(x, p, rcfg, lc,
                                             need_aux=need_aux,
                                             seq_lengths=seq_lengths)
            else:
                impl = "grouped"               # REPRO_DISABLE_KERNELS=1
        if y is None and impl == "grouped_shmap":
            from repro.core import ffn_shmap
            from repro.sharding import current_rules
            rules = current_rules() or {}
            mesh = rules.get("__mesh__")
            if (x.ndim == 3 and seq_lengths is None and ffn_shmap.applicable(
                    mesh, rcfg, cfg.d_ff, x.shape[1], x.shape[0])):
                y, aux = ffn_shmap.routed_ffn_shmap(x, p, rcfg, lc, mesh,
                                                    need_aux=need_aux)
            else:
                impl = "grouped"
        if y is None:
            y, aux = routed_ffn.routed_ffn(x, p, rcfg, lc, impl=impl,
                                           need_aux=need_aux,
                                           seq_lengths=seq_lengths)
    if (dispatch.use_telemetry_counters(cfg) and x.ndim == 3
            and mode in ("prefill", "decode")):
        # jit-pure telemetry counters: re-run the (tiny) router einsum so
        # every execution path — kernel or jnp — reports identical loads
        choice, _, _ = routed_ffn.route(x, p["router"], rcfg, need_aux=False)
        aux = dict(aux)
        aux["tel_expert_load"] = _tel_expert_load(
            choice, rcfg.num_groups, x, seq_lengths)
        aux["tel_expert_drop"] = jnp.asarray(
            aux.get("dropped", 0.0), jnp.float32)
    return y, aux


def ffn_apply(p: dict, x: jax.Array, cfg: ModelConfig, mode: str = "train",
              seq_lengths=None) -> Tuple[jax.Array, dict]:
    """seq_lengths: per-row real lengths (B,) for batched ragged prefill —
    threads the exact-length dispatch capacity into the routed paths (the
    dense FFN is per-token, so it ignores them)."""
    lc = cfg.spt.lora
    if routed_applicable(cfg):
        return _routed_apply(p, x, cfg, mode, seq_lengths=seq_lengths)
    act = routed_ffn.ACTIVATIONS[cfg.activation]
    up = lora.linear(x, p["wi"], lc)
    up = shard(up, "batch", None, "ffn")
    if cfg.gated_ffn:
        gate = lora.linear(x, p["wg"], lc)
        h = act(gate) * up
    else:
        h = act(up)
    y = lora.linear(h, p["wo"], lc)
    return shard(y, "batch", None, None), {}
