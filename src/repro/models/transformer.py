"""Decoder-only LM assembly: embeddings -> pattern-unit scan over blocks ->
final norm -> (tied or separate) LM head.

Layer patterns (`ModelConfig.pattern`) cycle block kinds over layers, e.g.
("attn",) for dense/MoE archs, ("rec", "rec", "attn") for RecurrentGemma,
("ssd",) for Mamba-2.  Layers are stacked into `lax.scan`-able pattern
*units* (all units share one param structure), keeping the HLO small enough
to compile 64-layer 314B-param configs on a 512-device mesh; the remainder
layers (num_layers % len(pattern)) run unrolled as a tail.

Frontends (VLM patches / audio frames) are STUBS per the assignment:
`batch["frontend_embeds"]` carries precomputed embeddings that are
prepended to the token embeddings.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import lora
from repro.core.params import ParamDef, init_tree, stack_defs
from repro.models import attention, ffn, layers, moe, rglru, ssd
from repro.sharding import shard

AUX_KEYS = ("lb_loss", "dropped", "qerr")


def _merge_tel(acc: dict, src: dict) -> None:
    """Fold a layer's telemetry counters (tel_* aux entries, emitted only
    when dispatch.use_telemetry_counters(cfg)) into ``acc`` in place.
    Unlike AUX_KEYS these are not scalars — shapes like (B,) or (B, G)
    are summed across the blocks of one pattern unit and kept per-unit
    by the scan (serving/telemetry.py drains them once per iteration)."""
    for k, v in src.items():
        if k.startswith("tel_"):
            acc[k] = acc[k] + v if k in acc else v


# ---------------------------------------------------------------- blocks
def block_defs(cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    defs: dict = {"norm_mix": layers.norm_defs(d, cfg.norm)}
    if kind == "attn":
        defs["mixer"] = attention.attn_defs(cfg)
    elif kind == "rec":
        defs["mixer"] = rglru.rglru_defs(cfg)
    elif kind == "ssd":
        defs["mixer"] = ssd.ssd_defs(cfg)
    else:
        raise ValueError(kind)
    if kind != "ssd":  # ssd blocks have no FFN sub-layer (mamba2)
        if cfg.num_experts > 0:
            defs["norm_ffn"] = layers.norm_defs(d, cfg.norm)
            defs["ffn"] = moe.moe_defs(cfg)
        elif cfg.d_ff > 0:
            defs["norm_ffn"] = layers.norm_defs(d, cfg.norm)
            defs["ffn"] = ffn.ffn_defs(cfg)
    return defs


def block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    if kind == "attn":
        return attention.init_cache(cfg, batch, max_len, cfg.window)
    if kind == "rec":
        return rglru.init_rec_cache(cfg, batch)
    if kind == "ssd":
        return ssd.init_ssm_cache(cfg, batch)
    raise ValueError(kind)


def block_apply(p: dict, x: jax.Array, cfg: ModelConfig, kind: str, *,
                mode: str, cache=None, pos=None, kv_valid=None,
                page_table=None, seq_lengths=None
                ) -> Tuple[jax.Array, Any, Dict[str, jax.Array]]:
    aux = {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}
    h = layers.apply_norm(p["norm_mix"], x, cfg.norm)
    if kind == "attn":
        y, new_cache, a_aux = attention.attn_apply(
            p["mixer"], h, cfg, mode=mode, causal=True, window=cfg.window,
            cache=cache, pos=pos, kv_valid=kv_valid, page_table=page_table,
            seq_lengths=seq_lengths)
    elif kind == "rec":
        y, new_cache, a_aux = rglru.rec_apply(
            p["mixer"], h, cfg, mode=mode, cache=cache)
    elif kind == "ssd":
        y, new_cache, a_aux = ssd.ssd_apply(
            p["mixer"], h, cfg, mode=mode, cache=cache)
    else:
        raise ValueError(kind)
    for k in AUX_KEYS:
        if k in a_aux:
            aux[k] = aux[k] + jnp.asarray(a_aux[k], jnp.float32)
    _merge_tel(aux, a_aux)
    x = x + y.astype(x.dtype)
    if "ffn" in p:
        h2 = layers.apply_norm(p["norm_ffn"], x, cfg.norm)
        # mode gates the FFN execution path (decode-shaped kernel at
        # (B, 1, d)) and the router aux (inference skips lb_loss)
        if cfg.num_experts > 0:
            y2, f_aux = moe.moe_apply(p["ffn"], h2, cfg, mode=mode,
                                      seq_lengths=seq_lengths)
        else:
            y2, f_aux = ffn.ffn_apply(p["ffn"], h2, cfg, mode=mode,
                                      seq_lengths=seq_lengths)
        x = x + y2.astype(x.dtype)
        for k in AUX_KEYS:
            if k in f_aux:
                aux[k] = aux[k] + jnp.asarray(f_aux[k], jnp.float32)
        _merge_tel(aux, f_aux)
    return x, new_cache, aux


# ---------------------------------------------------------------- stacking
def _unit_defs(cfg: ModelConfig) -> dict:
    return {f"b{i}_{kind}": block_defs(cfg, kind)
            for i, kind in enumerate(cfg.pattern)}


def _tail_kinds(cfg: ModelConfig) -> Tuple[str, ...]:
    rem = cfg.num_layers % len(cfg.pattern)
    return cfg.pattern[:rem]


def num_units(cfg: ModelConfig) -> int:
    return cfg.num_layers // len(cfg.pattern)


def _is_axes(x):
    return isinstance(x, tuple)


def block_cache_axes(cfg: ModelConfig, kind: str,
                     kv_paged: bool = False) -> dict:
    """Logical partition axes mirroring block_cache structure."""
    if kind == "attn":
        if kv_paged and cfg.window is None:
            # paged pools: the page axis replaces batch and is kept
            # replicated (multi-host page sharding is a ROADMAP follow-on)
            ax = {"k": (None, "kv_heads", None, None),
                  "v": (None, "kv_heads", None, None),
                  "slot_pos": (None, None)}
            if attention.sparse_applicable(cfg):
                ax["codes"] = (None, "kv_heads", None, None)
            return ax
        ax = {"k": ("batch", "kv_heads", "seq_shard", None),
              "v": ("batch", "kv_heads", "seq_shard", None),
              "slot_pos": ("batch", None)}
        if attention.sparse_applicable(cfg):
            ax["codes"] = ("batch", "kv_heads", "seq_shard", None)
        return ax
    if kind == "rec":
        return {"h": ("batch", "lru"), "conv": ("batch", None, "lru")}
    if kind == "ssd":
        return {"h": ("batch", "ssm_heads", None, None),
                "conv": ("batch", None, None)}
    raise ValueError(kind)


def cache_axes(cfg: ModelConfig, kv_paged: bool = False) -> dict:
    units = {}
    for i, kind in enumerate(cfg.pattern):
        ax = block_cache_axes(cfg, kind, kv_paged)
        units[f"b{i}_{kind}"] = jax.tree_util.tree_map(
            lambda t: ("layer", *t), ax, is_leaf=_is_axes)
    out = {"units": units}
    tail = _tail_kinds(cfg)
    if tail:
        out["tail"] = {f"t{i}_{kind}": block_cache_axes(cfg, kind, kv_paged)
                       for i, kind in enumerate(tail)}
    return out


def lm_defs(cfg: ModelConfig) -> dict:
    defs: dict = {
        "embed": layers.embed_defs(cfg.padded_vocab, cfg.d_model),
        "final_norm": layers.norm_defs(cfg.d_model, cfg.norm),
        "units": stack_defs(_unit_defs(cfg), num_units(cfg)),
    }
    tail = _tail_kinds(cfg)
    if tail:
        defs["tail"] = {f"t{i}_{kind}": block_defs(cfg, kind)
                        for i, kind in enumerate(tail)}
    if not cfg.tie_embeddings:
        defs["head"] = {"w": ParamDef((cfg.d_model, cfg.padded_vocab),
                                      jnp.bfloat16, ("embed", "vocab"),
                                      init="fan_in", trainable=False)}
    if cfg.positional == "learned":
        defs["pos"] = layers.pos_embed_defs(cfg.max_position, cfg.d_model)
    return defs


def lm_init(cfg: ModelConfig, key: jax.Array) -> dict:
    return init_tree(lm_defs(cfg), key)


def _kind_paged(cfg: ModelConfig, kind: str, kv_pages) -> bool:
    """A block's cache uses the paged pool layout: attention without a SWA
    ring (the ring is already window-bounded) under a paged engine."""
    return kind == "attn" and kv_pages is not None and cfg.window is None


def paged_applicable(cfg: ModelConfig) -> bool:
    """The paged KV layout has something to page: at least one attention
    block whose cache is a full-length strip (no SWA ring bound)."""
    return ("attn" in cfg.pattern and cfg.window is None
            and cfg.family != "audio")


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                kv_pages: Optional[int] = None) -> dict:
    """kv_pages: when set, attention caches become (kv_pages, page_size,
    ...) pools shared across slots (serving/kv_pages.py) instead of
    per-slot (batch, max_len, ...) strips; recurrent/SSM states and SWA
    ring caches keep the per-slot layout."""
    def one_cache(kind):
        if _kind_paged(cfg, kind, kv_pages):
            return attention.init_paged_cache(cfg, kv_pages)
        return block_cache(cfg, kind, batch, max_len)

    unit_caches = {}
    for i, kind in enumerate(cfg.pattern):
        one = one_cache(kind)
        unit_caches[f"b{i}_{kind}"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (num_units(cfg), *x.shape)),
            one)
    caches = {"units": unit_caches}
    tail = _tail_kinds(cfg)
    if tail:
        caches["tail"] = {f"t{i}_{kind}": one_cache(kind)
                          for i, kind in enumerate(tail)}
    return caches


# ---------------------------------------------------------------- forward
def _embed_inputs(params: dict, cfg: ModelConfig, batch: Dict[str, jax.Array],
                  pos0: Any = 0) -> jax.Array:
    tokens = batch["tokens"]
    x = layers.embed_lookup(params["embed"], tokens, cfg.scale_embed,
                            cfg.d_model)
    if cfg.frontend_tokens and "frontend_embeds" in batch:
        fe = batch["frontend_embeds"].astype(x.dtype)
        x = jnp.concatenate([fe, x], axis=1)
    if cfg.positional == "learned":
        s = x.shape[1]
        p0 = jnp.asarray(pos0, jnp.int32)
        # scalar pos0 -> (s,) positions; per-slot (B,) pos0 -> (B, s)
        pos = p0[..., None] + jnp.arange(s, dtype=jnp.int32) \
            if p0.ndim else p0 + jnp.arange(s, dtype=jnp.int32)
        x = x + jnp.take(params["pos"]["pos_embedding"], pos, axis=0,
                         mode="clip")
    return shard(x, "batch", None, None)


def _run_blocks(params: dict, cfg: ModelConfig, x: jax.Array, *, mode: str,
                caches=None, pos=None, remat: bool = True, kv_valid=None,
                page_table=None, seq_lengths=None
                ) -> Tuple[jax.Array, Any, Dict[str, jax.Array]]:
    aux_total = {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}

    def unit_body(carry, xs):
        # sequence-parallel residual stream: remat saves the carry in this
        # (batch x seq/model)-sharded form (DESIGN.md §4, §Perf log)
        h = shard(carry, "batch", "seq_sp", None)
        unit_p = xs["params"]
        unit_c = xs.get("cache")
        new_caches = {}
        aux_u = {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}
        for i, kind in enumerate(cfg.pattern):
            name = f"b{i}_{kind}"
            c = None if unit_c is None else unit_c[name]
            h, nc, aux = block_apply(unit_p[name], h, cfg, kind, mode=mode,
                                     cache=c, pos=pos, kv_valid=kv_valid,
                                     page_table=page_table,
                                     seq_lengths=seq_lengths)
            new_caches[name] = nc
            for k in AUX_KEYS:
                aux_u[k] = aux_u[k] + aux[k]
            _merge_tel(aux_u, aux)
        ys: Dict[str, Any] = {"aux": aux_u}
        if unit_c is not None:
            ys["cache"] = new_caches
        return h, ys

    body = unit_body
    if remat and mode == "train":
        body = jax.checkpoint(unit_body, prevent_cse=False)

    xs: Dict[str, Any] = {"params": params["units"]}
    if caches is not None:
        xs["cache"] = caches["units"]
    from repro.core.chunking import maybe_scan
    x, ys = maybe_scan(body, x, xs)
    for k in AUX_KEYS:
        aux_total[k] = aux_total[k] + jnp.sum(ys["aux"][k])
    for k, v in ys["aux"].items():
        if k.startswith("tel_"):
            aux_total[k] = v          # stacked per scan unit: (U, ...)
    new_caches = {"units": ys["cache"]} if caches is not None else None

    tail = _tail_kinds(cfg)
    if tail:
        tail_caches = {}
        for i, kind in enumerate(tail):
            name = f"t{i}_{kind}"
            c = None if caches is None else caches["tail"][name]
            x, nc, aux = block_apply(params["tail"][name], x, cfg, kind,
                                     mode=mode, cache=c, pos=pos,
                                     kv_valid=kv_valid,
                                     page_table=page_table,
                                     seq_lengths=seq_lengths)
            tail_caches[name] = nc
            for k in AUX_KEYS:
                aux_total[k] = aux_total[k] + aux[k]
            for k, v in aux.items():
                if k.startswith("tel_"):      # tail blocks append a unit row
                    row = jnp.asarray(v)[None]
                    aux_total[k] = (
                        row if k not in aux_total
                        else jnp.concatenate([aux_total[k], row], axis=0))
        if caches is not None:
            new_caches["tail"] = tail_caches
    return x, new_caches, aux_total


def lm_hidden(params: dict, cfg: ModelConfig, batch: Dict[str, jax.Array],
              remat: bool = True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Train-mode forward to final hidden states (B, S_total, d)."""
    x = _embed_inputs(params, cfg, batch)
    x, _, aux = _run_blocks(params, cfg, x, mode="train", remat=remat)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    return x, aux


def head_weight(params: dict, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"]["embedding"].T
    return params["head"]["w"]


def logits_of(params: dict, cfg: ModelConfig, hidden: jax.Array) -> jax.Array:
    w = jax.lax.stop_gradient(head_weight(params, cfg))
    out = jnp.einsum("...d,dv->...v", hidden, w.astype(hidden.dtype))
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        out = jnp.tanh(out / c) * c
    return shard(out, "batch", None, "vocab")


def lm_prefill(params: dict, cfg: ModelConfig, batch: Dict[str, jax.Array],
               max_len: int) -> Tuple[Any, jax.Array]:
    """Process the prompt; returns (caches, last-position logits)."""
    bsz = batch["tokens"].shape[0]
    caches = init_caches(cfg, bsz, max_len)
    x = _embed_inputs(params, cfg, batch)
    x, caches, _ = _run_blocks(params, cfg, x, mode="prefill", caches=caches,
                               pos=0, remat=False)
    x = layers.apply_norm(params["final_norm"], x[:, -1:], cfg.norm)
    return caches, logits_of(params, cfg, x)


def lm_decode_step(params: dict, cfg: ModelConfig, caches: Any,
                   token: jax.Array, pos: jax.Array,
                   kv_valid: Optional[jax.Array] = None,
                   page_table: Optional[jax.Array] = None,
                   return_counters: bool = False):
    """One token for every sequence in the batch.  token: (B,);
    pos: () shared position, or (B,) per-slot positions (continuous
    batching decodes slots sitting at ragged depths).
    kv_valid: optional (B, cache_size) slot-validity mask computed ONCE by
    the caller (the serving engine) and shared by every attention layer —
    otherwise each layer rederives it from its cache's slot positions.
    page_table: optional (B, max_pages) slot->page map — signals that the
    attention caches in ``caches`` are paged pools (init_caches was called
    with kv_pages); None means the contiguous strip layout.
    return_counters: also return the telemetry counter tree (tel_* aux
    entries, stacked per pattern unit) as a third element — requires
    ``spt.telemetry`` != "off" for the tree to be non-empty.  The default
    keeps the exact two-element return so existing traces are unchanged."""
    x = _embed_inputs(params, cfg, {"tokens": token[:, None]}, pos0=pos)
    x, caches, aux = _run_blocks(params, cfg, x, mode="decode", caches=caches,
                                 pos=pos, remat=False, kv_valid=kv_valid,
                                 page_table=page_table)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    logits = logits_of(params, cfg, x)
    if return_counters:
        tel = {k: v for k, v in aux.items() if k.startswith("tel_")}
        return caches, logits, tel
    return caches, logits


# ------------------------------------------------- serving cache plumbing
def supports_ragged_prefill(cfg: ModelConfig) -> bool:
    """Right-padded ragged prefill is exact only for pure-attention stacks:
    padding past a sequence's length is causally invisible to attention,
    but it would corrupt recurrent (rec/ssd) states."""
    return all(k == "attn" for k in cfg.pattern)


def _mask_invalid_slots(caches: dict, lengths: jax.Array) -> dict:
    """Mark attention-cache slots holding positions >= lengths[b] as empty
    (slot_pos = -1) so a right-padded prefill leaves no phantom KV."""
    def walk(tree, lead):
        out = {}
        for name, v in tree.items():
            if isinstance(v, dict):
                out[name] = walk(v, lead)
            elif name == "slot_pos":
                ln = lengths.reshape((1,) * lead + (-1, 1))
                out[name] = jnp.where(v >= ln, jnp.int32(-1), v)
            else:
                out[name] = v
        return out

    new = {"units": walk(caches["units"], 1)}
    if "tail" in caches:
        new["tail"] = walk(caches["tail"], 0)
    return new


def length_sensitive(cfg: ModelConfig) -> bool:
    """Right-padding alone changes this config's real-token outputs unless
    per-row lengths are threaded through the layers: sparse MHA's top-L
    budget and routed-FFN / MoE dispatch capacity scale with the (static)
    sequence length."""
    return ((cfg.num_heads > 0 and attention.sparse_applicable(cfg))
            or ffn.routed_applicable(cfg) or cfg.num_experts > 0)


def lm_prefill_ragged(params: dict, cfg: ModelConfig,
                      batch: Dict[str, jax.Array], lengths: jax.Array,
                      max_len: int, return_counters: bool = False):
    """Prefill a (B, S) batch of right-padded prompts of per-sequence
    `lengths` (total model positions, i.e. including any frontend tokens).
    Returns (caches, logits at each sequence's last real position).

    Row outputs are exact — identical to prefilling each row alone at its
    exact length: the causal mask hides pad keys from real queries, and
    for length-sensitive configs the per-row lengths are threaded into
    sparse-MHA selection budgets and routed-FFN/MoE dispatch capacities
    (which routes sparse prefill through the jnp path; ragged budgets in
    the fused prefill kernel are a follow-on)."""
    bsz = batch["tokens"].shape[0]
    caches = init_caches(cfg, bsz, max_len)
    x = _embed_inputs(params, cfg, batch)
    sl = lengths if length_sensitive(cfg) else None
    x, caches, aux = _run_blocks(params, cfg, x, mode="prefill",
                                 caches=caches, pos=0, remat=False,
                                 seq_lengths=sl)
    idx = jnp.clip(lengths - 1, 0, x.shape[1] - 1)
    x_last = jnp.take_along_axis(
        x, idx[:, None, None].astype(jnp.int32), axis=1)        # (B, 1, d)
    x_last = layers.apply_norm(params["final_norm"], x_last, cfg.norm)
    caches = _mask_invalid_slots(caches, lengths)
    logits = logits_of(params, cfg, x_last)
    if return_counters:
        tel = {k: v for k, v in aux.items() if k.startswith("tel_")}
        return caches, logits, tel
    return caches, logits


def write_slot_caches_rows(dst: dict, rows: dict, slots: jax.Array) -> dict:
    """Scatter every row of a (Bp, ...) prefill group's caches into its
    engine slot in ONE call (the serial engine paid one host-synced jit
    call per admission).  Each target row is replaced wholesale (KV,
    slot_pos, recurrent states), which doubles as the slot's recycling
    reset.  slots: (Bp,) int32; -1 marks a bucket-padding dummy row,
    which routes out of bounds and is dropped."""
    def walk(d, r, lead):
        out = {}
        for name, v in d.items():
            if isinstance(v, dict):
                out[name] = walk(v, r[name], lead)
            elif lead:                         # stacked units: (U, B, ...)
                dest = jnp.where(slots >= 0, slots, jnp.int32(v.shape[1]))
                out[name] = v.at[:, dest].set(r[name].astype(v.dtype),
                                              mode="drop")
            else:                              # tail blocks: (B, ...)
                dest = jnp.where(slots >= 0, slots, jnp.int32(v.shape[0]))
                out[name] = v.at[dest].set(r[name].astype(v.dtype),
                                           mode="drop")
        return out

    new = {"units": walk(dst["units"], rows["units"], True)}
    if "tail" in dst:
        new["tail"] = walk(dst["tail"], rows["tail"], False)
    return new


def _map_blocks(caches: dict, fn) -> dict:
    """Apply fn(kind, block_cache_dict, lead) over every block's cache.
    Block kind is recovered from the 'b{i}_{kind}' / 't{i}_{kind}' names."""
    def one(tree, lead):
        return {name: fn(name.split("_", 1)[1], blk, lead)
                for name, blk in tree.items()}

    new = {"units": one(caches["units"], True)}
    if "tail" in caches:
        new["tail"] = one(caches["tail"], False)
    return new


def write_slot_caches_paged_rows(dst: dict, rows: dict, slots: jax.Array,
                                 page_table: jax.Array,
                                 cfg: ModelConfig) -> dict:
    """Paged counterpart of write_slot_caches_rows: one page-wise scatter
    covers every row of a prefill group (prefill rows are always
    contiguous — prefill compute is layout-agnostic; the serial engine
    paid one host-side jit call per admission).  Recurrent/SSM states and
    SWA ring caches keep the per-slot scatter.  Page rows past a slot's
    allocation (bucketed right-pad overhang with -1 page ids) are dropped
    — decode overwrites them before any read.  slots: (Bp,) int32 slot
    per row, -1 for bucket-padding dummy rows; their page rows become all
    -1 ids, so every write drops.  Page ids are unique across slots, so
    the batched scatter has no conflicting destinations."""
    from repro.serving import kv_pages

    ps = cfg.spt.kv_page_size
    ns = page_table.shape[0]
    pt_rows = jnp.where(slots[:, None] >= 0,
                        page_table[jnp.clip(slots, 0, ns - 1)],
                        jnp.int32(-1))                    # (Bp, MP)

    def one(dst_tree, row_tree, lead):
        out = {}
        for bname, blk in dst_tree.items():
            kind = bname.split("_", 1)[1]
            paged = kind == "attn" and cfg.window is None
            rblk = row_tree[bname]
            nb = {}
            for name, v in blk.items():
                r = rblk[name]
                if paged:
                    pad = -1 if name == "slot_pos" else 0
                    if lead:                   # (U, ...) -> vmap over U
                        nb[name] = jax.vmap(
                            lambda pool, seq: kv_pages.scatter_prefill_rows(
                                pool, pt_rows, seq, ps, pad))(v, r)
                    else:
                        nb[name] = kv_pages.scatter_prefill_rows(
                            v, pt_rows, r, ps, pad)
                elif lead:
                    dest = jnp.where(slots >= 0, slots,
                                     jnp.int32(v.shape[1]))
                    nb[name] = v.at[:, dest].set(r.astype(v.dtype),
                                                 mode="drop")
                else:
                    dest = jnp.where(slots >= 0, slots,
                                     jnp.int32(v.shape[0]))
                    nb[name] = v.at[dest].set(r.astype(v.dtype), mode="drop")
            out[bname] = nb
        return out

    new = {"units": one(dst["units"], rows["units"], True)}
    if "tail" in dst:
        new["tail"] = one(dst["tail"], rows["tail"], False)
    return new


def reset_page_slots(caches: dict, cfg: ModelConfig, pid: jax.Array,
                     ok: jax.Array) -> dict:
    """Invalidate slot_pos of freshly allocated pages (pid (B,), ok (B,)
    from kv_pages.alloc_masked): a recycled page still carries its previous
    tenant's slot_pos rows, which would look valid to the self-derived
    kv_valid fallback.  K/V/code rows need no reset — they are masked by
    validity until overwritten."""
    dest = jnp.where(ok, pid, jnp.int32(1 << 30))         # huge -> drop

    def blk_fn(kind, blk, lead):
        if not (kind == "attn" and cfg.window is None):
            return blk
        new = dict(blk)
        sp = blk["slot_pos"]
        if lead:
            new["slot_pos"] = sp.at[:, dest].set(-1, mode="drop")
        else:
            new["slot_pos"] = sp.at[dest].set(-1, mode="drop")
        return new

    return _map_blocks(caches, blk_fn)
