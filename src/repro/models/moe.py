"""Mixture-of-Experts FFN (grok-1 / mixtral style: softmax top-2 of E).

Reuses the capacity-bucketed, per-sequence dispatch engine
(core/dispatch.py) that also implements the paper's routed FFN — the two
are the same mechanism at different granularity (DESIGN.md
§Arch-applicability).  Expert FFN hidden dims are sharded on the "model"
mesh axis; experts themselves are replicated so routing stays local (no
all-to-all in the baseline; an EP variant is a hillclimb option).

Because the mechanism is identical, the fused routed-FFN Pallas kernels
serve MoE too (ROADMAP "MoE kernel reuse"): ``spt.ffn_impl="pallas"``
lowers train/prefill through ``grouped_ffn_kernel`` (in-kernel
scalar-prefetch dispatch, softmax top-k gates in place of the |logit|
router) with the jnp path as the differentiated reference, and serving
decode at (B, 1, d) through ``decode_ffn_kernel`` (top-k expert ids
scalar-prefetched into the weight-block index_maps — no dispatch buffer).
``REPRO_DISABLE_KERNELS=1`` forces the jnp path everywhere.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import dispatch, lora
from repro.core.params import ParamDef
from repro.core.routed_ffn import ACTIVATIONS
from repro.sharding import shard


def moe_defs(cfg: ModelConfig) -> dict:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    lc = cfg.spt.lora
    defs = {
        "router": ParamDef((d, e), jnp.float32, ("embed", "expert"),
                           init="fan_in", trainable=True),
        "wi": ParamDef((e, d, f), jnp.bfloat16,
                       ("expert", "embed", "expert_ffn"),
                       init="fan_in", trainable=False),
        "wo": ParamDef((e, f, d), jnp.bfloat16,
                       ("expert", "expert_ffn", "embed"),
                       init="fan_in", trainable=False),
    }
    if cfg.gated_ffn:
        defs["wg"] = ParamDef((e, d, f), jnp.bfloat16,
                              ("expert", "embed", "expert_ffn"),
                              init="fan_in", trainable=False)
    if lc.enabled:
        r = lc.rank
        defs["lora_wi"] = {
            "b": ParamDef((d, r), jnp.float32, ("embed", "lora_rank"),
                          init="fan_in", trainable=True),
            "c": ParamDef((e, r, f), jnp.float32,
                          ("expert", "lora_rank", "expert_ffn"),
                          init="zeros", trainable=True)}
        defs["lora_wo"] = {
            "b": ParamDef((e, f, r), jnp.float32,
                          ("expert", "expert_ffn", "lora_rank"),
                          init="fan_in", trainable=True),
            "c": ParamDef((r, d), jnp.float32, ("lora_rank", "embed"),
                          init="zeros", trainable=True)}
        if cfg.gated_ffn:
            defs["lora_wg"] = {
                "b": ParamDef((d, r), jnp.float32, ("embed", "lora_rank"),
                              init="fan_in", trainable=True),
                "c": ParamDef((e, r, f), jnp.float32,
                              ("expert", "lora_rank", "ffn"), init="zeros",
                              trainable=True)}
    return defs


def _route_experts(p: dict, x: jax.Array, cfg: ModelConfig
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Softmax router: (choice (B,S,k) int32, gate (B,S,k) f32 renormalized
    over the top-k, probs (B,S,E) f32).  The softmax always runs — unlike
    the routed FFN's |logit| router it feeds the gates, not just the
    load-balance loss."""
    k = cfg.experts_per_token
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, choice = jax.lax.top_k(probs, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)   # renormalize top-k
    return choice.astype(jnp.int32), gate, probs


def _moe_lora_tree(p: dict) -> Optional[dict]:
    """Adapt MoE LoRA params to the routed-FFN kernels' lora_params layout
    (identical shapes: experts are the group axis)."""
    if "lora_wi" not in p:
        return None
    t = {"lora_inner": p["lora_wi"], "lora_outer": p["lora_wo"]}
    if "lora_wg" in p:
        t["lora_gate"] = p["lora_wg"]
    return t


def _moe_cap_dyn(cfg: ModelConfig, seq_lengths):
    if seq_lengths is None:
        return None
    return dispatch.capacity_dyn(seq_lengths, cfg.num_experts,
                                 cfg.experts_per_token,
                                 cfg.moe_capacity_factor,
                                 pad=cfg.spt.dispatch_pad)


def _moe_reference(x: jax.Array, p: dict, cfg: ModelConfig, need_aux: bool,
                   seq_lengths=None) -> Tuple[jax.Array, dict]:
    """The jnp capacity-dispatch path (BSpMV analogue) — also the
    differentiated reference for the fused-kernel forward."""
    lc = cfg.spt.lora
    b, s, d = x.shape
    e = cfg.num_experts
    choice, gate, probs = _route_experts(p, x, cfg)
    cap = dispatch.capacity(s, e, cfg.experts_per_token,
                            cfg.moe_capacity_factor,
                            pad=cfg.spt.dispatch_pad)
    plan = dispatch.make_plan(choice, gate, e, cap,
                              cap_dyn=_moe_cap_dyn(cfg, seq_lengths))
    xg = dispatch.gather(x, plan)                        # (B, E, C, d)
    xg = shard(xg, "batch", None, None, None)

    def proj_in(w_key, lora_key):
        w = jax.lax.stop_gradient(p[w_key]).astype(x.dtype)
        up = jnp.einsum("becd,edf->becf", xg, w)
        if lc.enabled and lora_key in p:
            li = p[lora_key]
            xb = jnp.einsum("becd,dr->becr", xg, li["b"].astype(x.dtype))
            up = up + lc.scale * jnp.einsum(
                "becr,erf->becf", xb, li["c"].astype(x.dtype))
        return up

    act = ACTIVATIONS[cfg.activation]
    up = proj_in("wi", "lora_wi")
    if cfg.gated_ffn:
        h = act(proj_in("wg", "lora_wg")) * up
    else:
        h = act(up)
    h = shard(h, "batch", None, None, "ffn")
    wo = jax.lax.stop_gradient(p["wo"]).astype(x.dtype)
    y = jnp.einsum("becf,efd->becd", h, wo)
    if lc.enabled and "lora_wo" in p:
        hb = jnp.einsum("becf,efr->becr", h, p["lora_wo"]["b"].astype(x.dtype))
        y = y + lc.scale * jnp.einsum(
            "becr,rd->becd", hb, p["lora_wo"]["c"].astype(x.dtype))
    out = dispatch.combine(y, plan, s).astype(x.dtype)
    aux = {
        "lb_loss": (dispatch.load_balance_loss(probs, choice, e)
                    if need_aux else jnp.zeros((), jnp.float32)),
        "dropped": plan.dropped,
    }
    return out, aux


# ------------------------------------------------- fused kernel paths
def _moe_kernel_forward(x: jax.Array, p: dict, cfg: ModelConfig,
                        need_aux: bool, seq_lengths=None
                        ) -> Tuple[jax.Array, dict]:
    """Route + plan in jnp, expert GEMMs in the fused grouped kernel (the
    token gather rides in-kernel via the scalar-prefetched plan index);
    the combine scatter-add stays jnp, mirroring kernels/routed_ffn/ops."""
    from repro.kernels.routed_ffn.routed_ffn import grouped_ffn_kernel
    b, s, d = x.shape
    e = cfg.num_experts
    sg = jax.lax.stop_gradient
    choice, gate, probs = _route_experts(p, x, cfg)
    cap = dispatch.capacity(s, e, cfg.experts_per_token,
                            cfg.moe_capacity_factor,
                            pad=cfg.spt.dispatch_pad)
    plan = dispatch.make_plan(choice, gate, e, cap,
                              cap_dyn=_moe_cap_dyn(cfg, seq_lengths))
    y = grouped_ffn_kernel(
        x, plan.index, sg(p["wi"]), sg(p["wo"]),
        sg(p["wg"]) if cfg.gated_ffn else None,
        _moe_lora_tree(p), cfg.spt.lora.scale, act=cfg.activation)
    out = dispatch.combine(y.astype(x.dtype), plan, s)
    aux = {
        "lb_loss": (dispatch.load_balance_loss(probs, choice, e)
                    if need_aux else jnp.zeros((), jnp.float32)),
        "dropped": plan.dropped,
    }
    return out, aux


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _moe_kernel_op(x, p, cfg, need_aux):
    return _moe_kernel_forward(x, p, cfg, need_aux)


def _moe_kernel_fwd(x, p, cfg, need_aux):
    return _moe_kernel_forward(x, p, cfg, need_aux), (x, p)


def _moe_kernel_bwd(cfg, need_aux, res, cts):
    # identical routing plan => identical function; differentiate the jnp
    # reference (same contract as kernels/routed_ffn/ops.py)
    x, p = res

    def ref(x_, p_):
        return _moe_reference(x_, p_, cfg, need_aux)

    _, vjp = jax.vjp(ref, x, p)
    return vjp(cts)


_moe_kernel_op.defvjp(_moe_kernel_fwd, _moe_kernel_bwd)


def _moe_decode_kernel(x: jax.Array, p: dict, cfg: ModelConfig
                       ) -> Tuple[jax.Array, dict]:
    """Serving decode at (B, 1, d): the top-k expert ids index the expert
    weight blocks directly in the block-gather kernel — no capacity plan,
    no dispatch buffer, no scatter.  Inference-only (no VJP)."""
    from repro.kernels.routed_ffn.routed_ffn import decode_ffn_kernel
    sg = jax.lax.stop_gradient
    choice, gate, _ = _route_experts(p, x, cfg)
    y = decode_ffn_kernel(
        x[:, 0], choice[:, 0], gate[:, 0], sg(p["wi"]), sg(p["wo"]),
        sg(p["wg"]) if cfg.gated_ffn else None,
        _moe_lora_tree(p), cfg.spt.lora.scale, act=cfg.activation)
    aux = {"lb_loss": jnp.zeros((), jnp.float32),
           "dropped": jnp.zeros((), jnp.float32)}
    return y.astype(x.dtype)[:, None], aux


def moe_apply(p: dict, x: jax.Array, cfg: ModelConfig, mode: str = "train",
              seq_lengths=None) -> Tuple[jax.Array, dict]:
    """x: (B, S, d) -> (y, aux).  The router softmax stays (it feeds the
    top-k gates) but inference modes skip the load-balance loss.  With
    ``spt.ffn_impl="pallas"`` (and REPRO_DISABLE_KERNELS unset) the expert
    GEMMs lower through the fused routed-FFN kernels — decode-shaped
    inputs skip the capacity plan entirely.

    seq_lengths: per-row real lengths (B,) for batched ragged prefill
    (exact-length expert capacity per row).  Serving-only: the kernel path
    then skips the custom-VJP wrapper."""
    need_aux = mode == "train"
    squeeze = x.ndim == 2
    if squeeze:
        x = x[None]
    if (mode == "decode" and x.shape[1] == 1
            and dispatch.use_decode_ffn_kernel(cfg)):
        out, aux = _moe_decode_kernel(x, p, cfg)
    elif dispatch.use_routed_ffn_kernel(cfg):
        if seq_lengths is not None:
            out, aux = _moe_kernel_forward(x, p, cfg, need_aux,
                                           seq_lengths=seq_lengths)
        else:
            out, aux = _moe_kernel_op(x, p, cfg, need_aux)
    else:
        out, aux = _moe_reference(x, p, cfg, need_aux,
                                  seq_lengths=seq_lengths)
    if dispatch.use_telemetry_counters(cfg) and mode in ("prefill", "decode"):
        # jit-pure telemetry counters (serving/telemetry.py): re-run the
        # tiny router einsum so kernel and jnp paths report identical loads
        from repro.models.ffn import _tel_expert_load
        choice, _, _ = _route_experts(p, x, cfg)
        aux = dict(aux)
        aux["tel_expert_load"] = _tel_expert_load(
            choice, cfg.num_experts, x, seq_lengths)
        aux["tel_expert_drop"] = jnp.asarray(
            aux.get("dropped", 0.0), jnp.float32)
    return (out[0] if squeeze else out), aux
