"""Mixture-of-Experts FFN (grok-1 / mixtral style: softmax top-2 of E).

Reuses the capacity-bucketed, per-sequence dispatch engine
(core/dispatch.py) that also implements the paper's routed FFN — the two
are the same mechanism at different granularity (DESIGN.md
§Arch-applicability).  Expert FFN hidden dims are sharded on the "model"
mesh axis; experts themselves are replicated so routing stays local (no
all-to-all in the baseline; an EP variant is a hillclimb option).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import dispatch, lora
from repro.core.params import ParamDef
from repro.core.routed_ffn import ACTIVATIONS
from repro.sharding import shard


def moe_defs(cfg: ModelConfig) -> dict:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    lc = cfg.spt.lora
    defs = {
        "router": ParamDef((d, e), jnp.float32, ("embed", "expert"),
                           init="fan_in", trainable=True),
        "wi": ParamDef((e, d, f), jnp.bfloat16,
                       ("expert", "embed", "expert_ffn"),
                       init="fan_in", trainable=False),
        "wo": ParamDef((e, f, d), jnp.bfloat16,
                       ("expert", "expert_ffn", "embed"),
                       init="fan_in", trainable=False),
    }
    if cfg.gated_ffn:
        defs["wg"] = ParamDef((e, d, f), jnp.bfloat16,
                              ("expert", "embed", "expert_ffn"),
                              init="fan_in", trainable=False)
    if lc.enabled:
        r = lc.rank
        defs["lora_wi"] = {
            "b": ParamDef((d, r), jnp.float32, ("embed", "lora_rank"),
                          init="fan_in", trainable=True),
            "c": ParamDef((e, r, f), jnp.float32,
                          ("expert", "lora_rank", "expert_ffn"),
                          init="zeros", trainable=True)}
        defs["lora_wo"] = {
            "b": ParamDef((e, f, r), jnp.float32,
                          ("expert", "expert_ffn", "lora_rank"),
                          init="fan_in", trainable=True),
            "c": ParamDef((r, d), jnp.float32, ("lora_rank", "embed"),
                          init="zeros", trainable=True)}
        if cfg.gated_ffn:
            defs["lora_wg"] = {
                "b": ParamDef((d, r), jnp.float32, ("embed", "lora_rank"),
                              init="fan_in", trainable=True),
                "c": ParamDef((e, r, f), jnp.float32,
                              ("expert", "lora_rank", "ffn"), init="zeros",
                              trainable=True)}
    return defs


def moe_apply(p: dict, x: jax.Array, cfg: ModelConfig, mode: str = "train"
              ) -> Tuple[jax.Array, dict]:
    """x: (B, S, d) -> (y, aux).  The router softmax stays (it feeds the
    top-k gates) but inference modes skip the load-balance loss.
    Follow-on (ROADMAP): reuse the routed-FFN kernel switch here — the
    dispatch mechanism is identical at expert granularity."""
    lc = cfg.spt.lora
    squeeze = x.ndim == 2
    if squeeze:
        x = x[None]
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, choice = jax.lax.top_k(probs, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)   # renormalize top-k
    cap = dispatch.capacity(s, e, k, cfg.moe_capacity_factor,
                            pad=cfg.spt.dispatch_pad)
    plan = dispatch.make_plan(choice.astype(jnp.int32), gate, e, cap)
    xg = dispatch.gather(x, plan)                        # (B, E, C, d)
    xg = shard(xg, "batch", None, None, None)

    def proj_in(w_key, lora_key):
        w = jax.lax.stop_gradient(p[w_key]).astype(x.dtype)
        up = jnp.einsum("becd,edf->becf", xg, w)
        if lc.enabled and lora_key in p:
            li = p[lora_key]
            xb = jnp.einsum("becd,dr->becr", xg, li["b"].astype(x.dtype))
            up = up + lc.scale * jnp.einsum(
                "becr,erf->becf", xb, li["c"].astype(x.dtype))
        return up

    act = ACTIVATIONS[cfg.activation]
    up = proj_in("wi", "lora_wi")
    if cfg.gated_ffn:
        h = act(proj_in("wg", "lora_wg")) * up
    else:
        h = act(up)
    h = shard(h, "batch", None, None, "ffn")
    wo = jax.lax.stop_gradient(p["wo"]).astype(x.dtype)
    y = jnp.einsum("becf,efd->becd", h, wo)
    if lc.enabled and "lora_wo" in p:
        hb = jnp.einsum("becf,efr->becr", h, p["lora_wo"]["b"].astype(x.dtype))
        y = y + lc.scale * jnp.einsum(
            "becr,rd->becd", hb, p["lora_wo"]["c"].astype(x.dtype))
    out = dispatch.combine(y, plan, s).astype(x.dtype)
    aux = {
        "lb_loss": (dispatch.load_balance_loss(probs, choice, e)
                    if mode == "train" else jnp.zeros((), jnp.float32)),
        "dropped": plan.dropped,
    }
    return (out[0] if squeeze else out), aux
