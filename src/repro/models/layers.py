"""Shared neural building blocks: norms, RoPE, embeddings."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.params import ParamDef


# ---------------------------------------------------------------- norms
def norm_defs(dim: int, kind: str, axis: Optional[str] = "embed") -> dict:
    defs = {"scale": ParamDef((dim,), jnp.float32, (axis,), init="ones",
                              trainable=False)}
    if kind == "layernorm":
        defs["bias"] = ParamDef((dim,), jnp.float32, (axis,), init="zeros",
                                trainable=False)
    return defs


def apply_norm(p: dict, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        raise ValueError(kind)
    return y.astype(x.dtype)


# ---------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, head_dim); pos: (seq,) or (batch, seq) per-sequence
    absolute positions (continuous batching decodes slots at ragged
    positions).  LLaMA-style rotate-half."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                         # (hd/2,)
    if pos.ndim == 2 and x.ndim == 4:
        pos = pos[:, None]                                # broadcast over heads
    angles = pos[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------- embeddings
def embed_defs(vocab: int, dim: int) -> dict:
    return {"embedding": ParamDef((vocab, dim), jnp.bfloat16,
                                  ("vocab", "embed"), init="normal:0.02",
                                  trainable=False)}


def embed_lookup(p: dict, tokens: jax.Array, scale: bool,
                 d_model: int) -> jax.Array:
    x = jnp.take(p["embedding"], tokens, axis=0)
    if scale:
        x = x * jnp.asarray(d_model ** 0.5, x.dtype)
    return x


def pos_embed_defs(max_pos: int, dim: int) -> dict:
    return {"pos_embedding": ParamDef((max_pos, dim), jnp.bfloat16,
                                      (None, "embed"), init="normal:0.02",
                                      trainable=False)}
