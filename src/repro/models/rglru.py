"""Griffin/RecurrentGemma recurrent block: temporal conv + RG-LRU.

    r_t = sigmoid(x_t W_a)                 (recurrence gate)
    i_t = sigmoid(x_t W_i)                 (input gate)
    log a_t = -c * softplus(Lambda) * r_t  (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses an associative scan over time; decode is a single step.
The r/i gate weights are block-diagonal as in Griffin — and on TPU that is
also a sharding property: each "model" shard owns whole gate blocks, so the
gates need no collective (EXPERIMENTS.md §Perf it8).  The paper's sparse
MHA applies to Griffin's *local attention* layers, not here; LoRA applies
to all projections in this block.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import lora
from repro.core.params import ParamDef
from repro.sharding import shard

_C = 8.0


def _gate_blocks(cfg: ModelConfig) -> int:
    """Block-diagonal gate count (Griffin's design): 16 when divisible so
    each model shard owns whole blocks — the gates then need NO collective
    (§Perf it8); falls back to 1 block (= full matrix) for tiny test dims."""
    w = cfg.resolved_lru_width
    return 16 if w % (16 * 8) == 0 else 1


def rglru_defs(cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, cfg.resolved_lru_width
    nb = _gate_blocks(cfg)
    wb = w // nb
    lc = cfg.spt.lora
    return {
        "w_gate": lora.linear_defs(d, w, lc, "embed", "lru"),
        "w_branch": lora.linear_defs(d, w, lc, "embed", "lru"),
        "w_out": lora.linear_defs(w, d, lc, "lru", "embed"),
        "conv": ParamDef((cfg.conv_width, w), jnp.float32, ("conv", "lru"),
                         init="normal:0.1", trainable=False),
        "w_a": ParamDef((nb, wb, wb), jnp.float32, ("lru_blocks", None, None),
                        init="fan_in", trainable=False),
        "w_i": ParamDef((nb, wb, wb), jnp.float32, ("lru_blocks", None, None),
                        init="fan_in", trainable=False),
        "lam": ParamDef((w,), jnp.float32, ("lru",), init="uniform:1.0",
                        trainable=False),
    }


def init_rec_cache(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    w = cfg.resolved_lru_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), jnp.float32),
    }


def _causal_conv(x: jax.Array, kernel: jax.Array,
                 state: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv along time.  x: (B, S, W); kernel: (K, W).
    Returns (y, new_state) where state carries the last K-1 inputs."""
    k = kernel.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * kernel[i].astype(x.dtype)
            for i in range(k))
    new_state = xp[:, -(k - 1):]
    return y, new_state


def _gates(p: dict, xc: jax.Array) -> Tuple[jax.Array, jax.Array]:
    xf = xc.astype(jnp.float32)
    nb, wb, _ = p["w_a"].shape
    lead = xf.shape[:-1]
    xb = xf.reshape(*lead, nb, wb)
    # block-diagonal gates: contraction stays within a block, so a model
    # shard owning whole blocks computes its gates with zero collectives
    r = jax.nn.sigmoid(jnp.einsum("...nw,nwv->...nv", xb, p["w_a"])
                       ).reshape(*lead, nb * wb)
    i = jax.nn.sigmoid(jnp.einsum("...nw,nwv->...nv", xb, p["w_i"])
                       ).reshape(*lead, nb * wb)
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    return a, b


def rglru_scan(p: dict, xc: jax.Array,
               h0: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Linear recurrence h_t = a_t h_{t-1} + b_t via associative scan.
    xc: (B, S, W) post-conv branch input.  Returns (h_seq, h_last)."""
    a, b = _gates(p, xc)
    if h0 is not None:  # fold initial state into step 0
        b = b.at[:, 0].add(a[:, 0] * h0)

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    return h, h[:, -1]


def rglru_step(p: dict, xc: jax.Array, h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One decode step.  xc: (B, W); h: (B, W)."""
    a, b = _gates(p, xc[:, None, :])
    h_new = a[:, 0] * h + b[:, 0]
    return h_new, h_new


def rec_apply(p: dict, x: jax.Array, cfg: ModelConfig, *,
              mode: str = "train",
              cache: Optional[dict] = None
              ) -> Tuple[jax.Array, Optional[dict], dict]:
    """Griffin recurrent block.  x: (B, S, d)."""
    lc = cfg.spt.lora
    gate = jax.nn.gelu(lora.linear(x, p["w_gate"], lc))
    branch = lora.linear(x, p["w_branch"], lc)
    branch = shard(branch, "batch", None, "lru")
    conv_state = None if cache is None else cache["conv"]
    xc, new_conv = _causal_conv(branch, p["conv"], conv_state)
    new_cache = cache
    if mode in ("train", "prefill"):
        h_seq, h_last = rglru_scan(p, xc, None if cache is None else cache["h"])
        if mode == "prefill":
            new_cache = {"h": h_last, "conv": new_conv.astype(jnp.float32)}
        out = h_seq.astype(x.dtype)
    elif mode == "decode":
        assert cache is not None
        h_new, _ = rglru_step(p, xc[:, 0], cache["h"])
        new_cache = {"h": h_new, "conv": new_conv.astype(jnp.float32)}
        out = h_new[:, None, :].astype(x.dtype)
    else:
        raise ValueError(mode)
    y = lora.linear(out * gate, p["w_out"], lc)
    return shard(y, "batch", None, None), new_cache, {}
