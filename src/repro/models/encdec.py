"""Encoder-decoder LM (whisper-base backbone).

Per the assignment the conv audio frontend is a STUB: `input_specs()`
provides precomputed frame embeddings (B, F, d) directly.  The encoder is a
bidirectional transformer stack; the decoder adds causal self-attention and
cross-attention to the encoder output.  Sparse MHA applies to all three
attention forms (the paper supports encoders and decoders via the look-ahead
mask, §4.1); routed FFN applies to both stacks.

Cross-attention K/V (+PQ codes) are computed once at prefill and cached.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import lora, pq
from repro.core import sparse_attention as sa
from repro.core.params import ParamDef, stack_defs
from repro.models import attention, ffn, layers
from repro.sharding import shard


# ------------------------------------------------------------- defs
def _enc_block_defs(cfg: ModelConfig) -> dict:
    return {
        "norm_attn": layers.norm_defs(cfg.d_model, cfg.norm),
        "attn": attention.attn_defs(cfg),
        "norm_ffn": layers.norm_defs(cfg.d_model, cfg.norm),
        "ffn": ffn.ffn_defs(cfg),
    }


def _dec_block_defs(cfg: ModelConfig) -> dict:
    return {
        "norm_self": layers.norm_defs(cfg.d_model, cfg.norm),
        "self_attn": attention.attn_defs(cfg),
        "norm_cross": layers.norm_defs(cfg.d_model, cfg.norm),
        "cross_attn": attention.attn_defs(cfg),
        "norm_ffn": layers.norm_defs(cfg.d_model, cfg.norm),
        "ffn": ffn.ffn_defs(cfg),
    }


def encdec_defs(cfg: ModelConfig) -> dict:
    defs = {
        "embed": layers.embed_defs(cfg.padded_vocab, cfg.d_model),
        "pos_enc": layers.pos_embed_defs(cfg.max_position, cfg.d_model),
        "pos_dec": layers.pos_embed_defs(cfg.max_position, cfg.d_model),
        "enc_blocks": stack_defs(_enc_block_defs(cfg), cfg.encoder_layers),
        "enc_norm": layers.norm_defs(cfg.d_model, cfg.norm),
        "dec_blocks": stack_defs(_dec_block_defs(cfg), cfg.num_layers),
        "dec_norm": layers.norm_defs(cfg.d_model, cfg.norm),
    }
    return defs


# ------------------------------------------------------------- encoder
def encode(params: dict, cfg: ModelConfig, audio_embeds: jax.Array,
           remat: bool = True) -> jax.Array:
    """audio_embeds: (B, F, d) stub frame embeddings."""
    f = audio_embeds.shape[1]
    pos = jnp.arange(f, dtype=jnp.int32)
    x = audio_embeds.astype(cfg.dtype) + jnp.take(
        params["pos_enc"]["pos_embedding"], pos, axis=0, mode="clip")
    x = shard(x, "batch", None, None)

    def body(h, p):
        hh = layers.apply_norm(p["norm_attn"], h, cfg.norm)
        y, _, _ = attention.attn_apply(p["attn"], hh, cfg, mode="train",
                                       causal=False, rope=False)
        h = h + y
        hh = layers.apply_norm(p["norm_ffn"], h, cfg.norm)
        y, _ = ffn.ffn_apply(p["ffn"], hh, cfg, mode="train")
        return h + y, None

    fn = jax.checkpoint(body, prevent_cse=False) if remat else body
    from repro.core.chunking import maybe_scan
    x, _ = maybe_scan(fn, x, params["enc_blocks"])
    return layers.apply_norm(params["enc_norm"], x, cfg.norm)


# ------------------------------------------------------------- decoder
def _dec_block(p: dict, x: jax.Array, cfg: ModelConfig, enc_out, *,
               mode: str, cache=None, pos=None, seq_lengths=None):
    new_cache = dict(cache) if cache is not None else None
    h = layers.apply_norm(p["norm_self"], x, cfg.norm)
    y, self_c, _ = attention.attn_apply(
        p["self_attn"], h, cfg, mode=mode, causal=True,
        cache=None if cache is None else cache["self"], pos=pos, rope=False,
        seq_lengths=seq_lengths)
    x = x + y
    h = layers.apply_norm(p["norm_cross"], x, cfg.norm)
    if mode == "decode":
        y = _cross_decode(p["cross_attn"], h, cfg, cache["cross"])
        cross_c = cache["cross"]
    else:
        # cross-attention keys are the encoder frames (all real); ragged
        # right-padding only pads *queries*, whose outputs are discarded
        y, _, _ = attention.attn_apply(p["cross_attn"], h, cfg, mode="train",
                                       causal=False, kv_x=enc_out, rope=False)
        cross_c = (_build_cross_cache(p["cross_attn"], cfg, enc_out)
                   if mode == "prefill" else None)
    x = x + y
    h = layers.apply_norm(p["norm_ffn"], x, cfg.norm)
    y, aux = ffn.ffn_apply(p["ffn"], h, cfg, mode=mode,
                           seq_lengths=seq_lengths)
    x = x + y
    if new_cache is not None:
        new_cache = {"self": self_c, "cross": cross_c}
    return x, new_cache, aux


def _build_cross_cache(p: dict, cfg: ModelConfig, enc_out: jax.Array) -> dict:
    lc = cfg.spt.lora
    hd = cfg.resolved_head_dim
    k = attention._project(p["wk"], enc_out, lc, cfg.num_kv_heads, hd,
                           "kv_heads")
    v = attention._project(p["wv"], enc_out, lc, cfg.num_kv_heads, hd,
                           "kv_heads")
    out = {"k": k.astype(cfg.dtype), "v": v.astype(cfg.dtype)}
    if attention.sparse_applicable(cfg):
        out["codes"] = pq.assign(k, p["pq"]["codebooks"]).astype(jnp.int8)
    return out


def _cross_decode(p: dict, x: jax.Array, cfg: ModelConfig,
                  cross: dict) -> jax.Array:
    lc = cfg.spt.lora
    hd = cfg.resolved_head_dim
    b, s, _ = x.shape
    q = attention._project(p["wq"], x, lc, cfg.num_heads, hd, "heads")
    scale = hd ** -0.5
    valid = jnp.ones((b, cross["k"].shape[2]), bool)
    if attention.sparse_applicable(cfg):
        out = sa.sparse_mha_decode(q, cross["k"], cross["v"], cross["codes"],
                                   p["pq"]["codebooks"],
                                   attention._sa_config(cfg), scale, valid)
    else:
        out = sa.dense_attention(q, cross["k"], cross["v"], scale,
                                 causal=False, kv_valid=valid, chunk_q=1)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.num_heads * hd)
    return lora.linear(out, p["wo"], lc)


def _decode_stack(params: dict, cfg: ModelConfig, x: jax.Array, enc_out, *,
                  mode: str, caches=None, pos=None, remat: bool = True,
                  seq_lengths=None):
    def body(h, xs):
        p = xs["params"]
        c = xs.get("cache")
        h, nc, aux = _dec_block(p, h, cfg, enc_out, mode=mode, cache=c,
                                pos=pos, seq_lengths=seq_lengths)
        ys: Dict[str, Any] = {"aux": aux}
        if c is not None:
            ys["cache"] = nc
        return h, ys

    fn = body
    if remat and mode == "train":
        fn = jax.checkpoint(body, prevent_cse=False)
    xs: Dict[str, Any] = {"params": params["dec_blocks"]}
    if caches is not None:
        xs["cache"] = caches
    from repro.core.chunking import maybe_scan
    x, ys = maybe_scan(fn, x, xs)
    return x, ys.get("cache"), ys["aux"]


def _embed_dec(params: dict, cfg: ModelConfig, tokens: jax.Array,
               pos0) -> jax.Array:
    x = layers.embed_lookup(params["embed"], tokens, cfg.scale_embed,
                            cfg.d_model)
    s = tokens.shape[1]
    pos = jnp.asarray(pos0, jnp.int32) + jnp.arange(s, dtype=jnp.int32)
    return x + jnp.take(params["pos_dec"]["pos_embedding"], pos, axis=0,
                        mode="clip")


# ------------------------------------------------------------- public API
def encdec_hidden(params: dict, cfg: ModelConfig,
                  batch: Dict[str, jax.Array], remat: bool = True
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Train forward.  batch: {frontend_embeds (B,F,d), tokens (B,S)}."""
    enc_out = encode(params, cfg, batch["frontend_embeds"], remat=remat)
    x = _embed_dec(params, cfg, batch["tokens"], 0)
    x, _, aux = _decode_stack(params, cfg, x, enc_out, mode="train",
                              remat=remat)
    x = layers.apply_norm(params["dec_norm"], x, cfg.norm)
    aux = {k: jnp.sum(v) for k, v in aux.items()}
    return x, aux


def init_dec_caches(cfg: ModelConfig, batch: int, max_len: int,
                    enc_len: int) -> dict:
    n = cfg.num_layers
    hk, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def stackit(x):
        return jnp.broadcast_to(x[None], (n, *x.shape))

    self_c = jax.tree_util.tree_map(
        stackit, attention.init_cache(cfg, batch, max_len, cfg.window))
    cross = {"k": jnp.zeros((n, batch, hk, enc_len, hd), cfg.dtype),
             "v": jnp.zeros((n, batch, hk, enc_len, hd), cfg.dtype)}
    if attention.sparse_applicable(cfg):
        m = attention._pq_config(cfg).num_books
        cross["codes"] = jnp.zeros((n, batch, hk, enc_len, m), jnp.int8)
    return {"self": self_c, "cross": cross}


def cache_axes(cfg: ModelConfig) -> dict:
    """Logical partition axes mirroring init_dec_caches structure."""
    kv = ("layer", "batch", "kv_heads", "seq_shard", None)
    self_ax = {"k": kv, "v": kv, "slot_pos": ("layer", "batch", None)}
    if attention.sparse_applicable(cfg):
        self_ax["codes"] = kv
    cross = {"k": kv, "v": kv}
    if attention.sparse_applicable(cfg):
        cross["codes"] = kv
    return {"self": self_ax, "cross": cross}


def encdec_prefill(params: dict, cfg: ModelConfig,
                   batch: Dict[str, jax.Array], max_len: int
                   ) -> Tuple[Any, jax.Array]:
    enc_out = encode(params, cfg, batch["frontend_embeds"], remat=False)
    bsz = batch["tokens"].shape[0]
    caches = init_dec_caches(cfg, bsz, max_len,
                             batch["frontend_embeds"].shape[1])
    x = _embed_dec(params, cfg, batch["tokens"], 0)
    x, caches, _ = _decode_stack(params, cfg, x, enc_out, mode="prefill",
                                 caches=caches, pos=0, remat=False)
    x = layers.apply_norm(params["dec_norm"], x[:, -1:], cfg.norm)
    from repro.models.transformer import logits_of
    return caches, logits_of(params, cfg, x)


def encdec_prefill_ragged(params: dict, cfg: ModelConfig,
                          batch: Dict[str, jax.Array], lengths: jax.Array,
                          max_len: int) -> Tuple[Any, jax.Array]:
    """Batched ragged prefill for the enc-dec family: (Bp, S) right-padded
    decoder prompts with per-row real `lengths` (decoder tokens only; the
    encoder frames are a separate, always-dense axis).  Row outputs are
    exact vs. batch-1 encdec_prefill at exact length: the causal self-attn
    mask hides pad keys, sparse self-attn gets per-row top-L budgets, the
    routed FFN per-row capacities, and cross-attention only ever pads
    *queries*.  Returns (caches, logits at each row's last real position);
    self-cache slots past a row's length are invalidated."""
    from repro.models.transformer import length_sensitive, logits_of
    enc_out = encode(params, cfg, batch["frontend_embeds"], remat=False)
    bsz = batch["tokens"].shape[0]
    caches = init_dec_caches(cfg, bsz, max_len,
                             batch["frontend_embeds"].shape[1])
    x = _embed_dec(params, cfg, batch["tokens"], 0)
    sl = lengths if length_sensitive(cfg) else None
    x, caches, _ = _decode_stack(params, cfg, x, enc_out, mode="prefill",
                                 caches=caches, pos=0, remat=False,
                                 seq_lengths=sl)
    idx = jnp.clip(lengths - 1, 0, x.shape[1] - 1)
    x_last = jnp.take_along_axis(
        x, idx[:, None, None].astype(jnp.int32), axis=1)      # (B, 1, d)
    x_last = layers.apply_norm(params["dec_norm"], x_last, cfg.norm)
    sp = caches["self"]["slot_pos"]                           # (n, B, size)
    caches = dict(caches)
    caches["self"] = dict(caches["self"])
    caches["self"]["slot_pos"] = jnp.where(
        sp >= lengths[None, :, None], jnp.int32(-1), sp)
    return caches, logits_of(params, cfg, x_last)


def encdec_decode_step(params: dict, cfg: ModelConfig, caches: Any,
                       token: jax.Array, pos: jax.Array
                       ) -> Tuple[Any, jax.Array]:
    x = _embed_dec(params, cfg, token[:, None], pos)
    x, caches, _ = _decode_stack(params, cfg, x, None, mode="decode",
                                 caches=caches, pos=pos, remat=False)
    x = layers.apply_norm(params["dec_norm"], x, cfg.norm)
    from repro.models.transformer import logits_of
    return caches, logits_of(params, cfg, x)
