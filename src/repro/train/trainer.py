"""Trainer: jit'd train_step loop + checkpoint/restart + straggler monitor
+ preemption-safe shutdown.  Works on one CPU device (tests/examples) and
on the production mesh (launch/train.py) through the same code path.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch import steps as steps_lib
from repro.optim.adamw import OptimizerConfig
from repro.train import checkpoint
from repro.train import state as S
from repro.train.straggler import StepTimeMonitor, StragglerConfig


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_interval: int = 50
    keep_checkpoints: int = 3
    log_interval: int = 10
    loss_chunk: int = 512


class Trainer:
    def __init__(self, cfg: ModelConfig, ocfg: OptimizerConfig,
                 tcfg: TrainerConfig, mesh=None, rules=None,
                 seed: int = 0):
        self.cfg = cfg
        self.ocfg = ocfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.rules = rules
        self.monitor = StepTimeMonitor()
        self.metrics_log: list = []
        self._stop = False

        step_fn = steps_lib.build_train_step(cfg, ocfg,
                                             loss_chunk=tcfg.loss_chunk)
        if mesh is not None and rules is not None:
            from repro.configs.shapes import input_specs  # noqa: F401
            st = S.state_specs(cfg, rules)
            self._step = jax.jit(step_fn, donate_argnums=(0,))
        else:
            self._step = jax.jit(step_fn, donate_argnums=(0,))

        # resume-or-init
        start = None
        if tcfg.ckpt_dir:
            start = checkpoint.latest_step(tcfg.ckpt_dir)
        if start is not None:
            self.state = checkpoint.restore(tcfg.ckpt_dir, start)
            self.start_step = int(start)
        else:
            self.state = S.init_state(cfg, jax.random.PRNGKey(seed))
            self.start_step = 0

        # preemption-safe: SIGTERM triggers an emergency checkpoint
        try:
            signal.signal(signal.SIGTERM, self._on_sigterm)
        except ValueError:      # not in main thread (tests)
            pass

    def _on_sigterm(self, *_):
        self._stop = True

    def _save(self, step: int) -> None:
        if self.tcfg.ckpt_dir:
            checkpoint.save(self.state, step, self.tcfg.ckpt_dir,
                            keep=self.tcfg.keep_checkpoints)

    def run(self, data: Iterator[Dict[str, np.ndarray]],
            step_hook: Optional[Callable[[int, dict], None]] = None) -> dict:
        step = self.start_step
        for batch in data:
            if step >= self.tcfg.total_steps or self._stop:
                break
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.monitor.start()
            self.state, metrics = self._step(self.state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            self.monitor.stop(step)
            step += 1
            if step % self.tcfg.log_interval == 0 or step == 1:
                self.metrics_log.append({"step": step, **metrics})
            if step_hook:
                step_hook(step, metrics)
            if self.tcfg.ckpt_dir and step % self.tcfg.ckpt_interval == 0:
                self._save(step)
            if self.monitor.should_act():
                # straggler density high: checkpoint eagerly so a scheduler
                # can replace the slow host with bounded lost work
                self._save(step)
                self.monitor.events.append(
                    {"step": step, "action": "eager_checkpoint"})
        self._save(step)
        return {"final_step": step,
                "metrics": self.metrics_log,
                "straggler": self.monitor.summary(),
                "interrupted": self._stop}
