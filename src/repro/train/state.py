"""Train state: {step, train (LoRA/router/codebooks), frozen (base), opt}.

The trainable/frozen split happens at the *tree* level (core.params
partition), so jax.grad only ever differentiates the small subtree — the
frozen 314B-param base never gets gradient buffers.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import params as P
from repro.models import encdec, transformer
from repro.optim.adamw import adamw_init
from repro.sharding.context import spec_for


def model_defs(cfg: ModelConfig) -> dict:
    if cfg.family == "audio":
        return encdec.encdec_defs(cfg)
    return transformer.lm_defs(cfg)


def model_hidden(params: dict, cfg: ModelConfig, batch: Dict[str, Any],
                 remat: bool = True):
    if cfg.family == "audio":
        return encdec.encdec_hidden(params, cfg, batch, remat=remat)
    return transformer.lm_hidden(params, cfg, batch, remat=remat)


def init_state(cfg: ModelConfig, key: jax.Array) -> dict:
    defs = model_defs(cfg)
    params = P.init_tree(defs, key)
    mask = P.trainable_mask(defs)
    train, frozen = P.partition(params, mask)
    return {
        "step": jnp.zeros((), jnp.int32),
        "train": train,
        "frozen": frozen,
        "opt": adamw_init(train),
    }


def abstract_state(cfg: ModelConfig) -> dict:
    defs = model_defs(cfg)
    params = P.abstract_tree(defs)
    mask = P.trainable_mask(defs)
    train, frozen = P.partition(params, mask)
    f32 = lambda sds: jax.ShapeDtypeStruct(sds.shape, jnp.float32)
    return {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "train": train,
        "frozen": frozen,
        "opt": {"m": jax.tree_util.tree_map(f32, train),
                "v": jax.tree_util.tree_map(f32, train)},
    }


def state_specs(cfg: ModelConfig, rules) -> dict:
    from jax.sharding import PartitionSpec
    defs = model_defs(cfg)
    specs = P.spec_tree(defs, rules)
    mask = P.trainable_mask(defs)
    train_s, frozen_s = P.partition(specs, mask)
    return {
        "step": PartitionSpec(),
        "train": train_s,
        "frozen": frozen_s,
        "opt": {"m": train_s, "v": train_s},
    }


def full_params(state: dict) -> dict:
    return P.combine(state["train"], state["frozen"])


def param_specs(cfg: ModelConfig, rules) -> dict:
    return P.spec_tree(model_defs(cfg), rules)
