"""LM cross-entropy, vocab-TP-aware and sequence-chunked.

The logits tensor (B, S, V) for 256k vocabs dominates activation memory if
materialized at once; we scan over sequence chunks so only (B, C, V) lives
at a time, sharded on the vocab axis ("model").  Reductions over the
sharded vocab dim lower to all-reduces under pjit automatically.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.sharding import shard


def lm_cross_entropy(params: dict, cfg: ModelConfig, hidden: jax.Array,
                     labels: jax.Array, chunk: int = 512
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """hidden: (B, S_h, d); labels: (B, S_lab) with -1 = ignore.
    The last S_lab hidden positions predict the labels (frontend tokens are
    automatically excluded)."""
    s_lab = labels.shape[1]
    h = hidden[:, -s_lab:, :]
    w = jax.lax.stop_gradient(transformer.head_weight(params, cfg))

    c = min(chunk, s_lab)
    if s_lab % c != 0:
        c = s_lab
    starts = jnp.arange(0, s_lab, c)

    def chunk_fn(start):
        hc = jax.lax.dynamic_slice_in_dim(h, start, c, axis=1)
        lc = jax.lax.dynamic_slice_in_dim(labels, start, c, axis=1)
        logits = jnp.einsum("bsd,dv->bsv", hc, w.astype(hc.dtype))
        if cfg.logits_softcap:
            cap = cfg.logits_softcap
            logits = jnp.tanh(logits / cap) * cap
        logits = shard(logits, "batch", None, "vocab")
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        ok = (lc >= 0).astype(jnp.float32)
        nll = (lse - tgt) * ok
        correct = (jnp.argmax(logits, -1) == lc).astype(jnp.float32) * ok
        return nll.sum(), ok.sum(), correct.sum()

    from repro.core.chunking import maybe_map
    nlls, oks, cors = maybe_map(chunk_fn, starts)
    total, denom, correct = nlls.sum(), oks.sum(), cors.sum()
    denom = jnp.maximum(denom, 1.0)
    loss = total / denom
    return loss, {"nll_sum": total, "tokens": denom,
                  "accuracy": correct / denom}
