"""Straggler mitigation: per-step timing statistics with outlier policy.

At 1000+ nodes the common failure mode is not crashes but *slow* hosts
(thermal throttling, flaky ICI links, noisy neighbors).  The monitor keeps
a rolling window of step times; a step whose z-score exceeds the threshold
increments a per-run straggle counter, and `should_act()` fires when the
recent straggle density crosses the action threshold — the trainer responds
by (a) emitting an ops event and (b) checkpointing eagerly so a scheduler
can evict/replace the slow host with bounded lost work.  (Synchronous SPMD
means one slow host drags the whole step — detection is global by
construction, so any host's timeline identifies the event.)
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
import time
from typing import Deque, List, Optional


@dataclasses.dataclass
class StragglerConfig:
    window: int = 50
    z_threshold: float = 3.0
    min_samples: int = 10
    act_density: float = 0.2     # fraction of recent steps flagged -> act


class StepTimeMonitor:
    def __init__(self, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.times: Deque[float] = collections.deque(maxlen=cfg.window)
        self.flags: Deque[bool] = collections.deque(maxlen=cfg.window)
        self.events: List[dict] = []
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        assert self._t0 is not None
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self.record(step, dt)
        return dt

    def record(self, step: int, dt: float) -> bool:
        flagged = False
        if len(self.times) >= self.cfg.min_samples:
            med = statistics.median(self.times)
            mad = statistics.median(abs(t - med) for t in self.times)
            sd = 1.4826 * mad + 1e-9      # robust sigma: outliers already in
            z = (dt - med) / sd           # the window cannot mask new ones
            if z > self.cfg.z_threshold:
                flagged = True
                self.events.append({"step": step, "dt": dt, "z": z})
        self.times.append(dt)
        self.flags.append(flagged)
        return flagged

    def should_act(self) -> bool:
        if len(self.flags) < self.cfg.min_samples:
            return False
        return (sum(self.flags) / len(self.flags)) >= self.cfg.act_density

    def summary(self) -> dict:
        return {
            "steps": len(self.times),
            "mean_s": statistics.fmean(self.times) if self.times else 0.0,
            "flagged": sum(self.flags),
            "events": self.events[-5:],
        }
