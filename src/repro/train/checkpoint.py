"""Checkpointing: atomic, content-addressed, elastic-restore.

Layout per step:  <dir>/step_<n>.tmp-<pid>/  ->  atomic rename  ->
<dir>/step_<n>/  containing one ``arrays.npz`` (leaf path -> array) and
``manifest.json`` (step, leaf list, dtypes, sha256 of the npz).  Restore
reads host numpy and re-places onto whatever mesh/sharding the *current*
process uses — so a checkpoint taken on 256 chips restores onto 512 or 8
(elastic scaling by construction).

Partitioned trees (train/frozen with None holes) round-trip exactly: None
subtrees are recorded in the manifest and reconstructed.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _walk(tree: Any, path: str = "") -> List[Tuple[str, Any]]:
    if tree is None:
        return [(path + "/__none__", None)]
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out.extend(_walk(tree[k], f"{path}/{k}"))
        return out
    return [(path, tree)]


def _unwalk(items: Dict[str, Any]) -> Any:
    root: Dict[str, Any] = {}
    for path, value in items.items():
        parts = [p for p in path.split("/") if p]
        if parts[-1] == "__none__":
            parts = parts[:-1]
            value = None
        if not parts:
            return value
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value
    return root


def save(state: Any, step: int, ckpt_dir: str, keep: int = 3) -> str:
    base = pathlib.Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    tmp = base / f"step_{step:08d}.tmp-{os.getpid()}"
    final = base / f"step_{step:08d}"
    if final.exists():
        return str(final)
    tmp.mkdir(parents=True, exist_ok=True)
    leaves = _walk(state)
    arrays = {}
    meta = {"step": int(step), "leaves": []}
    for path, value in leaves:
        if value is None:
            meta["leaves"].append({"path": path, "none": True})
            continue
        arr = np.asarray(jax.device_get(value))
        key = path.strip("/").replace("/", ".")
        logical = str(arr.dtype)
        if arr.dtype.kind not in "biufc":  # ml_dtypes (bf16/f8): raw view
            arr = arr.view({1: np.uint8, 2: np.uint16,
                            4: np.uint32}[arr.dtype.itemsize])
        arrays[key] = arr
        meta["leaves"].append({"path": path, "key": key,
                               "dtype": logical,
                               "shape": list(arr.shape)})
    npz_path = tmp / "arrays.npz"
    np.savez(npz_path, **arrays)
    with open(npz_path, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()
    meta["sha256"] = digest
    (tmp / "manifest.json").write_text(json.dumps(meta))
    os.replace(tmp, final)          # atomic publish
    _gc(base, keep)
    return str(final)


def _gc(base: pathlib.Path, keep: int) -> None:
    steps = sorted(p for p in base.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and ".tmp-" not in p.name)
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)
    for p in base.iterdir():        # orphaned tmp dirs from crashes
        if ".tmp-" in p.name:
            shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in base.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and ".tmp-" not in p.name)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None,
            shardings: Any = None, verify: bool = True) -> Any:
    """Load a checkpoint; optionally place leaves with a sharding tree of
    the same structure (elastic re-sharding happens here)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    final = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    meta = json.loads((final / "manifest.json").read_text())
    if verify:
        with open(final / "arrays.npz", "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        if digest != meta["sha256"]:
            raise IOError(f"checkpoint {final} corrupt (sha mismatch)")
    npz = np.load(final / "arrays.npz")
    items: Dict[str, Any] = {}
    for leaf in meta["leaves"]:
        if leaf.get("none"):
            items[leaf["path"]] = None
            continue
        arr = npz[leaf["key"]]
        if str(arr.dtype) != leaf["dtype"]:   # restore ml_dtypes view
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, leaf["dtype"], None)
                                    or leaf["dtype"]))
        items[leaf["path"]] = arr
    tree = _unwalk(items)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s) if x is not None else None,
            tree, shardings,
            is_leaf=lambda x: x is None or isinstance(x, np.ndarray))
    else:
        tree = jax.tree_util.tree_map(
            lambda x: jax.device_put(x) if x is not None else None, tree,
            is_leaf=lambda x: x is None or isinstance(x, np.ndarray))
    return tree
