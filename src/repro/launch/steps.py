"""Step builders + sharding spec assembly for pjit lowering.

Everything the dry-run, the trainer, and the server share lives here:
  * build_train_step(cfg, ocfg)   — fwd + bwd + AdamW on the LoRA subtree
  * prefill / decode step fns     — serving-side lowerables
  * *_shardings helpers           — NamedSharding trees from logical rules
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig, ShapeSpec
from repro.core import params as P
from repro.optim.adamw import OptimizerConfig, adamw_update
from repro.serving import engine
from repro.sharding.context import spec_for
from repro.train import state as S
from repro.train.loss import lm_cross_entropy


# ------------------------------------------------------------- steps
def build_train_step(cfg: ModelConfig, ocfg: OptimizerConfig,
                     loss_chunk: int = 512) -> Callable:
    def train_step(state: dict, batch: Dict[str, jax.Array]):
        def loss_fn(train):
            params = P.combine(train, state["frozen"])
            hidden, aux = S.model_hidden(params, cfg, batch, remat=True)
            lm_loss, stats = lm_cross_entropy(params, cfg, hidden,
                                              batch["labels"], loss_chunk)
            nl = max(1, cfg.num_layers)
            total = lm_loss
            total += cfg.spt.lb_loss_weight * aux.get("lb_loss", 0.0) / nl
            if cfg.spt.qerr_loss_weight:
                total += cfg.spt.qerr_loss_weight * aux.get("qerr", 0.0) / nl
            return total, {"lm_loss": lm_loss, **stats,
                           "lb_loss": aux.get("lb_loss", 0.0),
                           "dropped": aux.get("dropped", 0.0)}

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["train"])
        new_train, new_opt, om = adamw_update(
            state["train"], grads, state["opt"], state["step"], ocfg)
        new_state = {"step": state["step"] + 1, "train": new_train,
                     "frozen": state["frozen"], "opt": new_opt}
        metrics = {"loss": loss, **metrics, **om}
        return new_state, metrics

    return train_step


def build_prefill_step(cfg: ModelConfig, max_len: int) -> Callable:
    return engine.build_prefill_step(cfg, max_len)


def build_decode_step(cfg: ModelConfig) -> Callable:
    return engine.build_decode_step(cfg)


# ------------------------------------------------------------- shardings
def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _map_specs(mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: _ns(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec))


def batch_specs(cfg: ModelConfig, specs: Dict[str, Any], rules) -> dict:
    """PartitionSpec per batch input (train/prefill)."""
    out = {}
    for name, sds in specs.items():
        if name in ("tokens", "labels"):
            out[name] = spec_for(sds.shape, ("batch", None), rules)
        elif name == "frontend_embeds":
            out[name] = spec_for(sds.shape, ("batch", None, None), rules)
        elif name == "token":
            out[name] = spec_for(sds.shape, ("batch",), rules)
        elif name == "pos":
            out[name] = PartitionSpec()
        else:
            raise KeyError(name)
    return out


def cache_specs(cfg: ModelConfig, abstract_caches, rules):
    axes = engine.decode_cache_axes(cfg)
    return jax.tree_util.tree_map(
        lambda sds, ax: spec_for(sds.shape, ax, rules),
        abstract_caches, axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def train_shardings(cfg: ModelConfig, mesh, rules, specs):
    st = S.state_specs(cfg, rules)
    bt = batch_specs(cfg, specs, rules)
    scalar = PartitionSpec()
    metric_specs = scalar  # all metrics are scalars -> replicated
    return (_map_specs(mesh, st), _map_specs(mesh, bt),
            _map_specs(mesh, st), _ns(mesh, metric_specs))


def decode_shardings(cfg: ModelConfig, mesh, rules, abstract_caches, specs):
    ps = S.param_specs(cfg, rules)
    cs = cache_specs(cfg, abstract_caches, rules)
    bs = batch_specs(cfg, specs, rules)
    logits = spec_for((1, 1, cfg.padded_vocab), ("batch", None, "vocab"),
                      rules)
    return (_map_specs(mesh, ps), _map_specs(mesh, cs),
            _map_specs(mesh, bs), _ns(mesh, logits))
