"""Production training launcher.

    python -m repro.launch.train --arch qwen3-0.6b --steps 200 \
        --mesh 1x1 --batch 8 --seq 512 --ckpt /tmp/ckpt

On a real TPU slice the mesh is (data, model) [x pod]; on the CPU container
use --mesh 1x1.  The same Trainer/step code path runs in both.
"""
import argparse
import json
import sys

import jax

from repro import configs
from repro.data.pipeline import DataConfig, synthetic_dataset
from repro.launch.mesh import make_mesh
from repro.optim.adamw import OptimizerConfig
from repro.sharding import axis_rules, rules_for_mesh
from repro.train.trainer import Trainer, TrainerConfig


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the arch's reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 16x16")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--variant", default="spt", choices=["spt", "lora", "full"])
    args = ap.parse_args()

    from repro.launch.dryrun import apply_variant  # reuse variant logic
    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    cfg = apply_variant(cfg, args.variant)
    dp, tp = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((dp, tp), ("data", "model"))
    rules = rules_for_mesh(mesh)
    ocfg = OptimizerConfig(lr=args.lr, total_steps=args.steps)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt)
    data = synthetic_dataset(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   global_batch=args.batch), steps=args.steps + 1)
    with mesh, axis_rules(rules):
        trainer = Trainer(cfg, ocfg, tcfg, mesh=mesh, rules=rules)
        report = trainer.run(data)
    print(json.dumps({"final_step": report["final_step"],
                      "last_metrics": report["metrics"][-1] if report["metrics"] else None,
                      "straggler": report["straggler"]}, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
