"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh), all in seconds-per-step-per-chip:

    compute    = HLO_FLOPs(per device)      / PEAK_FLOPS
    memory     = HLO_bytes(per device)      / HBM_BW
    collective = collective_bytes(per dev)  / ICI_BW

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (the SPMD
partitioned per-device module).  Collective bytes are parsed from the
optimized HLO text: sum of result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute (async
``-start`` forms counted once, ``-done`` skipped).

Hardware constants (TPU v5e target): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  The collective term assumes the payload crosses one
logical link serially — a deliberate, consistent upper bound; ring
algorithms overlap hops, so treat it as a comparison metric, not a wall
clock prediction.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*([^=]+?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*((?:\([^)]*\)|\S+?))\s+"
    r"([a-z][\w\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(%?[\w.\-]+)\s+\(.*\)\s+->\s+.*\{")
_OPERAND_RE = re.compile(r"%[\w.\-]+")

_MAJOR_OPS = ("dot", "convolution", "gather", "scatter", "scatter-add",
              "dynamic-update-slice", "dynamic-slice", "sort")


def hbm_traffic(hlo_text: str) -> int:
    """Fusion-aware HBM traffic model: sum operand+result bytes of *major*
    ops only (dot / conv / gather / scatter / dynamic-(update-)slice /
    sort), attributing a fusion node's operands when its fused computation
    contains a major op.  Elementwise chains are assumed fused (free), which
    matches TPU codegen far better than XLA:CPU's ``bytes accessed``.
    Still an upper-ish bound: VMEM-resident reuse is not modeled."""
    name_bytes: Dict[str, int] = {}
    comp_major: Dict[str, bool] = {}
    comp_of_line: Dict[int, str] = {}
    cur_comp = ""
    lines = hlo_text.splitlines()
    for i, line in enumerate(lines):
        mc = _COMP_RE.match(line)
        if mc:
            cur_comp = mc.group(1).lstrip("%")
            comp_major.setdefault(cur_comp, False)
        comp_of_line[i] = cur_comp
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, typ, op = m.group(1), m.group(2), m.group(3)
        name_bytes[name] = shape_bytes(typ)
        if op in _MAJOR_OPS:
            comp_major[cur_comp] = True
    total = 0
    for i, line in enumerate(lines):
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, typ, op, rest = m.groups()
        is_major = op in _MAJOR_OPS
        if op == "fusion":
            mcalls = re.search(r"calls=(%?[\w.\-]+)", rest)
            if mcalls and comp_major.get(mcalls.group(1).lstrip("%")):
                is_major = True
        if not is_major:
            continue
        # stop operand scan at control fields
        args = rest.split("), ")[0]
        total += name_bytes.get(name, shape_bytes(typ))
        for om in _OPERAND_RE.finditer(args):
            total += name_bytes.get(om.group(0), 0)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by collectives, keyed by op kind."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        out[kind] = out.get(kind, 0) + shape_bytes(m.group(1))
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    coll_by_kind: Dict[str, int]
    peak_memory: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Lower-bound step time: max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def to_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "coll_by_kind": self.coll_by_kind,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "bottleneck": self.bottleneck,
            "t_bound": self.t_bound, "peak_memory": self.peak_memory,
        }


def analyze(compiled, hlo_text: Optional[str] = None,
            traffic_model: bool = True) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    hbm = float(hbm_traffic(text)) if traffic_model \
        else float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(text)
    peak_mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            peak_mem = float(
                getattr(ma, "temp_size_in_bytes", 0)
                + getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    return Roofline(flops=flops, hbm_bytes=hbm,
                    coll_bytes=float(sum(coll.values())),
                    coll_by_kind=coll, peak_memory=peak_mem)


def model_flops(cfg, tokens: int) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) for a train step;
    2 N D for inference steps (caller divides)."""
    n = active_params(cfg)
    return 6.0 * n * tokens


def active_params(cfg) -> float:
    """Active (FLOP-relevant) parameter count: standard 6ND convention —
    embeddings excluded; MoE experts count experts_per_token/num_experts;
    routed-FFN weights count beta = G'/G (only activated blocks compute)."""
    from repro.core.params import count_params
    from repro.train.state import model_defs
    total = count_params(model_defs(cfg))
    total -= cfg.padded_vocab * cfg.d_model          # embedding lookup
    if cfg.positional == "learned":
        total -= cfg.max_position * cfg.d_model
        if cfg.family == "audio":
            total -= cfg.max_position * cfg.d_model  # enc+dec tables
    n_ffn_layers = sum(1 for t in cfg.layer_types() if t != "ssd")
    ffn_mats = 3 if cfg.gated_ffn else 2
    if cfg.num_experts > 0:
        frac = cfg.experts_per_token / cfg.num_experts
        per_layer = cfg.num_experts * cfg.d_model * cfg.d_ff * ffn_mats
        total -= per_layer * n_ffn_layers * (1.0 - frac)
    elif cfg.spt.routed_ffn and cfg.d_ff > 0 \
            and cfg.d_ff % cfg.spt.ffn_groups == 0:
        beta = cfg.spt.ffn_active_groups / cfg.spt.ffn_groups
        per_layer = cfg.d_model * cfg.d_ff * ffn_mats
        if cfg.family == "audio":
            n_ffn_layers += cfg.encoder_layers
        total -= per_layer * n_ffn_layers * (1.0 - beta)
    return float(max(total, 1.0))
