"""Production serving launcher: batched generation over request slots.

    python -m repro.launch.serve --arch qwen3-0.6b --smoke --requests 8 \
        --prompt-len 64 --gen 32
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.mesh import make_mesh
from repro.serving.engine import Engine
from repro.sharding import axis_rules, rules_for_mesh
from repro.train.state import model_defs
from repro.core.params import init_tree


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    dp, tp = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((dp, tp), ("data", "model"))
    rules = rules_for_mesh(mesh)
    with mesh, axis_rules(rules):
        params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
        engine = Engine(cfg, params,
                        max_len=args.prompt_len + args.gen + 8)
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (args.requests, args.prompt_len), 0,
            cfg.vocab_size, dtype=jnp.int32)}
        if cfg.frontend:
            batch["frontend_embeds"] = jax.random.normal(
                jax.random.PRNGKey(2),
                (args.requests, cfg.frontend_tokens, cfg.d_model),
                jnp.bfloat16)
        t0 = time.time()
        result = engine.generate(batch, steps=args.gen,
                                 temperature=args.temperature,
                                 key=jax.random.PRNGKey(3))
        dt = time.time() - t0
    toks = args.requests * args.gen
    print(json.dumps({
        "requests": args.requests, "generated_tokens": toks,
        "wall_s": round(dt, 2), "tokens_per_s": round(toks / dt, 1),
        "sample": result.tokens[0][:8],
    }, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
