"""Production serving launcher: continuous batching over decode slots.

    python -m repro.launch.serve --arch qwen3-0.6b --smoke --requests 16 \
        --prompt-len 32 --gen 16

More requests than `--slots` stream through the engine's request queue;
finished slots are recycled for waiting prompts.  Timing is honest: the
first run is a warmup that absorbs jit tracing/compilation, the second run
is timed with `block_until_ready` at every prefill/decode boundary, and
prefill vs. steady-state decode tokens/s are reported separately.
"""
import argparse
import json
import sys
import time

import jax
import numpy as np

from repro import configs
from repro.core.params import init_tree
from repro.launch.mesh import make_mesh
from repro.serving.engine import Engine, Request
from repro.sharding import axis_rules, rules_for_mesh
from repro.train.state import model_defs


def build_requests(cfg, num: int, prompt_len: int, gen: int,
                   ragged: bool, seed: int = 1, top_k: int = 0,
                   top_p: float = 0.0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(num):
        ln = (int(rng.integers(max(4, prompt_len // 2), prompt_len + 1))
              if ragged else prompt_len)
        toks = rng.integers(0, cfg.vocab_size, size=ln, dtype=np.int32)
        fe = None
        if cfg.frontend:
            fe = rng.standard_normal(
                (cfg.frontend_tokens, cfg.d_model)).astype(np.float32)
        reqs.append(Request(uid=i, tokens=toks.tolist(),
                            max_new_tokens=gen, frontend_embeds=fe,
                            top_k=top_k, top_p=top_p))
    return reqs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--slots", type=int, default=8,
                    help="decode slots (batch width); requests beyond this "
                         "queue and stream in as slots free up")
    ap.add_argument("--decode-chunk", type=int, default=16,
                    help="decode steps per compiled while_loop chunk")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="token id that retires a slot early")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--ragged", action="store_true",
                    help="draw ragged prompt lengths in [L/2, L]")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--decode-impl", default="auto",
                    choices=("auto", "kernel", "jnp"),
                    help="sparse-MHA decode path: fused Pallas kernel vs "
                         "jnp fallback (auto follows spt.attn_impl; "
                         "REPRO_DISABLE_KERNELS=1 forces jnp)")
    ap.add_argument("--ffn-impl", default=None,
                    choices=("pallas", "grouped", "dense"),
                    help="routed-FFN train/prefill path: 'pallas' = fused "
                         "grouped-GEMM kernel with in-kernel dispatch; "
                         "default keeps the arch config's setting")
    ap.add_argument("--decode-ffn-impl", default="auto",
                    choices=("auto", "kernel", "jnp"),
                    help="routed-FFN decode path at (B, 1, d): block-gather "
                         "Pallas kernel (no dispatch buffer) vs the grouped "
                         "jnp capacity path (auto follows --ffn-impl; "
                         "REPRO_DISABLE_KERNELS=1 forces jnp)")
    ap.add_argument("--kv-layout", default="contiguous",
                    choices=("contiguous", "paged"),
                    help="serving KV-cache layout: 'paged' shares a pool of "
                         "fixed-size pages across slots (admission waits for "
                         "pages, not just a free slot) so long-context "
                         "max_len no longer reserves a full strip per slot")
    ap.add_argument("--page-size", type=int, default=128,
                    help="rows per KV page (paged layout)")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="page-pool size (default: contiguous-parity "
                         "slots*ceil(max_len/page_size); set lower to serve "
                         "under a fixed KV-memory budget)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k sampling truncation inside the compiled "
                         "decode chunk (0 = off; needs --temperature > 0)")
    ap.add_argument("--top-p", type=float, default=0.0,
                    help="nucleus sampling inside the compiled decode chunk"
                         " (keep the smallest probability mass >= p; 0 = "
                         "off; needs --temperature > 0)")
    ap.add_argument("--prefill-batch", type=int, default=None,
                    help="max queued requests drained per batched ragged "
                         "prefill call (default: --slots; 1 = the old "
                         "serial batch-1 admission)")
    ap.add_argument("--arrival-qps", type=float, default=None,
                    help="serve through the long-lived loop with seeded "
                         "Poisson arrivals at this offered rate instead of "
                         "one burst (engine.serve(); stats add p50/p99 "
                         "TTFT/TPOT, preemptions, shed)")
    ap.add_argument("--priorities", action="store_true",
                    help="phased priority workload: first half of the "
                         "requests are background (priority 0), second "
                         "half interactive (priority 1) — under page-pool "
                         "pressure the scheduler preempts backgrounds and "
                         "re-admits them by recompute")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="TTFT deadline for the interactive requests "
                         "(all requests without --priorities): a queued "
                         "request past its deadline is shed, and one past "
                         "half of it may preempt deadline-free peers")
    ap.add_argument("--prefill-decode-ratio", type=float, default=0.0,
                    help="overlap knob: with decodes in flight, admit at "
                         "most ratio * decode_chunk * active_slots prompt "
                         "tokens per scheduling iteration instead of "
                         "pausing decode until every free slot is filled "
                         "(0 = fill all free slots before each chunk)")
    ap.add_argument("--telemetry", default="off",
                    choices=("off", "counters", "trace"),
                    help="serving observability: 'counters' threads "
                         "jit-pure sparsity/expert/page counters through "
                         "the compiled chunk (drained once per scheduling "
                         "iteration); 'trace' adds per-request lifecycle "
                         "timelines and scheduler spans; outputs are "
                         "bit-identical across all three")
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto-loadable Chrome trace.json of "
                         "the timed run here (implies --telemetry trace)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the final metrics snapshot (counters/"
                         "gauges/histograms) as JSON here")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    telemetry = "trace" if args.trace_out else args.telemetry
    cfg = cfg.with_spt(decode_attn_impl=args.decode_impl,
                       decode_ffn_impl=args.decode_ffn_impl,
                       kv_layout=args.kv_layout,
                       kv_page_size=args.page_size,
                       telemetry=telemetry)
    if args.ffn_impl is not None:
        cfg = cfg.with_spt(ffn_impl=args.ffn_impl)
    dp, tp = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((dp, tp), ("data", "model"))
    rules = rules_for_mesh(mesh)
    with mesh, axis_rules(rules):
        params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
        engine = Engine(cfg, params,
                        max_len=args.prompt_len + args.gen + 8,
                        num_slots=args.slots, eos_id=args.eos_id,
                        decode_chunk=args.decode_chunk,
                        kv_pages=args.kv_pages,
                        prefill_batch=args.prefill_batch,
                        prefill_decode_ratio=args.prefill_decode_ratio)
        key = jax.random.PRNGKey(3) if args.temperature > 0 else None
        if cfg.family == "audio":
            return _serve_audio_legacy(cfg, engine, args, key)
        reqs = build_requests(cfg, args.requests, args.prompt_len, args.gen,
                              args.ragged, top_k=args.top_k,
                              top_p=args.top_p)
        # warmup: absorbs tracing + compilation for every shape in the run
        # (deadlines/priorities are applied AFTER it — a deadline shorter
        # than compile time would shed the very requests being traced)
        t0 = time.perf_counter()
        engine.run(reqs, temperature=args.temperature, key=key)
        warmup_wall_s = time.perf_counter() - t0
        if args.priorities or args.deadline_s is not None:
            import dataclasses as _dc
            half = len(reqs) // 2
            reqs = [_dc.replace(
                r,
                priority=(0 if args.priorities and i < half
                          else 1 if args.priorities else r.priority),
                deadline_s=(args.deadline_s
                            if (not args.priorities or i >= half)
                            else None))
                for i, r in enumerate(reqs)]

        # steady state: compiled throughout, synced at every boundary
        t0 = time.perf_counter()
        if args.arrival_qps is not None:
            from repro.serving.engine import ArrivalSchedule
            result = engine.serve(
                ArrivalSchedule.poisson(reqs, args.arrival_qps, seed=0),
                temperature=args.temperature, key=key)
        else:
            result = engine.run(reqs, temperature=args.temperature, key=key)
        wall_s = time.perf_counter() - t0
        stats = engine.last_stats
    out = {
        "arch": cfg.name,
        "requests": args.requests, "slots": args.slots,
        "generated_tokens": sum(len(c.tokens) for c in result),
        "warmup_wall_s": round(warmup_wall_s, 2),
        "steady_wall_s": round(wall_s, 2),
        **stats.as_dict(),
        "finish_reasons": sorted({c.finish_reason for c in result}),
        "sample": result[0].tokens[:8],
    }
    if args.trace_out:
        from repro.serving import trace_export
        trace = trace_export.write_trace(engine.last_recorder,
                                         args.trace_out)
        out["trace_out"] = args.trace_out
        out["trace_events"] = len(trace["traceEvents"])
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(stats.snapshot().as_dict(), f, indent=1)
        out["metrics_out"] = args.metrics_out
    print(json.dumps(out, indent=1))
    return 0


def _serve_audio_legacy(cfg, engine, args, key):
    """Enc-dec audio family: continuous batching does not cover it yet, so
    serve the fixed batch through the per-token path — still warmed up and
    timed honestly (generate() syncs on its host-side token lists)."""
    import jax.numpy as jnp
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (args.requests, args.prompt_len), 0,
        cfg.vocab_size, dtype=jnp.int32)}
    if cfg.frontend:
        batch["frontend_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.requests, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    engine.generate(batch, steps=args.gen, temperature=args.temperature,
                    key=key)                                      # warmup
    t0 = time.perf_counter()
    result = engine.generate(batch, steps=args.gen,
                             temperature=args.temperature, key=key)
    dt = time.perf_counter() - t0
    toks = args.requests * args.gen
    print(json.dumps({
        "arch": cfg.name, "mode": "legacy-audio",
        "requests": args.requests, "generated_tokens": toks,
        "steady_wall_s": round(dt, 2),
        "tokens_per_s": round(toks / dt, 1),
        "sample": result.tokens[0][:8],
    }, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
