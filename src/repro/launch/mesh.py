"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the leading ``pod``
axis is pure data parallelism (gradient all-reduce crosses the slow
inter-pod links once per step) — DESIGN.md §4.

Defined as functions (never module-level constants) so importing this
module cannot touch jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (1, 1) on one CPU device)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def mesh_num_devices(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
