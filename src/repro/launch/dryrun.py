import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-importing module: jax locks the
# device count on first init.  Everything below is ordinary.
"""Multi-pod dry-run (deliverable e): for every (arch x input-shape) cell,
``jit(step).lower(...).compile()`` against the production mesh — 16x16
single-pod and 2x16x16 multi-pod — with ShapeDtypeStruct inputs (no device
allocation).  Prints memory_analysis() and cost_analysis() and records the
roofline terms (launch/roofline.py) to JSON for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch gemma-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import SHAPES_BY_NAME, ModelConfig, ShapeSpec
from repro.configs.shapes import input_specs
from repro.launch import roofline, steps
from repro.launch.mesh import make_production_mesh, mesh_num_devices
from repro.optim.adamw import OptimizerConfig
from repro.serving import engine
from repro.sharding import axis_rules, rules_for_mesh
from repro.train import state as S


def apply_variant(cfg: ModelConfig, variant: str) -> ModelConfig:
    if variant == "spt":
        return cfg
    if variant == "lora":
        return cfg.with_spt(sparse_mha=False, routed_ffn=False)
    if variant == "full":
        import dataclasses as dc
        from repro.core.lora import LoRAConfig
        return cfg.with_spt(sparse_mha=False, routed_ffn=False,
                            lora=LoRAConfig(enabled=False))
    raise ValueError(variant)


def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
               loss_chunk: int = 512):
    """Returns (lowered, aux_info). Pure AOT: no arrays are created."""
    rules = rules_for_mesh(mesh)
    specs = input_specs(cfg, shape)
    with mesh, axis_rules(rules):
        if shape.kind == "train":
            step = steps.build_train_step(cfg, OptimizerConfig(),
                                          loss_chunk=loss_chunk)
            st_sh, b_sh, out_sh, m_sh = steps.train_shardings(
                cfg, mesh, rules, specs)
            fn = jax.jit(step, in_shardings=(st_sh, b_sh),
                         out_shardings=(out_sh, m_sh),
                         donate_argnums=(0,))
            lowered = fn.lower(S.abstract_state(cfg), specs)
        elif shape.kind == "prefill":
            step = steps.build_prefill_step(cfg, max_len=shape.seq_len)
            rules_ = rules
            ps = steps._map_specs(mesh, S.param_specs(cfg, rules_))
            bs = steps._map_specs(
                mesh, steps.batch_specs(cfg, specs, rules_))
            fn = jax.jit(step, in_shardings=(ps, bs))
            lowered = fn.lower(_abstract_params(cfg), specs)
        elif shape.kind == "decode":
            step = steps.build_decode_step(cfg)
            caches = engine.abstract_decode_caches(
                cfg, shape.global_batch, shape.seq_len)
            ps, cs, bs, ls = steps.decode_shardings(cfg, mesh, rules, caches,
                                                    specs)
            fn = jax.jit(step, in_shardings=(ps, cs, bs["token"], bs["pos"]),
                         out_shardings=(cs, ls), donate_argnums=(1,))
            lowered = fn.lower(_abstract_params(cfg), caches,
                               specs["token"], specs["pos"])
        else:
            raise ValueError(shape.kind)
    return lowered


def _abstract_params(cfg: ModelConfig):
    from repro.core.params import abstract_tree
    return abstract_tree(S.model_defs(cfg))


def _unit_config(cfg: ModelConfig, units: int) -> ModelConfig:
    """A copy of cfg with exactly `units` pattern units (no tail)."""
    import dataclasses as dc
    kw = {"num_layers": units * len(cfg.pattern)}
    if cfg.family == "audio":
        kw["encoder_layers"] = units
    return dc.replace(cfg, **kw)


def _analysis_cfg(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Bigger chunks => fewer unrolled loop iterations in analysis mode.
    (ssm_chunk is left alone: SSD FLOPs scale with the chunk size.)"""
    return cfg.with_spt(chunk_q=min(2048, shape.seq_len))


def exact_roofline(cfg: ModelConfig, shape: ShapeSpec, mesh,
                   verbose: bool = False) -> Dict[str, Any]:
    """Loop-aware cost accounting (EXPERIMENTS.md §Dry-run calibration):
    XLA cost_analysis counts while-loop bodies ONCE, so the scanned
    lowering undercounts by the trip count.  We lower 1-unit and 2-unit
    copies of the model with every loop unrolled (analysis_mode) and
    extrapolate linearly: F(U units) = F1 + (U - 1) (F2 - F1).  Tail layers
    (num_layers % pattern) count fractionally."""
    from repro.core.chunking import analysis_mode
    acfg = _analysis_cfg(cfg, shape)
    units_equiv = cfg.num_layers / len(cfg.pattern)
    out: Dict[str, Any] = {}
    rl = {}
    with analysis_mode():
        for u in (1, 2):
            compiled = lower_cell(_unit_config(acfg, u), shape, mesh,
                                  loss_chunk=2048).compile()
            rl[u] = roofline.analyze(compiled)
    per_unit = {
        "flops": rl[2].flops - rl[1].flops,
        "hbm_bytes": rl[2].hbm_bytes - rl[1].hbm_bytes,
        "coll_bytes": rl[2].coll_bytes - rl[1].coll_bytes,
    }
    total = roofline.Roofline(
        flops=rl[1].flops + per_unit["flops"] * (units_equiv - 1),
        hbm_bytes=rl[1].hbm_bytes + per_unit["hbm_bytes"] * (units_equiv - 1),
        coll_bytes=max(0.0, rl[1].coll_bytes
                       + per_unit["coll_bytes"] * (units_equiv - 1)),
        coll_by_kind={k: int(v + (rl[2].coll_by_kind.get(k, 0) - v)
                             * (units_equiv - 1))
                      for k, v in rl[1].coll_by_kind.items()})
    out["per_unit"] = per_unit
    out["one_unit"] = rl[1].to_dict()
    out["roofline_exact"] = total.to_dict()
    return out


def parse_overrides(pairs) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for pair in pairs or []:
        k, _, v = pair.partition("=")
        if v.lower() in ("true", "false"):
            out[k] = v.lower() == "true"
            continue
        for cast in (int, float):
            try:
                out[k] = cast(v)
                break
            except ValueError:
                continue
        else:
            out[k] = v
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: str = "spt", verbose: bool = True,
             cfg_override: Optional[ModelConfig] = None,
             spt_overrides: Optional[Dict[str, Any]] = None
             ) -> Dict[str, Any]:
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = configs.cell_supported(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}
    cfg = cfg_override or apply_variant(configs.get_config(arch), variant)
    if spt_overrides:
        cfg = cfg.with_spt(**spt_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_num_devices(mesh)
    t0 = time.time()
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "mesh": "multi" if multi_pod else "single", "chips": chips,
    }
    try:
        lowered = lower_cell(cfg, shape, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        rl = roofline.analyze(compiled)
        if shape.kind == "train":
            tokens = shape.global_batch * shape.seq_len
            mf = roofline.model_flops(cfg, tokens)
        elif shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
            mf = roofline.model_flops(cfg, tokens) / 3.0  # fwd only: 2ND
        else:
            mf = 2.0 * roofline.active_params(cfg) * shape.global_batch
        mem = None
        try:
            ma = compiled.memory_analysis()
            mem = {k: int(getattr(ma, k)) for k in
                   ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                    "alias_size_in_bytes") if hasattr(ma, k)}
            if verbose:
                print(f"  memory_analysis: {mem}")
        except Exception as e:  # pragma: no cover
            mem = {"error": str(e)}
        result.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "roofline_scanned": rl.to_dict(),
            "model_flops_total": mf,
            "model_flops_per_chip": mf / chips,
            "memory_analysis": mem,
        })
        if not multi_pod:   # roofline table is single-pod only
            try:
                result.update(exact_roofline(cfg, shape, mesh))
                ex = result["roofline_exact"]
                result["useful_flops_ratio"] = (
                    (mf / chips) / ex["flops"] if ex["flops"] else None)
                if verbose:
                    print(f"  roofline(exact): flops/dev={ex['flops']:.3e} "
                          f"bytes/dev={ex['hbm_bytes']:.3e} "
                          f"coll/dev={ex['coll_bytes']:.3e}")
                    print(f"    compute={ex['t_compute']*1e3:.2f}ms "
                          f"memory={ex['t_memory']*1e3:.2f}ms "
                          f"collective={ex['t_collective']*1e3:.2f}ms "
                          f"-> {ex['bottleneck']}-bound  "
                          f"useful={result['useful_flops_ratio']}")
            except Exception as e:
                result["roofline_exact_error"] = repr(e)
        if verbose:
            c = rl.to_dict()
            print(f"  cost_analysis(scanned): flops/dev={rl.flops:.3e} "
                  f"bytes/dev={rl.hbm_bytes:.3e} coll/dev={rl.coll_bytes:.3e}")
    except Exception as e:
        result.update({"status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()})
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES_BY_NAME) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="spt",
                    choices=["spt", "lora", "full"])
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch x shape) cell")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--set", action="append", default=[], dest="overrides",
                    help="SPTConfig override, e.g. --set attn_impl=sparse_masked")
    ap.add_argument("--tag", default="", help="suffix for output filenames")
    args = ap.parse_args()
    overrides = parse_overrides(args.overrides)

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    archs = list(configs.ARCH_NAMES) if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(SHAPES_BY_NAME) if (args.all or not args.shape) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
                if args.variant != "spt":
                    tag += f"_{args.variant}"
                if args.tag:
                    tag += f"_{args.tag}"
                print(f"[dryrun] {tag}")
                res = run_cell(arch, shape, mp, args.variant,
                               spt_overrides=overrides)
                if overrides:
                    res["spt_overrides"] = overrides
                (outdir / f"{tag}.json").write_text(json.dumps(res, indent=1))
                print(f"  -> {res['status']}" +
                      (f" ({res.get('reason', res.get('error', ''))})"
                       if res["status"] != "ok" else ""))
                failures += res["status"] == "error"
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
