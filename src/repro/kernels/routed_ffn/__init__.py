from repro.kernels.routed_ffn.ops import (routed_ffn,  # noqa: F401
                                          routed_ffn_decode)
