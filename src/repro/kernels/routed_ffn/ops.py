"""Public routed-FFN op: route+dispatch in jnp (sharding-aware), fused
grouped GEMMs (incl. LoRA) in the Pallas kernel, combine in jnp.

Drop-in for core.routed_ffn.routed_ffn; backward differentiates the
reference grouped path (identical routing plan => identical function).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import dispatch, lora
from repro.core.routed_ffn import RoutedFFNConfig, route
from repro.core.routed_ffn import routed_ffn as routed_ffn_core
from repro.kernels.routed_ffn.routed_ffn import grouped_ffn_kernel


def _forward(x, p, cfg: RoutedFFNConfig, lora_cfg, interpret):
    b, s, d = x.shape
    choice, gate_w, probs = route(x, p["router"], cfg)
    cap = dispatch.capacity(s, cfg.num_groups, cfg.active_groups,
                            cfg.capacity_factor)
    plan = dispatch.make_plan(choice, gate_w, cfg.num_groups, cap)
    xg = dispatch.gather(x, plan)                       # (B, G, C, d)
    lora_params = None
    if lora_cfg.enabled and "lora_inner" in p:
        lora_params = {k: p[k] for k in
                       ("lora_inner", "lora_gate", "lora_outer") if k in p}
    y = grouped_ffn_kernel(
        xg, jax.lax.stop_gradient(p["w_inner"]),
        jax.lax.stop_gradient(p["w_outer"]),
        jax.lax.stop_gradient(p["w_gate"]) if cfg.gated else None,
        lora_params, lora_cfg.scale, act=cfg.activation, interpret=interpret)
    out = dispatch.combine(y.astype(x.dtype), plan, s)
    aux = {
        "lb_loss": dispatch.load_balance_loss(probs, choice, cfg.num_groups),
        "dropped": plan.dropped,
    }
    return out, aux


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _op(x, p, cfg, lora_cfg, interpret):
    return _forward(x, p, cfg, lora_cfg, interpret)


def _fwd(x, p, cfg, lora_cfg, interpret):
    out = _forward(x, p, cfg, lora_cfg, interpret)
    return out, (x, p)


def _bwd(cfg, lora_cfg, interpret, res, cts):
    x, p = res
    g, aux_ct = cts

    def ref(x_, p_):
        return routed_ffn_core(x_, p_, cfg, lora_cfg, impl="grouped")

    _, vjp = jax.vjp(ref, x, p)
    return vjp((g, aux_ct))


_op.defvjp(_fwd, _bwd)


def routed_ffn(x: jax.Array, p: dict, cfg: RoutedFFNConfig,
               lora_cfg: lora.LoRAConfig, interpret: bool = True
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    squeeze = x.ndim == 2
    if squeeze:
        x = x[None]
    out, aux = _op(x, p, cfg, lora_cfg, interpret)
    return (out[0] if squeeze else out), aux
