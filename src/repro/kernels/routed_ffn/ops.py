"""Public routed-FFN ops.

``routed_ffn`` (train / prefill): route + capacity plan in jnp
(sharding-aware), then the fused Pallas kernel runs the grouped GEMMs
(incl. LoRA) with the token gather fused in-kernel — the plan's index
array rides as a scalar-prefetch operand and token tiles are DMA'd from
the raw (B, S, d) activations, so the (B, G, C, d) dispatch buffer the
jnp path materializes never reaches HBM.  The combine scatter-add stays
in jnp: it is the differentiable half of dispatch, and the backward pass
differentiates the reference grouped path anyway (identical routing plan
=> identical function).

``routed_ffn_decode`` (serving decode, x of shape (B, 1, d)): skips the
plan entirely — the top-G' choices index the weight blocks directly in
the block-gather kernel.  Inference-only, no VJP (the grouped path stays
the oracle; tests/test_routed_ffn_kernel.py asserts parity).

``interpret=None`` derives the mode from the backend (compiled on TPU,
interpreter elsewhere), so serving needs no plumbing.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dispatch, lora
from repro.core.routed_ffn import RoutedFFNConfig, route
from repro.core.routed_ffn import routed_ffn as routed_ffn_core
from repro.kernels.routed_ffn.routed_ffn import (decode_ffn_kernel,
                                                 grouped_ffn_kernel)


def _lora_tree(p: dict, lora_cfg) -> Optional[dict]:
    if lora_cfg.enabled and "lora_inner" in p:
        return {k: p[k] for k in
                ("lora_inner", "lora_gate", "lora_outer") if k in p}
    return None


def _forward(x, p, cfg: RoutedFFNConfig, lora_cfg, interpret, need_aux,
             seq_lengths=None):
    b, s, d = x.shape
    choice, gate_w, probs = route(x, p["router"], cfg, need_aux=need_aux)
    cap = dispatch.capacity(s, cfg.num_groups, cfg.active_groups,
                            cfg.capacity_factor, pad=cfg.capacity_pad)
    cap_dyn = None if seq_lengths is None else dispatch.capacity_dyn(
        seq_lengths, cfg.num_groups, cfg.active_groups,
        cfg.capacity_factor, pad=cfg.capacity_pad)
    plan = dispatch.make_plan(choice, gate_w, cfg.num_groups, cap,
                              cap_dyn=cap_dyn)
    y = grouped_ffn_kernel(
        x, plan.index, jax.lax.stop_gradient(p["w_inner"]),
        jax.lax.stop_gradient(p["w_outer"]),
        jax.lax.stop_gradient(p["w_gate"]) if cfg.gated else None,
        _lora_tree(p, lora_cfg), lora_cfg.scale, act=cfg.activation,
        interpret=interpret)
    out = dispatch.combine(y.astype(x.dtype), plan, s)
    aux = {
        "lb_loss": (dispatch.load_balance_loss(probs, choice, cfg.num_groups)
                    if need_aux else jnp.zeros((), jnp.float32)),
        "dropped": plan.dropped,
    }
    return out, aux


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _op(x, p, cfg, lora_cfg, interpret, need_aux):
    return _forward(x, p, cfg, lora_cfg, interpret, need_aux)


def _fwd(x, p, cfg, lora_cfg, interpret, need_aux):
    out = _forward(x, p, cfg, lora_cfg, interpret, need_aux)
    return out, (x, p)


def _bwd(cfg, lora_cfg, interpret, need_aux, res, cts):
    x, p = res

    def ref(x_, p_):
        return routed_ffn_core(x_, p_, cfg, lora_cfg, impl="grouped",
                               need_aux=need_aux)

    _, vjp = jax.vjp(ref, x, p)
    return vjp(cts)


_op.defvjp(_fwd, _bwd)


def routed_ffn(x: jax.Array, p: dict, cfg: RoutedFFNConfig,
               lora_cfg: lora.LoRAConfig,
               interpret: Optional[bool] = None, *, need_aux: bool = True,
               seq_lengths: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Drop-in for core.routed_ffn.routed_ffn (impl="grouped" semantics).

    seq_lengths: per-row real lengths for batched ragged prefill (each row
    keeps its exact-length dispatch capacity).  That path is serving-only,
    so it bypasses the custom-VJP wrapper — differentiating a ragged
    prefill raises instead of silently dropping the capacity override."""
    squeeze = x.ndim == 2
    if squeeze:
        x = x[None]
    if seq_lengths is not None:
        out, aux = _forward(x, p, cfg, lora_cfg, interpret, need_aux,
                            seq_lengths=seq_lengths)
    else:
        out, aux = _op(x, p, cfg, lora_cfg, interpret, need_aux)
    return (out[0] if squeeze else out), aux


def routed_ffn_decode(x: jax.Array, p: dict, cfg: RoutedFFNConfig,
                      lora_cfg: lora.LoRAConfig,
                      interpret: Optional[bool] = None
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Decode-shaped routed FFN: x (B, 1, d) (or (B, d)) -> same shape.

    One token per sequence makes capacity bucketing pure overhead (G*C
    slots of plan, gather and scatter to use G'), so the router's top-G'
    choices are scalar-prefetched into the block-gather kernel and index
    the weight blocks directly.  No dispatch buffer is built at any
    width.  Inference-only — no VJP; aux is zeros (no load-balance term
    at serving time).
    """
    squeeze = x.ndim == 2
    x3 = x[:, None] if squeeze else x
    choice, gate_w, _ = route(x3, p["router"], cfg, need_aux=False)
    y = decode_ffn_kernel(
        x3[:, 0], choice[:, 0], gate_w[:, 0],
        jax.lax.stop_gradient(p["w_inner"]),
        jax.lax.stop_gradient(p["w_outer"]),
        jax.lax.stop_gradient(p["w_gate"]) if cfg.gated else None,
        _lora_tree(p, lora_cfg), lora_cfg.scale, act=cfg.activation,
        interpret=interpret)
    y = y.astype(x.dtype)
    aux = {"lb_loss": jnp.zeros((), jnp.float32),
           "dropped": jnp.zeros((), jnp.float32)}
    return (y if squeeze else y[:, None]), aux
