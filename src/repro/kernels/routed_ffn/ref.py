"""Oracles for the routed-FFN kernels (pure-jnp einsum forms)."""
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.routed_ffn import ACTIVATIONS


def grouped_ffn_ref(xg: jax.Array, w_inner: jax.Array, w_outer: jax.Array,
                    w_gate: Optional[jax.Array] = None,
                    act: str = "relu") -> jax.Array:
    """xg: (B, G, C, d) -> (B, G, C, d); pure-jnp einsum form."""
    fn = ACTIVATIONS[act]
    up = jnp.einsum("bgcd,gdf->bgcf", xg.astype(jnp.float32),
                    w_inner.astype(jnp.float32))
    if w_gate is not None:
        gate = jnp.einsum("bgcd,gdf->bgcf", xg.astype(jnp.float32),
                          w_gate.astype(jnp.float32))
        h = fn(gate) * up
    else:
        h = fn(up)
    y = jnp.einsum("bgcf,gfd->bgcd", h, w_outer.astype(jnp.float32))
    return y.astype(xg.dtype)


def decode_ffn_ref(x: jax.Array, choice: jax.Array, gate: jax.Array,
                   w_inner: jax.Array, w_outer: jax.Array,
                   w_gate: Optional[jax.Array] = None,
                   lora_params: Optional[dict] = None,
                   lora_scale: float = 1.0, act: str = "relu") -> jax.Array:
    """Oracle for ``decode_ffn_kernel`` — and the XLA-executable stand-in
    for it in benchmarks (table5 convention): gather the top-G' weight
    blocks per token and contract directly, with no capacity plan, no
    (B, G, C, d) dispatch buffer and no scatter-add.

    x: (B, d); choice: (B, G') int32; gate: (B, G') f32 -> y: (B, d).
    """
    fn = ACTIVATIONS[act]
    f32 = jnp.float32
    xf = x.astype(f32)

    def proj_up(w, lora_key):
        up = jnp.einsum("bd,bgdf->bgf", xf, w[choice].astype(f32))
        if lora_params is not None and lora_key in lora_params:
            li = lora_params[lora_key]
            xb = jnp.einsum("bd,dr->br", xf, li["b"].astype(f32))
            up = up + lora_scale * jnp.einsum(
                "br,bgrf->bgf", xb, li["c"][choice].astype(f32))
        return up

    up = proj_up(w_inner, "lora_inner")
    if w_gate is not None:
        h = fn(proj_up(w_gate, "lora_gate")) * up
    else:
        h = fn(up)
    y = jnp.einsum("bgf,bgfd->bgd", h, w_outer[choice].astype(f32))
    if lora_params is not None and "lora_outer" in lora_params:
        lo = lora_params["lora_outer"]
        hb = jnp.einsum("bgf,bgfr->bgr", h, lo["b"][choice].astype(f32))
        y = y + lora_scale * jnp.einsum("bgr,rd->bgd", hb,
                                        lo["c"].astype(f32))
    y = jnp.einsum("bg,bgd->bd", gate.astype(f32), y)
    return y.astype(x.dtype)
