"""Oracle for the grouped-GEMM routed FFN kernel."""
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.routed_ffn import ACTIVATIONS


def grouped_ffn_ref(xg: jax.Array, w_inner: jax.Array, w_outer: jax.Array,
                    w_gate: Optional[jax.Array] = None,
                    act: str = "relu") -> jax.Array:
    """xg: (B, G, C, d) -> (B, G, C, d); pure-jnp einsum form."""
    fn = ACTIVATIONS[act]
    up = jnp.einsum("bgcd,gdf->bgcf", xg.astype(jnp.float32),
                    w_inner.astype(jnp.float32))
    if w_gate is not None:
        gate = jnp.einsum("bgcd,gdf->bgcf", xg.astype(jnp.float32),
                          w_gate.astype(jnp.float32))
        h = fn(gate) * up
    else:
        h = fn(up)
    y = jnp.einsum("bgcf,gfd->bgcd", h, w_outer.astype(jnp.float32))
    return y.astype(xg.dtype)
