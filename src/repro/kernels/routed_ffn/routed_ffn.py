"""Blocked grouped-GEMM kernels — the TPU form of the paper's BSpMV (§5.2).

The paper iterates over weight blocks, gathers the tokens that activated
each block, and runs one dense GEMM per block on its own CUDA stream.  Two
kernels cover the two serving regimes:

``grouped_ffn_kernel`` (train / prefill) fuses the token *gather* into the
grouped GEMMs: the capacity plan's ``index`` (core/dispatch.py) rides in as
a scalar-prefetch operand, and each (Tc, d) token tile is DMA'd row-by-row
from the raw (B, S, d) activations straight into VMEM — the (B, G, C, d)
dispatch buffer the jnp path materializes in HBM never exists.  Per tile —

    y[b, g] = act(x[index[b, g]] @ W_I[g] (+ LoRA)) @ W_O[g] (+ LoRA)

— optionally gated (GeGLU/SwiGLU), with the FFN hidden dim tiled so each
weight column slab streams through VMEM once while a (Tc, d) f32
accumulator carries partial y.  LoRA rides inside the kernel as rank-r
side-matmuls so the fused op is exactly the fine-tuned layer.  The gather
runs once per token tile (at the first F step) and the tile is reused for
every F slab — the jnp path re-reads the gathered buffer per slab.

``decode_ffn_kernel`` (serving decode, x of shape (B, d)) skips dispatch
entirely: at one token per sequence a capacity plan is G*C slots of
bookkeeping to use G', so the per-token top-G' ``choice`` is
scalar-prefetched instead and indexes the weight blocks directly in the
BlockSpec index_maps —

    y[b] = sum_g  gate[b, g] * act(x[b] @ W_I[choice[b, g]]) @ W_O[...]

— no plan, no gather, no scatter-add.

Tiling: capacities / hidden dims that are not tile multiples are zero-
padded up to one (pad slots carry the empty-slot index, pad hidden columns
carry zero weights, so both are exact no-ops) instead of silently falling
back to whole-dimension tiles that blow the VMEM budget at odd sizes.

Grid (grouped): (B, G, C/Tc, F/Tf), F minor.  VMEM @ defaults (Tc=128,
Tf=256, d<=6144): x tile 3.1 MB + weight slabs 2-3 x 3.1 MB bf16 + acc
3.1 MB < 16 MB.  ``interpret=None`` derives from the backend (compiled on
TPU, interpreter elsewhere).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret as _resolve_interpret
from repro.kernels.topl_select.topl_select import vmem

_ACTS = {"relu": jax.nn.relu, "gelu": jax.nn.gelu, "silu": jax.nn.silu}


def _pad_tile(n: int, tile: int) -> int:
    """Tile size for a dim of extent n: whole dim when it fits in one tile,
    else the requested tile with n zero-padded up to a multiple."""
    return n if n <= tile else tile


def _pad_to(n: int, tile: int) -> int:
    return -(-n // tile) * tile


def _pad_f_operands(tf_pad, w_inner, w_outer, w_gate, lora_params):
    """Zero-pad the FFN hidden dim of every F-carrying operand.  Exact:
    act(0 (+ gated 0*act(0))) = 0 for relu/gelu/silu, and the padded
    W_O rows / LoRA-outer rows are zero, so pad columns contribute
    nothing to y."""
    if not tf_pad:
        return w_inner, w_outer, w_gate, lora_params
    zf = ((0, 0), (0, 0), (0, tf_pad))
    w_inner = jnp.pad(w_inner, zf)
    w_outer = jnp.pad(w_outer, ((0, 0), (0, tf_pad), (0, 0)))
    if w_gate is not None:
        w_gate = jnp.pad(w_gate, zf)
    if lora_params is not None:
        lora_params = dict(lora_params)
        for k in ("lora_inner", "lora_gate"):
            if k in lora_params:
                li = lora_params[k]
                lora_params[k] = {"b": li["b"], "c": jnp.pad(li["c"], zf)}
        lo = lora_params["lora_outer"]
        lora_params["lora_outer"] = {
            "b": jnp.pad(lo["b"], ((0, 0), (0, tf_pad), (0, 0))),
            "c": lo["c"]}
    return w_inner, w_outer, w_gate, lora_params


# ------------------------------------------------------------ train/prefill
def _make_grouped_kernel(act: str, s: int, tc: int, nft: int, gated: bool,
                         use_lora: bool, scale: float):
    def kernel(*refs):
        i = 0
        idx_ref = refs[i]; i += 1                        # scalar prefetch
        x_hbm = refs[i]; i += 1                          # (B, S, d) in ANY
        wi_ref = refs[i]; i += 1
        wg_ref = None
        if gated:
            wg_ref = refs[i]; i += 1
        wo_ref = refs[i]; i += 1
        li_b = li_c = lg_b = lg_c = lo_b = lo_c = None
        if use_lora:
            li_b = refs[i]; i += 1
            li_c = refs[i]; i += 1
            if gated:
                lg_b = refs[i]; i += 1
                lg_c = refs[i]; i += 1
            lo_b = refs[i]; i += 1
            lo_c = refs[i]; i += 1
        y_ref = refs[i]; i += 1
        xs_ref = refs[i]; i += 1                         # (Tc, d) token tile
        acc_ref = refs[i]; i += 1
        hb_ref = refs[i] if use_lora else None
        if use_lora:
            i += 1
        sem = refs[i]

        bi = pl.program_id(0)
        gi = pl.program_id(1)
        ci = pl.program_id(2)
        fi = pl.program_id(3)

        @pl.when(fi == 0)
        def _gather_and_init():
            # In-kernel dispatch: DMA this tile's Tc token rows from the
            # raw activations in HBM.  Empty slots (index == S) clamp to a
            # real row; their garbage y rows are killed downstream (the
            # combine scatter drops index-S slots and zero-weights them).
            # Start every row copy before draining the semaphore so the
            # DMAs overlap instead of paying Tc serial round-trips (each
            # wait retires one row's worth of bytes; rows are same-sized).
            def row_copy(j):
                row = jnp.minimum(idx_ref[bi, gi, ci * tc + j], s - 1)
                return pltpu.make_async_copy(
                    x_hbm.at[bi, pl.ds(row, 1)], xs_ref.at[pl.ds(j, 1)], sem)

            def start_row(j, _):
                row_copy(j).start()
                return 0

            def wait_row(j, _):
                row_copy(j).wait()
                return 0

            jax.lax.fori_loop(0, tc, start_row, 0)
            jax.lax.fori_loop(0, tc, wait_row, 0)
            acc_ref[...] = jnp.zeros_like(acc_ref)
            if hb_ref is not None:
                hb_ref[...] = jnp.zeros_like(hb_ref)

        x = xs_ref[...].astype(jnp.float32)              # (Tc, d)
        f32 = jnp.float32
        dot = lambda a, b: jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())), preferred_element_type=f32)
        up = dot(x, wi_ref[0].astype(f32))               # (Tc, Tf)
        if use_lora:
            xb = dot(x, li_b[...].astype(f32))           # (Tc, r)
            up = up + scale * dot(xb, li_c[0].astype(f32))
        if gated:
            gate = dot(x, wg_ref[0].astype(f32))
            if use_lora:
                xbg = dot(x, lg_b[...].astype(f32))
                gate = gate + scale * dot(xbg, lg_c[0].astype(f32))
            h = _ACTS[act](gate) * up
        else:
            h = _ACTS[act](up)
        acc_ref[...] += dot(h, wo_ref[0].astype(f32))
        if use_lora:
            hb_ref[...] += dot(h, lo_b[0].astype(f32))   # (Tc, r)

        @pl.when(fi == nft - 1)
        def _finish():
            y = acc_ref[...]
            if use_lora:
                y = y + scale * jax.lax.dot_general(
                    hb_ref[...], lo_c[...].astype(f32),
                    (((1,), (0,)), ((), ())), preferred_element_type=f32)
            y_ref[0, 0] = y.astype(y_ref.dtype)

    return kernel


def grouped_ffn_kernel(x: jax.Array, index: jax.Array, w_inner: jax.Array,
                       w_outer: jax.Array,
                       w_gate: Optional[jax.Array] = None,
                       lora_params: Optional[dict] = None,
                       lora_scale: float = 1.0, *,
                       act: str = "relu", tile_c: int = 128,
                       tile_f: int = 256,
                       interpret: Optional[bool] = None) -> jax.Array:
    """x: (B, S, d) raw activations; index: (B, G, C) int32 dispatch plan
    (slot -> token position, S = empty); w_inner: (G, d, F); w_outer:
    (G, F, d).  Returns y: (B, G, C, d).

    The gather is fused: token tiles are DMA'd from x per plan index
    inside the kernel, so no (B, G, C, d) input buffer touches HBM.
    Empty slots produce unspecified (finite) y rows — ``dispatch.combine``
    both zero-weights and scatter-drops them; standalone callers must mask
    by ``plan.slot_ok``.

    lora_params (optional): {"lora_inner": {b (d,r), c (G,r,F)},
    ["lora_gate": ...,] "lora_outer": {b (G,F,r), c (r,d)}}.
    """
    interpret = _resolve_interpret(interpret)
    b, s, d = x.shape
    _, g, c = index.shape
    _, _, f = w_inner.shape
    tc = _pad_tile(c, tile_c)
    tf = _pad_tile(f, tile_f)
    c_pad = _pad_to(c, tc) - c
    tf_pad = _pad_to(f, tf) - f
    if c_pad:                                 # pad slots are empty (-> S)
        index = jnp.pad(index, ((0, 0), (0, 0), (0, c_pad)),
                        constant_values=s)
    w_inner, w_outer, w_gate, lora_params = _pad_f_operands(
        tf_pad, w_inner, w_outer, w_gate, lora_params)
    cp_, fp_ = c + c_pad, f + tf_pad
    nft = fp_ // tf
    gated = w_gate is not None
    use_lora = lora_params is not None
    grid = (b, g, cp_ // tc, nft)

    wi_spec = pl.BlockSpec((1, d, tf), lambda bi, gi, ci, fi, idx: (gi, 0, fi))
    wo_spec = pl.BlockSpec((1, tf, d), lambda bi, gi, ci, fi, idx: (gi, fi, 0))
    y_spec = pl.BlockSpec((1, 1, tc, d),
                          lambda bi, gi, ci, fi, idx: (bi, gi, ci, 0))
    inputs = [x, w_inner]
    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY), wi_spec]
    if gated:
        inputs.append(w_gate)
        in_specs.append(wi_spec)
    inputs.append(w_outer)
    in_specs.append(wo_spec)
    scratch = [vmem((tc, d), x.dtype), vmem((tc, d), jnp.float32)]
    if use_lora:
        li = lora_params["lora_inner"]
        r = li["b"].shape[-1]
        b_in_spec = pl.BlockSpec((d, r), lambda bi, gi, ci, fi, idx: (0, 0))
        c_in_spec = pl.BlockSpec((1, r, tf),
                                 lambda bi, gi, ci, fi, idx: (gi, 0, fi))
        inputs += [li["b"], li["c"]]
        in_specs += [b_in_spec, c_in_spec]
        if gated:
            lg = lora_params["lora_gate"]
            inputs += [lg["b"], lg["c"]]
            in_specs += [b_in_spec, c_in_spec]
        lo = lora_params["lora_outer"]
        b_out_spec = pl.BlockSpec((1, tf, r),
                                  lambda bi, gi, ci, fi, idx: (gi, fi, 0))
        c_out_spec = pl.BlockSpec((r, d), lambda bi, gi, ci, fi, idx: (0, 0))
        inputs += [lo["b"], lo["c"]]
        in_specs += [b_out_spec, c_out_spec]
        scratch.append(vmem((tc, r), jnp.float32))
    scratch.append(pltpu.SemaphoreType.DMA)
    kernel = _make_grouped_kernel(act, s, tc, nft, gated, use_lora,
                                  lora_scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
        out_specs=y_spec, scratch_shapes=scratch)
    y = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, g, cp_, d), x.dtype),
        interpret=interpret)(index.astype(jnp.int32), *inputs)
    return y[:, :, :c] if c_pad else y


# ----------------------------------------------------------------- decode
def _make_decode_kernel(act: str, n_active: int, nft: int, gated: bool,
                        use_lora: bool, scale: float):
    def kernel(*refs):
        i = 0
        ch_ref = refs[i]; i += 1                         # scalar prefetch
        gt_ref = refs[i]; i += 1                         # scalar prefetch
        x_ref = refs[i]; i += 1
        wi_ref = refs[i]; i += 1
        wg_ref = None
        if gated:
            wg_ref = refs[i]; i += 1
        wo_ref = refs[i]; i += 1
        li_b = li_c = lg_b = lg_c = lo_b = lo_c = None
        if use_lora:
            li_b = refs[i]; i += 1
            li_c = refs[i]; i += 1
            if gated:
                lg_b = refs[i]; i += 1
                lg_c = refs[i]; i += 1
            lo_b = refs[i]; i += 1
            lo_c = refs[i]; i += 1
        y_ref = refs[i]; i += 1
        acc_ref = refs[i]; i += 1
        hb_ref = refs[i] if use_lora else None

        bi = pl.program_id(0)
        gi = pl.program_id(1)
        fi = pl.program_id(2)

        @pl.when((gi == 0) & (fi == 0))
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        if hb_ref is not None:
            @pl.when(fi == 0)
            def _init_hb():
                hb_ref[...] = jnp.zeros_like(hb_ref)

        gt = gt_ref[bi, gi]
        x = x_ref[...].astype(jnp.float32)               # (1, d)
        f32 = jnp.float32
        dot = lambda a, b: jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())), preferred_element_type=f32)
        up = dot(x, wi_ref[0].astype(f32))               # (1, Tf)
        if use_lora:
            xb = dot(x, li_b[...].astype(f32))           # (1, r)
            up = up + scale * dot(xb, li_c[0].astype(f32))
        if gated:
            gate = dot(x, wg_ref[0].astype(f32))
            if use_lora:
                xbg = dot(x, lg_b[...].astype(f32))
                gate = gate + scale * dot(xbg, lg_c[0].astype(f32))
            h = _ACTS[act](gate) * up
        else:
            h = _ACTS[act](up)
        acc_ref[...] += gt * dot(h, wo_ref[0].astype(f32))
        if use_lora:
            hb_ref[...] += dot(h, lo_b[0].astype(f32))   # (1, r)

            @pl.when(fi == nft - 1)
            def _lora_out():
                acc_ref[...] += gt * scale * jax.lax.dot_general(
                    hb_ref[...], lo_c[...].astype(f32),
                    (((1,), (0,)), ((), ())), preferred_element_type=f32)

        @pl.when((gi == n_active - 1) & (fi == nft - 1))
        def _finish():
            y_ref[...] = acc_ref[...].astype(y_ref.dtype)

    return kernel


def decode_ffn_kernel(x: jax.Array, choice: jax.Array, gate: jax.Array,
                      w_inner: jax.Array, w_outer: jax.Array,
                      w_gate: Optional[jax.Array] = None,
                      lora_params: Optional[dict] = None,
                      lora_scale: float = 1.0, *,
                      act: str = "relu", tile_f: int = 256,
                      interpret: Optional[bool] = None) -> jax.Array:
    """Decode-shaped routed FFN: one token per sequence, no dispatch plan.

    x: (B, d); choice: (B, G') int32 top-G' group ids; gate: (B, G') f32
    per-choice output gates (ones when ungated).  choice and gate ride as
    scalar-prefetch operands: choice drives the weight-block BlockSpec
    index_maps (the "block gather"), gate scales each block's contribution
    inside the accumulator.  Returns y: (B, d) = sum over active blocks.
    """
    interpret = _resolve_interpret(interpret)
    b, d = x.shape
    _, n_active = choice.shape
    _, _, f = w_inner.shape
    tf = _pad_tile(f, tile_f)
    tf_pad = _pad_to(f, tf) - f
    w_inner, w_outer, w_gate, lora_params = _pad_f_operands(
        tf_pad, w_inner, w_outer, w_gate, lora_params)
    nft = (f + tf_pad) // tf
    gated = w_gate is not None
    use_lora = lora_params is not None
    grid = (b, n_active, nft)

    x_spec = pl.BlockSpec((1, d), lambda bi, gi, fi, ch, gt: (bi, 0))
    wi_spec = pl.BlockSpec(
        (1, d, tf), lambda bi, gi, fi, ch, gt: (ch[bi, gi], 0, fi))
    wo_spec = pl.BlockSpec(
        (1, tf, d), lambda bi, gi, fi, ch, gt: (ch[bi, gi], fi, 0))
    y_spec = pl.BlockSpec((1, d), lambda bi, gi, fi, ch, gt: (bi, 0))
    inputs = [x, w_inner]
    in_specs = [x_spec, wi_spec]
    if gated:
        inputs.append(w_gate)
        in_specs.append(wi_spec)
    inputs.append(w_outer)
    in_specs.append(wo_spec)
    scratch = [vmem((1, d), jnp.float32)]
    if use_lora:
        li = lora_params["lora_inner"]
        r = li["b"].shape[-1]
        b_in_spec = pl.BlockSpec((d, r), lambda bi, gi, fi, ch, gt: (0, 0))
        c_in_spec = pl.BlockSpec(
            (1, r, tf), lambda bi, gi, fi, ch, gt: (ch[bi, gi], 0, fi))
        inputs += [li["b"], li["c"]]
        in_specs += [b_in_spec, c_in_spec]
        if gated:
            lg = lora_params["lora_gate"]
            inputs += [lg["b"], lg["c"]]
            in_specs += [b_in_spec, c_in_spec]
        lo = lora_params["lora_outer"]
        b_out_spec = pl.BlockSpec(
            (1, tf, r), lambda bi, gi, fi, ch, gt: (ch[bi, gi], fi, 0))
        c_out_spec = pl.BlockSpec((r, d), lambda bi, gi, fi, ch, gt: (0, 0))
        inputs += [lo["b"], lo["c"]]
        in_specs += [b_out_spec, c_out_spec]
        scratch.append(vmem((1, r), jnp.float32))
    kernel = _make_decode_kernel(act, n_active, nft, gated, use_lora,
                                 lora_scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2, grid=grid, in_specs=in_specs,
        out_specs=y_spec, scratch_shapes=scratch)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), x.dtype),
        interpret=interpret)(choice.astype(jnp.int32),
                             gate.astype(jnp.float32), *inputs)
