"""Blocked grouped-GEMM kernel — the TPU form of the paper's BSpMV (§5.2).

The paper iterates over weight blocks, gathers the tokens that activated
each block, and runs one dense GEMM per block on its own CUDA stream.  Here
the (B, G, C, d) capacity-bucketed token buffer (core/dispatch.py) is the
batching; the kernel fuses both projections per block —

    y[b, g] = act(x[b, g] @ W_I[g] (+ LoRA)) @ W_O[g] (+ LoRA)

— optionally gated (GeGLU/SwiGLU), with the FFN hidden dim tiled so each
weight column slab streams through VMEM once while a (Tc, d) f32
accumulator carries partial y.  LoRA rides inside the kernel as rank-r
side-matmuls so the fused op is exactly the fine-tuned layer.

Grid: (B, G, C/Tc, F/Tf), F minor.  VMEM @ defaults (Tc=128, Tf=256,
d<=6144): x 3.1 MB + weight slabs 2-3 x 3.1 MB bf16 + acc 3.1 MB < 16 MB.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.topl_select.topl_select import vmem

_ACTS = {"relu": jax.nn.relu, "gelu": jax.nn.gelu, "silu": jax.nn.silu}


def _make_kernel(act: str, nft: int, gated: bool, use_lora: bool,
                 scale: float):
    def kernel(*refs):
        i = 0
        x_ref = refs[i]; i += 1
        wi_ref = refs[i]; i += 1
        wg_ref = None
        if gated:
            wg_ref = refs[i]; i += 1
        wo_ref = refs[i]; i += 1
        li_b = li_c = lg_b = lg_c = lo_b = lo_c = None
        if use_lora:
            li_b = refs[i]; i += 1
            li_c = refs[i]; i += 1
            if gated:
                lg_b = refs[i]; i += 1
                lg_c = refs[i]; i += 1
            lo_b = refs[i]; i += 1
            lo_c = refs[i]; i += 1
        y_ref = refs[i]; i += 1
        acc_ref = refs[i]; i += 1
        hb_ref = refs[i] if use_lora else None

        fi = pl.program_id(3)

        @pl.when(fi == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            if hb_ref is not None:
                hb_ref[...] = jnp.zeros_like(hb_ref)

        x = x_ref[0, 0].astype(jnp.float32)              # (Tc, d)
        f32 = jnp.float32
        dot = lambda a, b: jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())), preferred_element_type=f32)
        up = dot(x, wi_ref[0].astype(f32))               # (Tc, Tf)
        if use_lora:
            xb = dot(x, li_b[...].astype(f32))           # (Tc, r)
            up = up + scale * dot(xb, li_c[0].astype(f32))
        if gated:
            gate = dot(x, wg_ref[0].astype(f32))
            if use_lora:
                xbg = dot(x, lg_b[...].astype(f32))
                gate = gate + scale * dot(xbg, lg_c[0].astype(f32))
            h = _ACTS[act](gate) * up
        else:
            h = _ACTS[act](up)
        acc_ref[...] += dot(h, wo_ref[0].astype(f32))
        if use_lora:
            hb_ref[...] += dot(h, lo_b[0].astype(f32))   # (Tc, r)

        @pl.when(fi == nft - 1)
        def _finish():
            y = acc_ref[...]
            if use_lora:
                y = y + scale * jax.lax.dot_general(
                    hb_ref[...], lo_c[...].astype(f32),
                    (((1,), (0,)), ((), ())), preferred_element_type=f32)
            y_ref[0, 0] = y.astype(y_ref.dtype)

    return kernel


def grouped_ffn_kernel(xg: jax.Array, w_inner: jax.Array, w_outer: jax.Array,
                       w_gate: Optional[jax.Array] = None,
                       lora_params: Optional[dict] = None,
                       lora_scale: float = 1.0, *,
                       act: str = "relu", tile_c: int = 128,
                       tile_f: int = 256,
                       interpret: bool = False) -> jax.Array:
    """xg: (B, G, C, d); w_inner: (G, d, F); w_outer: (G, F, d).

    lora_params (optional): {"lora_inner": {b (d,r), c (G,r,F)},
    ["lora_gate": ...,] "lora_outer": {b (G,F,r), c (r,d)}}.
    """
    b, g, c, d = xg.shape
    _, _, f = w_inner.shape
    tc = min(tile_c, c)
    if c % tc:
        tc = c
    tf = min(tile_f, f)
    if f % tf:
        tf = f
    nft = f // tf
    gated = w_gate is not None
    use_lora = lora_params is not None
    grid = (b, g, c // tc, nft)
    x_spec = pl.BlockSpec((1, 1, tc, d), lambda bi, gi, ci, fi: (bi, gi, ci, 0))
    wi_spec = pl.BlockSpec((1, d, tf), lambda bi, gi, ci, fi: (gi, 0, fi))
    wo_spec = pl.BlockSpec((1, tf, d), lambda bi, gi, ci, fi: (gi, fi, 0))
    y_spec = pl.BlockSpec((1, 1, tc, d), lambda bi, gi, ci, fi: (bi, gi, ci, 0))
    inputs = [xg, w_inner]
    in_specs = [x_spec, wi_spec]
    if gated:
        inputs.append(w_gate)
        in_specs.append(wi_spec)
    inputs.append(w_outer)
    in_specs.append(wo_spec)
    scratch = [vmem((tc, d), jnp.float32)]
    if use_lora:
        li = lora_params["lora_inner"]
        r = li["b"].shape[-1]
        b_in_spec = pl.BlockSpec((d, r), lambda bi, gi, ci, fi: (0, 0))
        c_in_spec = pl.BlockSpec((1, r, tf), lambda bi, gi, ci, fi: (gi, 0, fi))
        inputs += [li["b"], li["c"]]
        in_specs += [b_in_spec, c_in_spec]
        if gated:
            lg = lora_params["lora_gate"]
            inputs += [lg["b"], lg["c"]]
            in_specs += [b_in_spec, c_in_spec]
        lo = lora_params["lora_outer"]
        b_out_spec = pl.BlockSpec((1, tf, r), lambda bi, gi, ci, fi: (gi, fi, 0))
        c_out_spec = pl.BlockSpec((r, d), lambda bi, gi, ci, fi: (0, 0))
        inputs += [lo["b"], lo["c"]]
        in_specs += [b_out_spec, c_out_spec]
        scratch.append(vmem((tc, r), jnp.float32))
    kernel = _make_kernel(act, nft, gated, use_lora, lora_scale)
    return pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs, out_specs=y_spec,
        out_shape=jax.ShapeDtypeStruct((b, g, c, d), xg.dtype),
        scratch_shapes=scratch, interpret=interpret)(*inputs)
