"""Pallas TPU kernels for the SPT hot spots (DESIGN.md §6).

Each kernel directory ships:
  <name>.py — pl.pallas_call body with explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrapper (custom_vjp: fused forward, ref backward)
  ref.py    — pure-jnp oracle (reuses the validated core/ implementations)

Validated on CPU with interpret=True; TPU (v5e) is the compile target.
"""
