"""Pallas TPU kernels for the SPT hot spots (DESIGN.md §6).

Each kernel directory ships:
  <name>.py — pl.pallas_call body with explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrapper (custom_vjp: fused forward, ref backward)
  ref.py    — pure-jnp oracle (reuses the validated core/ implementations)

Validated on CPU with interpret=True; TPU (v5e) is the compile target.

Every public wrapper takes ``interpret: Optional[bool] = None`` and routes
it through ``resolve_interpret`` at the innermost pallas_call site: None
means "derive from the backend" (interpret everywhere except real TPU),
so callers never hard-code a platform assumption.  analysis/lint.py
enforces the ``None`` default repo-wide.
"""
from __future__ import annotations

from typing import Optional

import jax


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """None -> interpret off TPU, compiled on TPU; explicit bool wins."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret
