from repro.kernels.pq_quantize.ops import pq_assign  # noqa: F401
