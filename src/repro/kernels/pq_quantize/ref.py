"""Oracle for the PQ assignment kernel: the validated core implementation."""
import jax

from repro.core import pq


def pq_assign_ref(x: jax.Array, codebooks: jax.Array) -> jax.Array:
    """x: (G, n, d) -> (G, n, M) int32."""
    return pq.assign(x, codebooks)
