"""Public jit wrapper for the fused PQ assignment kernel.

Codes are integer outputs (no gradient); the codebooks train through the
DKM quantization-error loss on the jnp path, so no custom VJP is needed —
the op is non-differentiable by construction (like the paper's).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.pq_quantize.pq_quantize import pq_assign_kernel


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def pq_assign(x: jax.Array, codebooks: jax.Array, *, tile_n: int = 256,
              interpret: Optional[bool] = None) -> jax.Array:
    """x: (..., n, d); codebooks (M, E, d') -> (..., n, M) int32.

    interpret=None derives from the backend (interpret off TPU, compiled
    on TPU) — see kernels.resolve_interpret.
    """
    lead = x.shape[:-2]
    g = 1
    for s in lead:
        g *= s
    xg = x.reshape(g, *x.shape[-2:])
    codes = pq_assign_kernel(xg, codebooks, tile_n=tile_n,
                             interpret=interpret)
    return codes.reshape(*lead, x.shape[-2], codebooks.shape[0])
