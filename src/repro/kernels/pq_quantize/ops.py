"""Public jit wrapper for the fused PQ assignment kernel.

Codes are integer outputs (no gradient); the codebooks train through the
DKM quantization-error loss on the jnp path, so no custom VJP is needed —
the op is non-differentiable by construction (like the paper's).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.pq_quantize.pq_quantize import pq_assign_kernel


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def pq_assign(x: jax.Array, codebooks: jax.Array, *, tile_n: int = 256,
              interpret: bool = True) -> jax.Array:
    """x: (..., n, d); codebooks (M, E, d') -> (..., n, M) int32.

    interpret=True by default in this CPU container; pass False on TPU.
    """
    lead = x.shape[:-2]
    g = 1
    for s in lead:
        g *= s
    xg = x.reshape(g, *x.shape[-2:])
    codes = pq_assign_kernel(xg, codebooks, tile_n=tile_n,
                             interpret=interpret)
    return codes.reshape(*lead, x.shape[-2], codebooks.shape[0])
