"""Fused cdist+argmin PQ assignment kernel (paper §5.1, Algorithm 2).

The paper fuses the CUDA cdist and argmin kernels so the (n, E) distance
matrix never reaches global memory; we do the same for HBM: each grid step
loads one (Tn, d) slab of vectors plus the full (M, E, d') codebooks into
VMEM, computes per-subspace distances via a -2 x cᵀ MXU matmul, and argmins
in VREGs.  Only the (Tn, M) int32 codes are written back.

Grid: (batch*heads, n / Tn).  VMEM per step (defaults Tn=256, d<=256,
E=16): x 256 KB + codebooks ~16 KB + codes 16 KB — comfortably < 16 MB.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret


def _kernel(x_ref, cb_ref, codes_ref):
    x = x_ref[0].astype(jnp.float32)            # (Tn, d)
    m, e, dp = cb_ref.shape
    outs = []
    for i in range(m):
        sub = x[:, i * dp:(i + 1) * dp]          # (Tn, d')
        cb = cb_ref[i].astype(jnp.float32)       # (E, d')
        dots = jnp.dot(sub, cb.T, preferred_element_type=jnp.float32)
        c2 = jnp.sum(cb * cb, axis=1)
        dist = c2[None, :] - 2.0 * dots          # ||x||^2 constant in argmin
        outs.append(jnp.argmin(dist, axis=1).astype(jnp.int32))
    codes_ref[0] = jnp.stack(outs, axis=1)


def pq_assign_kernel(x: jax.Array, codebooks: jax.Array, *, tile_n: int = 256,
                     interpret: Optional[bool] = None) -> jax.Array:
    """x: (G, n, d); codebooks: (M, E, d') -> codes (G, n, M) int32."""
    interpret = resolve_interpret(interpret)
    g, n, d = x.shape
    m, e, dp = codebooks.shape
    assert d == m * dp, (x.shape, codebooks.shape)
    tn = min(tile_n, n)
    if n % tn != 0:
        tn = n
    grid = (g, n // tn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tn, d), lambda gi, i: (gi, i, 0)),
            pl.BlockSpec((m, e, dp), lambda gi, i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tn, m), lambda gi, i: (gi, i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, n, m), jnp.int32),
        interpret=interpret,
    )(x, codebooks)
