"""Public wrappers: threshold kernel + jnp binary-search index emission.

The fused attention kernel consumes thresholds directly (no indices ever
materialize).  ``topl_select`` — thresholds from the Pallas kernel, then the
sort-free compaction — exists for the decode path and for parity tests
against the CSR-index formulation.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import sparse_attention as sa
from repro.kernels.topl_select.topl_select import (
    decode_topl_thresholds_kernel, topl_thresholds_kernel)


@functools.partial(jax.jit, static_argnames=(
    "l", "max_score", "causal", "window", "q_offset", "interpret"))
def topl_thresholds(codes_q: jax.Array, codes_k: jax.Array, *, l: int,
                    max_score: int, causal: bool = True,
                    window: Optional[int] = None, q_offset: int = 0,
                    interpret: Optional[bool] = None) -> jax.Array:
    return topl_thresholds_kernel(
        codes_q, codes_k, l=l, max_score=max_score, causal=causal,
        window=window, q_offset=q_offset, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "l", "max_score", "sum_rows", "heads_per_batch", "tile_k", "interpret"))
def decode_topl_thresholds(codes_q: jax.Array, codes_k: jax.Array,
                           kv_valid: jax.Array, *, l: int, max_score: int,
                           sum_rows: bool, heads_per_batch: int,
                           tile_k: int = 512,
                           interpret: Optional[bool] = None) -> jax.Array:
    """Decode-shaped thresholds: (G, R, M) query codes vs (G, S, M) cached
    codes under a (B, S) validity mask -> (G, R_out, 2) [t, need]."""
    return decode_topl_thresholds_kernel(
        codes_q, codes_k, kv_valid.astype(jnp.int32), l=l,
        max_score=max_score, sum_rows=sum_rows,
        heads_per_batch=heads_per_batch, tile_k=tile_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "l", "max_score", "causal", "window", "q_offset", "interpret"))
def topl_select(codes_q: jax.Array, codes_k: jax.Array, *, l: int,
                max_score: int, causal: bool = True,
                window: Optional[int] = None, q_offset: int = 0,
                interpret: Optional[bool] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """(G, nq, M) x (G, nk, M) -> indices (G, nq, L), valid (G, nq, L)."""
    thr = topl_thresholds(codes_q, codes_k, l=l, max_score=max_score,
                          causal=causal, window=window, q_offset=q_offset,
                          interpret=interpret)
    g, nq, m = codes_q.shape
    nk = codes_k.shape[1]
    s = jnp.sum(
        (codes_q[:, :, None, :] == codes_k[:, None, :, :]).astype(jnp.int32),
        axis=-1)
    q_pos = q_offset + jnp.arange(nq, dtype=jnp.int32)
    k_pos = jnp.arange(nk, dtype=jnp.int32)
    valid = sa.attention_mask(q_pos, k_pos, causal, window)[None]
    t = thr[..., 0:1]
    need = thr[..., 1:2]
    sm = jnp.where(valid, s, -1)
    above = sm > t
    at_t = sm == t
    rev_rank = jnp.cumsum(at_t[..., ::-1].astype(jnp.int32),
                          axis=-1)[..., ::-1]
    eligible = above | (at_t & (rev_rank <= need))
    cs = jnp.cumsum(eligible.astype(jnp.int32), axis=-1)
    n_sel = cs[..., -1]
    targets = jnp.arange(1, l + 1, dtype=jnp.int32)
    lo = jnp.zeros((g, nq, l), jnp.int32)
    hi = jnp.full_like(lo, nk)
    for _ in range(max(1, nk.bit_length())):
        mid = (lo + hi) // 2
        cs_mid = jnp.take_along_axis(cs, jnp.minimum(mid, nk - 1), axis=-1)
        go_right = cs_mid < targets
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
    idx = jnp.minimum(lo, nk - 1)
    return idx, targets <= n_sel[..., None]
