"""Streaming bucket-threshold kernel — paper Algorithm 3 on TPU.

The paper's bucket sort puts each key into one of M+1 integer-score buckets
(shared memory, thread-per-query) and reads buckets high-to-low until L keys
are collected.  The TPU form computes, for a (Tq) tile of queries, the
per-query score *histogram* by streaming (Tk) key-code tiles through VMEM,
then derives the equivalent of "where reading stops": the threshold bucket
``t`` and the residual tie budget ``need`` (# keys to take at score == t,
most recent first).  Downstream consumers (the fused attention kernel, or
the jnp emit step) never sort anything.

Grid: (G, nq/Tq, nk/Tk), key axis minor => the histogram scratch carries
across key tiles.  VMEM: codes tiles (Tq+Tk) x M int32 + hist (Tq, M+1).
Output: (G, nq, 2) int32 = [t, need] per query.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scores(cq, ck):
    """(Tq, M) x (Tk, M) -> (Tq, Tk) int32 match counts (Eq. 6)."""
    m = cq.shape[1]
    s = jnp.zeros((cq.shape[0], ck.shape[0]), jnp.int32)
    for i in range(m):
        s = s + (cq[:, i][:, None] == ck[:, i][None, :]).astype(jnp.int32)
    return s


def _mask(q_pos, k_pos, causal, window):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


def _hist_kernel(cq_ref, ck_ref, thr_ref, hist_ref, *, max_score, l,
                 causal, window, q_offset, tq, tk, nkt):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    cq = cq_ref[0]
    ck = ck_ref[0]
    s = _scores(cq, ck)
    q_pos = q_offset + qi * tq + jax.lax.broadcasted_iota(jnp.int32, (tq,), 0)
    k_pos = ki * tk + jax.lax.broadcasted_iota(jnp.int32, (tk,), 0)
    valid = _mask(q_pos, k_pos, causal, window)
    sm = jnp.where(valid, s, -1)
    for v in range(max_score + 1):
        hist_ref[:, v] += jnp.sum((sm == v).astype(jnp.int32), axis=1)

    @pl.when(ki == nkt - 1)
    def _finish():
        hist = hist_ref[...]                          # (Tq, M+1)
        # ge[v] = #keys with score >= v  (suffix sums, small static loop)
        ge = jnp.cumsum(hist[:, ::-1], axis=1)[:, ::-1]
        meets = (ge >= l).astype(jnp.int32)
        t = jnp.maximum(jnp.sum(meets, axis=1) - 1, 0)
        ge_pad = jnp.concatenate(
            [ge, jnp.zeros((hist.shape[0], 1), jnp.int32)], axis=1)
        n_above = jnp.take_along_axis(ge_pad, (t + 1)[:, None], axis=1)[:, 0]
        need = l - n_above
        thr_ref[0] = jnp.stack([t, need], axis=1).astype(jnp.int32)


def topl_thresholds_kernel(codes_q: jax.Array, codes_k: jax.Array, *,
                           l: int, max_score: int, causal: bool,
                           window: Optional[int], q_offset: int = 0,
                           tile_q: int = 256, tile_k: int = 512,
                           interpret: bool = False) -> jax.Array:
    """codes_q: (G, nq, M); codes_k: (G, nk, M) -> (G, nq, 2) [t, need]."""
    g, nq, m = codes_q.shape
    _, nk, _ = codes_k.shape
    tq = min(tile_q, nq)
    if nq % tq:
        tq = nq
    tk = min(tile_k, nk)
    if nk % tk:
        tk = nk
    nkt = nk // tk
    grid = (g, nq // tq, nkt)
    kernel = functools.partial(
        _hist_kernel, max_score=max_score, l=l, causal=causal, window=window,
        q_offset=q_offset, tq=tq, tk=tk, nkt=nkt)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, m), lambda gi, qi, ki: (gi, qi, 0)),
            pl.BlockSpec((1, tk, m), lambda gi, qi, ki: (gi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, 2), lambda gi, qi, ki: (gi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((g, nq, 2), jnp.int32),
        scratch_shapes=[vmem((tq, max_score + 1), jnp.int32)],
        interpret=interpret,
    )(codes_q, codes_k)


def vmem(shape, dtype):
    """VMEM scratch allocation (works under interpret=True on CPU too)."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
