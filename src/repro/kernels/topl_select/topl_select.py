"""Streaming bucket-threshold kernel — paper Algorithm 3 on TPU.

The paper's bucket sort puts each key into one of M+1 integer-score buckets
(shared memory, thread-per-query) and reads buckets high-to-low until L keys
are collected.  The TPU form computes, for a (Tq) tile of queries, the
per-query score *histogram* by streaming (Tk) key-code tiles through VMEM,
then derives the equivalent of "where reading stops": the threshold bucket
``t`` and the residual tie budget ``need`` (# keys to take at score == t,
most recent first).  Downstream consumers (the fused attention kernel, or
the jnp emit step) never sort anything.

Grid: (G, nq/Tq, nk/Tk), key axis minor => the histogram scratch carries
across key tiles.  VMEM: codes tiles (Tq+Tk) x M int32 + hist (Tq, M+1).
Output: (G, nq, 2) int32 = [t, need] per query.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret


def _scores(cq, ck):
    """(Tq, M) x (Tk, M) -> (Tq, Tk) int32 match counts (Eq. 6)."""
    m = cq.shape[1]
    s = jnp.zeros((cq.shape[0], ck.shape[0]), jnp.int32)
    for i in range(m):
        s = s + (cq[:, i][:, None] == ck[:, i][None, :]).astype(jnp.int32)
    return s


def _mask(q_pos, k_pos, causal, window):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


def _hist_kernel(cq_ref, ck_ref, thr_ref, hist_ref, *, max_score, l,
                 causal, window, q_offset, tq, tk, nkt):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    cq = cq_ref[0]
    ck = ck_ref[0]
    s = _scores(cq, ck)
    q_pos = q_offset + qi * tq + jax.lax.broadcasted_iota(jnp.int32, (tq,), 0)
    k_pos = ki * tk + jax.lax.broadcasted_iota(jnp.int32, (tk,), 0)
    valid = _mask(q_pos, k_pos, causal, window)
    sm = jnp.where(valid, s, -1)
    hist_accumulate(hist_ref, sm, max_score)

    @pl.when(ki == nkt - 1)
    def _finish():
        thr_ref[0] = hist_reduce(hist_ref[...], l)


def topl_thresholds_kernel(codes_q: jax.Array, codes_k: jax.Array, *,
                           l: int, max_score: int, causal: bool,
                           window: Optional[int], q_offset: int = 0,
                           tile_q: int = 256, tile_k: int = 512,
                           interpret: Optional[bool] = None) -> jax.Array:
    """codes_q: (G, nq, M); codes_k: (G, nk, M) -> (G, nq, 2) [t, need]."""
    interpret = resolve_interpret(interpret)
    g, nq, m = codes_q.shape
    _, nk, _ = codes_k.shape
    tq = min(tile_q, nq)
    if nq % tq:
        tq = nq
    tk = min(tile_k, nk)
    if nk % tk:
        tk = nk
    nkt = nk // tk
    grid = (g, nq // tq, nkt)
    kernel = functools.partial(
        _hist_kernel, max_score=max_score, l=l, causal=causal, window=window,
        q_offset=q_offset, tq=tq, tk=tk, nkt=nkt)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, m), lambda gi, qi, ki: (gi, qi, 0)),
            pl.BlockSpec((1, tk, m), lambda gi, qi, ki: (gi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, 2), lambda gi, qi, ki: (gi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((g, nq, 2), jnp.int32),
        scratch_shapes=[vmem((tq, max_score + 1), jnp.int32)],
        interpret=interpret,
    )(codes_q, codes_k)


def vmem(shape, dtype):
    """VMEM scratch allocation (works under interpret=True on CPU too)."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


# ---------------------------------------------------------------- decode
def hist_counts(sm, max_score):
    """(R_out, N) masked scores (-1 = dead slot) -> (R_out, max_score+1)
    bucket counts.  N is arbitrary: the two-pass threshold kernel folds one
    Tk tile at a time into scratch, the fused one-pass decode kernel counts
    the whole cache row in its first grid step.  Integer counts are
    order-independent, so both routes derive bit-identical thresholds."""
    return jnp.stack([jnp.sum((sm == v).astype(jnp.int32), axis=1)
                      for v in range(max_score + 1)], axis=1)


def hist_accumulate(hist_ref, sm, max_score):
    """Fold one (R_out, Tk) masked-score tile into the bucket histogram
    scratch (the streaming form used by the two-pass threshold kernel)."""
    hist_ref[...] += hist_counts(sm, max_score)


def hist_reduce(hist, l):
    """Histogram (R_out, max_score+1) -> (R_out, 2) int32 [threshold bucket
    t, tie budget need]: the bucket where high-to-low reading stops at L
    keys, and how many score==t keys (most recent first) still fit."""
    ge = jnp.cumsum(hist[:, ::-1], axis=1)[:, ::-1]
    meets = (ge >= l).astype(jnp.int32)
    t = jnp.maximum(jnp.sum(meets, axis=1) - 1, 0)
    ge_pad = jnp.concatenate(
        [ge, jnp.zeros((hist.shape[0], 1), jnp.int32)], axis=1)
    n_above = jnp.take_along_axis(ge_pad, (t + 1)[:, None], axis=1)[:, 0]
    return jnp.stack([t, l - n_above], axis=1).astype(jnp.int32)


def _decode_hist_kernel(cq_ref, ck_ref, valid_ref, thr_ref, hist_ref, *,
                        max_score, l, sum_rows, nkt):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    cq = cq_ref[0]                                # (R, M)
    ck = ck_ref[0]                                # (Tk, M)
    s = _scores(cq, ck)                           # (R, Tk)
    if sum_rows:                                  # GQA-shared ("kvgroup"):
        s = jnp.sum(s, axis=0, keepdims=True)     # one selection per kv head
    valid = valid_ref[0] != 0                     # (Tk,)
    sm = jnp.where(valid[None, :], s, -1)         # (R_out, Tk)
    hist_accumulate(hist_ref, sm, max_score)

    @pl.when(ki == nkt - 1)
    def _finish():
        thr_ref[0] = hist_reduce(hist_ref[...], l)


def decode_topl_thresholds_kernel(codes_q: jax.Array, codes_k: jax.Array,
                                  kv_valid: jax.Array, *, l: int,
                                  max_score: int, sum_rows: bool,
                                  heads_per_batch: int, tile_k: int = 512,
                                  interpret: Optional[bool] = None
                                  ) -> jax.Array:
    """Decode-shaped threshold pass: one query token per group.

    codes_q: (G, R, M) — the R query heads sharing one kv head (G = B*Hk);
    codes_k: (G, S, M) cached key codes; kv_valid: (B, S) nonzero = slot
    participates (plain causal caches and ring-buffer SWA caches both reduce
    to this mask — no positional logic in-kernel).

    sum_rows=True is the "kvgroup" granularity: the R heads' match counts
    are summed (score in [0, R*M]) and ONE threshold is emitted per kv head;
    sum_rows=False ("qhead") keeps a per-row histogram.  No jnp.repeat of
    codes across query heads in either mode.

    Returns (G, R_out, 2) int32 [threshold bucket, tie budget],
    R_out = 1 if sum_rows else R.
    """
    interpret = resolve_interpret(interpret)
    g, r, m = codes_q.shape
    _, nk, _ = codes_k.shape
    tk = min(tile_k, nk)
    if nk % tk:
        tk = nk
    nkt = nk // tk
    r_out = 1 if sum_rows else r
    hpb = heads_per_batch
    kernel = functools.partial(_decode_hist_kernel, max_score=max_score, l=l,
                               sum_rows=sum_rows, nkt=nkt)
    return pl.pallas_call(
        kernel,
        grid=(g, nkt),
        in_specs=[
            pl.BlockSpec((1, r, m), lambda gi, ki: (gi, 0, 0)),
            pl.BlockSpec((1, tk, m), lambda gi, ki: (gi, ki, 0)),
            pl.BlockSpec((1, tk), lambda gi, ki: (gi // hpb, ki)),
        ],
        out_specs=pl.BlockSpec((1, r_out, 2), lambda gi, ki: (gi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, r_out, 2), jnp.int32),
        scratch_shapes=[vmem((r_out, max_score + 1), jnp.int32)],
        interpret=interpret,
    )(codes_q, codes_k, kv_valid)
