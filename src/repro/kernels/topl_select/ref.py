"""Oracle for top-L selection: the validated bucket_select from core
(set-equivalent to sort-based select_topl; see tests)."""
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import pq
from repro.core import sparse_attention as sa


def thresholds_ref(codes_q: jax.Array, codes_k: jax.Array, *, l: int,
                   max_score: int, causal: bool, window: Optional[int],
                   q_offset: int = 0) -> jax.Array:
    """(G, nq, M), (G, nk, M) -> (G, nq, 2) [threshold bucket, tie budget]."""
    g, nq, m = codes_q.shape
    nk = codes_k.shape[1]
    # direct integer compare (exact, any E)
    s = jnp.sum(
        (codes_q[:, :, None, :] == codes_k[:, None, :, :]).astype(jnp.int32),
        axis=-1)                                        # (G, nq, nk)
    q_pos = q_offset + jnp.arange(nq, dtype=jnp.int32)
    k_pos = jnp.arange(nk, dtype=jnp.int32)
    valid = sa.attention_mask(q_pos, k_pos, causal, window)[None]
    sm = jnp.where(valid, s, -1)
    counts = jnp.stack([jnp.sum((sm == v).astype(jnp.int32), axis=-1)
                        for v in range(max_score + 1)], axis=-1)
    ge = jnp.cumsum(counts[..., ::-1], axis=-1)[..., ::-1]
    t = jnp.maximum(jnp.sum((ge >= l).astype(jnp.int32), axis=-1) - 1, 0)
    ge_pad = jnp.concatenate([ge, jnp.zeros_like(ge[..., :1])], axis=-1)
    n_above = jnp.take_along_axis(ge_pad, (t + 1)[..., None], axis=-1)[..., 0]
    return jnp.stack([t, l - n_above], axis=-1).astype(jnp.int32)


def decode_thresholds_ref(codes_q: jax.Array, codes_k: jax.Array,
                          kv_valid: jax.Array, *, l: int, max_score: int,
                          sum_rows: bool) -> jax.Array:
    """Decode-shaped oracle: (G, R, M) query codes vs (G, S, M) cached key
    codes under a (B, S) validity mask (G = B * heads) -> (G, R_out, 2).
    sum_rows=True sums the R rows' match counts first ("kvgroup")."""
    g, r, m = codes_q.shape
    nk = codes_k.shape[1]
    b = kv_valid.shape[0]
    s = jnp.sum(
        (codes_q[:, :, None, :] == codes_k[:, None, :, :]).astype(jnp.int32),
        axis=-1)                                        # (G, R, S)
    if sum_rows:
        s = jnp.sum(s, axis=1, keepdims=True)           # (G, 1, S)
    valid = jnp.repeat(kv_valid != 0, g // b, axis=0)[:, None, :]
    sm = jnp.where(valid, s, -1)
    counts = jnp.stack([jnp.sum((sm == v).astype(jnp.int32), axis=-1)
                        for v in range(max_score + 1)], axis=-1)
    ge = jnp.cumsum(counts[..., ::-1], axis=-1)[..., ::-1]
    t = jnp.maximum(jnp.sum((ge >= l).astype(jnp.int32), axis=-1) - 1, 0)
    ge_pad = jnp.concatenate([ge, jnp.zeros_like(ge[..., :1])], axis=-1)
    n_above = jnp.take_along_axis(ge_pad, (t + 1)[..., None], axis=-1)[..., 0]
    return jnp.stack([t, l - n_above], axis=-1).astype(jnp.int32)


def topl_select_ref(codes_q: jax.Array, codes_k: jax.Array, *, l: int,
                    max_score: int, causal: bool, window: Optional[int],
                    q_offset: int = 0) -> Tuple[jax.Array, jax.Array]:
    """Full index emission via core.bucket_select (binary-search compaction)."""
    s = jnp.sum(
        (codes_q[:, :, None, :] == codes_k[:, None, :, :]).astype(jnp.int32),
        axis=-1).astype(jnp.float32)
    nq, nk = s.shape[1], s.shape[2]
    q_pos = q_offset + jnp.arange(nq, dtype=jnp.int32)
    k_pos = jnp.arange(nk, dtype=jnp.int32)
    valid = sa.attention_mask(q_pos, k_pos, causal, window)[None]
    return sa.bucket_select(s, valid, l, max_score)
