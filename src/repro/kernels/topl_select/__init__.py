from repro.kernels.topl_select.ops import topl_select, topl_thresholds  # noqa
