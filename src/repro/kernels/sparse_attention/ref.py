"""Oracle for the fused sparse-attention kernel: the validated jnp path
(PQ assign -> bucket_select -> gather attention), restricted to identical
selection semantics (same thresholds, most-recent-ties-first)."""
from typing import Optional, Tuple

import jax

from repro.core import sparse_attention as sa


def sparse_mha_ref(q, k, v, codebooks, cfg: sa.SparseAttentionConfig,
                   scale: float, causal: bool = True,
                   window: Optional[int] = None, q_offset: int = 0
                   ) -> Tuple[jax.Array, dict]:
    return sa.sparse_mha(q, k, v, codebooks, cfg, scale, causal=causal,
                         window=window, q_offset=q_offset)


def sparse_mha_decode_ref(q, k_cache, v_cache, codes_cache, codebooks,
                          cfg: sa.SparseAttentionConfig, scale: float,
                          kv_valid) -> jax.Array:
    """Oracle for the fused decode kernel: the jnp fallback (bucket_select
    over cached codes -> grouped gather attention), identical selection
    semantics (threshold bucket + most-recent-slot ties)."""
    return sa.sparse_mha_decode(q, k_cache, v_cache, codes_cache, codebooks,
                                cfg, scale, kv_valid)
