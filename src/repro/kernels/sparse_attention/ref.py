"""Oracle for the fused sparse-attention kernel: the validated jnp path
(PQ assign -> bucket_select -> gather attention), restricted to identical
selection semantics (same thresholds, most-recent-ties-first)."""
from typing import Optional, Tuple

import jax

from repro.core import sparse_attention as sa


def sparse_mha_ref(q, k, v, codebooks, cfg: sa.SparseAttentionConfig,
                   scale: float, causal: bool = True,
                   window: Optional[int] = None, q_offset: int = 0
                   ) -> Tuple[jax.Array, dict]:
    return sa.sparse_mha(q, k, v, codebooks, cfg, scale, causal=causal,
                         window=window, q_offset=q_offset)
