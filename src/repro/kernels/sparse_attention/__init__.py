from repro.kernels.sparse_attention.ops import sparse_mha  # noqa: F401
