"""Fused sparse MHA kernel: SDDMM -> masked softmax -> SpMM in one pass
(paper §5.1), reshaped for the TPU memory hierarchy.

The GPU implementation materializes a CSR attention matrix and calls
cuSPARSE SDDMM/SpMM.  On TPU we fuse *through the selection*: for each
(Tq) query tile the kernel streams (Tk) key/value tiles through VMEM —
newest tile first — computes the integer PQ match scores in VREGs, masks
to the top-L-eligible set using the per-query [threshold, tie-budget] from
the bucket-histogram kernel, and folds the surviving logits into an online
(flash-style) softmax accumulator.  Neither the (n, L) index matrix nor any
gathered K/V copy ever exists: HBM traffic is O(n d) per query tile instead
of O(n L d) for the gather formulation (the measured ~60x memory-term gap
in EXPERIMENTS.md §Perf).

Key-tile skip: a tile with no eligible pair skips its MXU work via pl.when
— with top-1/8 sparsity most off-diagonal tiles skip, which is where the
FLOP-side win appears on real hardware.

Grid: (G, nq/Tq, nk/Tk) with the key axis minor (sequential) and REVERSED
so the most-recent-ties-first budget is consumed in order.
Scratch (VMEM, f32): m (Tq,1), l (Tq,1), acc (Tq, dh), ties taken (Tq,1).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret

from repro.kernels.topl_select.topl_select import vmem


def _scores(cq, ck):
    m = cq.shape[1]
    s = jnp.zeros((cq.shape[0], ck.shape[0]), jnp.int32)
    for i in range(m):
        s = s + (cq[:, i][:, None] == ck[:, i][None, :]).astype(jnp.int32)
    return s


def _attn_kernel(q_ref, k_ref, v_ref, cq_ref, ck_ref, thr_ref, o_ref,
                 m_ref, l_ref, acc_ref, tie_ref, *,
                 scale, causal, window, q_offset, tq, tk, nkt):
    qi = pl.program_id(1)
    kj = pl.program_id(2)                 # 0 .. nkt-1, tiles visited newest->oldest
    ki = nkt - 1 - kj                     # actual key-tile index

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        tie_ref[...] = jnp.zeros_like(tie_ref)

    cq = cq_ref[0]
    ck = ck_ref[0]
    s = _scores(cq, ck)                   # (Tq, Tk) int32
    q_pos = q_offset + qi * tq + jax.lax.broadcasted_iota(jnp.int32, (tq,), 0)
    k_pos = ki * tk + jax.lax.broadcasted_iota(jnp.int32, (tk,), 0)
    valid = jnp.ones((tq, tk), bool)
    if causal:
        valid &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        valid &= k_pos[None, :] > q_pos[:, None] - window
    thr = thr_ref[0]                      # (Tq, 2)
    t = thr[:, 0][:, None]
    need = thr[:, 1][:, None]
    sm = jnp.where(valid, s, -1)
    above = sm > t
    at_t = sm == t
    # ties more recent than position b: taken so far + ties right of b in tile
    rev_incl = jnp.cumsum(at_t[:, ::-1].astype(jnp.int32), axis=1)[:, ::-1]
    rev_excl = rev_incl - at_t.astype(jnp.int32)
    taken = tie_ref[:, 0][:, None]
    elig_t = at_t & ((taken + rev_excl) < need)
    eligible = above | elig_t
    tie_ref[:, 0] += jnp.sum(elig_t.astype(jnp.int32), axis=1)

    @pl.when(jnp.any(eligible))
    def _block():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (Tq, Tk)
        logits = jnp.where(eligible, logits, -jnp.inf)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
        finite = m_new > -jnp.inf
        m_safe = jnp.where(finite, m_new, 0.0)
        alpha = jnp.where(finite, jnp.exp(m_prev - m_safe), 1.0)
        p = jnp.where(finite[:, None], jnp.exp(logits - m_safe[:, None]), 0.0)
        p = jnp.where(eligible, p, 0.0)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        m_ref[:, 0] = m_new

    @pl.when(kj == nkt - 1)
    def _finish():
        l = l_ref[:, 0]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
        out = jnp.where((l > 0)[:, None], out, 0.0)
        o_ref[0] = out.astype(o_ref.dtype)


def sparse_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                            codes_q: jax.Array, codes_k: jax.Array,
                            thresholds: jax.Array, *, scale: float,
                            causal: bool, window: Optional[int],
                            q_offset: int = 0, kv_map=None,
                            tile_q: int = 256, tile_k: int = 512,
                            interpret: Optional[bool] = None) -> jax.Array:
    """q: (Gq, nq, dh); k/v/codes_k: (Gk, nk, ...); thresholds: (Gq, nq, 2).

    kv_map: callable mapping a q-group index -> kv-group index (GQA);
    identity if None.
    """
    interpret = resolve_interpret(interpret)
    gq, nq, dh = q.shape
    gk, nk, _ = k.shape
    m = codes_q.shape[-1]
    tq = min(tile_q, nq)
    if nq % tq:
        tq = nq
    tk = min(tile_k, nk)
    if nk % tk:
        tk = nk
    nkt = nk // tk
    kvm = kv_map if kv_map is not None else (lambda g: g)
    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, tq=tq, tk=tk, nkt=nkt)
    return pl.pallas_call(
        kernel,
        grid=(gq, nq // tq, nkt),
        in_specs=[
            pl.BlockSpec((1, tq, dh), lambda g, qi, kj: (g, qi, 0)),
            pl.BlockSpec((1, tk, dh),
                         lambda g, qi, kj: (kvm(g), nkt - 1 - kj, 0)),
            pl.BlockSpec((1, tk, dh),
                         lambda g, qi, kj: (kvm(g), nkt - 1 - kj, 0)),
            pl.BlockSpec((1, tq, m), lambda g, qi, kj: (g, qi, 0)),
            pl.BlockSpec((1, tk, m),
                         lambda g, qi, kj: (kvm(g), nkt - 1 - kj, 0)),
            pl.BlockSpec((1, tq, 2), lambda g, qi, kj: (g, qi, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, dh), lambda g, qi, kj: (g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((gq, nq, dh), q.dtype),
        scratch_shapes=[
            vmem((tq, 1), jnp.float32),
            vmem((tq, 1), jnp.float32),
            vmem((tq, dh), jnp.float32),
            vmem((tq, 1), jnp.int32),
        ],
        interpret=interpret,
    )(q, k, v, codes_q, codes_k, thresholds)


# ---------------------------------------------------------------- decode
def _decode_attn_kernel(q_ref, k_ref, v_ref, cq_ref, ck_ref, thr_ref,
                        valid_ref, o_ref, m_ref, l_ref, acc_ref, tie_ref, *,
                        scale, sum_rows, nkt):
    kj = pl.program_id(1)                 # tiles visited newest slot first

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        tie_ref[...] = jnp.zeros_like(tie_ref)

    cq = cq_ref[0]                        # (R, M)
    ck = ck_ref[0]                        # (Tk, M)
    s = _scores(cq, ck)                   # (R, Tk)
    if sum_rows:                          # kvgroup: one shared selection
        s = jnp.sum(s, axis=0, keepdims=True)         # (1, Tk)
    valid = valid_ref[0] != 0             # (Tk,)
    thr = thr_ref[0]                      # (R_out, 2)
    t = thr[:, 0][:, None]
    need = thr[:, 1][:, None]
    sm = jnp.where(valid[None, :], s, -1)
    above = sm > t
    at_t = sm == t
    # ties more recent (higher slot index) than position b: taken so far in
    # previously visited (newer) tiles + ties right of b inside this tile
    rev_incl = jnp.cumsum(at_t[:, ::-1].astype(jnp.int32), axis=1)[:, ::-1]
    rev_excl = rev_incl - at_t.astype(jnp.int32)
    taken = tie_ref[:, 0][:, None]
    elig_t = at_t & ((taken + rev_excl) < need)
    eligible = above | elig_t             # (R_out, Tk)
    tie_ref[:, 0] += jnp.sum(elig_t.astype(jnp.int32), axis=1)

    @pl.when(jnp.any(eligible))
    def _block():
        q = q_ref[0].astype(jnp.float32)              # (R, dh)
        k = k_ref[0].astype(jnp.float32)              # (Tk, dh)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # (R, Tk)
        logits = jnp.where(eligible, logits, -jnp.inf)        # bcast if kvgroup
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
        finite = m_new > -jnp.inf
        m_safe = jnp.where(finite, m_new, 0.0)
        alpha = jnp.where(finite, jnp.exp(m_prev - m_safe), 1.0)
        p = jnp.where(finite[:, None], jnp.exp(logits - m_safe[:, None]), 0.0)
        p = jnp.where(eligible, p, 0.0)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        m_ref[:, 0] = m_new

    @pl.when(kj == nkt - 1)
    def _finish():
        l = l_ref[:, 0]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
        out = jnp.where((l > 0)[:, None], out, 0.0)
        o_ref[0] = out.astype(o_ref.dtype)


def sparse_decode_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                                   codes_q: jax.Array, codes_k: jax.Array,
                                   thresholds: jax.Array,
                                   kv_valid: jax.Array, *, scale: float,
                                   sum_rows: bool, heads_per_batch: int,
                                   tile_k: int = 512,
                                   interpret: Optional[bool] = None
                                   ) -> jax.Array:
    """Fused single-token sparse-MHA decode (PQ score -> threshold mask ->
    online-softmax attention) over the KV cache, one pass per key tile.

    GQA layout: the R query heads of one kv head ride the sublane axis —
    q/codes_q: (G, R, ...) with G = B*Hk — so key/value/code tiles are
    streamed ONCE per kv group instead of being jnp.repeat-ed per query
    head.  k/v: (G, S, dh); codes_k: (G, S, M).

    thresholds: (G, R_out, 2) [t, need] from decode_topl_thresholds_kernel
    (R_out = 1 under the shared "kvgroup" selection, R per-head).
    kv_valid: (B, S) nonzero = cache slot participates; both plain causal
    caches and ring-buffer sliding-window caches reduce to this mask.

    Key tiles are visited newest-slot-first so the most-recent-ties-first
    budget is consumed in canonical order; tiles with no eligible key skip
    their MXU work via pl.when.  Memory: O(Tk) VMEM tiles + (R, dh)
    accumulators — no (S,) score row ever reaches HBM.
    """
    interpret = resolve_interpret(interpret)
    g, r, dh = q.shape
    _, nk, _ = k.shape
    m = codes_q.shape[-1]
    r_out = thresholds.shape[1]
    tk = min(tile_k, nk)
    if nk % tk:
        tk = nk
    nkt = nk // tk
    hpb = heads_per_batch
    kernel = functools.partial(_decode_attn_kernel, scale=scale,
                               sum_rows=sum_rows, nkt=nkt)
    return pl.pallas_call(
        kernel,
        grid=(g, nkt),
        in_specs=[
            pl.BlockSpec((1, r, dh), lambda gi, kj: (gi, 0, 0)),
            pl.BlockSpec((1, tk, dh), lambda gi, kj: (gi, nkt - 1 - kj, 0)),
            pl.BlockSpec((1, tk, dh), lambda gi, kj: (gi, nkt - 1 - kj, 0)),
            pl.BlockSpec((1, r, m), lambda gi, kj: (gi, 0, 0)),
            pl.BlockSpec((1, tk, m), lambda gi, kj: (gi, nkt - 1 - kj, 0)),
            pl.BlockSpec((1, r_out, 2), lambda gi, kj: (gi, 0, 0)),
            pl.BlockSpec((1, tk), lambda gi, kj: (gi // hpb, nkt - 1 - kj)),
        ],
        out_specs=pl.BlockSpec((1, r, dh), lambda gi, kj: (gi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, r, dh), q.dtype),
        scratch_shapes=[
            vmem((r, 1), jnp.float32),
            vmem((r, 1), jnp.float32),
            vmem((r, dh), jnp.float32),
            vmem((r_out, 1), jnp.int32),
        ],
        interpret=interpret,
    )(q, k, v, codes_q, codes_k, thresholds, kv_valid)
