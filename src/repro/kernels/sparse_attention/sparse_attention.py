"""Fused sparse MHA kernel: SDDMM -> masked softmax -> SpMM in one pass
(paper §5.1), reshaped for the TPU memory hierarchy.

The GPU implementation materializes a CSR attention matrix and calls
cuSPARSE SDDMM/SpMM.  On TPU we fuse *through the selection*: for each
(Tq) query tile the kernel streams (Tk) key/value tiles through VMEM —
newest tile first — computes the integer PQ match scores in VREGs, masks
to the top-L-eligible set using the per-query [threshold, tie-budget] from
the bucket-histogram kernel, and folds the surviving logits into an online
(flash-style) softmax accumulator.  Neither the (n, L) index matrix nor any
gathered K/V copy ever exists: HBM traffic is O(n d) per query tile instead
of O(n L d) for the gather formulation (the measured ~60x memory-term gap
in EXPERIMENTS.md §Perf).

Key-tile skip: a tile with no eligible pair skips its MXU work via pl.when
— with top-1/8 sparsity most off-diagonal tiles skip, which is where the
FLOP-side win appears on real hardware.

Grid: (G, nq/Tq, nk/Tk) with the key axis minor (sequential) and REVERSED
so the most-recent-ties-first budget is consumed in order.
Scratch (VMEM, f32): m (Tq,1), l (Tq,1), acc (Tq, dh), ties taken (Tq,1).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret

from repro.kernels.topl_select.topl_select import (
    hist_counts, hist_reduce, vmem)


def _scores(cq, ck):
    m = cq.shape[1]
    s = jnp.zeros((cq.shape[0], ck.shape[0]), jnp.int32)
    for i in range(m):
        s = s + (cq[:, i][:, None] == ck[:, i][None, :]).astype(jnp.int32)
    return s


def _attn_kernel(q_ref, k_ref, v_ref, cq_ref, ck_ref, thr_ref, o_ref,
                 m_ref, l_ref, acc_ref, tie_ref, *,
                 scale, causal, window, q_offset, tq, tk, nkt):
    qi = pl.program_id(1)
    kj = pl.program_id(2)                 # 0 .. nkt-1, tiles visited newest->oldest
    ki = nkt - 1 - kj                     # actual key-tile index

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        tie_ref[...] = jnp.zeros_like(tie_ref)

    cq = cq_ref[0]
    ck = ck_ref[0]
    s = _scores(cq, ck)                   # (Tq, Tk) int32
    q_pos = q_offset + qi * tq + jax.lax.broadcasted_iota(jnp.int32, (tq,), 0)
    k_pos = ki * tk + jax.lax.broadcasted_iota(jnp.int32, (tk,), 0)
    valid = jnp.ones((tq, tk), bool)
    if causal:
        valid &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        valid &= k_pos[None, :] > q_pos[:, None] - window
    thr = thr_ref[0]                      # (Tq, 2)
    t = thr[:, 0][:, None]
    need = thr[:, 1][:, None]
    sm = jnp.where(valid, s, -1)
    above = sm > t
    at_t = sm == t
    # ties more recent than position b: taken so far + ties right of b in tile
    rev_incl = jnp.cumsum(at_t[:, ::-1].astype(jnp.int32), axis=1)[:, ::-1]
    rev_excl = rev_incl - at_t.astype(jnp.int32)
    taken = tie_ref[:, 0][:, None]
    elig_t = at_t & ((taken + rev_excl) < need)
    eligible = above | elig_t
    tie_ref[:, 0] += jnp.sum(elig_t.astype(jnp.int32), axis=1)

    @pl.when(jnp.any(eligible))
    def _block():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (Tq, Tk)
        logits = jnp.where(eligible, logits, -jnp.inf)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
        finite = m_new > -jnp.inf
        m_safe = jnp.where(finite, m_new, 0.0)
        alpha = jnp.where(finite, jnp.exp(m_prev - m_safe), 1.0)
        p = jnp.where(finite[:, None], jnp.exp(logits - m_safe[:, None]), 0.0)
        p = jnp.where(eligible, p, 0.0)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        m_ref[:, 0] = m_new

    @pl.when(kj == nkt - 1)
    def _finish():
        l = l_ref[:, 0]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
        out = jnp.where((l > 0)[:, None], out, 0.0)
        o_ref[0] = out.astype(o_ref.dtype)


def sparse_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                            codes_q: jax.Array, codes_k: jax.Array,
                            thresholds: jax.Array, *, scale: float,
                            causal: bool, window: Optional[int],
                            q_offset: int = 0, kv_map=None,
                            tile_q: int = 256, tile_k: int = 512,
                            interpret: Optional[bool] = None) -> jax.Array:
    """q: (Gq, nq, dh); k/v/codes_k: (Gk, nk, ...); thresholds: (Gq, nq, 2).

    kv_map: callable mapping a q-group index -> kv-group index (GQA);
    identity if None.
    """
    interpret = resolve_interpret(interpret)
    gq, nq, dh = q.shape
    gk, nk, _ = k.shape
    m = codes_q.shape[-1]
    tq = min(tile_q, nq)
    if nq % tq:
        tq = nq
    tk = min(tile_k, nk)
    if nk % tk:
        tk = nk
    nkt = nk // tk
    kvm = kv_map if kv_map is not None else (lambda g: g)
    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, tq=tq, tk=tk, nkt=nkt)
    return pl.pallas_call(
        kernel,
        grid=(gq, nq // tq, nkt),
        in_specs=[
            pl.BlockSpec((1, tq, dh), lambda g, qi, kj: (g, qi, 0)),
            pl.BlockSpec((1, tk, dh),
                         lambda g, qi, kj: (kvm(g), nkt - 1 - kj, 0)),
            pl.BlockSpec((1, tk, dh),
                         lambda g, qi, kj: (kvm(g), nkt - 1 - kj, 0)),
            pl.BlockSpec((1, tq, m), lambda g, qi, kj: (g, qi, 0)),
            pl.BlockSpec((1, tk, m),
                         lambda g, qi, kj: (kvm(g), nkt - 1 - kj, 0)),
            pl.BlockSpec((1, tq, 2), lambda g, qi, kj: (g, qi, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, dh), lambda g, qi, kj: (g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((gq, nq, dh), q.dtype),
        scratch_shapes=[
            vmem((tq, 1), jnp.float32),
            vmem((tq, 1), jnp.float32),
            vmem((tq, dh), jnp.float32),
            vmem((tq, 1), jnp.int32),
        ],
        interpret=interpret,
    )(q, k, v, codes_q, codes_k, thresholds)


# ---------------------------------------------------------------- decode
def _softmax_init(m_ref, l_ref, acc_ref, tie_ref):
    m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)
    tie_ref[...] = jnp.zeros_like(tie_ref)


def _attend_tile(sm, thr, q_get, k_get, v_get,
                 m_ref, l_ref, acc_ref, tie_ref, *, scale):
    """One newest-first key tile of the thresholded online-softmax decode
    attention — shared verbatim between the two-pass kernel and the fused
    one-pass kernel's phase 2, so the two dispatch tiers stay bit-identical.

    sm: (R_out, Tk) masked scores (-1 = dead slot); thr: (R_out, 2)
    [t, need]; q/k/v_get: thunks returning the (R, dh)/(Tk, dh)/(Tk, dh)
    tiles (deferred so a fully ineligible tile skips the VMEM reads and
    MXU work via pl.when)."""
    t = thr[:, 0][:, None]
    need = thr[:, 1][:, None]
    above = sm > t
    at_t = sm == t
    # ties more recent (higher slot index) than position b: taken so far in
    # previously visited (newer) tiles + ties right of b inside this tile
    rev_incl = jnp.cumsum(at_t[:, ::-1].astype(jnp.int32), axis=1)[:, ::-1]
    rev_excl = rev_incl - at_t.astype(jnp.int32)
    taken = tie_ref[:, 0][:, None]
    elig_t = at_t & ((taken + rev_excl) < need)
    eligible = above | elig_t             # (R_out, Tk)
    tie_ref[:, 0] += jnp.sum(elig_t.astype(jnp.int32), axis=1)

    @pl.when(jnp.any(eligible))
    def _block():
        q = q_get().astype(jnp.float32)               # (R, dh)
        k = k_get().astype(jnp.float32)               # (Tk, dh)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # (R, Tk)
        logits = jnp.where(eligible, logits, -jnp.inf)        # bcast if kvgroup
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
        finite = m_new > -jnp.inf
        m_safe = jnp.where(finite, m_new, 0.0)
        alpha = jnp.where(finite, jnp.exp(m_prev - m_safe), 1.0)
        p = jnp.where(finite[:, None], jnp.exp(logits - m_safe[:, None]), 0.0)
        p = jnp.where(eligible, p, 0.0)
        v = v_get().astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        m_ref[:, 0] = m_new


def _write_out(o_ref, l_ref, acc_ref):
    l = l_ref[:, 0]
    out = acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
    out = jnp.where((l > 0)[:, None], out, 0.0)
    o_ref[0] = out.astype(o_ref.dtype)


def _decode_attn_kernel(q_ref, k_ref, v_ref, cq_ref, ck_ref, thr_ref,
                        valid_ref, o_ref, m_ref, l_ref, acc_ref, tie_ref, *,
                        scale, sum_rows, nkt):
    kj = pl.program_id(1)                 # tiles visited newest slot first

    @pl.when(kj == 0)
    def _init():
        _softmax_init(m_ref, l_ref, acc_ref, tie_ref)

    cq = cq_ref[0]                        # (R, M)
    ck = ck_ref[0]                        # (Tk, M)
    s = _scores(cq, ck)                   # (R, Tk)
    if sum_rows:                          # kvgroup: one shared selection
        s = jnp.sum(s, axis=0, keepdims=True)         # (1, Tk)
    valid = valid_ref[0] != 0             # (Tk,)
    sm = jnp.where(valid[None, :], s, -1)
    _attend_tile(sm, thr_ref[0],
                 lambda: q_ref[0], lambda: k_ref[0], lambda: v_ref[0],
                 m_ref, l_ref, acc_ref, tie_ref, scale=scale)

    @pl.when(kj == nkt - 1)
    def _finish():
        _write_out(o_ref, l_ref, acc_ref)


def sparse_decode_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                                   codes_q: jax.Array, codes_k: jax.Array,
                                   thresholds: jax.Array,
                                   kv_valid: jax.Array, *, scale: float,
                                   sum_rows: bool, heads_per_batch: int,
                                   tile_k: int = 512,
                                   interpret: Optional[bool] = None
                                   ) -> jax.Array:
    """Fused single-token sparse-MHA decode (PQ score -> threshold mask ->
    online-softmax attention) over the KV cache, one pass per key tile.

    GQA layout: the R query heads of one kv head ride the sublane axis —
    q/codes_q: (G, R, ...) with G = B*Hk — so key/value/code tiles are
    streamed ONCE per kv group instead of being jnp.repeat-ed per query
    head.  k/v: (G, S, dh); codes_k: (G, S, M).

    thresholds: (G, R_out, 2) [t, need] from decode_topl_thresholds_kernel
    (R_out = 1 under the shared "kvgroup" selection, R per-head).
    kv_valid: (B, S) nonzero = cache slot participates; both plain causal
    caches and ring-buffer sliding-window caches reduce to this mask.

    Key tiles are visited newest-slot-first so the most-recent-ties-first
    budget is consumed in canonical order; tiles with no eligible key skip
    their MXU work via pl.when.  Memory: O(Tk) VMEM tiles + (R, dh)
    accumulators — no (S,) score row ever reaches HBM.
    """
    interpret = resolve_interpret(interpret)
    g, r, dh = q.shape
    _, nk, _ = k.shape
    m = codes_q.shape[-1]
    r_out = thresholds.shape[1]
    tk = min(tile_k, nk)
    if nk % tk:
        tk = nk
    nkt = nk // tk
    hpb = heads_per_batch
    kernel = functools.partial(_decode_attn_kernel, scale=scale,
                               sum_rows=sum_rows, nkt=nkt)
    return pl.pallas_call(
        kernel,
        grid=(g, nkt),
        in_specs=[
            pl.BlockSpec((1, r, dh), lambda gi, kj: (gi, 0, 0)),
            pl.BlockSpec((1, tk, dh), lambda gi, kj: (gi, nkt - 1 - kj, 0)),
            pl.BlockSpec((1, tk, dh), lambda gi, kj: (gi, nkt - 1 - kj, 0)),
            pl.BlockSpec((1, r, m), lambda gi, kj: (gi, 0, 0)),
            pl.BlockSpec((1, tk, m), lambda gi, kj: (gi, nkt - 1 - kj, 0)),
            pl.BlockSpec((1, r_out, 2), lambda gi, kj: (gi, 0, 0)),
            pl.BlockSpec((1, tk), lambda gi, kj: (gi // hpb, nkt - 1 - kj)),
        ],
        out_specs=pl.BlockSpec((1, r, dh), lambda gi, kj: (gi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, r, dh), q.dtype),
        scratch_shapes=[
            vmem((r, 1), jnp.float32),
            vmem((r, 1), jnp.float32),
            vmem((r, dh), jnp.float32),
            vmem((r_out, 1), jnp.int32),
        ],
        interpret=interpret,
    )(q, k, v, codes_q, codes_k, thresholds, kv_valid)


# ----------------------------------------------- fused one-pass decode
def _decode_scratch(r, r_out, dh):
    """m / l / acc / tie / thr — the fused kernel owns the [t, need] pair
    as VMEM scratch, so the thresholds tensor never round-trips through
    HBM (the histogram itself lives in registers of the first grid step)."""
    return [
        vmem((r, 1), jnp.float32),
        vmem((r, 1), jnp.float32),
        vmem((r, dh), jnp.float32),
        vmem((r_out, 1), jnp.int32),
        vmem((r_out, 2), jnp.int32),
    ]


def _pair_of(nkt: int) -> int:
    """Key tiles folded into one grid step: 2 when the tile count is even
    (one double-width block read, two attention sub-tiles in newest-first
    order — halves the grid without touching the accumulation order), else
    1 (ragged tile counts fall back to the single-tile schedule)."""
    return 2 if nkt > 1 and nkt % 2 == 0 else 1


def _mask_scores(cq, ck, valid, sum_rows):
    """Codes + validity -> (R_out, N) masked match scores (-1 = dead)."""
    s = _scores(cq, ck)
    if sum_rows:
        s = jnp.sum(s, axis=0, keepdims=True)
    return jnp.where(valid[None, :], s, -1)


def _fused_step(kj, q_ref, thresholds, tiles, o_ref, m_ref, l_ref, acc_ref,
                tie_ref, thr_ref, *, scale, sum_rows, nsteps):
    """Shared step body of the fused one-pass decode kernels (contiguous
    and paged).  Step 0 computes the FULL-cache threshold in one shot
    (thresholds(): identical integer math to the standalone threshold
    kernel, so the two-pass tier stays bit-identical) and every step then
    replays its `tiles` — [(masked-score thunk, k thunk, v thunk), ...]
    newest slot first — through the exact two-pass attention body."""
    @pl.when(kj == 0)
    def _init():
        _softmax_init(m_ref, l_ref, acc_ref, tie_ref)
        thr_ref[...] = thresholds()

    for sm_get, k_get, v_get in tiles:
        _attend_tile(sm_get(), thr_ref[...], lambda: q_ref[0], k_get, v_get,
                     m_ref, l_ref, acc_ref, tie_ref, scale=scale)

    @pl.when(kj == nsteps - 1)
    def _finish():
        _write_out(o_ref, l_ref, acc_ref)


def _fused_decode_kernel(q_ref, k_ref, v_ref, cq_ref, ck_ref, valid_ref,
                         o_ref, m_ref, l_ref, acc_ref, tie_ref, thr_ref, *,
                         scale, l, max_score, sum_rows, tk, pair, nsteps):
    kj = pl.program_id(1)
    cq = cq_ref[0]
    ck_all = ck_ref[0]                    # (nk, M) — whole (padded) cache
    valid_all = valid_ref[0] != 0         # (nk,)

    def thresholds():
        sm_all = _mask_scores(cq, ck_all, valid_all, sum_rows)
        return hist_reduce(hist_counts(sm_all, max_score), l)

    base = (nsteps - 1 - kj) * pair       # oldest tile of this step's block

    def tile(h):
        start = (base + h) * tk

        def sm_get():
            ck = jax.lax.dynamic_slice_in_dim(ck_all, start, tk, axis=0)
            valid = jax.lax.dynamic_slice_in_dim(valid_all, start, tk)
            return _mask_scores(cq, ck, valid, sum_rows)

        return (sm_get,
                lambda: k_ref[0, h * tk:(h + 1) * tk],
                lambda: v_ref[0, h * tk:(h + 1) * tk])

    _fused_step(kj, q_ref, thresholds, [tile(h) for h in
                                        reversed(range(pair))],
                o_ref, m_ref, l_ref, acc_ref, tie_ref, thr_ref,
                scale=scale, sum_rows=sum_rows, nsteps=nsteps)


def fused_sparse_decode_attention_kernel(
        q: jax.Array, k: jax.Array, v: jax.Array, codes_q: jax.Array,
        codes_k: jax.Array, kv_valid: jax.Array, *, scale: float, l: int,
        max_score: int, sum_rows: bool, heads_per_batch: int,
        tile_k: int = 512, interpret: Optional[bool] = None) -> jax.Array:
    """One-pass decode: thresholds fused into the attention kernel, and
    the key axis swept at HALF the two-pass grid length.

    The whole (padded) code cache and validity row ride as pinned blocks —
    M int8 lanes per slot vs 2*dh f32/bf16 lanes of K+V, so they are the
    cheap operands — and grid step 0 computes the full score histogram and
    the (R_out, 2) [t, need] thresholds in ONE shot into VMEM scratch: no
    prologue steps, no thresholds HBM round-trip, no second kernel launch.
    Each step then reads one (pair*Tk) K/V block and replays its `pair`
    sub-tiles newest-slot-first through the two-pass kernels' attention
    body, so the eligibility rule, tie budget, and online-softmax
    accumulation ORDER are identical and the output stays bit-identical to
    the two-pass tier (the sweep visits the same Tk tiles in the same
    order — only the number visited per grid step changes).

    Grid (G, nkt / pair), pair = 2 when nkt is even.  vs the two-pass
    pipeline: one launch instead of two, thresholds never exist in HBM,
    and half the grid steps (double-width K/V DMA per step).

    Shapes as sparse_decode_attention_kernel; nk must be a multiple of
    pair*tile_k (the ops wrapper zero-pads, dead slots carry kv_valid=0);
    the pinned codes block keeps VMEM at O(nk*M) int8 — ~64 KB at S=8192.
    """
    interpret = resolve_interpret(interpret)
    g, r, dh = q.shape
    _, nk, _ = k.shape
    m = codes_q.shape[-1]
    r_out = 1 if sum_rows else r
    tk = min(tile_k, nk)
    if nk % tk:
        tk = nk
    nkt = nk // tk
    pair = _pair_of(nkt)
    nsteps = nkt // pair
    hpb = heads_per_batch
    kernel = functools.partial(_fused_decode_kernel, scale=scale, l=l,
                               max_score=max_score, sum_rows=sum_rows,
                               tk=tk, pair=pair, nsteps=nsteps)
    return pl.pallas_call(
        kernel,
        grid=(g, nsteps),
        in_specs=[
            pl.BlockSpec((1, r, dh), lambda gi, kj: (gi, 0, 0)),
            pl.BlockSpec((1, pair * tk, dh),
                         lambda gi, kj: (gi, nsteps - 1 - kj, 0)),
            pl.BlockSpec((1, pair * tk, dh),
                         lambda gi, kj: (gi, nsteps - 1 - kj, 0)),
            pl.BlockSpec((1, r, m), lambda gi, kj: (gi, 0, 0)),
            pl.BlockSpec((1, nk, m), lambda gi, kj: (gi, 0, 0)),
            pl.BlockSpec((1, nk), lambda gi, kj: (gi // hpb, 0)),
        ],
        out_specs=pl.BlockSpec((1, r, dh), lambda gi, kj: (gi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, r, dh), q.dtype),
        scratch_shapes=_decode_scratch(r, r_out, dh),
        interpret=interpret,
    )(q, k, v, codes_q, codes_k, kv_valid)


# ----------------------------------------------- kernel-native paging
def _fused_decode_paged_kernel(pt_ref, q_ref, k_ref, v_ref, cq_ref,
                               ckslab_ref, valid_ref, o_ref, m_ref, l_ref,
                               acc_ref, tie_ref, thr_ref, *, scale, l,
                               max_score, sum_rows, tk, pair, nsteps, hpb,
                               mp, ps):
    kj = pl.program_id(1)
    gi_b = pl.program_id(0) // hpb
    cq = cq_ref[0]
    # Codes + validity ride ONCE as whole-pool-slab / whole-row pinned
    # blocks (M int8 lanes vs 2*dh f32 lanes of K+V — the cheap operands);
    # logical tiles are sliced in-register via the scalar-prefetched page
    # table, never gathered from HBM.
    slab = ckslab_ref[:, 0].astype(jnp.int32)             # (P, ps, M)
    pages = jax.lax.dynamic_slice_in_dim(
        pt_ref[...], gi_b, 1, axis=0)[0]                  # (MP,)
    valid_all = valid_ref[0] != 0                         # (MP*ps,)

    def thresholds():
        ck_all = jnp.take(slab, pages, axis=0).reshape(mp * ps, -1)
        return hist_reduce(hist_counts(
            _mask_scores(cq, ck_all, valid_all, sum_rows), max_score), l)

    base = (nsteps - 1 - kj) * pair       # oldest view tile in this block

    def tile(h):
        vt = base + h                     # logical view tile index

        def sm_get():
            page = jax.lax.dynamic_index_in_dim(pages, vt // (ps // tk),
                                                keepdims=False)
            ck = jax.lax.dynamic_slice(
                slab, (page, (vt % (ps // tk)) * tk, 0),
                (1, tk, slab.shape[-1]))[0]
            valid = jax.lax.dynamic_slice_in_dim(valid_all, vt * tk, tk)
            return _mask_scores(cq, ck, valid, sum_rows)

        return (sm_get,
                lambda: k_ref[0, 0, h * tk:(h + 1) * tk],
                lambda: v_ref[0, 0, h * tk:(h + 1) * tk])

    _fused_step(kj, q_ref, thresholds, [tile(h) for h in
                                        reversed(range(pair))],
                o_ref, m_ref, l_ref, acc_ref, tie_ref, thr_ref,
                scale=scale, sum_rows=sum_rows, nsteps=nsteps)


def fused_sparse_decode_attention_paged_kernel(
        page_table: jax.Array, q: jax.Array, k_pool: jax.Array,
        v_pool: jax.Array, codes_q: jax.Array, codes_pool: jax.Array,
        kv_valid: jax.Array, *, scale: float, l: int, max_score: int,
        sum_rows: bool, heads_per_batch: int, tile_k: int = 512,
        interpret: Optional[bool] = None) -> jax.Array:
    """Fused one-pass decode reading the paged KV pool DIRECTLY: the
    per-slot page table rides as a scalar-prefetch operand and the K/V/code
    BlockSpec index_maps translate each logical view block to
    (page_table[slot, block // blocks_per_page], head, offset) — no
    gathered (B, Hk, S, .) view of the pool ever materializes.

    Thresholds are computed in grid step 0 from the codes POOL SLAB (every
    page of this head's code pool pinned in VMEM — int8, M lanes, so the
    slab is ~2*dh*itemsize/M times smaller than K+V) by gathering the MP
    logical pages in-register via the prefetched table; identical integer
    math to the standalone threshold kernel.  Each step then reads one
    (pair*Tk) K/V block of a single page and replays its sub-tiles
    newest-slot-first through the shared attention body — pair = 2 when
    tiles_per_page is even, so blocks never straddle a page.  Per-tile
    code/validity tiles are sliced in-register from the same pinned slab /
    full row (the cheap operands ride once; only K/V stream per-step).

    page_table: (B, MP) int32, clamped non-negative by the caller (the
    repo-wide convention: unallocated -> page 0, whose garbage rows carry
    kv_valid == 0).  q/codes_q: (G, R, .) with G = B*Hk; pools:
    (P, Hk, page_size, .); kv_valid: (B, MP*page_size) in view coordinates.
    The tile size divides page_size so no tile straddles a page boundary;
    the sweep visits the same Tk tiles in the same newest-first order as
    the contiguous kernel, so given equal tile_k the output is
    bit-identical to running the contiguous fused kernel (or the two-pass
    pair) over the gathered view.
    """
    interpret = resolve_interpret(interpret)
    g, r, dh = q.shape
    _, hk, ps, _ = k_pool.shape
    mp = page_table.shape[1]
    m = codes_q.shape[-1]
    r_out = 1 if sum_rows else r
    tk = min(tile_k, ps)
    if ps % tk:
        tk = ps
    ppt = ps // tk                        # tiles per page
    pair = _pair_of(ppt)                  # pairs never straddle a page
    nsteps = (mp * ps) // (pair * tk)
    bpp = ppt // pair                     # (pair*tk)-blocks per page
    hpb = heads_per_batch
    num_pages = k_pool.shape[0]
    kernel = functools.partial(_fused_decode_paged_kernel, scale=scale, l=l,
                               max_score=max_score, sum_rows=sum_rows,
                               tk=tk, pair=pair, nsteps=nsteps, hpb=hpb,
                               mp=mp, ps=ps)

    def pool_idx(gi, kj, pt):             # newest-first view block -> pool
        bt = nsteps - 1 - kj
        return (pt[gi // hpb, bt // bpp], gi % hpb, bt % bpp, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g, nsteps),
        in_specs=[
            pl.BlockSpec((1, r, dh), lambda gi, kj, pt: (gi, 0, 0)),
            pl.BlockSpec((1, 1, pair * tk, dh), pool_idx),
            pl.BlockSpec((1, 1, pair * tk, dh), pool_idx),
            pl.BlockSpec((1, r, m), lambda gi, kj, pt: (gi, 0, 0)),
            pl.BlockSpec((num_pages, 1, ps, m),
                         lambda gi, kj, pt: (0, gi % hpb, 0, 0)),
            pl.BlockSpec((1, mp * ps), lambda gi, kj, pt: (gi // hpb, 0)),
        ],
        out_specs=pl.BlockSpec((1, r, dh), lambda gi, kj, pt: (gi, 0, 0)),
        scratch_shapes=_decode_scratch(r, r_out, dh),
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, r, dh), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), q, k_pool, v_pool, codes_q, codes_pool,
      kv_valid)


def _dense_decode_paged_kernel(pt_ref, q_ref, k_ref, v_ref, valid_ref, o_ref,
                               m_ref, l_ref, acc_ref, *, scale, nkt):
    del pt_ref
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = valid_ref[0] != 0             # (Tk,)

    @pl.when(jnp.any(valid))
    def _block():
        q = q_ref[0].astype(jnp.float32)              # (R, dh)
        k = k_ref[0, 0].astype(jnp.float32)           # (Tk, dh)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        logits = jnp.where(valid[None, :], logits, -jnp.inf)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
        finite = m_new > -jnp.inf
        m_safe = jnp.where(finite, m_new, 0.0)
        alpha = jnp.where(finite, jnp.exp(m_prev - m_safe), 1.0)
        p = jnp.where(finite[:, None], jnp.exp(logits - m_safe[:, None]), 0.0)
        p = jnp.where(valid[None, :], p, 0.0)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        m_ref[:, 0] = m_new

    @pl.when(kj == nkt - 1)
    def _finish():
        _write_out(o_ref, l_ref, acc_ref)


def dense_decode_attention_paged_kernel(
        page_table: jax.Array, q: jax.Array, k_pool: jax.Array,
        v_pool: jax.Array, kv_valid: jax.Array, *, scale: float,
        heads_per_batch: int, tile_k: int = 512,
        interpret: Optional[bool] = None) -> jax.Array:
    """Dense single-token decode attention over the paged KV pool with the
    same scalar-prefetched (page_id, offset) tile addressing as the sparse
    paged kernel — the dense serving route also stops paying the per-step
    gather.  Online softmax over valid slots; dead/garbage rows masked to
    -inf.  Tiles stream forward (no tie budget, so order is free).
    """
    interpret = resolve_interpret(interpret)
    g, r, dh = q.shape
    _, hk, ps, _ = k_pool.shape
    mp = page_table.shape[1]
    tk = min(tile_k, ps)
    if ps % tk:
        tk = ps
    ppt = ps // tk
    nkt = (mp * ps) // tk
    hpb = heads_per_batch
    kernel = functools.partial(_dense_decode_paged_kernel, scale=scale,
                               nkt=nkt)

    def pool_idx(gi, kj, pt):
        return (pt[gi // hpb, kj // ppt], gi % hpb, kj % ppt, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g, nkt),
        in_specs=[
            pl.BlockSpec((1, r, dh), lambda gi, kj, pt: (gi, 0, 0)),
            pl.BlockSpec((1, 1, tk, dh), pool_idx),
            pl.BlockSpec((1, 1, tk, dh), pool_idx),
            pl.BlockSpec((1, tk), lambda gi, kj, pt: (gi // hpb, kj)),
        ],
        out_specs=pl.BlockSpec((1, r, dh), lambda gi, kj, pt: (gi, 0, 0)),
        scratch_shapes=[
            vmem((r, 1), jnp.float32),
            vmem((r, 1), jnp.float32),
            vmem((r, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, r, dh), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), q, k_pool, v_pool, kv_valid)
