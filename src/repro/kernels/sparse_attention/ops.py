"""Public fused sparse MHA ops: Pallas forward, ref (jnp) backward.

Train/prefill (`sparse_mha`): pq_assign kernel + bucket-histogram kernel +
fused attention kernel.  Backward differentiates the reference
implementation, which selects the identical top-L set (same integer
thresholds and tie rule), so the gradient is consistent with the fused
forward up to float associativity — the same contract the paper's unit
tests check (§A.2, Figure 11).

Serving decode (`sparse_mha_decode`): decode-threshold kernel + fused
single-token attention kernel over the KV cache; inference-only, no VJP.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import pq
from repro.core import sparse_attention as sa
from repro.kernels import resolve_interpret
from repro.kernels.pq_quantize.ops import pq_assign
from repro.kernels.sparse_attention.sparse_attention import (
    dense_decode_attention_paged_kernel, fused_sparse_decode_attention_kernel,
    fused_sparse_decode_attention_paged_kernel, sparse_attention_kernel,
    sparse_decode_attention_kernel)
from repro.kernels.topl_select.topl_select import (
    decode_topl_thresholds_kernel, topl_thresholds_kernel)


def _fused_forward(q, k, v, codebooks, cfg: sa.SparseAttentionConfig,
                   scale, causal, window, q_offset, interpret):
    b, hq, nq, dh = q.shape
    _, hk, nk, _ = k.shape
    r = hq // hk
    l = sa.top_l(nk, cfg, window)
    codes_q = pq_assign(q, codebooks, interpret=interpret)
    codes_k = pq_assign(k, codebooks, interpret=interpret)
    qf = q.reshape(b * hq, nq, dh)
    kf = k.reshape(b * hk, nk, dh)
    vf = v.reshape(b * hk, nk, dh)
    cqf = codes_q.reshape(b * hq, nq, -1)
    ckf = codes_k.reshape(b * hk, nk, -1)

    def kv_map(g):  # q group (b*Hq + h) -> kv group (b*Hk + h // r)
        return (g // hq) * hk + (g % hq) // r

    # PQ codes per q-head against its kv head's codes -> thresholds
    ck_for_q = jnp.repeat(codes_k, r, axis=1).reshape(b * hq, nk, -1)
    thr = topl_thresholds_kernel(
        cqf, ck_for_q, l=l, max_score=cfg.pq.num_books, causal=causal,
        window=window, q_offset=q_offset, tile_q=min(cfg.chunk_q, nq),
        interpret=interpret)
    out = sparse_attention_kernel(
        qf, kf, vf, cqf, ckf, thr, scale=scale, causal=causal, window=window,
        q_offset=q_offset, kv_map=kv_map, tile_q=min(cfg.chunk_q, nq),
        interpret=interpret)
    return out.reshape(b, hq, nq, dh)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def _sparse_mha_op(q, k, v, codebooks, cfg, scale, causal, window, q_offset,
                   interpret):
    return _fused_forward(q, k, v, codebooks, cfg, scale, causal, window,
                          q_offset, interpret)


def _fwd(q, k, v, codebooks, cfg, scale, causal, window, q_offset, interpret):
    out = _fused_forward(q, k, v, codebooks, cfg, scale, causal, window,
                         q_offset, interpret)
    return out, (q, k, v, codebooks)


def _bwd(cfg, scale, causal, window, q_offset, interpret, res, g):
    q, k, v, codebooks = res

    def ref(q_, k_, v_, cb_):
        out, _ = sa.sparse_mha(q_, k_, v_, cb_, cfg, scale, causal=causal,
                               window=window, q_offset=q_offset)
        return out

    _, vjp = jax.vjp(ref, q, k, v, codebooks)
    return vjp(g)


_sparse_mha_op.defvjp(_fwd, _bwd)


def sparse_mha(q, k, v, codebooks, cfg: sa.SparseAttentionConfig,
               scale: float, causal: bool = True,
               window: Optional[int] = None, q_offset: int = 0,
               interpret: Optional[bool] = None
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Drop-in replacement for core.sparse_attention.sparse_mha.

    interpret=None derives from the backend (resolved here, before the
    custom_vjp, so forward and backward agree on the mode)."""
    out = _sparse_mha_op(q, k, v, codebooks, cfg, scale, causal, window,
                         q_offset, resolve_interpret(interpret))
    aux = {"l": jnp.asarray(sa.top_l(k.shape[2], cfg, window), jnp.int32)}
    if cfg.qerr_loss_weight > 0:
        aux["qerr"] = (pq.quantization_error(q, codebooks)
                       + pq.quantization_error(k, codebooks))
    return out, aux


@functools.partial(jax.jit, static_argnames=("cfg", "scale", "tile_k",
                                             "interpret", "fuse"))
def sparse_mha_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                      codes_cache: jax.Array, codebooks: jax.Array,
                      cfg: sa.SparseAttentionConfig, scale: float,
                      kv_valid: jax.Array, *, tile_k: int = 512,
                      interpret: Optional[bool] = None,
                      fuse: bool = True) -> jax.Array:
    """Drop-in replacement for core.sparse_attention.sparse_mha_decode.

    fuse=True (default): ONE Pallas kernel — grid step 0 derives the
    [t, need] thresholds from the whole code cache (pinned int8 codes
    block; one-shot histogram, same integer math as the standalone
    threshold kernel) straight into VMEM scratch, and the attention sweep
    pairs key tiles two-per-step, so the launch count, the thresholds HBM
    round-trip, AND half the grid steps disappear.  fuse=False: the
    original two-pass pipeline (decode-threshold kernel + attention
    kernel), kept as the bisection / fallback tier; both tiers produce
    bit-identical output (they share the attention-tile body and visit
    the same key tiles in the same newest-first order).

    q: (B, Hq, 1, d); caches: (B, Hk, S, d); codes_cache: (B, Hk, S, M);
    kv_valid: (B, S) bool.  Inference-only — no VJP (the jnp fallback stays
    the oracle; tests/test_sparse_decode.py asserts parity).
    interpret=None derives the mode from the backend (compiled on TPU,
    interpreter elsewhere), so the serving path needs no plumbing.

    The 1-token query codes are assigned on the jnp path (O(B*Hq*M*E), far
    below kernel-launch granularity and bit-identical to the fallback's);
    all O(S) work — code matching, threshold histogram, attention — runs in
    Pallas, with the R query heads of each kv group packed on the sublane
    axis so no cache tensor is repeated across query heads.

    A cache length that is not a multiple of tile_k is zero-padded up to
    one — and, on the fused tier, up to an EVEN tile count so the kernel
    can pair tiles (padded slots carry kv_valid=0, which the selection
    treats exactly like any dead slot; dead tiles leave every accumulator
    untouched, so the tiers stay bit-identical across their different pad
    lengths) — keeping the kernels' Tk tiling at arbitrary serving
    max_len.
    """
    interpret = resolve_interpret(interpret)
    b, hq, _, d = q.shape
    _, hk, s, _ = k_cache.shape
    r = hq // hk
    m = codebooks.shape[0]
    l = sa.top_l(s, cfg, None)
    sum_rows = cfg.select_granularity == "kvgroup"
    max_score = cfg.pq.num_books * (r if sum_rows else 1)
    codes_q = pq.assign(q, codebooks)                     # (B, Hq, 1, M)
    cqg = codes_q.reshape(b * hk, r, m)
    ckg = codes_cache.astype(jnp.int32).reshape(b * hk, s, m)
    qg = q.reshape(b * hk, r, d)
    kg = k_cache.reshape(b * hk, s, d)
    vg = v_cache.reshape(b * hk, s, d)
    kvv = kv_valid.astype(jnp.int32)                      # (B, S)
    tk = min(tile_k, s)
    ntile = -(-s // tk)
    if fuse and ntile > 1 and ntile % 2:
        ntile += 1          # fused kernel pairs key tiles: even tile count
    pad = ntile * tk - s
    if pad:
        zkv = ((0, 0), (0, pad), (0, 0))
        kg, vg, ckg = (jnp.pad(t, zkv) for t in (kg, vg, ckg))
        kvv = jnp.pad(kvv, ((0, 0), (0, pad)))            # padded -> invalid
    if fuse:
        out = fused_sparse_decode_attention_kernel(
            qg, kg, vg, cqg, ckg, kvv, scale=scale, l=l,
            max_score=max_score, sum_rows=sum_rows, heads_per_batch=hk,
            tile_k=tk, interpret=interpret)
        return out.reshape(b, hq, 1, d)
    thr = decode_topl_thresholds_kernel(
        cqg, ckg, kvv, l=l, max_score=max_score, sum_rows=sum_rows,
        heads_per_batch=hk, tile_k=tk, interpret=interpret)
    out = sparse_decode_attention_kernel(
        qg, kg, vg, cqg, ckg, thr, kvv, scale=scale, sum_rows=sum_rows,
        heads_per_batch=hk, tile_k=tk, interpret=interpret)
    return out.reshape(b, hq, 1, d)


@functools.partial(jax.jit, static_argnames=("cfg", "scale", "tile_k",
                                             "interpret"))
def sparse_mha_decode_paged(q: jax.Array, k_pool: jax.Array,
                            v_pool: jax.Array, codes_pool: jax.Array,
                            codebooks: jax.Array,
                            cfg: sa.SparseAttentionConfig, scale: float,
                            kv_valid: jax.Array, page_table: jax.Array, *,
                            tile_k: int = 512,
                            interpret: Optional[bool] = None) -> jax.Array:
    """Paged-pool counterpart of ``sparse_mha_decode``: the fused one-pass
    kernel reads K/V/code tiles straight out of the global page pools via
    the scalar-prefetched page table — no gathered (B, Hk, S, .) view is
    ever built, so per-step HBM traffic drops from pool-gather + kernel
    read to the kernel read alone.

    q: (B, Hq, 1, d); pools: (num_pages, Hk, page_size, .); page_table:
    (B, MP) int32 with -1 = unallocated (clamped to page 0 here — the
    repo-wide convention; those garbage rows carry kv_valid == 0);
    kv_valid: (B, MP*page_size) bool in view coordinates.  The top-L
    budget is computed over the view length, matching the gathered-view
    path exactly; with equal tile_k the output is bit-identical to
    ``sparse_mha_decode`` over ``kv_pages.gather_pages`` views.
    The view length is a page multiple, so no padding is ever needed.
    """
    interpret = resolve_interpret(interpret)
    b, hq, _, d = q.shape
    _, hk, ps, _ = k_pool.shape
    mp = page_table.shape[1]
    view = mp * ps
    r = hq // hk
    m = codebooks.shape[0]
    l = sa.top_l(view, cfg, None)
    sum_rows = cfg.select_granularity == "kvgroup"
    max_score = cfg.pq.num_books * (r if sum_rows else 1)
    codes_q = pq.assign(q, codebooks)                     # (B, Hq, 1, M)
    cqg = codes_q.reshape(b * hk, r, m)
    qg = q.reshape(b * hk, r, d)
    kvv = kv_valid.astype(jnp.int32)                      # (B, MP*ps)
    pt = jnp.maximum(page_table, 0)
    out = fused_sparse_decode_attention_paged_kernel(
        pt, qg, k_pool, v_pool, cqg, codes_pool, kvv, scale=scale, l=l,
        max_score=max_score, sum_rows=sum_rows, heads_per_batch=hk,
        tile_k=tile_k, interpret=interpret)
    return out.reshape(b, hq, 1, d)


@functools.partial(jax.jit, static_argnames=("scale", "tile_k", "interpret"))
def dense_mha_decode_paged(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, scale: float,
                           kv_valid: jax.Array, page_table: jax.Array, *,
                           tile_k: int = 512,
                           interpret: Optional[bool] = None) -> jax.Array:
    """Dense decode attention straight off the paged KV pool (same
    (page_id, offset) scalar-prefetch addressing as the sparse route) —
    online softmax over the valid view slots, GQA query heads packed on
    the sublane axis.  q: (B, Hq, 1, d); pools: (num_pages, Hk, ps, d);
    kv_valid: (B, MP*ps); page_table: (B, MP) int32 (-1 clamped here)."""
    interpret = resolve_interpret(interpret)
    b, hq, _, d = q.shape
    _, hk, _, _ = k_pool.shape
    r = hq // hk
    qg = q.reshape(b * hk, r, d)
    kvv = kv_valid.astype(jnp.int32)
    pt = jnp.maximum(page_table, 0)
    out = dense_decode_attention_paged_kernel(
        pt, qg, k_pool, v_pool, kvv, scale=scale, heads_per_batch=hk,
        tile_k=tile_k, interpret=interpret)
    return out.reshape(b, hq, 1, d)
