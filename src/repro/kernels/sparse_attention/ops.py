"""Public fused sparse MHA op: Pallas forward, ref (jnp) backward.

Forward = pq_assign kernel + bucket-histogram kernel + fused attention
kernel.  Backward differentiates the reference implementation, which selects
the identical top-L set (same integer thresholds and tie rule), so the
gradient is consistent with the fused forward up to float associativity —
the same contract the paper's unit tests check (§A.2, Figure 11).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import sparse_attention as sa
from repro.kernels.pq_quantize.ops import pq_assign
from repro.kernels.sparse_attention.sparse_attention import \
    sparse_attention_kernel
from repro.kernels.topl_select.topl_select import topl_thresholds_kernel


def _fused_forward(q, k, v, codebooks, cfg: sa.SparseAttentionConfig,
                   scale, causal, window, q_offset, interpret):
    b, hq, nq, dh = q.shape
    _, hk, nk, _ = k.shape
    r = hq // hk
    l = sa.top_l(nk, cfg, window)
    codes_q = pq_assign(q, codebooks, interpret=interpret)
    codes_k = pq_assign(k, codebooks, interpret=interpret)
    qf = q.reshape(b * hq, nq, dh)
    kf = k.reshape(b * hk, nk, dh)
    vf = v.reshape(b * hk, nk, dh)
    cqf = codes_q.reshape(b * hq, nq, -1)
    ckf = codes_k.reshape(b * hk, nk, -1)

    def kv_map(g):  # q group (b*Hq + h) -> kv group (b*Hk + h // r)
        return (g // hq) * hk + (g % hq) // r

    # PQ codes per q-head against its kv head's codes -> thresholds
    ck_for_q = jnp.repeat(codes_k, r, axis=1).reshape(b * hq, nk, -1)
    thr = topl_thresholds_kernel(
        cqf, ck_for_q, l=l, max_score=cfg.pq.num_books, causal=causal,
        window=window, q_offset=q_offset, tile_q=min(cfg.chunk_q, nq),
        interpret=interpret)
    out = sparse_attention_kernel(
        qf, kf, vf, cqf, ckf, thr, scale=scale, causal=causal, window=window,
        q_offset=q_offset, kv_map=kv_map, tile_q=min(cfg.chunk_q, nq),
        interpret=interpret)
    return out.reshape(b, hq, nq, dh)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def _sparse_mha_op(q, k, v, codebooks, cfg, scale, causal, window, q_offset,
                   interpret):
    return _fused_forward(q, k, v, codebooks, cfg, scale, causal, window,
                          q_offset, interpret)


def _fwd(q, k, v, codebooks, cfg, scale, causal, window, q_offset, interpret):
    out = _fused_forward(q, k, v, codebooks, cfg, scale, causal, window,
                         q_offset, interpret)
    return out, (q, k, v, codebooks)


def _bwd(cfg, scale, causal, window, q_offset, interpret, res, g):
    q, k, v, codebooks = res

    def ref(q_, k_, v_, cb_):
        out, _ = sa.sparse_mha(q_, k_, v_, cb_, cfg, scale, causal=causal,
                               window=window, q_offset=q_offset)
        return out

    _, vjp = jax.vjp(ref, q, k, v, codebooks)
    return vjp(g)


_sparse_mha_op.defvjp(_fwd, _bwd)


def sparse_mha(q, k, v, codebooks, cfg: sa.SparseAttentionConfig,
               scale: float, causal: bool = True,
               window: Optional[int] = None, q_offset: int = 0,
               interpret: bool = True
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Drop-in replacement for core.sparse_attention.sparse_mha."""
    out = _sparse_mha_op(q, k, v, codebooks, cfg, scale, causal, window,
                         q_offset, interpret)
    aux = {"l": jnp.asarray(sa.top_l(k.shape[2], cfg, window), jnp.int32)}
    if cfg.qerr_loss_weight > 0:
        from repro.core import pq as pq_core
        aux["qerr"] = (pq_core.quantization_error(q, codebooks)
                       + pq_core.quantization_error(k, codebooks))
    return out, aux
