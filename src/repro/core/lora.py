"""LoRA: low-rank adaptation (paper §2.2, Eq. 5).

Y = X W + s * (X B) C     with W frozen, B in R^{d x r}, C in R^{r x h}.

B is normal-initialized, C zero-initialized so fine-tuning starts from the
pre-trained function exactly (standard LoRA init; s = alpha / r).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.params import ParamDef


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    rank: int = 16
    alpha: float = 16.0
    enabled: bool = True

    @property
    def scale(self) -> float:
        return self.alpha / max(1, self.rank)


def param_defs(d_in: int, d_out: int, cfg: LoRAConfig,
               in_axis: Optional[str] = None,
               out_axis: Optional[str] = None) -> dict:
    """LoRA adapter defs for a (d_in, d_out) projection.

    The B side carries the input sharding, the C side the output sharding,
    so TP placement matches the frozen weight it adapts.
    """
    return {
        "b": ParamDef((d_in, cfg.rank), jnp.float32,
                      (in_axis, "lora_rank"), init="fan_in", trainable=True),
        "c": ParamDef((cfg.rank, d_out), jnp.float32,
                      ("lora_rank", out_axis), init="zeros", trainable=True),
    }


def linear_defs(d_in: int, d_out: int, cfg: LoRAConfig,
                in_axis: Optional[str] = None,
                out_axis: Optional[str] = None,
                base_init: str = "fan_in",
                dtype=jnp.bfloat16) -> dict:
    """A frozen base projection + its LoRA adapter."""
    out = {
        "w": ParamDef((d_in, d_out), dtype, (in_axis, out_axis),
                      init=base_init, trainable=False),
    }
    if cfg.enabled:
        out["lora"] = param_defs(d_in, d_out, cfg, in_axis, out_axis)
    return out


def apply_lora(x: jax.Array, lora: dict, scale: float) -> jax.Array:
    """s * (x B) C — computed narrow-first so FLOPs stay O(n d r)."""
    xb = jnp.einsum("...d,dr->...r", x, lora["b"].astype(x.dtype))
    return scale * jnp.einsum("...r,rh->...h", xb, lora["c"].astype(x.dtype))


def linear(x: jax.Array, p: dict, cfg: LoRAConfig) -> jax.Array:
    """Y = X W (+ LoRA delta). W is frozen — stop_gradient keeps the
    backward graph free of dW even if the optimizer would mask it anyway."""
    w = jax.lax.stop_gradient(p["w"])
    y = jnp.einsum("...d,dh->...h", x, w.astype(x.dtype))
    if cfg.enabled and "lora" in p:
        y = y + apply_lora(x, p["lora"], cfg.scale)
    return y


def merge(p: dict, cfg: LoRAConfig) -> jax.Array:
    """W' = W + s B C — inference-time merge (paper §2.2)."""
    w = p["w"].astype(jnp.float32)
    if cfg.enabled and "lora" in p:
        w = w + cfg.scale * (p["lora"]["b"] @ p["lora"]["c"])
    return w.astype(p["w"].dtype)
