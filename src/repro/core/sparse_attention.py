"""Sparse multi-head attention (paper §4.1, Algorithm 1).

Pipeline per attention layer:
  1. quantize Q and K with the layer's PQ codebooks      (core/pq.py)
  2. integer match-count scores s(q,k) in [0, M]          (Eq. 6)
  3. select the top-L keys per query under the attention
     mask (causal and/or sliding-window)
  4. attention restricted to the selected pairs, softmax
     renormalized over the L selected keys               (revised softmax)

TPU adaptation (DESIGN.md §2): the GPU CSR SDDMM/SpMM pair becomes a
fixed-L gather + dense MXU compute.  The selection is *exactly L per row*
(structurally rectangular sparsity), so the (n, L) index matrix is the CSR
``Indices`` array with an implicit ``Indptr = [0, L, 2L, ...]``.

Canonical tie-break (shared with the Pallas kernels so index sets match
bit-exactly): prefer higher score, then the more recent key (higher index).

Everything here is pure jnp — memory-bounded by chunking the query axis —
and doubles as the oracle for kernels/sparse_attention.  The fused Pallas
kernel is selected with attn_impl="pallas" in the model layer.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import pq


@dataclasses.dataclass(frozen=True)
class SparseAttentionConfig:
    pq: pq.PQConfig
    top_fraction: float = 0.125    # L = top_fraction * n (paper default 1/8)
    min_l: int = 16                # floor so tiny test shapes stay sane
    pad_l_to: int = 1              # pad L up (128 on TPU for MXU alignment)
    chunk_q: int = 256             # query-chunk for score/gather streaming
    select_granularity: str = "qhead"  # "qhead" (faithful) | "kvgroup" (GQA opt)
    qerr_loss_weight: float = 0.0  # optional DKM quantization-error aux loss


def top_l(seq_len: int, cfg: SparseAttentionConfig,
          window: Optional[int] = None) -> int:
    """L for a given sequence length (bounded by the SWA window if any)."""
    horizon = seq_len if window is None else min(seq_len, window)
    l = max(cfg.min_l, int(round(horizon * cfg.top_fraction)))
    l = -(-l // cfg.pad_l_to) * cfg.pad_l_to
    return min(l, horizon)


def top_l_dyn(horizon: jax.Array, cfg: SparseAttentionConfig,
              window: Optional[int] = None) -> jax.Array:
    """Traced counterpart of ``top_l`` for per-row lengths (B,) int32 —
    batched ragged prefill gives every row the selection budget its exact
    length would have had.  Matches the host formula bit-for-bit when
    ``top_fraction`` is exactly representable in float32 (jnp.round and
    Python round are both half-to-even); every config in the repo uses
    dyadic fractions."""
    h = jnp.asarray(horizon, jnp.int32)
    if window is not None:
        h = jnp.minimum(h, window)
    l = jnp.maximum(cfg.min_l, jnp.round(
        h.astype(jnp.float32) * cfg.top_fraction).astype(jnp.int32))
    l = -(-l // cfg.pad_l_to) * cfg.pad_l_to
    return jnp.minimum(l, h)


def _combined_score(scores: jax.Array, key_pos: jax.Array,
                    mask: jax.Array, nk: int) -> jax.Array:
    """Fold the tie-break into one sortable f32: score*nk + key_index.
    Exact for score*nk + j < 2^24 (checked by callers' shapes)."""
    comb = scores * float(nk) + key_pos.astype(jnp.float32)
    neg = jnp.asarray(-1.0, jnp.float32)  # any masked value < 0 works
    return jnp.where(mask, comb, neg)


def select_topl(scores: jax.Array, l: int, mask: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """Top-L selection with canonical tie-break (sort-based reference).

    scores: (..., nq, nk) f32 integer-valued; mask: (..., nq, nk) bool
    returns indices (..., nq, L) int32, valid (..., nq, L) bool
    """
    nk = scores.shape[-1]
    key_pos = jnp.arange(nk, dtype=jnp.int32)
    comb = _combined_score(scores, key_pos, mask, nk)
    top, idx = jax.lax.top_k(comb, l)
    return idx.astype(jnp.int32), top >= 0.0


def bucket_select(scores: jax.Array, valid: jax.Array, l: int,
                  max_score: int, l_dyn: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Sort-free top-L: the paper's bucket-sort (Algorithm 3) in vector form.

    scores: (..., nk) integer-valued in [0, max_score]; valid: (..., nk).
    Selected set == select_topl's (score desc, then most-recent key); the
    emitted index order is ascending key position.

    Twice TPU-native: the integer bucket trick avoids float sort (paper's
    GPU motivation) AND stays partition-friendly under SPMD — lax.top_k
    lowers to a sort that forces an all-gather of the (.., nq, nk) score
    tensor (measured: 17 GB/device at grok scale), while this form is
    histograms + cumsums, all elementwise along the key axis.

    l_dyn: optional traced budgets broadcastable to scores.shape[:-1]
    (e.g. (B, 1, 1) per-row budgets for ragged prefill); must be <= l,
    which stays the static output width.
    Returns (idx (..., L) int32 ascending, sel_valid (..., L) bool).
    """
    s = jnp.where(valid, scores.astype(jnp.int32), -1)
    nk = s.shape[-1]
    budget = jnp.asarray(l if l_dyn is None else l_dyn, jnp.int32)
    counts = jnp.stack([jnp.sum((s == v).astype(jnp.int32), axis=-1)
                        for v in range(max_score + 1)], axis=-1)
    ge = jnp.cumsum(counts[..., ::-1], axis=-1)[..., ::-1]  # #(s >= v)
    meets = (ge >= budget[..., None]).astype(jnp.int32)  # non-increasing in v
    t = jnp.maximum(jnp.sum(meets, axis=-1) - 1, 0)         # threshold bucket
    ge_pad = jnp.concatenate([ge, jnp.zeros_like(ge[..., :1])], axis=-1)
    n_above = jnp.take_along_axis(ge_pad, (t + 1)[..., None], axis=-1)[..., 0]
    need_at_t = budget - n_above
    above = s > t[..., None]
    at_t = s == t[..., None]
    rev_rank = jnp.cumsum(at_t[..., ::-1].astype(jnp.int32),
                          axis=-1)[..., ::-1]    # 1 = most recent tie
    eligible = above | (at_t & (rev_rank <= need_at_t[..., None]))
    cs = jnp.cumsum(eligible.astype(jnp.int32), axis=-1)
    n_sel = cs[..., -1]
    # Compact eligible positions into L slots WITHOUT a scatter: slot i holds
    # the (i+1)-th set bit of `eligible` = binary search over the cumsum.
    # Batched take_along_axis gathers keep every lead dim sharded (a
    # flatten+scatter formulation materializes a (rows, nk) iota and drops
    # the head sharding — 51 GB/device at grok scale).
    targets = jnp.arange(1, l + 1, dtype=jnp.int32)      # (L,)
    lo = jnp.zeros((*s.shape[:-1], l), jnp.int32)
    hi = jnp.full_like(lo, nk)
    steps = max(1, nk.bit_length())   # ceil(log2(nk + 1)) search iterations
    for _ in range(steps):
        mid = (lo + hi) // 2
        cs_mid = jnp.take_along_axis(cs, jnp.minimum(mid, nk - 1), axis=-1)
        go_right = cs_mid < targets
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
    idx = jnp.minimum(lo, nk - 1).astype(jnp.int32)
    sel_valid = targets <= n_sel[..., None]
    return idx, sel_valid


def attention_mask(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
                   window: Optional[int]) -> jax.Array:
    """(nq, nk) bool validity mask built from positions (never materialize
    an (n, n) mask at full sequence — callers pass chunked q_pos)."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


def attention_from_indices(q: jax.Array, k: jax.Array, v: jax.Array,
                           indices: jax.Array, valid: jax.Array,
                           scale: float) -> jax.Array:
    """Gather-based sparse attention (SDDMM -> softmax -> SpMM analogue).

    q: (B, Hq, nq, d); k, v: (B, Hk, nk, d); Hq = R * Hk
    indices/valid: (B, Hq, nq, L) — key positions per *query head* (the
    layout stays query-head-major throughout so TP sharding of Hq never has
    to split the (Hk, R) product across the mesh axis).
    """
    from repro.sharding import shard
    b, hq, nq, d = q.shape
    _, hk, nk, _ = k.shape
    r = hq // hk
    l = indices.shape[-1]
    # Repeat KV to query heads, then take_along_axis with (B, Hq) as true
    # batch dims: both the forward gather AND its VJP scatter stay batched,
    # so SPMD keeps batch+head sharding in both directions.  (Flattening Hq
    # into the gather row merges a sharded dim and replicates the backward
    # scatter indices — 206 GB/device at grok scale; see §Dry-run calib.)
    k_rep = shard(jnp.repeat(k, r, axis=1), "batch", "heads", None, None)
    v_rep = shard(jnp.repeat(v, r, axis=1), "batch", "heads", None, None)
    flat = indices.reshape(b, hq, nq * l, 1)
    k_sel = jnp.take_along_axis(k_rep, flat, axis=2).reshape(b, hq, nq, l, d)
    v_sel = jnp.take_along_axis(v_rep, flat, axis=2).reshape(b, hq, nq, l, d)
    k_sel = shard(k_sel, "batch", "heads", None, None, None)
    v_sel = shard(v_sel, "batch", "heads", None, None, None)
    logits = jnp.einsum("bhnd,bhnld->bhnl", q, k_sel,
                        preferred_element_type=jnp.float32) * scale
    logits = shard(logits, "batch", "heads", None, None)
    logits = jnp.where(valid, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(valid, w, 0.0)                         # all-invalid rows -> 0
    out = jnp.einsum("bhnl,bhnld->bhnd", w.astype(v_sel.dtype), v_sel)
    return shard(out, "batch", "heads", None, None)


def sparse_mha(q: jax.Array, k: jax.Array, v: jax.Array,
               codebooks: jax.Array, cfg: SparseAttentionConfig,
               scale: float, causal: bool = True,
               window: Optional[int] = None,
               q_offset: int = 0,
               seq_lengths: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full Algorithm 1 for a (possibly GQA) attention layer, training form.

    q: (B, Hq, nq, d); k, v: (B, Hk, nk, d).  q_offset is the absolute
    position of q[..., 0, :] (for decode/prefill continuation).

    seq_lengths: optional per-row real lengths (B,) int32 for batched
    ragged prefill — each row's top-L budget is top_l(seq_lengths[b])
    instead of top_l(nk), so a right-padded row selects exactly the set
    its exact-length batch-1 prefill would (the causal mask already hides
    the pad keys from every real query).  The static gather width stays
    top_l(nk) >= every per-row budget.

    Selection, gather, and attention all happen inside one query-chunk loop
    so the live gather buffer is (B, H, chunk, L, d) — the O(n L d) memory
    claim holds chunk-wise (the fused Pallas kernel does the same per tile).
    Returns (out (B, Hq, nq, d), aux{qerr, l}).
    """
    from repro.core.chunking import maybe_map
    b, hq, nq, d = q.shape
    _, hk, nk, _ = k.shape
    r = hq // hk
    l = top_l(nk, cfg, window)
    l_dyn = (None if seq_lengths is None
             else top_l_dyn(seq_lengths, cfg, window).reshape(b, 1, 1))
    codes_q = pq.assign(q, codebooks)                    # (B, Hq, nq, M)
    codes_k = pq.assign(k, codebooks)                    # (B, Hk, nk, M)
    k_pos = jnp.arange(nk, dtype=jnp.int32)

    from repro.sharding import shard

    def chunk_fn(start):
        q_pos = q_offset + start + jnp.arange(chunk, dtype=jnp.int32)
        mask = attention_mask(q_pos, k_pos, causal, window)   # (chunk, nk)
        if cfg.select_granularity == "kvgroup":
            # one selection per kv head, reused by its R query heads
            cqc = jax.lax.dynamic_slice_in_dim(
                codes_q, start, chunk, axis=2).reshape(b, hk, r, chunk, -1)
            s = pq.match_scores(cqc, codes_k[:, :, None],
                                cfg.pq.num_codewords)
            s = jnp.sum(s, axis=2)                       # (B, Hk, chunk, nk)
            s = shard(s, "batch", "kv_heads", None, None)
        else:
            cqc = jax.lax.dynamic_slice_in_dim(codes_q, start, chunk, axis=2)
            ckq = jnp.repeat(codes_k, r, axis=1)         # (B, Hq, nk, M) int
            ckq = shard(ckq, "batch", "heads", None, None)
            s = pq.match_scores(cqc, ckq, cfg.pq.num_codewords)
            s = shard(s, "batch", "heads", None, None)
        max_s = cfg.pq.num_books * (r if cfg.select_granularity == "kvgroup"
                                    else 1)
        idx, vld = bucket_select(s, mask[None, None], l, max_s, l_dyn=l_dyn)
        if cfg.select_granularity == "kvgroup":
            idx = jnp.repeat(idx, r, axis=1)             # broadcast to q heads
            vld = jnp.repeat(vld, r, axis=1)
        qc = jax.lax.dynamic_slice_in_dim(q, start, chunk, axis=2)
        return attention_from_indices(qc, k, v, idx, vld, scale)

    chunk = min(cfg.chunk_q, nq)
    if nq % chunk != 0:
        chunk = nq
    starts = jnp.arange(0, nq, chunk)
    # checkpoint: the (chunk, L, d) gathers are recomputed in backward
    # instead of being stacked across all chunks (O(n L d) live, not O(n^2)).
    outs = maybe_map(jax.checkpoint(chunk_fn, prevent_cse=False), starts)
    out = jnp.moveaxis(outs, 0, 2).reshape(b, hq, nq, d)
    aux = {"l": jnp.asarray(l, jnp.int32)}
    if cfg.qerr_loss_weight > 0:
        aux["qerr"] = (pq.quantization_error(q, codebooks, codes_q)
                       + pq.quantization_error(k, codebooks, codes_k))
    return out, aux


def _eligibility(s: jax.Array, valid: jax.Array, l: int,
                 max_score: int) -> jax.Array:
    """The top-L set as a boolean mask (no indices): threshold bucket +
    most-recent tie budget — the selection semantics of bucket_select in
    mask form.  All ops elementwise along the key axis (partition-friendly)."""
    sm = jnp.where(valid, s.astype(jnp.int32), -1)
    counts = jnp.stack([jnp.sum((sm == v).astype(jnp.int32), axis=-1)
                        for v in range(max_score + 1)], axis=-1)
    ge = jnp.cumsum(counts[..., ::-1], axis=-1)[..., ::-1]
    t = jnp.maximum(jnp.sum((ge >= l).astype(jnp.int32), axis=-1) - 1, 0)
    ge_pad = jnp.concatenate([ge, jnp.zeros_like(ge[..., :1])], axis=-1)
    n_above = jnp.take_along_axis(ge_pad, (t + 1)[..., None], axis=-1)[..., 0]
    need = (l - n_above)[..., None]
    above = sm > t[..., None]
    at_t = sm == t[..., None]
    rev_rank = jnp.cumsum(at_t[..., ::-1].astype(jnp.int32),
                          axis=-1)[..., ::-1]
    return above | (at_t & (rev_rank <= need))


def sparse_mha_masked(q: jax.Array, k: jax.Array, v: jax.Array,
                      codebooks: jax.Array, cfg: SparseAttentionConfig,
                      scale: float, causal: bool = True,
                      window: Optional[int] = None, q_offset: int = 0
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Fused-kernel-equivalent execution (and its roofline analysis proxy):
    the top-L set is applied as a MASK on dense per-chunk logits — no (n, L)
    index matrix, no gathered K/V copies.  Selection semantics are identical
    to sparse_mha/bucket_select; HBM traffic per chunk is O(chunk * nk)
    instead of O(chunk * L * d) for the gather form (~d/8x less at L=n/8).
    The Pallas kernel additionally skips ineligible key tiles on the MXU,
    which XLA cannot express here — so this form's *compute* term is an
    upper bound on the kernel's."""
    from repro.core.chunking import maybe_map
    from repro.sharding import shard
    b, hq, nq, d = q.shape
    _, hk, nk, _ = k.shape
    r = hq // hk
    l = top_l(nk, cfg, window)
    codes_q = pq.assign(q, codebooks)
    codes_k = pq.assign(k, codebooks)
    ckq = shard(jnp.repeat(codes_k, r, axis=1), "batch", "heads", None, None)
    k_rep = shard(jnp.repeat(k, r, axis=1), "batch", "heads", None, None)
    v_rep = shard(jnp.repeat(v, r, axis=1), "batch", "heads", None, None)
    k_pos = jnp.arange(nk, dtype=jnp.int32)

    def chunk_fn(start):
        q_pos = q_offset + start + jnp.arange(chunk, dtype=jnp.int32)
        mask = attention_mask(q_pos, k_pos, causal, window)
        cqc = jax.lax.dynamic_slice_in_dim(codes_q, start, chunk, axis=2)
        s = pq.match_scores(cqc, ckq, cfg.pq.num_codewords)
        s = shard(s, "batch", "heads", None, None)
        eligible = _eligibility(s, mask[None, None], l, cfg.pq.num_books)
        qc = jax.lax.dynamic_slice_in_dim(q, start, chunk, axis=2)
        logits = jnp.einsum("bhnd,bhmd->bhnm", qc, k_rep,
                            preferred_element_type=jnp.float32) * scale
        logits = jnp.where(eligible, logits, -jnp.inf)
        w = jax.nn.softmax(logits, axis=-1)
        w = jnp.where(eligible, w, 0.0)
        return jnp.einsum("bhnm,bhmd->bhnd", w.astype(v.dtype), v_rep)

    chunk = min(cfg.chunk_q, nq)
    if nq % chunk != 0:
        chunk = nq
    starts = jnp.arange(0, nq, chunk)
    outs = maybe_map(jax.checkpoint(chunk_fn, prevent_cse=False), starts)
    out = jnp.moveaxis(outs, 0, 2).reshape(b, hq, nq, d)
    aux = {"l": jnp.asarray(l, jnp.int32)}
    if cfg.qerr_loss_weight > 0:
        aux["qerr"] = (pq.quantization_error(q, codebooks, codes_q)
                       + pq.quantization_error(k, codebooks, codes_k))
    return out, aux


def _decode_attention_from_indices(q: jax.Array, k: jax.Array, v: jax.Array,
                                   indices: jax.Array, valid: jax.Array,
                                   scale: float) -> jax.Array:
    """Single-token gather attention, grouped by kv head so no (B, Hq, S, d)
    repeat of the cache ever materializes (the train-time
    attention_from_indices repeats KV for SPMD scatter reasons that don't
    apply to the inference-only decode path).

    q: (B, Hq, 1, d); k, v: (B, Hk, S, d); indices/valid: (B, Hsel, 1, L)
    with Hsel = Hk ("kvgroup" shared selection) or Hq (per-head).
    """
    from repro.sharding import shard
    b, hq, _, d = q.shape
    _, hk, _, _ = k.shape
    r = hq // hk
    l = indices.shape[-1]
    rsel = indices.shape[1] // hk                        # 1 | R
    idx = indices.reshape(b, hk, rsel * l, 1)
    k_sel = jnp.take_along_axis(k, idx, axis=2).reshape(b, hk, rsel, l, d)
    v_sel = jnp.take_along_axis(v, idx, axis=2).reshape(b, hk, rsel, l, d)
    k_sel = shard(k_sel, "batch", "kv_heads", None, None, None)
    v_sel = shard(v_sel, "batch", "kv_heads", None, None, None)
    qg = q.reshape(b, hk, r, d)
    vld = valid.reshape(b, hk, rsel, l)
    if rsel == 1:                                        # selection shared by
        k_sel, v_sel = k_sel[:, :, 0], v_sel[:, :, 0]    # the group's R heads
        logits = jnp.einsum("bgrd,bgld->bgrl", qg, k_sel,
                            preferred_element_type=jnp.float32) * scale
    else:
        logits = jnp.einsum("bgrd,bgrld->bgrl", qg, k_sel,
                            preferred_element_type=jnp.float32) * scale
    logits = jnp.where(vld, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(vld, w, 0.0)                           # all-invalid rows -> 0
    eq = "bgrl,bgld->bgrd" if rsel == 1 else "bgrl,bgrld->bgrd"
    out = jnp.einsum(eq, w.astype(v_sel.dtype), v_sel)
    return shard(out.reshape(b, hq, 1, d), "batch", "heads", None, None)


def sparse_mha_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                      codes_cache: jax.Array, codebooks: jax.Array,
                      cfg: SparseAttentionConfig, scale: float,
                      kv_valid: jax.Array) -> jax.Array:
    """One-token decode: select top-L over the cached keys' codes.

    q: (B, Hq, 1, d); caches: (B, Hk, S, d); codes_cache: (B, Hk, S, M)
    kv_valid: (B, S) bool — which cache slots participate (covers both plain
    causal caches and ring-buffer sliding-window caches).

    This is the jnp fallback and the parity oracle for the fused Pallas
    decode kernel (kernels/sparse_attention/ops.sparse_mha_decode).  All
    GQA broadcasting is by reshape — no cache tensor is jnp.repeat-ed
    across query heads, so the fallback stays usable at long S.
    """
    b, hq, _, d = q.shape
    _, hk, s, _ = k_cache.shape
    r = hq // hk
    l = top_l(s, cfg, None)
    codes_q = pq.assign(q, codebooks)                    # (B, Hq, 1, M)
    ck = codes_cache.astype(jnp.int32)                   # (B, Hk, S, M)
    cq = codes_q.reshape(b, hk, r, 1, -1)
    scores = pq.match_scores(cq, ck[:, :, None], cfg.pq.num_codewords)
    if cfg.select_granularity == "kvgroup":
        scores = jnp.sum(scores, axis=2)                 # (B, Hk, 1, S)
    else:
        scores = scores.reshape(b, hq, 1, s)             # (B, Hq, 1, S)
    valid = kv_valid[:, None, None, :]                   # (B, 1, 1, S)
    max_s = cfg.pq.num_books * (r if cfg.select_granularity == "kvgroup"
                                else 1)
    idx, vld = bucket_select(scores, valid, l, max_s)
    return _decode_attention_from_indices(q, k_cache, v_cache, idx, vld,
                                          scale)


def sparse_mha_decode_masked(q: jax.Array, k_cache: jax.Array,
                             v_cache: jax.Array, codes_cache: jax.Array,
                             codebooks: jax.Array,
                             cfg: SparseAttentionConfig, scale: float,
                             kv_valid: jax.Array) -> jax.Array:
    """Fused-kernel-equivalent decode execution: the top-L set is applied as
    a MASK on grouped dense logits — no (1, L) index row, no gathered K/V,
    no bucket_select compaction.  Same selection semantics as
    sparse_mha_decode; this is the XLA-executable stand-in for the Pallas
    decode kernel's compute graph (benchmarks/decode_attention.py) — the
    kernel additionally skips ineligible key tiles and never writes the
    (S,) score row to HBM."""
    b, hq, _, d = q.shape
    _, hk, s, _ = k_cache.shape
    r = hq // hk
    l = top_l(s, cfg, None)
    codes_q = pq.assign(q, codebooks)
    ck = codes_cache.astype(jnp.int32)
    cq = codes_q.reshape(b, hk, r, 1, -1)
    scores = pq.match_scores(cq, ck[:, :, None], cfg.pq.num_codewords)
    valid = kv_valid[:, None, None, None, :]             # (B, 1, 1, 1, S)
    if cfg.select_granularity == "kvgroup":
        ssum = jnp.sum(scores, axis=2, keepdims=True)    # (B, Hk, 1, 1, S)
        eligible = _eligibility(ssum, valid, l, cfg.pq.num_books * r)
    else:
        eligible = _eligibility(scores, valid, l, cfg.pq.num_books)
    qg = q.reshape(b, hk, r, 1, d)
    logits = jnp.einsum("bgrnd,bgsd->bgrns", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(eligible, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(eligible, w, 0.0)
    out = jnp.einsum("bgrns,bgsd->bgrnd", w.astype(v_cache.dtype), v_cache)
    return out.reshape(b, hq, 1, d)


def dense_attention(q: jax.Array, k: jax.Array, v: jax.Array, scale: float,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, kv_valid: Optional[jax.Array] = None,
                    chunk_q: int = 512) -> jax.Array:
    """Dense (Full/LoRA baseline) attention, query-chunked so the (n, n)
    weight matrix never materializes at once.  GQA-aware.
    kv_valid: optional (B, nk) bool for decode-style masking."""
    b, hq, nq, d = q.shape
    _, hk, nk, _ = k.shape
    r = hq // hk
    qf = q.reshape(b, hk, r, nq, d)
    k_pos = jnp.arange(nk, dtype=jnp.int32)

    def chunk_fn(start):
        qc = jax.lax.dynamic_slice_in_dim(qf, start, chunk, axis=3)
        q_pos = q_offset + start + jnp.arange(chunk, dtype=jnp.int32)
        mask = attention_mask(q_pos, k_pos, causal, window)   # (chunk, nk)
        if kv_valid is not None:
            mask = mask[None] & kv_valid[:, None, :]          # (B, chunk, nk)
            mask = mask[:, None, None]                        # (B,1,1,chunk,nk)
        logits = jnp.einsum("bgrnd,bgmd->bgrnm", qc, k,
                            preferred_element_type=jnp.float32) * scale
        logits = jnp.where(mask, logits, -jnp.inf)
        w = jax.nn.softmax(logits, axis=-1)
        w = jnp.where(jnp.isfinite(logits).any(-1, keepdims=True), w, 0.0)
        return jnp.einsum("bgrnm,bgmd->bgrnd", w.astype(v.dtype), v)

    chunk = min(chunk_q, nq)
    if nq % chunk != 0:
        chunk = nq
    starts = jnp.arange(0, nq, chunk)
    from repro.core.chunking import maybe_map
    outs = maybe_map(chunk_fn, starts)                   # (nc, b, hk, r, chunk, d)
    out = jnp.moveaxis(outs, 0, 3).reshape(b, hk, r, nq, d)
    return out.reshape(b, hq, nq, d)


def selection_recall(q: jax.Array, k: jax.Array, codebooks: jax.Array,
                     cfg: SparseAttentionConfig, causal: bool = True,
                     window: Optional[int] = None) -> jax.Array:
    """Diagnostic (paper §4.1 reports ~90%): fraction of the true top-L
    q.k pairs that PQ selection recovers.  O(n^2) — small shapes only."""
    b, hq, nq, d = q.shape
    _, hk, nk, _ = k.shape
    r = hq // hk
    l = top_l(nk, cfg, window)
    q_pos = jnp.arange(nq, dtype=jnp.int32)
    k_pos = jnp.arange(nk, dtype=jnp.int32)
    mask = attention_mask(q_pos, k_pos, causal, window)
    k_rep = jnp.repeat(k, r, axis=1)                     # (B, Hq, nk, d)
    exact = jnp.einsum("bhnd,bhmd->bhnm", q, k_rep,
                       preferred_element_type=jnp.float32)
    exact = jnp.where(mask, exact, -jnp.inf)
    true_top, true_idx = jax.lax.top_k(exact, l)
    codes_q = pq.assign(q, codebooks)
    codes_k = pq.assign(k, codebooks)
    s = pq.match_scores(codes_q.reshape(b, hk, r, nq, -1),
                        codes_k[:, :, None], cfg.pq.num_codewords)
    s = s.reshape(b, hq, nq, nk)
    comb = _combined_score(s, k_pos, mask, nk)
    sel_top, sel_idx = jax.lax.top_k(comb, l)
    true_ok = jnp.isfinite(true_top)[..., None]
    sel_ok = (sel_top >= 0.0)[..., None]
    true_sets = jnp.minimum(
        (jax.nn.one_hot(true_idx, nk, dtype=jnp.float32) * true_ok).sum(-2), 1.0)
    sel_sets = jnp.minimum(
        (jax.nn.one_hot(sel_idx, nk, dtype=jnp.float32) * sel_ok).sum(-2), 1.0)
    inter = jnp.sum(true_sets * sel_sets, axis=-1)
    denom = jnp.minimum(jnp.sum(mask, -1), l).astype(jnp.float32)
    denom = jnp.broadcast_to(denom, inter.shape)
    return jnp.mean(jnp.where(denom > 0, inter / jnp.maximum(denom, 1.0), 1.0))
