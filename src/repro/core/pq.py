"""Product quantization (PQ) for sparse-MHA candidate selection (paper §4.1, §5.1).

A head-dimension vector x in R^d is chopped into M sub-vectors of size
d' = d/M; sub-vector m is assigned to its nearest codeword (L2) in codebook
C^m of E codewords.  The query/key similarity is the *integer* number of
shared codewords (paper Eq. 6):

    s(q, k) = sum_m  1[t_q^m == t_k^m]        in {0, ..., M}

Codebooks are maintained by interval EMA k-means (the paper uses DKM and
updates every 20 mini-batches; we keep the interval and use the streaming EMA
form of k-means, which is the TPU-friendly equivalent — no in-kernel sort,
no host sync).

Defaults follow the paper: codeword dim d' = 8, E = 16 codewords/book.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.params import ParamDef


@dataclasses.dataclass(frozen=True)
class PQConfig:
    head_dim: int
    code_dim: int = 8           # d' — dimension per codebook
    num_codewords: int = 16     # E
    update_interval: int = 20   # DKM/EMA codebook refresh cadence (steps)
    ema: float = 0.05           # EMA step for codebook update

    @property
    def num_books(self) -> int:  # M
        assert self.head_dim % self.code_dim == 0, (
            f"head_dim {self.head_dim} not divisible by code_dim {self.code_dim}")
        return self.head_dim // self.code_dim


def param_defs(cfg: PQConfig) -> dict:
    """Codebooks shared by Q and K of one attention layer: (M, E, d')."""
    return {
        "codebooks": ParamDef(
            shape=(cfg.num_books, cfg.num_codewords, cfg.code_dim),
            dtype=jnp.float32,
            axes=("codebook", "codeword", "code_dim"),
            init="normal:1.0",
            trainable=True,  # updated by EMA k-means, grads zeroed by optimizer mask
        )
    }


def assign(x: jax.Array, codebooks: jax.Array) -> jax.Array:
    """Assign each sub-vector to its nearest codeword.

    x:          (..., n, d)  with d = M * d'
    codebooks:  (M, E, d')
    returns codes: (..., n, M) int32 in [0, E)
    """
    m, e, dp = codebooks.shape
    *lead, n, d = x.shape
    assert d == m * dp, (x.shape, codebooks.shape)
    xs = x.reshape(*lead, n, m, dp).astype(jnp.float32)
    # ||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2 ; ||x||^2 constant over argmin.
    dots = jnp.einsum("...nmd,med->...nme", xs, codebooks)
    c2 = jnp.sum(codebooks * codebooks, axis=-1)  # (M, E)
    dist = c2 - 2.0 * dots
    return jnp.argmin(dist, axis=-1).astype(jnp.int32)  # (..., n, M)


def quantization_error(x: jax.Array, codebooks: jax.Array,
                       codes: Optional[jax.Array] = None) -> jax.Array:
    """Mean squared distance between vectors and their codewords (DKM error)."""
    m, e, dp = codebooks.shape
    *lead, n, d = x.shape
    if codes is None:
        codes = assign(x, codebooks)
    xs = x.reshape(*lead, n, m, dp).astype(jnp.float32)
    sel = jnp.take_along_axis(
        codebooks[None], codes.reshape(-1, m)[..., None, None], axis=-2)
    sel = sel.reshape(*lead, n, m, dp)
    return jnp.mean(jnp.sum((xs - sel) ** 2, axis=-1))


def match_scores(codes_q: jax.Array, codes_k: jax.Array,
                 num_codewords: int) -> jax.Array:
    """Integer similarity s(q,k) = #matching codewords (Eq. 6), MXU-friendly.

    codes_q: (..., nq, M) int32; codes_k: (..., nk, M) int32
    returns (..., nq, nk) float32 counts in [0, M].

    Implemented as a one-hot inner product so the O(nq*nk) term runs on the
    MXU as a (nq, M*E) x (M*E, nk) matmul instead of M broadcast compares.
    """
    e = num_codewords
    oh_q = jax.nn.one_hot(codes_q, e, dtype=jnp.bfloat16)   # (..., nq, M, E)
    oh_k = jax.nn.one_hot(codes_k, e, dtype=jnp.bfloat16)   # (..., nk, M, E)
    *lead_q, nq, m, _ = oh_q.shape
    *lead_k, nk, _, _ = oh_k.shape
    scores = jnp.einsum(
        "...qz,...kz->...qk",
        oh_q.reshape(*lead_q, nq, m * e),
        oh_k.reshape(*lead_k, nk, m * e),
        preferred_element_type=jnp.float32)
    return scores


def ema_update(codebooks: jax.Array, x: jax.Array,
               codes: Optional[jax.Array] = None,
               ema: float = 0.05) -> jax.Array:
    """One EMA k-means step: move each codeword toward the mean of its
    assigned sub-vectors.  Pure function — caller applies it every
    ``update_interval`` steps (paper §5.1: every 20 mini-batches).
    """
    m, e, dp = codebooks.shape
    d = m * dp
    xs = x.reshape(-1, m, dp).astype(jnp.float32)           # (N, M, d')
    if codes is None:
        codes = assign(x.reshape(-1, d), codebooks)         # (N, M)
    else:
        codes = codes.reshape(-1, m)
    oh = jax.nn.one_hot(codes, e, dtype=jnp.float32)        # (N, M, E)
    counts = jnp.sum(oh, axis=0)                            # (M, E)
    sums = jnp.einsum("nme,nmd->med", oh, xs)               # (M, E, d')
    means = sums / jnp.maximum(counts[..., None], 1.0)
    # codewords with no assignment stay put
    upd = jnp.where(counts[..., None] > 0, means, codebooks)
    return (1.0 - ema) * codebooks + ema * upd


def init_codebooks_from_data(x: jax.Array, cfg: PQConfig,
                             key: jax.Array) -> jax.Array:
    """k-means++-lite init: random sample of sub-vectors as codewords."""
    m, e, dp = cfg.num_books, cfg.num_codewords, cfg.code_dim
    xs = x.reshape(-1, m, dp).astype(jnp.float32)
    n = xs.shape[0]
    idx = jax.random.choice(key, n, (e,), replace=n < e)
    return jnp.transpose(xs[idx], (1, 0, 2))  # (M, E, d')
