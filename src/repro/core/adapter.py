"""Model Adapter (paper §3, Figure 2).

Takes a *dense* pre-trained parameter tree (our model zoo's layout with
``spt.disabled()``) and produces the SPT parameter tree for the same
architecture: LoRA adapters inserted (zero-initialized so the function is
unchanged at step 0), FFN weights re-blocked into routed groups, router and
PQ codebooks initialized.  The inverse (merge) folds LoRA back for serving.

This is the exact workflow the paper's ``[UPGRADE] mha.linear_q Linear ->
LoRALinear`` log lines describe, reproduced structurally in JAX.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import params as P
from repro.models import transformer


def _reblock_ffn(dense_ffn: dict, cfg: ModelConfig, spt_defs: dict,
                 spt_init: dict) -> dict:
    """dense {wi:{w},wo:{w}[,wg]} -> routed {w_inner,w_outer[,w_gate],router,
    lora_*} keeping the pre-trained weights bit-exact."""
    g = cfg.spt.ffn_groups
    d, dff = cfg.d_model, cfg.d_ff
    f = dff // g
    out = dict(spt_init)

    def rows(w):        # (.., d, D) -> (.., G, d, F); handles stacked layers
        lead = w.shape[:-2]
        return w.reshape(*lead, d, g, f).swapaxes(-3, -2)

    def cols(w):        # (.., D, d) -> (.., G, F, d)
        lead = w.shape[:-2]
        return w.reshape(*lead, g, f, d)

    out["w_inner"] = rows(dense_ffn["wi"]["w"])
    out["w_outer"] = cols(dense_ffn["wo"]["w"])
    if "wg" in dense_ffn:
        out["w_gate"] = rows(dense_ffn["wg"]["w"])
    return out


def adapt(dense_params: dict, dense_cfg: ModelConfig, spt_cfg: ModelConfig,
          key: jax.Array) -> dict:
    """Upgrade a dense-model tree to the SPT tree for ``spt_cfg``.

    Requirements: same architecture dims; dense_cfg.spt has sparse features
    off.  New parameters (LoRA B/C, router, codebooks) come from spt_cfg's
    initializers; pre-trained weights are copied (FFN re-blocked).
    """
    spt_init = P.init_tree(transformer.lm_defs(spt_cfg), key)

    def walk(dense: dict, spt: dict, path=()):
        out = {}
        for k, v in spt.items():
            if k in ("router", "lora_inner", "lora_outer", "lora_gate",
                     "pq", "lora"):
                out[k] = v                      # fresh SPT-only params
            elif k in ("w_inner", "w_outer", "w_gate"):
                out[k] = v                      # handled by _reblock_ffn
            elif isinstance(v, dict):
                if k == "ffn" and "w_inner" in v and "wi" in dense.get(k, {}):
                    out[k] = _reblock_ffn(dense[k], spt_cfg, None, v)
                elif k in dense and isinstance(dense[k], dict):
                    out[k] = walk(dense[k], v, path + (k,))
                else:
                    out[k] = v
            else:
                out[k] = dense[k] if k in dense else v
        return out

    return walk(dense_params, spt_init)


def upgrade_report(dense_params: dict, adapted: dict) -> str:
    """Human-readable '[UPGRADE]' log like the paper's Model Adapter."""
    lines = []

    def walk(d, a, path):
        if not isinstance(a, dict):
            return
        for k, v in a.items():
            p = path + (k,)
            if k in ("lora", "lora_inner", "lora_outer", "lora_gate"):
                lines.append(f"[UPGRADE] {'.'.join(path)} Linear -> LoRALinear")
            elif k == "router":
                lines.append(f"[UPGRADE] {'.'.join(path)} FFN -> RoutedFFN")
            elif k == "pq":
                lines.append(f"[UPGRADE] {'.'.join(path)} MHA -> SparseMHA")
            elif isinstance(v, dict):
                walk(d.get(k, {}) if isinstance(d, dict) else {}, v, p)

    walk(dense_params, adapted, ())
    return "\n".join(lines)
