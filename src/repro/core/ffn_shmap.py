"""Routed FFN with an explicit shard_map collective schedule (§Perf it10).

Why: under plain pjit, the TP contraction over the model-sharded FFN dim
emits all-reduces of the (B, G, C, d) dispatch-buffer cotangent — measured
at 727 GB/device/step on gemma-7b train_4k — and a sharding-constraint-only
sequence-parallel attempt made it worse (EXPERIMENTS.md §Perf it7: XLA
reshards around the gather/scatter instead of adopting AG->compute->RS).

This module pins the Megatron-SP schedule by hand:

    x (batch->data, seq->model)                     [seq-sharded residual]
      -- all_gather(seq, model) -> full local seq
      -- route + capacity dispatch (local, per sequence)
      -- up/gate GEMMs with the local (G, d, F/TP) weight shard
      -- down GEMM -> partial (B, G, C, d)
      -- combine scatter -> partial (B, S, d)
      -- psum_scatter(seq, model) -> (batch->data, seq->model) output

Collective bytes per layer: AG(N) + RS(N) forward, RS(N) + AG(N) backward,
N = |activations| — vs >= 2 all-reduces (2N each) for the pjit schedule.
The inner math reuses core.dispatch / core.routed_ffn pieces unchanged, so
the function is numerically identical to impl="grouped" (asserted in
tests/test_ffn_shmap.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import dispatch, lora
from repro.core.compat import shard_map
from repro.core.routed_ffn import (ACTIVATIONS, RoutedFFNConfig, route)


def _specs(mesh):
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model = "model" if "model" in mesh.axis_names else None
    b = batch_axes if batch_axes else None
    return b, model


def applicable(mesh, cfg: RoutedFFNConfig, d_ff: int, seq: int,
               batch: int) -> bool:
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return False
    tp = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    return (cfg.group_dim % tp == 0 and seq % tp == 0 and batch % dp == 0)


def routed_ffn_shmap(x: jax.Array, p: dict, cfg: RoutedFFNConfig,
                     lora_cfg: lora.LoRAConfig, mesh,
                     need_aux: bool = True
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d) logically; enters/leaves seq-sharded on "model".

    ``need_aux=False`` (inference) skips the router softmax, the
    load-balance loss and its cross-data pmean."""
    b_ax, model = _specs(mesh)
    r = lora_cfg.rank if lora_cfg.enabled else 0
    use_lora = lora_cfg.enabled and "lora_inner" in p
    act = ACTIVATIONS[cfg.activation]

    def inner(x_l, router_w, wi, wo, wg, li_b, li_c, lg_b, lg_c, lo_b, lo_c):
        # x_l: (b_loc, s/tp, d) -> gather full sequence locally
        xf = jax.lax.all_gather(x_l, "model", axis=1, tiled=True)
        bl, s, d = xf.shape
        choice, gate_w, probs = route(xf, router_w, cfg, need_aux=need_aux)
        cap = dispatch.capacity(s, cfg.num_groups, cfg.active_groups,
                                cfg.capacity_factor, pad=cfg.capacity_pad)
        plan = dispatch.make_plan(choice, gate_w, cfg.num_groups, cap)
        xg = dispatch.gather(xf, plan)                  # (bl, G, C, d)

        def proj_up(w, lb, lc_):
            up = jnp.einsum("bgcd,gdf->bgcf", xg,
                            jax.lax.stop_gradient(w).astype(xf.dtype))
            if use_lora:
                xb = jnp.einsum("bgcd,dr->bgcr", xg, lb.astype(xf.dtype))
                up = up + lora_cfg.scale * jnp.einsum(
                    "bgcr,grf->bgcf", xb, lc_.astype(xf.dtype))
            return up

        up = proj_up(wi, li_b, li_c)
        if cfg.gated:
            h = act(proj_up(wg, lg_b, lg_c)) * up
        else:
            h = act(up)
        y = jnp.einsum("bgcf,gfd->bgcd", h,
                       jax.lax.stop_gradient(wo).astype(xf.dtype))
        if use_lora:
            hb = jnp.einsum("bgcf,gfr->bgcr", h, lo_b.astype(xf.dtype))
            y = y + lora_cfg.scale * jnp.einsum(
                "bgcr,rd->bgcd", hb, lo_c.astype(xf.dtype))
        y_full = dispatch.combine(y.astype(xf.dtype), plan, s)
        # partial over the TP contraction -> reduce-scatter along seq
        y_out = jax.lax.psum_scatter(y_full, "model", scatter_dimension=1,
                                     tiled=True)
        if need_aux:
            lb_loss = jax.lax.pmean(
                dispatch.load_balance_loss(probs, choice, cfg.num_groups),
                axis_name=tuple(a for a in ("pod", "data")
                                if a in mesh.axis_names) or "model")
        else:
            lb_loss = jnp.zeros((), jnp.float32)
        dropped = jax.lax.pmean(plan.dropped, axis_name="model")
        return y_out, lb_loss, dropped

    zero = jnp.zeros((), jnp.float32)
    wi, wo = p["w_inner"], p["w_outer"]
    wg = p.get("w_gate", wi)                 # unused when not gated
    li_b = p["lora_inner"]["b"] if use_lora else zero
    li_c = p["lora_inner"]["c"] if use_lora else zero
    lg_b = p["lora_gate"]["b"] if (use_lora and cfg.gated) else zero
    lg_c = p["lora_gate"]["c"] if (use_lora and cfg.gated) else zero
    lo_b = p["lora_outer"]["b"] if use_lora else zero
    lo_c = p["lora_outer"]["c"] if use_lora else zero

    w_col = P(None, None, model)             # F sharded (last dim)
    w_row = P(None, model, None)             # F sharded (middle dim)
    scalar = P()
    in_specs = (P(b_ax, model, None),        # x: seq-sharded
                P(None, None),               # router (replicated)
                w_col, w_row, w_col,
                scalar if not use_lora else P(None, None),
                scalar if not use_lora else w_col,
                scalar if not (use_lora and cfg.gated) else P(None, None),
                scalar if not (use_lora and cfg.gated) else w_col,
                scalar if not use_lora else w_row,
                scalar if not use_lora else P(None, None))
    fn = shard_map(inner, mesh=mesh, in_specs=in_specs,
                   out_specs=(P(b_ax, model, None), P(), P()),
                   check_vma=False)
    y, lb_loss, dropped = fn(x, p["router"], wi, wo, wg, li_b, li_c,
                             lg_b, lg_c, lo_b, lo_c)
    return y, {"lb_loss": lb_loss, "dropped": dropped}
