"""Parameter definition machinery.

Every model layer declares its parameters as a tree of :class:`ParamDef`
(shape + dtype + *logical* partition axes + initializer).  From one tree of
defs we derive, with guaranteed structural consistency:

  * materialized parameters          (``init_tree``)
  * ``jax.ShapeDtypeStruct`` stand-ins for AOT lowering (``abstract_tree``)
  * ``PartitionSpec`` trees, after mapping logical axis names onto mesh axes
    through a rule table (``spec_tree``)

Logical axis names used throughout the framework (see sharding/rules.py for
the mesh mapping):

  embed      model width (d_model)               usually replicated
  heads      query heads                          -> "model"
  kv_heads   key/value heads                      -> "model" (when divisible)
  head_dim   per-head dim                         replicated
  ffn        FFN hidden dim                       -> "model"
  group      routed-FFN group axis                replicated (blocks stay whole)
  expert     MoE expert axis                      replicated (ffn dim sharded)
  vocab      vocabulary                           -> "model"
  lora_rank  LoRA inner rank                      replicated
  layer      stacked-layer axis (lax.scan)        replicated
  codebook / codeword / code_dim                  replicated (tiny)
  conv / state / lru  (SSM/recurrent internals)   replicated or "model"
  batch / seq                                     activation axes
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any, Callable, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

Tree = Any  # nested dict of ParamDef / arrays


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative description of a single parameter tensor."""

    shape: Tuple[int, ...]
    dtype: Any = jnp.bfloat16
    axes: Tuple[Optional[str], ...] = ()
    init: str = "normal:0.02"  # zeros | ones | normal:<std> | uniform:<s> | fan_in
    trainable: bool = True     # False => frozen (pre-trained base weights)

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} rank mismatch with shape {self.shape}")


def _make_init(defn: ParamDef) -> Callable[[jax.Array], jax.Array]:
    kind, _, arg = defn.init.partition(":")
    shape, dtype = defn.shape, defn.dtype
    if kind == "zeros":
        return lambda key: jnp.zeros(shape, dtype)
    if kind == "ones":
        return lambda key: jnp.ones(shape, dtype)
    if kind == "normal":
        std = float(arg or 0.02)
        return lambda key: (std * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    if kind == "uniform":
        s = float(arg or 1.0)
        return lambda key: jax.random.uniform(key, shape, jnp.float32, -s, s).astype(dtype)
    if kind == "fan_in":
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        std = 1.0 / math.sqrt(max(1, fan_in))
        return lambda key: (std * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    raise ValueError(f"unknown init {defn.init!r}")


def is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def _map_defs(fn: Callable[[ParamDef], Any], tree: Tree) -> Tree:
    if is_def(tree):
        return fn(tree)
    if isinstance(tree, Mapping):
        return {k: _map_defs(fn, v) for k, v in tree.items()}
    raise TypeError(f"bad def tree node: {type(tree)}")


def init_tree(tree: Tree, key: jax.Array) -> Tree:
    """Materialize parameters from a def tree (deterministic key splitting)."""
    leaves = []

    def collect(t, path):
        if is_def(t):
            leaves.append(path)
        else:
            for k in sorted(t.keys()):
                collect(t[k], path + (k,))

    collect(tree, ())
    keys = jax.random.split(key, max(1, len(leaves)))
    key_by_path = dict(zip(leaves, keys))

    def build(t, path):
        if is_def(t):
            return _make_init(t)(key_by_path[path])
        return {k: build(v, path + (k,)) for k, v in t.items()}

    return build(tree, ())


def abstract_tree(tree: Tree) -> Tree:
    return _map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), tree)


def spec_tree(tree: Tree, rules: Mapping[str, Any]) -> Tree:
    """Map logical axes -> mesh axes producing a PartitionSpec tree.

    ``rules[name]`` may be a mesh-axis name, a tuple of mesh axes, or None.
    A logical axis missing from the rules is replicated.  A rule is applied
    only if the dimension size is divisible by the mesh-axis extent recorded
    in ``rules['__sizes__']`` (so small models degrade to replication instead
    of failing to shard).
    """
    sizes = rules.get("__sizes__", {})

    def axis_ok(dim: int, mesh_axes) -> bool:
        if mesh_axes is None:
            return True
        axes = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
        total = 1
        for a in axes:
            total *= int(sizes.get(a, 1))
        return total > 0 and dim % total == 0

    def one(d: ParamDef) -> PartitionSpec:
        if not d.axes:
            return PartitionSpec()
        out = []
        used = set()
        for dim, name in zip(d.shape, d.axes):
            mesh_axes = rules.get(name) if name is not None else None
            if mesh_axes is None or not axis_ok(dim, mesh_axes):
                out.append(None)
                continue
            flat = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
            if any(a in used for a in flat):
                out.append(None)  # an axis can appear once per spec
                continue
            used.update(flat)
            out.append(mesh_axes if isinstance(mesh_axes, str) else tuple(mesh_axes))
        return PartitionSpec(*out)

    return _map_defs(one, tree)


def trainable_mask(tree: Tree) -> Tree:
    """Boolean tree: True for trainable leaves (LoRA/router/codebooks)."""
    return _map_defs(lambda d: d.trainable, tree)


def stack_defs(tree: Tree, n: int) -> Tree:
    """Prepend a ``layer`` axis of size n to every def (for lax.scan layers)."""

    def one(d: ParamDef) -> ParamDef:
        axes = d.axes if d.axes else (None,) * len(d.shape)
        return dataclasses.replace(
            d, shape=(n, *d.shape), axes=("layer", *axes))

    return _map_defs(one, tree)


def count_params(tree: Tree, only_trainable: Optional[bool] = None) -> int:
    total = 0

    def one(d: ParamDef):
        nonlocal total
        if only_trainable is None or d.trainable == only_trainable:
            total += math.prod(d.shape)

    _map_defs(one, tree)
    return total


def param_bytes(tree: Tree, only_trainable: Optional[bool] = None) -> int:
    total = 0

    def one(d: ParamDef):
        nonlocal total
        if only_trainable is None or d.trainable == only_trainable:
            total += math.prod(d.shape) * jnp.dtype(d.dtype).itemsize

    _map_defs(one, tree)
    return total


def partition(tree: Tree, mask: Tree) -> Tuple[Tree, Tree]:
    """Split a value tree into (selected, rest) by a bool tree of the same
    dict structure.  Unselected positions become None (empty pytree), so
    jax.grad over the selected tree never touches frozen tensors."""

    def walk2(t, m):
        if isinstance(t, Mapping):
            return {k: walk2(t[k], m[k]) for k in t}
        return None if m else t

    def walk1(t, m):
        if isinstance(t, Mapping):
            return {k: walk1(t[k], m[k]) for k in t}
        return t if m else None

    return walk1(tree, mask), walk2(tree, mask)


def combine(a: Tree, b: Tree) -> Tree:
    """Inverse of :func:`partition`."""
    if a is None:
        return b
    if b is None:
        return a
    assert isinstance(a, Mapping) and isinstance(b, Mapping)
    return {k: combine(a.get(k), b.get(k)) for k in set(a) | set(b)}


def tree_paths(tree: Tree) -> list:
    out = []

    def walk(t, path):
        if is_def(t) or not isinstance(t, Mapping):
            out.append(path)
            return
        for k in sorted(t.keys()):
            walk(t[k], path + (k,))

    walk(tree, ())
    return out
