"""Loop helpers with an *analysis mode* for exact cost accounting.

XLA's ``cost_analysis()`` counts a while-loop body ONCE, not times its trip
count (verified in EXPERIMENTS.md §Dry-run calibration).  Production code
wants rolled loops (small HLO, bounded buffers); the roofline dry-run wants
unrolled loops so FLOPs/bytes/collective counts are exact.  These wrappers
switch on a contextvar: `maybe_map`/`maybe_scan` behave like lax.map /
lax.scan normally and unroll into straight-line HLO under
``analysis_mode()``.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

_ANALYSIS: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_analysis_mode", default=False)


@contextlib.contextmanager
def analysis_mode(on: bool = True):
    token = _ANALYSIS.set(on)
    try:
        yield
    finally:
        _ANALYSIS.reset(token)


def in_analysis_mode() -> bool:
    return _ANALYSIS.get()


def maybe_map(fn: Callable, xs: jax.Array):
    """lax.map, or an unrolled stack under analysis mode."""
    if not _ANALYSIS.get():
        return jax.lax.map(fn, xs)
    outs = [fn(xs[i]) for i in range(xs.shape[0])]
    return jax.tree_util.tree_map(lambda *ys: jnp.stack(ys, 0), *outs)


def maybe_scan(body: Callable, init: Any, xs: Any,
               length: Optional[int] = None):
    """lax.scan, or an unrolled python loop under analysis mode."""
    if not _ANALYSIS.get():
        return jax.lax.scan(body, init, xs, length=length)
    if xs is None:
        n = length
        slices = [None] * n
    else:
        leaves = jax.tree_util.tree_leaves(xs)
        n = leaves[0].shape[0]
        slices = [jax.tree_util.tree_map(lambda a: a[i], xs)
                  for i in range(n)]
    carry = init
    ys = []
    for s in slices:
        carry, y = body(carry, s)
        ys.append(y)
    if ys and ys[0] is None:
        return carry, None
    stacked = jax.tree_util.tree_map(lambda *zs: jnp.stack(zs, 0), *ys)
    return carry, stacked
