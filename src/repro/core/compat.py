"""Version-compat shims for JAX APIs that moved between releases.

`shard_map` graduated from `jax.experimental.shard_map` to `jax.shard_map`
(and the replication-check kwarg was renamed `check_rep` -> `check_vma`
along the way).  Every explicit-collective schedule in this repo goes
through this one helper so the rest of the code can target the modern
spelling regardless of the installed JAX.
"""
from __future__ import annotations

from typing import Any, Optional

import jax


def shard_map(f, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None) -> Any:
    """`jax.shard_map` when present, else the experimental module.

    check_vma: None means "library default"; a bool is forwarded as
    `check_vma` (new JAX) or `check_rep` (old JAX).
    """
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
