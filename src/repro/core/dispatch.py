"""Capacity-bucketed token dispatch — the TPU analogue of the paper's BSpMV
(§5.2): "batch the tokens that activate the same block for efficient
computation".

On GPU the paper gathers a dynamic number of tokens per weight block and runs
one GEMM per block on its own stream.  Under XLA/jit shapes must be static,
so we use the standard fixed-capacity formulation; crucially the dispatch is
**per sequence** (batch-local): ranks come from a cumsum along the sequence
axis only, so under pjit every buffer keeps its batch sharding and no global
collective is ever induced by routing (a global-cumsum formulation forces
XLA to replicate the (B*S*K, d) dispatch buffers — measured in
EXPERIMENTS.md §Dry-run calibration).

Token t of sequence b activating block g lands in slot rank(t within (b, g))
if below capacity; overflowing (token, choice) pairs are dropped (the
monitor reports the fraction so capacity_factor can be raised).

The same engine serves the routed FFN (top-G' of G row-blocks) and MoE
layers (top-k of E experts) — the paper notes they are the same mechanism.

Shapes: x (B, S, d); choice/gate (B, S, K); plan.index (B, G, C).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DispatchPlan:
    """Static-shape routing plan for one layer invocation."""
    index: jax.Array      # (B, G, C) int32 — slot -> token position (S if empty)
    slot_ok: jax.Array    # (B, G, C) bool
    combine_w: jax.Array  # (B, G, C) f32
    dropped: jax.Array    # () f32 — dropped fraction of (token, choice) pairs


jax.tree_util.register_pytree_node(
    DispatchPlan,
    lambda p: ((p.index, p.slot_ok, p.combine_w, p.dropped), None),
    lambda _, c: DispatchPlan(*c))


def capacity(tokens_per_seq: int, num_groups: int, topk: int,
             capacity_factor: float, pad: int = 8) -> int:
    """Slots per (sequence, group), padded to a multiple of ``pad`` (>= 8).
    pad=128 makes the capacity dim shardable 16-way for the dispatch-SP
    optimization (EXPERIMENTS.md §Perf)."""
    pad = max(8, pad)
    c = int(tokens_per_seq * topk * capacity_factor / num_groups) + 1
    c = -(-c // pad) * pad
    return min(c, max(pad, -(-tokens_per_seq * topk // pad) * pad))


def capacity_dyn(tokens_per_seq: jax.Array, num_groups: int, topk: int,
                 capacity_factor: float, pad: int = 8) -> jax.Array:
    """Traced counterpart of ``capacity`` for per-row lengths (B,) int32 —
    batched ragged prefill needs each row's capacity to match what a
    batch-1 exact-length call would have used.  Bit-identical to the host
    formula whenever ``capacity_factor`` is exactly representable in
    float32 (true for every dyadic factor in the repo's configs)."""
    pad = max(8, pad)
    t = jnp.asarray(tokens_per_seq, jnp.int32)
    c = (t.astype(jnp.float32) * topk * capacity_factor
         / num_groups).astype(jnp.int32) + 1
    c = -(-c // pad) * pad
    return jnp.minimum(c, jnp.maximum(pad, -(-t * topk // pad) * pad))


def make_plan(choice: jax.Array, gate: jax.Array, num_groups: int,
              cap: int, cap_dyn: Optional[jax.Array] = None) -> DispatchPlan:
    """choice: (B, S, K) int32; gate: (B, S, K) f32.

    cap_dyn: optional per-row (B,) capacities (<= cap) — ragged prefill
    rows right-padded to a common S keep the capacity their exact length
    would have had, so drops match the batch-1 serial engine row-for-row
    (pad tokens sit after the real ones in position order, so real-token
    ranks are unaffected either way)."""
    b, s, k = choice.shape
    flat_choice = choice.reshape(b, s * k)
    flat_gate = gate.reshape(b, s * k)
    oh = jax.nn.one_hot(flat_choice, num_groups, dtype=jnp.int32)  # (B,SK,G)
    ranks = jnp.cumsum(oh, axis=1) - oh                  # exclusive, per seq
    rank = jnp.sum(ranks * oh, axis=-1)                  # (B, SK)
    limit = cap if cap_dyn is None else jnp.minimum(
        jnp.asarray(cap_dyn, jnp.int32), cap)[:, None]
    keep = rank < limit
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    token_id = jnp.broadcast_to(
        jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)[None, :], (b, s * k))
    # flat (G*C)-destination per (token, choice); dropped -> OOB (drop mode).
    # vmapped scatters lower to batched scatter so SPMD keeps batch sharding.
    dest = jnp.where(keep, flat_choice * cap + rank, num_groups * cap)

    def _scatter_row(di, so, cw, pos, tid, gt):
        return (di.at[pos].set(tid, mode="drop"),
                so.at[pos].set(True, mode="drop"),
                cw.at[pos].set(gt, mode="drop"))

    index0 = jnp.full((b, num_groups * cap), s, dtype=jnp.int32)
    ok0 = jnp.zeros((b, num_groups * cap), dtype=bool)
    cw0 = jnp.zeros((b, num_groups * cap), dtype=jnp.float32)
    index, slot_ok, combine_w = jax.vmap(_scatter_row)(
        index0, ok0, cw0, dest, token_id, flat_gate)
    shape = (b, num_groups, cap)
    return DispatchPlan(index.reshape(shape), slot_ok.reshape(shape),
                        combine_w.reshape(shape), dropped)


def gather(x: jax.Array, plan: DispatchPlan) -> jax.Array:
    """(B, S, d) -> (B, G, C, d); empty slots read a zero row."""
    b, s, d = x.shape
    _, g, c = plan.index.shape
    xz = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    out = jnp.take_along_axis(xz, plan.index.reshape(b, g * c)[..., None],
                              axis=1)
    return out.reshape(b, g, c, d)


def combine(y: jax.Array, plan: DispatchPlan, seq_len: int) -> jax.Array:
    """(B, G, C, d) -> (B, S, d) scatter-add with combine weights."""
    b, g, c, d = y.shape
    w = jnp.where(plan.slot_ok, plan.combine_w, 0.0).astype(y.dtype)
    yw = (y * w[..., None]).reshape(b, g * c, d)

    def _row(acc, pos, vals):                     # vmapped: batched scatter
        return acc.at[pos].add(vals, mode="drop")

    out = jnp.zeros((b, seq_len + 1, d), y.dtype)
    out = jax.vmap(_row)(out, plan.index.reshape(b, g * c), yw)
    return out[:, :seq_len]


# ------------------------------------------------------ kernel dispatch
# The second dispatch concern this module owns: which *execution path* a
# layer lowers through.  Kernel selection is a trace-time decision (shapes
# are static under jit), gated by one global kill switch plus per-feature
# config flags, so the jnp fallbacks stay one env var away for debugging
# and CI bisection.

def kernels_disabled() -> bool:
    """REPRO_DISABLE_KERNELS=1 forces every jnp fallback path (checked at
    trace time; unset/0/false = kernels allowed)."""
    import os
    return os.environ.get("REPRO_DISABLE_KERNELS", "0").strip().lower() \
        not in ("", "0", "false")


def use_sparse_decode_kernel(cfg) -> bool:
    """Should sparse-MHA decode lower through the fused Pallas kernel?

    cfg is a ModelConfig (duck-typed — importing configs here would cycle).
    spt.decode_attn_impl: "kernel" | "jnp" | "auto" (auto follows the
    train/prefill attn_impl, i.e. kernels on iff attn_impl == "pallas").
    REPRO_DISABLE_KERNELS=1 overrides everything.
    """
    if kernels_disabled():
        return False
    impl = getattr(cfg.spt, "decode_attn_impl", "auto")
    if impl == "auto":
        return cfg.spt.attn_impl == "pallas"
    return impl == "kernel"


def use_fused_decode_attn(cfg) -> bool:
    """Within the sparse-decode kernel tier, should the ONE-PASS fused
    kernel run (threshold histogram as a prologue phase of the attention
    grid) instead of the two-pass threshold + attention kernel pair?

    cfg is a ModelConfig (duck-typed).  spt.decode_attn_fuse: "fused" |
    "two_pass" | "auto" (auto = fused; two_pass is the bisection tier —
    both produce bit-identical output).  Only consulted when
    use_sparse_decode_kernel(cfg) already said yes, so the kill switch
    needs no separate handling here.
    """
    mode = getattr(cfg.spt, "decode_attn_fuse", "auto")
    if mode == "auto":
        return True
    return mode == "fused"


def use_paged_native_decode(cfg) -> bool:
    """Should paged-pool decode attention address K/V/code tiles straight
    from the page pools (scalar-prefetched page table in the kernels'
    BlockSpec index_maps) instead of materializing a gathered per-slot
    (B, Hk, S, .) view first?

    cfg is a ModelConfig (duck-typed).  spt.kv_paged_native: "kernel" |
    "gather" | "auto" (auto follows the decode attention kernel tier:
    native iff attn_impl == "pallas").  Unlike the layout switch
    (use_paged_kv) this IS a kernel decision, so REPRO_DISABLE_KERNELS=1
    forces the gathered-view fallback.
    """
    if kernels_disabled():
        return False
    impl = getattr(cfg.spt, "kv_paged_native", "auto")
    if impl == "auto":
        return cfg.spt.attn_impl == "pallas"
    return impl == "kernel"


def use_routed_ffn_kernel(cfg) -> bool:
    """Should train/prefill routed FFN lower through the fused Pallas
    grouped-GEMM kernel (in-kernel scalar-prefetch dispatch)?

    cfg is a ModelConfig (duck-typed).  spt.ffn_impl == "pallas" selects
    the kernel; REPRO_DISABLE_KERNELS=1 demotes it to the jnp grouped
    path (identical routing plan, so identical function).
    """
    if kernels_disabled():
        return False
    return getattr(cfg.spt, "ffn_impl", "grouped") == "pallas"


def use_decode_ffn_kernel(cfg) -> bool:
    """Should the serving-decode routed FFN (x of shape (B, 1, d)) lower
    through the block-gather Pallas kernel (no capacity plan, no dispatch
    buffer)?

    spt.decode_ffn_impl: "kernel" | "jnp" | "auto" (auto follows the
    train/prefill ffn_impl, i.e. kernel on iff ffn_impl == "pallas").
    REPRO_DISABLE_KERNELS=1 overrides everything.
    """
    if kernels_disabled():
        return False
    impl = getattr(cfg.spt, "decode_ffn_impl", "auto")
    if impl == "auto":
        return getattr(cfg.spt, "ffn_impl", "grouped") == "pallas"
    return impl == "kernel"


def use_paged_kv(cfg) -> bool:
    """Should the serving engine lay the KV cache out as fixed-size pages
    (shared pool + per-slot page tables, serving/kv_pages.py) instead of
    one contiguous max_len strip per slot?

    cfg is a ModelConfig (duck-typed).  spt.kv_layout: "paged" |
    "contiguous".  A pure layout decision — not a kernel — so the
    REPRO_DISABLE_KERNELS kill switch does not apply; the engine
    additionally requires transformer.paged_applicable(cfg) (an attention
    stack without a SWA ring cache) before engaging it.
    """
    return getattr(cfg.spt, "kv_layout", "contiguous") == "paged"


def telemetry_mode(cfg) -> str:
    """Serving-observability level: "off" | "counters" | "trace".

    cfg is a ModelConfig (duck-typed).  Like the KV-layout switch this is
    a pure config decision — no kernel is involved, so the
    REPRO_DISABLE_KERNELS kill switch does not apply.  "counters" threads
    jit-pure device counters through the compiled decode chunk / batched
    prefill; "trace" additionally records host-side request lifecycle
    events and scheduler spans (serving/telemetry.py).
    """
    return getattr(cfg.spt, "telemetry", "off")


def use_telemetry_counters(cfg) -> bool:
    """Should the model layers emit jit-pure telemetry counters (tel_*
    aux entries: sparse-MHA kept/eligible slots, routed-FFN/MoE expert
    loads and capacity drops)?

    Off by default so the decode-chunk jaxpr stays eqn-identical to a
    telemetry-free build (jaxpr.telemetry-cost audit); both "counters"
    and "trace" turn the device counters on.
    """
    return telemetry_mode(cfg) in ("counters", "trace")


def load_balance_loss(router_probs: jax.Array, choice: jax.Array,
                      num_groups: int) -> jax.Array:
    """Switch-style auxiliary loss (paper §4.2 'load-balancing loss'):
    G * sum_g f_g p_g over tokens of all sequences; == 1 at perfect balance.
    router_probs: (B, S, G); choice: (B, S, K)."""
    k = choice.shape[-1]
    oh = jax.nn.one_hot(choice, num_groups, dtype=jnp.float32)  # (B,S,K,G)
    f = jnp.mean(jnp.sum(oh, axis=2), axis=(0, 1)) / k          # (G,)
    p = jnp.mean(router_probs.astype(jnp.float32), axis=(0, 1))  # (G,)
    return num_groups * jnp.sum(f * p)
