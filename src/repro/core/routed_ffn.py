"""Routed FFN (paper §4.2, §5.2).

The FFN inner projection W_I (d x D) is organized into G row-groups of
F = D/G rows; the outer projection W_O (D x d) into the matching column
groups.  A single-layer router x_R = x W_R (W_R in R^{d x G}) selects the
top-G' groups by |x_R| per token; only those blocks are computed:

    y = sum_{g in top-G'}  act(x W_I[g]) W_O[g]

which equals the dense FFN with the non-activated entries of the hidden
vector h zeroed (Figure 6a: prune rows of W_I and the matching columns of
W_O — never the converse).  beta = G'/G is the FLOP fraction.

Two execution paths with identical semantics:
  * ``impl="dense"``   — mask-based oracle: full FFN, zero masked h.
  * ``impl="grouped"`` — capacity-bucketed BSpMV analogue (core/dispatch.py):
                         tokens batched per activated block, one dense GEMM
                         per block, scatter-add combine.  This is the path
                         whose FLOPs scale by beta.

GeGLU/SwiGLU variants route the gate and up projections jointly (both are
row-grouped) so the hidden mask stays consistent with the down projection.

All activations keep the (B, S, ...) layout so batch sharding survives
routing under pjit (see core/dispatch.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dispatch, lora
from repro.core.params import ParamDef
from repro.sharding import shard

ACTIVATIONS: Dict[str, Callable] = {
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


@dataclasses.dataclass(frozen=True)
class RoutedFFNConfig:
    d_model: int
    d_ff: int
    num_groups: int = 8            # G
    active_groups: int = 4         # G' (beta = G'/G; paper default 1/2)
    capacity_factor: float = 2.0   # slack so drop fraction ~ 0
    activation: str = "relu"
    gated: bool = False            # GeGLU/SwiGLU style (gate * up)
    gate_outputs: bool = False     # beyond-paper: sigmoid(router logit) gate
    capacity_pad: int = 8          # 128 enables dispatch-SP sharding (perf)
    lb_loss_weight: float = 0.01

    @property
    def group_dim(self) -> int:
        assert self.d_ff % self.num_groups == 0, (self.d_ff, self.num_groups)
        return self.d_ff // self.num_groups

    @property
    def beta(self) -> float:
        return self.active_groups / self.num_groups


def param_defs(cfg: RoutedFFNConfig, lora_cfg: lora.LoRAConfig) -> dict:
    g, d, f = cfg.num_groups, cfg.d_model, cfg.group_dim
    defs = {
        "router": ParamDef((d, cfg.num_groups), jnp.float32,
                           ("embed", "group"), init="fan_in", trainable=True),
        "w_inner": ParamDef((g, d, f), jnp.bfloat16,
                            ("group", "embed", "ffn"), init="fan_in",
                            trainable=False),
        "w_outer": ParamDef((g, f, d), jnp.bfloat16,
                            ("group", "ffn", "embed"), init="fan_in",
                            trainable=False),
    }
    if cfg.gated:
        defs["w_gate"] = ParamDef((g, d, f), jnp.bfloat16,
                                  ("group", "embed", "ffn"), init="fan_in",
                                  trainable=False)
    if lora_cfg.enabled:
        r = lora_cfg.rank
        defs["lora_inner"] = {
            "b": ParamDef((d, r), jnp.float32, ("embed", "lora_rank"),
                          init="fan_in", trainable=True),
            "c": ParamDef((g, r, f), jnp.float32, ("group", "lora_rank", "ffn"),
                          init="zeros", trainable=True),
        }
        defs["lora_outer"] = {
            "b": ParamDef((g, f, r), jnp.float32, ("group", "ffn", "lora_rank"),
                          init="fan_in", trainable=True),
            "c": ParamDef((r, d), jnp.float32, ("lora_rank", "embed"),
                          init="zeros", trainable=True),
        }
        if cfg.gated:
            defs["lora_gate"] = {
                "b": ParamDef((d, r), jnp.float32, ("embed", "lora_rank"),
                              init="fan_in", trainable=True),
                "c": ParamDef((g, r, f), jnp.float32,
                              ("group", "lora_rank", "ffn"),
                              init="zeros", trainable=True),
            }
    return defs


def route(x: jax.Array, router_w: jax.Array, cfg: RoutedFFNConfig,
          need_aux: bool = True
          ) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """Router forward: top-G' groups by |logit| (paper: largest magnitude).

    x: (B, S, d) -> (choice (B,S,G'), gate (B,S,G'), probs (B,S,G))

    ``need_aux=False`` (inference) skips the softmax over the full group
    axis — it exists only to feed the load-balance loss, which decode
    would otherwise pay per token per layer — and returns probs=None.
    """
    logits = jnp.einsum("bsd,dg->bsg", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1) if need_aux else None
    _, choice = jax.lax.top_k(jnp.abs(logits), cfg.active_groups)
    if cfg.gate_outputs:
        gate = jax.nn.sigmoid(jnp.take_along_axis(logits, choice, axis=-1))
    else:
        gate = jnp.ones_like(choice, dtype=jnp.float32)
    return choice.astype(jnp.int32), gate, probs


def _act(cfg: RoutedFFNConfig) -> Callable:
    return ACTIVATIONS[cfg.activation]


def _dense_forward(x: jax.Array, p: dict, cfg: RoutedFFNConfig,
                   lora_cfg: lora.LoRAConfig,
                   hidden_mask: jax.Array) -> jax.Array:
    """Oracle: full dense FFN with the (B, S, D) hidden group mask applied."""
    g, d, f = p["w_inner"].shape[0], cfg.d_model, cfg.group_dim

    def inner(w_key, lora_key):
        w = jax.lax.stop_gradient(
            jnp.transpose(p[w_key], (1, 0, 2)).reshape(d, g * f))
        up = jnp.einsum("bsd,df->bsf", x, w.astype(x.dtype))
        if lora_cfg.enabled and lora_key in p:
            li = p[lora_key]
            c = jnp.transpose(li["c"], (1, 0, 2)).reshape(lora_cfg.rank, g * f)
            xb = jnp.einsum("bsd,dr->bsr", x, li["b"].astype(x.dtype))
            up = up + lora_cfg.scale * jnp.einsum(
                "bsr,rf->bsf", xb, c.astype(x.dtype))
        return up

    up = inner("w_inner", "lora_inner")
    if cfg.gated:
        h = _act(cfg)(inner("w_gate", "lora_gate")) * up
    else:
        h = _act(cfg)(up)
    h = h * hidden_mask.astype(h.dtype)
    w_o = jax.lax.stop_gradient(p["w_outer"]).reshape(g * f, d)
    y = jnp.einsum("bsf,fd->bsd", h, w_o.astype(x.dtype))
    if lora_cfg.enabled and "lora_outer" in p:
        lo = p["lora_outer"]
        b_ = lo["b"].reshape(g * f, lora_cfg.rank)
        hb = jnp.einsum("bsf,fr->bsr", h, b_.astype(x.dtype))
        y = y + lora_cfg.scale * jnp.einsum(
            "bsr,rd->bsd", hb, lo["c"].astype(x.dtype))
    return y


def _grouped_forward(x: jax.Array, p: dict, cfg: RoutedFFNConfig,
                     lora_cfg: lora.LoRAConfig, choice: jax.Array,
                     gate_w: jax.Array,
                     seq_lengths: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, jax.Array]:
    """BSpMV analogue: batch tokens per activated block, dense GEMM/block.

    seq_lengths: optional per-row real lengths (B,) — ragged prefill rows
    right-padded to S keep the capacity of their exact length (see
    dispatch.make_plan)."""
    b, s, d = x.shape
    cap = dispatch.capacity(s, cfg.num_groups, cfg.active_groups,
                            cfg.capacity_factor, pad=cfg.capacity_pad)
    cap_dyn = None if seq_lengths is None else dispatch.capacity_dyn(
        seq_lengths, cfg.num_groups, cfg.active_groups,
        cfg.capacity_factor, pad=cfg.capacity_pad)
    plan = dispatch.make_plan(choice, gate_w, cfg.num_groups, cap,
                              cap_dyn=cap_dyn)
    xg = dispatch.gather(x, plan)                        # (B, G, C, d)
    xg = shard(xg, "batch", None, None, None)

    def inner(w_key, lora_key):
        w = jax.lax.stop_gradient(p[w_key]).astype(x.dtype)
        up = jnp.einsum("bgcd,gdf->bgcf", xg, w)
        if lora_cfg.enabled and lora_key in p:
            li = p[lora_key]
            xb = jnp.einsum("bgcd,dr->bgcr", xg, li["b"].astype(x.dtype))
            up = up + lora_cfg.scale * jnp.einsum(
                "bgcr,grf->bgcf", xb, li["c"].astype(x.dtype))
        return up

    up = inner("w_inner", "lora_inner")
    if cfg.gated:
        h = _act(cfg)(inner("w_gate", "lora_gate")) * up
    else:
        h = _act(cfg)(up)
    h = shard(h, "batch", None, None, "ffn")
    w_o = jax.lax.stop_gradient(p["w_outer"]).astype(x.dtype)
    y = jnp.einsum("bgcf,gfd->bgcd", h, w_o)
    if lora_cfg.enabled and "lora_outer" in p:
        lo = p["lora_outer"]
        hb = jnp.einsum("bgcf,gfr->bgcr", h, lo["b"].astype(x.dtype))
        y = y + lora_cfg.scale * jnp.einsum(
            "bgcr,rd->bgcd", hb, lo["c"].astype(x.dtype))
    return dispatch.combine(y, plan, s), plan.dropped


def routed_ffn(x: jax.Array, p: dict, cfg: RoutedFFNConfig,
               lora_cfg: lora.LoRAConfig, impl: str = "grouped",
               need_aux: bool = True,
               seq_lengths: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Apply the routed FFN. x: (B, S, d) (2D inputs get a batch dim).

    ``need_aux=False`` (inference) skips the router softmax and the
    load-balance loss; aux["lb_loss"] is then zero.
    ``seq_lengths`` (B,) gives ragged prefill rows their exact-length
    dispatch capacity (the dense oracle is per-token and needs none)."""
    squeeze = x.ndim == 2
    if squeeze:
        x = x[None]
    choice, gate_w, probs = route(x, p["router"], cfg, need_aux=need_aux)
    aux = {
        "lb_loss": (dispatch.load_balance_loss(probs, choice, cfg.num_groups)
                    if need_aux else jnp.zeros((), jnp.float32)),
        "dropped": jnp.zeros((), jnp.float32),
    }
    if impl == "dense":
        oh = jax.nn.one_hot(choice, cfg.num_groups, dtype=jnp.float32)
        group_mask = jnp.max(oh * gate_w[..., None], axis=2)   # (B, S, G)
        hidden_mask = jnp.repeat(group_mask, cfg.group_dim, axis=-1)
        y = _dense_forward(x, p, cfg, lora_cfg, hidden_mask)
    elif impl == "grouped":
        y, dropped = _grouped_forward(x, p, cfg, lora_cfg, choice, gate_w,
                                      seq_lengths=seq_lengths)
        aux["dropped"] = dropped
    else:
        raise ValueError(f"unknown impl {impl!r}")
    y = y.astype(x.dtype)
    return (y[0] if squeeze else y), aux
