from repro.optim.adamw import (OptimizerConfig, adamw_init, adamw_update,  # noqa
                               global_norm)
from repro.optim.schedule import lr_at  # noqa: F401
from repro.optim.compress import (CompressionConfig, compress_tree,  # noqa
                                  decompress_tree)
