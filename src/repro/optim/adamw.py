"""AdamW over the *trainable* subtree only (LoRA + router + codebooks).

The paper's setting makes this the dominant distributed-optimization win:
optimizer state and gradient all-reduce traffic scale with the LoRA
parameter count (~0.1-1% of the model), so DP sync is nearly free even
across pods.  Weight decay is enabled (paper §6.1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01   # paper: "weight decay is enabled"
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"     # cosine | linear | constant


def adamw_init(train_params: Any) -> Any:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, train_params),
        "v": jax.tree_util.tree_map(zeros, train_params),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(train_params: Any, grads: Any, opt_state: Any,
                 step: jax.Array, cfg: OptimizerConfig,
                 lr: Optional[jax.Array] = None
                 ) -> Tuple[Any, Any, dict]:
    from repro.optim.schedule import lr_at
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12)) \
        if cfg.grad_clip > 0 else jnp.ones(())
    lr_t = lr_at(cfg, step) if lr is None else lr
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr_t * (step_ + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(train_params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    outs = [upd(p, g, m, v) for p, g, m, v in
            zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
    metrics = {"grad_norm": gnorm, "lr": lr_t}
    return new_p, {"m": new_m, "v": new_v}, metrics
