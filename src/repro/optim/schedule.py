"""Learning-rate schedules (warmup + cosine/linear decay)."""
from __future__ import annotations

import jax.numpy as jnp


def lr_at(cfg, step):
    s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (s + 1.0) / max(1, cfg.warmup_steps))
    frac = jnp.clip((s - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay
