"""Gradient compression for the pod-crossing all-reduce (beyond-paper
distributed-optimization trick, DESIGN.md §4).

Two schemes, both stateless and unbiased-ish for LoRA-scale tensors:
  * int8: per-tensor absmax scaling, symmetric int8 quantization.
  * topk: keep the top-k fraction by magnitude (values + int32 indices),
    the rest dropped (error feedback is the caller's choice).

With LoRA-only gradients the traffic is already ~1000x smaller than full
tuning; compression is for the 1000+-node regime where even that crosses
slow inter-pod links every step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    scheme: str = "int8"   # int8 | topk | none
    topk_fraction: float = 0.1


def _c_int8(x: jax.Array):
    absmax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    q = jnp.clip(jnp.round(x / absmax * 127.0), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": absmax / 127.0}


def _d_int8(c) -> jax.Array:
    return c["q"].astype(jnp.float32) * c["scale"]


def _c_topk(x: jax.Array, frac: float):
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return {"vals": flat[idx], "idx": idx.astype(jnp.int32),
            "shape": x.shape}


def _d_topk(c) -> jax.Array:
    n = 1
    for d in c["shape"]:
        n *= d
    out = jnp.zeros((n,), jnp.float32).at[c["idx"]].set(
        c["vals"].astype(jnp.float32))
    return out.reshape(c["shape"])


def compress_tree(tree: Any, cfg: CompressionConfig) -> Any:
    if cfg.scheme == "none":
        return tree
    if cfg.scheme == "int8":
        return jax.tree_util.tree_map(_c_int8, tree)
    if cfg.scheme == "topk":
        return jax.tree_util.tree_map(
            lambda x: _c_topk(x, cfg.topk_fraction), tree)
    raise ValueError(cfg.scheme)


def decompress_tree(tree: Any, cfg: CompressionConfig) -> Any:
    if cfg.scheme == "none":
        return tree
    fn = _d_int8 if cfg.scheme == "int8" else _d_topk
    is_packet = lambda x: isinstance(x, dict) and ("q" in x or "vals" in x)
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_packet)
