"""Per-architecture smoke tests (deliverable f): a REDUCED config of each
assigned arch runs one forward/train step and a prefill+decode step on CPU;
output shapes are checked and outputs must be NaN-free."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.base import ShapeSpec
from repro.configs.shapes import input_specs, materialize
from repro.models import encdec, transformer

# full per-arch compile sweep (~4 min): excluded from scripts/ci_fast.sh
pytestmark = pytest.mark.slow

SMOKE_SHAPE = ShapeSpec("smoke", "train", 32, 2)


def _hidden(cfg, params, batch):
    if cfg.family == "audio":
        return encdec.encdec_hidden(params, cfg, batch, remat=False)
    return transformer.lm_hidden(params, cfg, batch, remat=False)


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_forward_shapes_and_nans(arch):
    cfg = configs.get_smoke(arch)
    params = (encdec.encdec_defs(cfg) if cfg.family == "audio"
              else transformer.lm_defs(cfg))
    from repro.core.params import init_tree
    params = init_tree(params, jax.random.PRNGKey(0))
    specs = input_specs(cfg, SMOKE_SHAPE)
    batch = materialize(specs, jax.random.PRNGKey(1), cfg.vocab_size)
    hidden, aux = _hidden(cfg, params, batch)
    s_expected = batch["tokens"].shape[1] + (
        cfg.frontend_tokens if cfg.frontend and cfg.family != "audio" else 0)
    assert hidden.shape == (2, s_expected, cfg.d_model)
    assert not bool(jnp.isnan(hidden).any()), f"{arch}: NaN in hidden"
    logits = transformer.logits_of(params, cfg, hidden[:, -4:])
    assert logits.shape == (2, 4, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN in logits"


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_train_step_decreases_loss_shape(arch):
    """One SGD-ish step on the LoRA params runs and loss is finite."""
    cfg = configs.get_smoke(arch)
    from repro.core.params import init_tree
    defs = (encdec.encdec_defs(cfg) if cfg.family == "audio"
            else transformer.lm_defs(cfg))
    params = init_tree(defs, jax.random.PRNGKey(0))
    specs = input_specs(cfg, SMOKE_SHAPE)
    batch = materialize(specs, jax.random.PRNGKey(1), cfg.vocab_size)

    def loss_fn(p):
        hidden, aux = _hidden(cfg, p, batch)
        s_lab = batch["labels"].shape[1]
        logits = transformer.logits_of(p, cfg, hidden[:, -s_lab:])
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)
        return -jnp.mean(ll) + 0.01 * aux["lb_loss"]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    gnorm = jax.tree_util.tree_reduce(
        lambda a, x: a + jnp.sum(jnp.square(x.astype(jnp.float32))), grads, 0.0)
    assert jnp.isfinite(gnorm)


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_prefill_then_decode(arch):
    cfg = configs.get_smoke(arch)
    from repro.core.params import init_tree
    if cfg.family == "audio":
        params = init_tree(encdec.encdec_defs(cfg), jax.random.PRNGKey(0))
        batch = materialize(
            input_specs(cfg, ShapeSpec("p", "prefill", 16, 2)),
            jax.random.PRNGKey(1), cfg.vocab_size)
        caches, logits = encdec.encdec_prefill(params, cfg, batch, max_len=24)
        assert logits.shape == (2, 1, cfg.padded_vocab)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        caches, logits = encdec.encdec_decode_step(
            params, cfg, caches, tok, jnp.asarray(16, jnp.int32))
        assert logits.shape == (2, 1, cfg.padded_vocab)
        assert not bool(jnp.isnan(logits).any())
        return
    params = init_tree(transformer.lm_defs(cfg), jax.random.PRNGKey(0))
    batch = materialize(input_specs(cfg, ShapeSpec("p", "prefill", 16, 2)),
                        jax.random.PRNGKey(1), cfg.vocab_size)
    caches, logits = transformer.lm_prefill(params, cfg, batch, max_len=24)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN prefill logits"
    pos0 = batch["tokens"].shape[1] + (cfg.frontend_tokens if cfg.frontend else 0)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    for step in range(2):
        caches, logits = transformer.lm_decode_step(
            params, cfg, caches, tok, jnp.asarray(pos0 + step, jnp.int32))
        assert logits.shape == (2, 1, cfg.padded_vocab)
        assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN decode logits"
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
