"""Disaggregated batched-prefill scheduler tests.

Row-identity: the batched ragged prefill (one (Bp, S) call per admission
group, per-row lengths threaded into sparse-MHA top-L budgets and
routed-FFN dispatch capacities) must produce greedy outputs identical to
the serial batch-1 engine across {dense, sparse-MHA decode kernel on/off}
x {contiguous, paged} x ragged lengths x EOS-recycled slots.  Plus: the
prefill/decode overlap loop, non-head-of-line-blocking partial admission,
per-request top-p (nucleus) sampling, model-level ragged exactness (LM +
enc-dec), and the batched page-wise scatter.  The wide sweep is `slow`;
everything else runs in scripts/ci_fast.sh."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.params import init_tree
from repro.models import encdec, transformer
from repro.serving import kv_pages as kvp
from repro.serving.engine import Engine, Request
from repro.train.state import model_defs

MAX_LEN, SLOTS, GEN, CHUNK, PS = 48, 3, 6, 4, 16


def _tiny_cfg(**spt):
    cfg = dataclasses.replace(
        configs.get_smoke("qwen3-0.6b"), num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256)
    spt.setdefault("kv_page_size", PS)
    return cfg.with_spt(**spt)


_params_cache = {}


def _params(cfg):
    key = (cfg.name, cfg.spt.sparse_mha, cfg.spt.routed_ffn, str(cfg.dtype))
    if key not in _params_cache:
        _params_cache[key] = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    return _params_cache[key]


def _reqs(cfg, lens, gen=GEN, seed=1, **kw):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, tokens=rng.integers(
        0, cfg.vocab_size, size=ln, dtype=np.int32).tolist(),
        max_new_tokens=gen, **kw) for i, ln in enumerate(lens)]


def _serial_vs_batched(cfg, reqs, eos_id=None, kv_layout="contiguous",
                      slots=SLOTS, ratio=0.0, max_len=MAX_LEN, kv_pages=None):
    params = _params(cfg)
    run_cfg = cfg.with_spt(kv_layout=kv_layout)
    serial = Engine(run_cfg, params, max_len=max_len, num_slots=slots,
                    decode_chunk=CHUNK, prefill_batch=1, kv_pages=kv_pages)
    batched = Engine(run_cfg, params, max_len=max_len, num_slots=slots,
                     decode_chunk=CHUNK, prefill_batch=slots,
                     prefill_decode_ratio=ratio, kv_pages=kv_pages)
    out_s = serial.run(reqs, eos_id=eos_id)
    out_b = batched.run(reqs, eos_id=eos_id)
    return out_s, out_b, serial, batched


# ------------------------------------------------------------ row identity
def test_batched_matches_serial_ragged_sparse():
    """Default SPT config (sparse MHA + routed FFN at paper capacity —
    real drops possible), ragged lengths, more requests than slots."""
    cfg = _tiny_cfg()
    reqs = _reqs(cfg, [16, 5, 23, 9, 12])
    out_s, out_b, serial, batched = _serial_vs_batched(cfg, reqs)
    assert [c.tokens for c in out_b] == [c.tokens for c in out_s]
    assert [c.finish_reason for c in out_b] == \
        [c.finish_reason for c in out_s]
    # the group admission actually batched (and the serial engine didn't)
    assert serial.last_stats.prefill_batch_occupancy == 1.0
    assert batched.last_stats.prefill_batch_occupancy > 1.0
    assert batched.last_stats.prefill_batches < serial.last_stats.admitted
    assert batched.last_stats.ttft_avg_s > 0.0
    assert len(batched._chunk_cache) == 1            # still traces once


def test_batched_matches_serial_dense_paged_and_contiguous():
    cfg = dataclasses.replace(_tiny_cfg(), name="tiny-dense-b").with_spt(
        sparse_mha=False, routed_ffn=False)
    reqs = _reqs(cfg, [5, 9, 11, 16], seed=2)
    for layout in ("contiguous", "paged"):
        out_s, out_b, _, _ = _serial_vs_batched(cfg, reqs, kv_layout=layout)
        assert [c.tokens for c in out_b] == [c.tokens for c in out_s], layout


def test_batched_matches_serial_paged_sparse_eos_recycling():
    """Paged layout + sparse jnp decode + EOS retirement: slots AND pages
    recycle between groups; batched admission must not disturb either."""
    cfg = _tiny_cfg()
    reqs = _reqs(cfg, [16, 16, 16, 16], seed=3)
    free = [c.tokens for c in Engine(
        cfg, _params(cfg), max_len=MAX_LEN, num_slots=SLOTS,
        decode_chunk=CHUNK).run(reqs)]
    eos = free[0][2]
    out_s, out_b, _, eng_b = _serial_vs_batched(cfg, reqs, eos_id=eos,
                                                kv_layout="paged")
    assert [c.tokens for c in out_b] == [c.tokens for c in out_s]
    assert out_b[0].finish_reason == "eos"
    assert eng_b.last_stats.completed == 4
    assert eng_b.last_stats.kv_pages_peak <= eng_b.last_stats.kv_pages_total


def test_batched_matches_serial_sparse_decode_kernel_on_off(monkeypatch):
    """The acceptance matrix: batched admission must be row-identical to
    the serial batch-1 engine on every {contiguous, paged} x {sparse decode
    kernel, jnp fallback} variant (all-f32; each variant is compared
    against ITS OWN serial run — kernel-vs-jnp parity itself is covered by
    tests/test_sparse_decode.py with float tolerances).  The kill switch
    must also reduce the batched kernel run to the batched jnp outputs."""
    base = dataclasses.replace(
        _tiny_cfg(), dtype=jnp.float32, name="tiny-f32").with_spt(
        routed_ffn=False)
    reqs = _reqs(base, [9, 14, 6], gen=3, seed=5)

    def run(layout, impl, batch, disable=False):
        monkeypatch.setenv("REPRO_DISABLE_KERNELS", "1" if disable else "0")
        cfg = base.with_spt(kv_layout=layout, decode_attn_impl=impl)
        try:
            eng = Engine(cfg, _params(base), max_len=32, num_slots=2,
                         decode_chunk=CHUNK, prefill_batch=batch)
            return [c.tokens for c in eng.run(reqs)]
        finally:
            monkeypatch.setenv("REPRO_DISABLE_KERNELS", "0")

    for layout in ("contiguous", "paged"):
        for impl in ("jnp", "kernel"):
            serial = run(layout, impl, batch=1)
            assert run(layout, impl, batch=2) == serial, (layout, impl)
    # kill switch: batched kernel run falls back to the batched jnp outputs
    assert run("paged", "kernel", batch=2, disable=True) \
        == run("paged", "jnp", batch=2)


def test_overlap_ratio_interleaves_and_matches():
    """prefill_decode_ratio > 0 interleaves admission groups with decode
    chunks (more, smaller prefill batches) without changing outputs."""
    cfg = _tiny_cfg()
    reqs = _reqs(cfg, [12, 9, 16, 7, 11, 14], seed=4)
    out_s, out_b, _, eng_o = _serial_vs_batched(cfg, reqs, ratio=1.0,
                                                slots=2)
    assert [c.tokens for c in out_b] == [c.tokens for c in out_s]
    s = eng_o.last_stats
    assert s.admitted == 6 and s.completed == 6
    assert s.prefill_batches >= 2      # the budget split the admissions


def test_partial_admission_no_head_of_line_block():
    """A big request that does not fit the page pool must not block later
    requests that do: they admit first, the big one follows once pages
    free, accounting stays correct."""
    cfg = _tiny_cfg()
    rng = np.random.default_rng(7)
    big = Request(uid=0, tokens=rng.integers(
        0, cfg.vocab_size, size=30, dtype=np.int32).tolist(),
        max_new_tokens=GEN)
    small = [Request(uid=1 + i, tokens=rng.integers(
        0, cfg.vocab_size, size=6, dtype=np.int32).tolist(),
        max_new_tokens=GEN) for i in range(2)]
    reqs = [big] + small
    frontend = 0
    ws_big = kvp.num_pages(30 + GEN - 1, PS)
    ws_small = kvp.num_pages(6 + GEN - 1, PS)
    pool = ws_big + ws_small          # big + one small, never all three
    params = _params(cfg)
    eng = Engine(cfg.with_spt(kv_layout="paged"), params, max_len=MAX_LEN,
                 num_slots=SLOTS, decode_chunk=CHUNK, kv_pages=pool)
    # seed a long-running resident so the pool is tight from the start:
    # run all three + resident together
    resident = Request(uid=9, tokens=rng.integers(
        0, cfg.vocab_size, size=30, dtype=np.int32).tolist(),
        max_new_tokens=GEN)
    out = eng.run([resident] + reqs)
    s = eng.last_stats
    assert s.admitted == 4 and s.completed == 4
    assert s.admission_stalls > 0      # somebody had to wait for pages
    # row-identity against the serial contiguous engine
    ref = Engine(cfg, params, max_len=MAX_LEN, num_slots=SLOTS,
                 decode_chunk=CHUNK, prefill_batch=1).run([resident] + reqs)
    assert [c.tokens for c in out] == [c.tokens for c in ref]


# ------------------------------------------------------------ model level
def test_lm_prefill_ragged_batch_rows_exact():
    """(Bp, S) batched ragged prefill logits == per-row batch-1 exact-length
    prefill, bitwise, for the length-sensitive default config at paper
    capacity (per-row top-L budgets + dispatch capacities)."""
    cfg = _tiny_cfg()
    assert transformer.length_sensitive(cfg)
    params = _params(cfg)
    rng = np.random.default_rng(11)
    lens = [5, 9, 16, 11]
    toks = np.zeros((4, 16), np.int32)
    prompts = []
    for i, ln in enumerate(lens):
        p = rng.integers(0, cfg.vocab_size, size=ln, dtype=np.int32)
        prompts.append(p)
        toks[i, :ln] = p
    _, lg_b = transformer.lm_prefill_ragged(
        params, cfg, {"tokens": jnp.asarray(toks)},
        jnp.asarray(lens, jnp.int32), MAX_LEN)
    for i, p in enumerate(prompts):
        _, lg_1 = transformer.lm_prefill_ragged(
            params, cfg, {"tokens": jnp.asarray(p[None, :])},
            jnp.asarray([len(p)], jnp.int32), MAX_LEN)
        np.testing.assert_array_equal(np.asarray(lg_b[i, -1]),
                                      np.asarray(lg_1[0, -1]))


def test_encdec_prefill_ragged_rows_match_batch1():
    """Enc-dec ragged prefill: per-row last-position logits equal the
    batch-1 encdec_prefill of each row at exact length."""
    cfg = dataclasses.replace(
        configs.get_smoke("whisper-base"), num_layers=2, encoder_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        vocab_size=256)
    params = init_tree(encdec.encdec_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(13)
    frames = jnp.asarray(rng.standard_normal((3, 6, cfg.d_model)),
                         jnp.float32)
    lens = [4, 9, 6]
    toks = np.zeros((3, 9), np.int32)
    prompts = []
    for i, ln in enumerate(lens):
        p = rng.integers(0, cfg.vocab_size, size=ln, dtype=np.int32)
        prompts.append(p)
        toks[i, :ln] = p
    _, lg_b = encdec.encdec_prefill_ragged(
        params, cfg, {"tokens": jnp.asarray(toks),
                      "frontend_embeds": frames},
        jnp.asarray(lens, jnp.int32), 24)
    for i, p in enumerate(prompts):
        _, lg_1 = encdec.encdec_prefill(
            params, cfg, {"tokens": jnp.asarray(p[None, :]),
                          "frontend_embeds": frames[i:i + 1]}, 24)
        a = np.asarray(lg_b[i, -1], np.float32)
        b = np.asarray(lg_1[0, -1], np.float32)
        assert int(a.argmax()) == int(b.argmax()), f"row {i}"
        np.testing.assert_allclose(a, b, rtol=1e-2, atol=1e-2)


def test_scatter_prefill_rows_batched_pagewise():
    """The batched page-wise scatter == per-row scatter_prefill loop, and
    dummy rows (all -1 page ids) drop without touching the pool."""
    rng = np.random.default_rng(17)
    pool0 = jnp.zeros((6, 2, PS, 8), jnp.float32)
    pts = jnp.asarray([[2, 5], [3, -1], [-1, -1]])       # row 2 = dummy
    seqs = jnp.asarray(rng.standard_normal((3, 2, 2 * PS, 8)), jnp.float32)
    got = kvp.scatter_prefill_rows(pool0, pts, seqs, PS)
    want = pool0
    for i in range(2):                                   # real rows only
        want = kvp.scatter_prefill(want, pts[i], seqs[i], PS)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # slot_pos-style (P, ps) pools too
    spool = jnp.full((6, PS), -1, jnp.int32)
    sseq = jnp.arange(3 * 2 * PS, dtype=jnp.int32).reshape(3, 2 * PS)
    got_s = kvp.scatter_prefill_rows(spool, pts, sseq, PS, pad_value=-1)
    want_s = spool
    for i in range(2):
        want_s = kvp.scatter_prefill(want_s, pts[i], sseq[i], PS, -1)
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))


# ---------------------------------------------------------------- sampling
def test_top_p_tiny_equals_greedy():
    """top_p -> 0 keeps only the top-1 token (the first sorted token always
    survives the nucleus), so sampling must reproduce greedy exactly —
    in the chunk AND in the host-side first-token path."""
    cfg = _tiny_cfg()
    reqs = _reqs(cfg, [16, 12], seed=19)
    greedy = [c.tokens for c in Engine(
        cfg, _params(cfg), max_len=MAX_LEN, num_slots=2,
        decode_chunk=CHUNK).run(reqs)]
    nucleus = [dataclasses.replace(r, temperature=1.3, top_p=1e-6)
               for r in reqs]
    out = Engine(cfg, _params(cfg), max_len=MAX_LEN, num_slots=2,
                 decode_chunk=CHUNK).run(nucleus, key=jax.random.PRNGKey(5))
    assert [c.tokens for c in out] == greedy


def test_top_p_statistical_nucleus_membership():
    """Every sampled token must lie inside the nucleus of the step's
    distribution: replay the engine's own prefix through the per-token
    decode path, recompute the nucleus set, assert membership.  Also:
    reproducible under the same key, moved by a different key."""
    cfg = dataclasses.replace(_tiny_cfg(), dtype=jnp.float32,
                              name="tiny-f32-topp")
    params = _params(cfg)
    top_p, temp, gen = 0.8, 1.5, 5
    prompts = _reqs(cfg, [14, 10], gen=gen, seed=23,
                    temperature=temp, top_p=top_p)
    eng = Engine(cfg, params, max_len=MAX_LEN, num_slots=2,
                 decode_chunk=CHUNK)
    out = eng.run(prompts, key=jax.random.PRNGKey(29))
    again = eng.run(prompts, key=jax.random.PRNGKey(29))
    assert [c.tokens for c in again] == [c.tokens for c in out]
    eng.run(prompts, key=jax.random.PRNGKey(31))     # different key: no
    # equality asserted (a tiny vocab can coincide), but the path runs
    # replay: logits at each step given the engine's generated prefix
    prefill = jax.jit(lambda p_, t: transformer.lm_prefill(
        p_, cfg, {"tokens": t}, max_len=MAX_LEN))
    decode = jax.jit(lambda p_, c, t, pos: transformer.lm_decode_step(
        p_, cfg, c, t, pos))
    for r, c in zip(prompts, out):
        toks = jnp.asarray(np.asarray(r.tokens, np.int32)[None])
        caches, logits = prefill(params, toks)
        pos0 = toks.shape[1]
        seq = c.tokens
        for t, picked in enumerate(seq):
            lg = np.asarray(logits[0, -1], np.float32)
            scaled = lg / temp
            srt = np.sort(scaled)[::-1]
            e = np.exp(srt - srt[0])
            probs = e / e.sum()
            cum = np.cumsum(probs)
            kcnt = max(1, int(((cum - probs) < top_p + 1e-5).sum()))
            nucleus = set(np.argsort(scaled)[::-1][:kcnt].tolist())
            assert picked in nucleus, f"step {t}: {picked} not in nucleus"
            if t + 1 < len(seq):
                caches, logits = decode(
                    params, caches, jnp.asarray([picked], jnp.int32),
                    jnp.asarray(pos0 + t, jnp.int32))


# ------------------------------------------------------------- wide sweep
@pytest.mark.slow
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("sparse", [False, True])
def test_batched_parity_sweep(layout, sparse):
    cfg = _tiny_cfg() if sparse else dataclasses.replace(
        _tiny_cfg(), name=f"tiny-sweep-{layout}").with_spt(
        sparse_mha=False, routed_ffn=False)
    reqs = _reqs(cfg, [16, 7, 21, 11, 5, 13], seed=37)
    out_s, out_b, _, _ = _serial_vs_batched(cfg, reqs, kv_layout=layout)
    assert [c.tokens for c in out_b] == [c.tokens for c in out_s]
