"""MoE kernel reuse (ROADMAP PR-3 follow-on): models/moe.py lowers through
the fused routed-FFN Pallas kernels — grouped (train/prefill, softmax
top-k gates in place of the |logit| router) and block-gather decode — with
the jnp capacity path as the differentiated reference and the
REPRO_DISABLE_KERNELS kill switch honored.  Interpret mode on CPU."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.analysis import jaxpr_audit
from repro.core import dispatch
from repro.core.params import init_tree
from repro.models import moe
from repro.serving.engine import Engine, Request
from repro.train.state import model_defs


def _cfg(**kw):
    cfg = configs.get_smoke("grok-1-314b")
    return dataclasses.replace(cfg, **kw) if kw else cfg


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    p = init_tree(moe.moe_defs(cfg), jax.random.PRNGKey(0))
    return cfg, p


def test_moe_kernel_matches_reference(setup):
    cfg, p = setup
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    yr, ar = moe.moe_apply(p, x, cfg, mode="train")
    yk, ak = moe.moe_apply(p, x, cfg.with_spt(ffn_impl="pallas"),
                           mode="train")
    np.testing.assert_allclose(np.asarray(yk, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=2e-2, atol=2e-3)
    np.testing.assert_allclose(float(ak["lb_loss"]), float(ar["lb_loss"]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(ak["dropped"]), float(ar["dropped"]),
                               rtol=1e-6)
    # inference skips the load-balance loss on both paths
    _, ai = moe.moe_apply(p, x, cfg.with_spt(ffn_impl="pallas"),
                          mode="prefill")
    assert float(ai["lb_loss"]) == 0.0


def test_moe_kernel_backward_matches_reference(setup):
    """The custom VJP differentiates the jnp reference (identical routing
    plan => identical function), so gradients agree to float noise."""
    cfg, p = setup
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, cfg.d_model))

    def loss(cfg_):
        def f(pp):
            y, aux = moe.moe_apply(pp, x, cfg_, mode="train")
            return jnp.sum(y.astype(jnp.float32) ** 2) + aux["lb_loss"]
        return jax.grad(f)(p)

    gr = loss(cfg)
    gk = loss(cfg.with_spt(ffn_impl="pallas"))
    for a, b in zip(jax.tree_util.tree_leaves(gr),
                    jax.tree_util.tree_leaves(gk)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-4)


def test_moe_decode_kernel_matches_grouped(setup):
    cfg, p = setup
    x = jax.random.normal(jax.random.PRNGKey(3), (3, 1, cfg.d_model))
    ck = cfg.with_spt(ffn_impl="pallas")
    assert dispatch.use_decode_ffn_kernel(ck)            # auto follows
    yk, ak = moe.moe_apply(p, x, ck, mode="decode")
    yr, _ = moe.moe_apply(p, x, cfg, mode="decode")
    assert float(ak["lb_loss"]) == 0.0
    np.testing.assert_allclose(np.asarray(yk, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=2e-2, atol=2e-3)


def test_moe_decode_builds_no_dispatch_buffer(setup):
    """At (B, 1, d) the decode path must not materialize a (B, E, C, d)
    capacity buffer — the expert ids index the weight blocks directly.
    Checked through the same analysis helper `python -m repro.analysis`
    gates CI with (one definition of "dispatch buffer", two enforcers)."""
    cfg, p = setup
    b, e = 4, cfg.num_experts
    x = jnp.zeros((b, 1, cfg.d_model))
    jaxpr = jax.make_jaxpr(lambda x: moe.moe_apply(
        p, x, cfg.with_spt(ffn_impl="pallas"), mode="decode")[0])(x)
    assert jaxpr_audit.dispatch_buffer_violations(
        jaxpr, batch=b, groups=e, entry="moe.decode") == []
    assert jaxpr_audit.pallas_call_count(jaxpr) > 0


def test_moe_kill_switch(setup, monkeypatch):
    cfg, p = setup
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, cfg.d_model))
    ck = cfg.with_spt(ffn_impl="pallas")
    monkeypatch.setenv("REPRO_DISABLE_KERNELS", "1")
    jaxpr = jax.make_jaxpr(
        lambda x: moe.moe_apply(p, x, ck, mode="train")[0])(x)
    assert jaxpr_audit.kernel_count_violations(jaxpr, "moe.kill-switch",
                                               "none") == []
    yd, _ = moe.moe_apply(p, x, ck, mode="train")
    monkeypatch.setenv("REPRO_DISABLE_KERNELS", "0")
    yr, _ = moe.moe_apply(p, x, cfg, mode="train")
    np.testing.assert_array_equal(np.asarray(yd), np.asarray(yr))


def test_moe_engine_greedy_kernel_on_vs_off():
    """Engine-level greedy serving of the MoE smoke arch: prefill through
    the fused grouped kernel, decode through the block-gather kernel,
    completions identical to the jnp path (all-f32 so accumulation-order
    noise cannot flip an argmax)."""
    base = dataclasses.replace(_cfg(), dtype=jnp.float32).with_spt(
        sparse_mha=False)
    params = jax.tree_util.tree_map(
        lambda a: a.astype(jnp.float32),
        init_tree(model_defs(base), jax.random.PRNGKey(0)))
    rng = np.random.default_rng(6)
    reqs = [Request(uid=i, tokens=rng.integers(
        0, base.vocab_size, size=ln).tolist(), max_new_tokens=3)
        for i, ln in enumerate([7, 11])]

    def run(impl):
        eng = Engine(base.with_spt(ffn_impl=impl), params, max_len=24,
                     num_slots=2, decode_chunk=4)
        return [c.tokens for c in eng.run(reqs)]

    assert run("pallas") == run("grouped")
