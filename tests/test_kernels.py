"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret=True),
over shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pq
from repro.core import sparse_attention as sa
from repro.core import routed_ffn as rf
from repro.core import lora as lora_mod
from repro.core.params import init_tree

# interpret-mode shape/dtype sweeps (~2-3 min): excluded from ci_fast.sh
pytestmark = pytest.mark.slow


def _cb(head_dim, code_dim=8, e=16, seed=0):
    cfg = pq.PQConfig(head_dim=head_dim, code_dim=code_dim, num_codewords=e)
    return cfg, init_tree(pq.param_defs(cfg), jax.random.PRNGKey(seed))["codebooks"]


# ------------------------------------------------------------ pq_quantize
@pytest.mark.parametrize("shape,dtype", [
    ((1, 1, 32, 16), jnp.float32),
    ((2, 3, 64, 32), jnp.float32),
    ((2, 2, 48, 64), jnp.bfloat16),
    ((1, 4, 128, 24), jnp.float32),
])
def test_pq_assign_kernel_matches_ref(shape, dtype):
    from repro.kernels.pq_quantize.ops import pq_assign
    from repro.kernels.pq_quantize.ref import pq_assign_ref
    cfg, cb = _cb(shape[-1], code_dim=8)
    x = jax.random.normal(jax.random.PRNGKey(1), shape).astype(dtype)
    got = pq_assign(x, cb, interpret=True)
    want = pq_assign_ref(x, cb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pq_assign_kernel_tiling_invariance():
    from repro.kernels.pq_quantize.ops import pq_assign
    cfg, cb = _cb(32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 2, 128, 32))
    a = pq_assign(x, cb, tile_n=32, interpret=True)
    b = pq_assign(x, cb, tile_n=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------ topl_select
@pytest.mark.parametrize("nq,nk,l,causal,window", [
    (32, 32, 8, True, None),
    (64, 64, 16, True, 24),
    (16, 48, 12, False, None),
    (64, 128, 32, True, None),
])
def test_topl_kernel_matches_ref(nq, nk, l, causal, window):
    from repro.kernels.topl_select.ops import topl_select, topl_thresholds
    from repro.kernels.topl_select.ref import thresholds_ref, topl_select_ref
    key = jax.random.PRNGKey(3)
    m = 4
    cq = jax.random.randint(key, (3, nq, m), 0, 16)
    ck = jax.random.randint(jax.random.PRNGKey(4), (3, nk, m), 0, 16)
    kw = dict(l=l, max_score=m, causal=causal, window=window)
    np.testing.assert_array_equal(
        np.asarray(topl_thresholds(cq, ck, interpret=True, **kw)),
        np.asarray(thresholds_ref(cq, ck, **kw)))
    ik, vk = topl_select(cq, ck, interpret=True, **kw)
    ir, vr = topl_select_ref(cq, ck, **kw)
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
    np.testing.assert_array_equal(np.asarray(vk), np.asarray(vr))


# ------------------------------------------------------ sparse attention
@pytest.mark.parametrize("b,hq,hk,n,d,frac,causal,window,dtype", [
    (2, 4, 2, 64, 32, 0.25, True, None, jnp.float32),
    (1, 2, 2, 64, 16, 0.125, True, None, jnp.float32),
    (2, 4, 1, 32, 32, 0.5, True, 16, jnp.float32),
    (1, 4, 4, 128, 64, 0.25, True, None, jnp.bfloat16),
    (1, 2, 2, 48, 24, 0.25, False, None, jnp.float32),
])
def test_fused_sparse_attention_matches_ref(b, hq, hk, n, d, frac, causal,
                                            window, dtype):
    from repro.kernels.sparse_attention.ops import sparse_mha as k_mha
    pcfg, cb = _cb(d)
    scfg = sa.SparseAttentionConfig(pq=pcfg, top_fraction=frac, min_l=4,
                                    chunk_q=16)
    q = jax.random.normal(jax.random.PRNGKey(5), (b, hq, n, d)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(6), (b, hk, n, d)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(7), (b, hk, n, d)).astype(dtype)
    out_k, _ = k_mha(q, k, v, cb, scfg, d ** -0.5, causal=causal,
                     window=window, interpret=True)
    out_r, _ = sa.sparse_mha(q, k, v, cb, scfg, d ** -0.5, causal=causal,
                             window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)


def test_fused_sparse_attention_backward_matches_ref():
    from repro.kernels.sparse_attention.ops import sparse_mha as k_mha
    pcfg, cb = _cb(32)
    scfg = sa.SparseAttentionConfig(pq=pcfg, top_fraction=0.25, min_l=4,
                                    chunk_q=16)
    q = jax.random.normal(jax.random.PRNGKey(8), (1, 2, 32, 32))
    k = jax.random.normal(jax.random.PRNGKey(9), (1, 2, 32, 32))
    v = jax.random.normal(jax.random.PRNGKey(10), (1, 2, 32, 32))

    def fk(q, k, v):
        return jnp.sum(k_mha(q, k, v, cb, scfg, 32 ** -0.5,
                             interpret=True)[0] ** 2)

    def fr(q, k, v):
        return jnp.sum(sa.sparse_mha(q, k, v, cb, scfg, 32 ** -0.5)[0] ** 2)

    gk = jax.grad(fk, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------ routed ffn
@pytest.mark.parametrize("bsz,s,d,dff,g,act_g,gated,act", [
    (2, 16, 32, 64, 4, 2, False, "relu"),
    (1, 24, 32, 64, 4, 2, True, "gelu"),
    (2, 16, 48, 96, 8, 4, True, "silu"),
    (1, 32, 64, 128, 4, 3, False, "gelu"),
])
def test_routed_ffn_kernel_matches_ref(bsz, s, d, dff, g, act_g, gated, act):
    from repro.kernels.routed_ffn.ops import routed_ffn as k_rffn
    lcfg = lora_mod.LoRAConfig(rank=4, alpha=4.0)
    rcfg = rf.RoutedFFNConfig(d_model=d, d_ff=dff, num_groups=g,
                              active_groups=act_g, capacity_factor=4.0,
                              gated=gated, activation=act)
    p = init_tree(rf.param_defs(rcfg, lcfg), jax.random.PRNGKey(11))
    x = jax.random.normal(jax.random.PRNGKey(12), (bsz, s, d))
    yk, _ = k_rffn(x, p, rcfg, lcfg, interpret=True)
    yr, _ = rf.routed_ffn(x, p, rcfg, lcfg, impl="grouped")
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=2e-2, atol=2e-3)


def test_routed_ffn_kernel_backward_matches_ref():
    from repro.kernels.routed_ffn.ops import routed_ffn as k_rffn
    lcfg = lora_mod.LoRAConfig(rank=4, alpha=4.0)
    rcfg = rf.RoutedFFNConfig(d_model=32, d_ff=64, num_groups=4,
                              active_groups=2, capacity_factor=4.0,
                              gated=True, activation="gelu")
    p = init_tree(rf.param_defs(rcfg, lcfg), jax.random.PRNGKey(13))
    x = jax.random.normal(jax.random.PRNGKey(14), (2, 16, 32))

    def fk(p):
        return jnp.sum(k_rffn(x, p, rcfg, lcfg, interpret=True)[0] ** 2)

    def fr(p):
        return jnp.sum(rf.routed_ffn(x, p, rcfg, lcfg, impl="grouped")[0] ** 2)

    gk = jax.grad(fk)(p)
    gr = jax.grad(fr)(p)
    flat_k = jax.tree_util.tree_leaves_with_path(gk)
    flat_r = {jax.tree_util.keystr(kp): v
              for kp, v in jax.tree_util.tree_leaves_with_path(gr)}
    for kp, v in flat_k:
        np.testing.assert_allclose(
            np.asarray(v), np.asarray(flat_r[jax.tree_util.keystr(kp)]),
            rtol=2e-2, atol=2e-3, err_msg=jax.tree_util.keystr(kp))
