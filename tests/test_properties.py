"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import dispatch, pq
from repro.core import sparse_attention as sa

jax.config.update("jax_platform_name", "cpu")

small = dict(max_examples=20, deadline=None)


# --------------------------------------------------------------- PQ
@settings(**small)
@given(n=st.integers(4, 32), m=st.integers(1, 4), e=st.integers(2, 8),
       seed=st.integers(0, 2 ** 16))
def test_pq_codes_in_range_and_self_score_max(n, m, e, seed):
    dp = 4
    key = jax.random.PRNGKey(seed)
    cb = jax.random.normal(key, (m, e, dp))
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, n, m * dp))
    codes = pq.assign(x, cb)
    assert codes.shape == (1, n, m)
    assert int(codes.min()) >= 0 and int(codes.max()) < e
    s = pq.match_scores(codes, codes, e)
    diag = jnp.diagonal(s, axis1=-2, axis2=-1)
    assert bool((diag == m).all())
    assert float(s.max()) <= m and float(s.min()) >= 0
    # symmetry
    np.testing.assert_array_equal(np.asarray(s),
                                  np.asarray(jnp.swapaxes(s, -1, -2)))


@settings(**small)
@given(seed=st.integers(0, 2 ** 16))
def test_pq_ema_reduces_quantization_error(seed):
    key = jax.random.PRNGKey(seed)
    cfg = pq.PQConfig(head_dim=16, code_dim=4, num_codewords=8)
    cb = jax.random.normal(key, (4, 8, 4))
    x = jax.random.normal(jax.random.fold_in(key, 1), (256, 16))
    e0 = float(pq.quantization_error(x, cb))
    for _ in range(10):
        cb = pq.ema_update(cb, x, ema=0.3)
    e1 = float(pq.quantization_error(x, cb))
    assert e1 <= e0 + 1e-5


# --------------------------------------------------------- selection
@settings(**small)
@given(nq=st.integers(4, 24), nk=st.integers(4, 48), l=st.integers(1, 16),
       maxs=st.integers(1, 6), pmask=st.floats(0.2, 1.0),
       seed=st.integers(0, 2 ** 16))
def test_bucket_select_equals_sort_select(nq, nk, l, maxs, pmask, seed):
    l = min(l, nk)
    key = jax.random.PRNGKey(seed)
    s = jax.random.randint(key, (2, nq, nk), 0, maxs + 1).astype(jnp.float32)
    mask = jax.random.bernoulli(jax.random.fold_in(key, 1), pmask,
                                (2, nq, nk))
    i1, v1 = sa.select_topl(s, l, mask)
    i2, v2 = sa.bucket_select(s, mask, l, maxs)
    a1, a2 = np.asarray(i1), np.asarray(i2)
    m1, m2 = np.asarray(v1), np.asarray(v2)
    assert m1.sum() == m2.sum()
    for b in range(a1.shape[0]):
        for q in range(a1.shape[1]):
            s1 = set(a1[b, q][m1[b, q]].tolist())
            s2 = set(a2[b, q][m2[b, q]].tolist())
            assert s1 == s2
    # count == min(L, #valid)
    nvalid = np.asarray(mask).sum(-1)
    np.testing.assert_array_equal(m2.sum(-1), np.minimum(nvalid, l))
    # all selected indices are valid positions
    mk = np.asarray(mask)
    for b in range(a2.shape[0]):
        for q in range(a2.shape[1]):
            for j, ok in zip(a2[b, q], m2[b, q]):
                if ok:
                    assert mk[b, q, j]


# --------------------------------------------------------- dispatch
@settings(**small)
@given(bsz=st.integers(1, 3), s=st.integers(2, 24), g=st.integers(2, 6),
       k=st.integers(1, 3), seed=st.integers(0, 2 ** 16))
def test_dispatch_roundtrip_identity(bsz, s, g, k, seed):
    """combine(gather(x)) with unit gates == k * x when nothing drops."""
    k = min(k, g)
    key = jax.random.PRNGKey(seed)
    choice = jax.random.randint(key, (bsz, s, k), 0, g)
    # force distinct choices per token to mimic top-k without replacement
    gate = jnp.ones((bsz, s, k), jnp.float32)
    cap = s * k  # no drops possible
    plan = dispatch.make_plan(choice, gate, g, cap)
    assert float(plan.dropped) == 0.0
    x = jax.random.normal(jax.random.fold_in(key, 2), (bsz, s, 8))
    xg = dispatch.gather(x, plan)
    y = dispatch.combine(xg, plan, s)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) * k,
                               rtol=1e-5, atol=1e-5)


@settings(**small)
@given(bsz=st.integers(1, 2), s=st.integers(4, 16), seed=st.integers(0, 999))
def test_dispatch_capacity_drops_are_reported(bsz, s, seed):
    g, k = 4, 2
    key = jax.random.PRNGKey(seed)
    # all tokens to group 0 -> guaranteed overflow at cap=8 < s*k
    choice = jnp.zeros((bsz, s, k), jnp.int32)
    gate = jnp.ones((bsz, s, k), jnp.float32)
    cap = 8
    plan = dispatch.make_plan(choice, gate, g, cap)
    expected_drop = max(0, s * k - cap) / (s * k)
    assert abs(float(plan.dropped) - expected_drop) < 1e-5


# --------------------------------------------------- sparse attention
@settings(**small)
@given(seed=st.integers(0, 2 ** 16), frac=st.sampled_from([0.25, 0.5, 1.0]))
def test_sparse_attention_rows_are_convex_combos(seed, frac):
    """Each output row lies in the convex hull of V rows (softmax weights)."""
    key = jax.random.PRNGKey(seed)
    cfg = pq.PQConfig(head_dim=16, code_dim=4, num_codewords=8)
    cb = jax.random.normal(key, (4, 8, 4))
    scfg = sa.SparseAttentionConfig(pq=cfg, top_fraction=frac, min_l=2,
                                    chunk_q=8)
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 16, 16))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 16, 16))
    v = jax.random.normal(jax.random.fold_in(key, 3), (1, 2, 16, 16))
    out, _ = sa.sparse_mha(q, k, v, cb, scfg, 0.25, causal=True)
    vmin = np.asarray(v).min(axis=2, keepdims=True)
    vmax = np.asarray(v).max(axis=2, keepdims=True)
    o = np.asarray(out)
    assert (o >= vmin - 1e-4).all() and (o <= vmax + 1e-4).all()
    assert not np.isnan(o).any()
