"""The explicit shard_map FFN schedule must be numerically identical to the
pjit grouped path (values and LoRA gradients) — checked on a trivial 1x1
mesh (multi-device behavior is covered by the dry-run compile proof)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ffn_shmap, lora as lora_mod
from repro.core import routed_ffn as rf
from repro.core.params import init_tree
from repro.launch.mesh import make_mesh


def _setup():
    lcfg = lora_mod.LoRAConfig(rank=4, alpha=4.0)
    rcfg = rf.RoutedFFNConfig(d_model=32, d_ff=64, num_groups=4,
                              active_groups=2, capacity_factor=4.0,
                              gated=True, activation="gelu")
    p = init_tree(rf.param_defs(rcfg, lcfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    return lcfg, rcfg, p, x


def test_shmap_matches_grouped_values_and_grads():
    lcfg, rcfg, p, x = _setup()
    mesh = make_mesh((1, 1), ("data", "model"))
    assert ffn_shmap.applicable(mesh, rcfg, 64, 16, 2)
    with mesh:
        y_s, aux_s = jax.jit(
            lambda x, p: ffn_shmap.routed_ffn_shmap(x, p, rcfg, lcfg, mesh)
        )(x, p)
    y_g, aux_g = rf.routed_ffn(x, p, rcfg, lcfg, impl="grouped")
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_g),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(float(aux_s["lb_loss"]),
                               float(aux_g["lb_loss"]), rtol=1e-5)

    with mesh:
        def loss_s(p):
            y, _ = ffn_shmap.routed_ffn_shmap(x, p, rcfg, lcfg, mesh)
            return jnp.sum(y.astype(jnp.float32) ** 2)
        g_s = jax.jit(jax.grad(loss_s))(p)

    def loss_g(p):
        y, _ = rf.routed_ffn(x, p, rcfg, lcfg, impl="grouped")
        return jnp.sum(y.astype(jnp.float32) ** 2)
    g_g = jax.grad(loss_g)(p)
    flat_g = {jax.tree_util.keystr(kp): v for kp, v in
              jax.tree_util.tree_leaves_with_path(g_g)}
    for kp, v in jax.tree_util.tree_leaves_with_path(g_s):
        key = jax.tree_util.keystr(kp)
        if "lora" in key or "router" in key:
            np.testing.assert_allclose(np.asarray(v), np.asarray(flat_g[key]),
                                       rtol=2e-3, atol=2e-3, err_msg=key)


def test_shmap_applicability_gates():
    lcfg, rcfg, p, x = _setup()
    assert not ffn_shmap.applicable(None, rcfg, 64, 16, 2)
    mesh = make_mesh((1, 1), ("data", "model"))
    # seq not divisible by tp=1 is impossible; group_dim check:
    bad = rf.RoutedFFNConfig(d_model=32, d_ff=60, num_groups=4,
                             active_groups=2)
    assert bad.d_ff % bad.num_groups == 0
