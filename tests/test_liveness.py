"""Memory-lifetime analysis tests: the liveness pass on hand-built
jaxprs with known peak live sets, a violating fixture per audit rule
(liveness.*, donation.*, memory.*), the engine-level greedy bit-identity
check for the extended chunk donation mask, and the full clean-at-HEAD
sweep (slow), following the tests/test_analysis.py pattern.

Byte expectations: pinned/donated straight-line peaks are exact; loop
and dynamic_update_slice fixtures allow a +64 B slack for the scalar
index/counter constants jax inserts."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro.analysis import baselines as bl
from repro.analysis import donation as dn
from repro.analysis import liveness as lv
from repro.analysis import registry

N = 4096            # one (1024,) f32 buffer


def rules(violations):
    return sorted({v.rule for v in violations})


def peak_of(fn, args, donated=None, names=None):
    closed = jax.make_jaxpr(fn)(*args)
    rep = lv.analyze_closed(closed, donated=donated, arg_names=names,
                            entry="fixture")
    return rep


def _x():
    return jax.ShapeDtypeStruct((1024,), jnp.float32)


# ------------------------------------------------------- liveness fixtures
def test_straight_line_pinned_vs_donated():
    """y = x*2; z = y*3.  Pinned x is resident at the second eqn
    (x+y+z = 3N); donated x dies after the first (peak y+z = 2N)."""
    fn = lambda x: (x * 2.0) * 3.0
    pinned = peak_of(fn, (_x(),), donated=[False], names=["x"])
    donated = peak_of(fn, (_x(),), donated=[True], names=["x"])
    assert pinned.signature.peak_live_bytes == 3 * N
    assert donated.signature.peak_live_bytes == 2 * N
    assert donated.signature.donated_bytes == N
    # provenance: the arg label survives into the peak contributors
    assert any(c.label == "x" for c in pinned.peak.contributors)


def test_while_carry_copy_surcharge():
    """A while carry holds one N-byte buffer: the body's live set is
    ~2N (old + new carry value).  A donated operand aliases the carry
    (peak ~2N); a pinned operand pays the copy-on-entry surcharge — the
    caller's buffer stays resident alongside the loop's copy (~3N)."""
    def fn(x):
        return jax.lax.while_loop(
            lambda c: c[0] < 10,
            lambda c: (c[0] + 1, c[1] * 2.0),
            (jnp.int32(0), x))[1]

    donated = peak_of(fn, (_x(),), donated=[True], names=["x"])
    pinned = peak_of(fn, (_x(),), donated=[False], names=["x"])
    assert 2 * N <= donated.signature.peak_live_bytes <= 2 * N + 64
    assert 3 * N <= pinned.signature.peak_live_bytes <= 3 * N + 64
    assert (pinned.signature.peak_live_bytes
            - donated.signature.peak_live_bytes) == N


def test_dynamic_update_slice_aliases_donated_operand():
    """An in-place cache write (DUS) whose operand is donated aliases
    its output (~1N + the row); pinned keeps both copies (~2N)."""
    row = jax.ShapeDtypeStruct((64,), jnp.float32)

    def fn(x, r):
        return jax.lax.dynamic_update_slice(x, r, (0,))

    rb = 64 * 4
    donated = peak_of(fn, (_x(), row), donated=[True, False])
    pinned = peak_of(fn, (_x(), row), donated=[False, False])
    assert N + rb <= donated.signature.peak_live_bytes <= N + rb + 64
    assert 2 * N + rb <= pinned.signature.peak_live_bytes <= 2 * N + rb + 64


def test_pallas_scratch_counts_exactly():
    """A pallas_call contributes operands + outputs + VMEM scratch and
    is never recursed into (its refs are not HBM buffers)."""
    from repro.kernels.topl_select.topl_select import vmem

    def kernel(x_ref, o_ref, s_ref):
        s_ref[...] = x_ref[...] * 2.0
        o_ref[...] = s_ref[...]

    shape = jax.ShapeDtypeStruct((128,), jnp.float32)   # 512 B
    fn = pl.pallas_call(kernel, out_shape=shape,
                        scratch_shapes=[vmem((128,), jnp.float32)],
                        interpret=True)
    rep = peak_of(fn, (shape,))
    assert rep.signature.peak_live_bytes == 3 * 512     # x + o + scratch
    assert rep.signature.pallas_calls == 1


def test_scan_xs_and_stacked_ys_stay_resident():
    """scan holds the full xs and the filling ys for its whole run:
    peak ≥ xs + ys + carry even though each iteration sees one slice."""
    xs = jax.ShapeDtypeStruct((8, 1024), jnp.float32)   # 8N... = 32768

    def fn(xs):
        return jax.lax.scan(lambda c, x: (c + x.sum(), x * 2.0),
                            jnp.float32(0.0), xs)[1]

    rep = peak_of(fn, (xs,), donated=[True], names=["xs"])
    assert rep.signature.peak_live_bytes >= 2 * 8 * N   # xs + stacked ys
    # per-iteration slices are labeled with provenance
    assert any(c.label == "xs[iter]" for c in rep.peak.contributors)


# --------------------------------------------------- liveness audit rules
def test_liveness_trace_failure_rule():
    def boom():
        raise RuntimeError("no trace")

    assert rules(lv.entry_violations("e", boom)) \
        == ["liveness.trace-failure"]


def test_liveness_empty_rule():
    empty = lv.MemoryReport(
        "e", lv.MemorySignature(0, 0, 0, 0), (),
        lv.PeakInfo(0, "entry", ()))
    assert rules(lv.entry_violations("e", lambda: empty)) \
        == ["liveness.empty"]


def test_liveness_donation_unused_rule():
    """An entry registered with expect_donation must report donated
    bytes — a zero means the mask plumbing silently broke."""
    assert "engine.decode_chunk" in lv._EXPECT_DONATION
    rep = lv.MemoryReport(
        "engine.decode_chunk", lv.MemorySignature(100, 0, 1, 0), (),
        lv.PeakInfo(100, "entry", ()))
    assert rules(lv.entry_violations("engine.decode_chunk", lambda: rep)) \
        == ["liveness.donation-unused"]


# ---------------------------------------------------- donation audit rules
def test_donation_missing_rule_fires_on_undonated_cache():
    cache = jnp.zeros((256,), jnp.float32)
    f = jax.jit(lambda c, x: c + x)                     # nothing donated
    vs = dn.donation_violations("e", f, (cache, jnp.float32(1.0)))
    assert rules(vs) == ["donation.missing"]
    g = jax.jit(lambda c, x: c + x, donate_argnums=(0,))
    assert dn.donation_violations("e", g, (cache, jnp.float32(1.0))) == []


@pytest.mark.filterwarnings("ignore:Some donated buffers were not usable")
def test_donation_cannot_alias_rule_fires_on_shape_mismatch():
    f = jax.jit(lambda x: x.sum(), donate_argnums=(0,))
    vs = dn.donation_violations("e", f, (jnp.ones((64,)),))
    assert rules(vs) == ["donation.cannot-alias"]


def test_donation_exempt_argnums_are_skipped():
    cache = jnp.zeros((256,), jnp.float32)
    f = jax.jit(lambda c, x: c + x)
    assert dn.donation_violations("e", f, (cache, jnp.float32(1.0)),
                                  exempt_argnums=(0,)) == []


def test_jit_site_lint():
    bad = "import jax\nf = jax.jit(g)\n"
    marked = ("import jax\n"
              "# no-donate: params are engine-owned\n"
              "f = jax.jit(g)\n")
    donating = "import jax\nf = jax.jit(g, donate_argnums=(0,))\n"
    assert rules(dn.jit_site_violations(bad, "serving/x.py")) \
        == ["donation.jit-site"]
    assert dn.jit_site_violations(marked, "serving/x.py") == []
    assert dn.jit_site_violations(donating, "serving/x.py") == []


# ----------------------------------------------------- memory ratchet rules
def _sig(peak=1000, donated=100, eqns=50, pallas=2):
    return {"peak_live_bytes": peak, "donated_bytes": donated,
            "eqns": eqns, "pallas_calls": pallas}


def test_memory_ratchet_fails_on_injected_regression():
    """The acceptance-criterion fixture: a grown live set (or a lost
    donation) against the golden signature must fail the gate."""
    golden = {"e": _sig()}
    assert bl.diff_signatures({"e": _sig()}, golden) == []
    assert rules(bl.diff_signatures({"e": _sig(peak=1500)}, golden)) \
        == ["memory.regression"]
    assert rules(bl.diff_signatures({"e": _sig(donated=0)}, golden)) \
        == ["memory.regression"]


def test_memory_ratchet_flags_unrecorded_improvements():
    golden = {"e": _sig()}
    assert rules(bl.diff_signatures({"e": _sig(peak=900)}, golden)) \
        == ["memory.stale-baseline"]
    assert rules(bl.diff_signatures({"e": _sig(donated=200)}, golden)) \
        == ["memory.stale-baseline"]


def test_memory_ratchet_flags_shape_drift_and_missing_entries():
    golden = {"e": _sig()}
    assert rules(bl.diff_signatures({"e": _sig(pallas=3)}, golden)) \
        == ["memory.signature-drift"]
    assert rules(bl.diff_signatures({"e": _sig(eqns=60)}, golden)) \
        == ["memory.signature-drift"]        # +20% > the ±10% band
    assert bl.diff_signatures({"e": _sig(eqns=54)}, golden) == []
    assert rules(bl.diff_signatures({"e": _sig(), "new": _sig()}, golden)) \
        == ["memory.baseline-missing"]
    assert rules(bl.diff_signatures({}, golden)) \
        == ["memory.baseline-missing"]


def test_committed_baselines_parse_and_cover_registry():
    golden = bl.load_baselines()
    assert set(golden) == set(lv.MEMORY_ENTRYPOINTS)
    for sig in golden.values():
        assert set(sig) == set(bl._FIELDS)
        assert sig["peak_live_bytes"] > 0


# ------------------------------------------- engine greedy bit-identity
def test_greedy_stream_bit_identical_under_donation():
    """The extended chunk donation mask (slot state included) must not
    change a single token vs the undonated eager engine — donated
    buffers being reused while the scheduler still holds host mirrors
    would show up here first."""
    from repro import configs
    from repro.core.params import init_tree
    from repro.serving.engine import Engine, Request
    from repro.train.state import model_defs

    cfg = dataclasses.replace(
        configs.get_smoke("qwen3-0.6b"), num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256).with_spt(ffn_capacity_factor=8.0)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    reqs = [Request(uid=i, tokens=rng.integers(
                0, 256, size=ln, dtype=np.int32).tolist(),
                max_new_tokens=6)
            for i, ln in enumerate([8, 11, 6, 9])]
    outs = {}
    for use_jit in (True, False):
        eng = Engine(cfg, params, max_len=48, jit=use_jit, num_slots=2,
                     decode_chunk=4)
        res = eng.run(list(reqs))
        outs[use_jit] = [(c.uid, c.tokens, c.finish_reason) for c in res]
    assert outs[True] == outs[False]


# ------------------------------------------------- full registry (slow)
@pytest.mark.slow
def test_memory_audits_clean_at_head():
    """liveness + donation + memory-ratchet over the real entrypoints —
    the same sweep scripts/analyze.sh gates CI with."""
    assert registry.run_audits(["liveness", "donation", "memory"]) == []
