"""Routed-FFN Pallas kernel-path parity (interpret=True on CPU).

Covers the fused grouped kernel (in-kernel scalar-prefetch dispatch) and
the decode block-gather kernel against the jnp grouped oracle:
gated/ungated x LoRA on/off x capacity drops x non-tile-multiple C and F
x decode shape (B, 1, d), plus the dispatch gating switches and an
engine-level greedy kernel-on == kernel-off check.  Fast cases run in
scripts/ci_fast.sh; only the widest sweep is `slow`."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.analysis import jaxpr_audit
from repro.core import dispatch
from repro.core import lora as lora_mod
from repro.core import routed_ffn as rf
from repro.core.params import init_tree
from repro.kernels.routed_ffn import ops as rffn_ops
from repro.kernels.routed_ffn.ref import decode_ffn_ref
from repro.kernels.routed_ffn.routed_ffn import (decode_ffn_kernel,
                                                 grouped_ffn_kernel)
from repro.models import ffn
from repro.serving.engine import Engine, Request
from repro.train.state import model_defs


def _setup(d, dff, g, gp, gated, lora_on, capf=4.0, act="gelu",
           gate_out=False, seed=0):
    lcfg = lora_mod.LoRAConfig(rank=4, alpha=4.0, enabled=lora_on)
    rcfg = rf.RoutedFFNConfig(d_model=d, d_ff=dff, num_groups=g,
                              active_groups=gp, capacity_factor=capf,
                              gated=gated, activation=act,
                              gate_outputs=gate_out)
    p = init_tree(rf.param_defs(rcfg, lcfg), jax.random.PRNGKey(seed))
    return rcfg, lcfg, p


# ------------------------------------------------------ fused grouped op
@pytest.mark.parametrize("bsz,s,d,dff,g,gp,gated,lora_on,capf", [
    (2, 16, 32, 64, 4, 2, False, False, 4.0),
    (1, 24, 32, 64, 4, 2, True, True, 4.0),
    (2, 64, 32, 64, 8, 4, True, True, 0.25),     # forces capacity drops
    (1, 16, 48, 96, 4, 3, False, True, 4.0),
])
def test_fused_grouped_matches_grouped(bsz, s, d, dff, g, gp, gated,
                                       lora_on, capf):
    rcfg, lcfg, p = _setup(d, dff, g, gp, gated, lora_on, capf)
    x = jax.random.normal(jax.random.PRNGKey(1), (bsz, s, d))
    yk, auxk = rffn_ops.routed_ffn(x, p, rcfg, lcfg, interpret=True)
    yr, auxr = rf.routed_ffn(x, p, rcfg, lcfg, impl="grouped")
    if capf < 1.0:                       # the drop case actually dropped
        assert float(auxr["dropped"]) > 0.0
    np.testing.assert_allclose(float(auxk["dropped"]),
                               float(auxr["dropped"]), rtol=1e-6)
    np.testing.assert_allclose(float(auxk["lb_loss"]),
                               float(auxr["lb_loss"]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=2e-2, atol=2e-3)


def test_fused_grouped_skips_aux_at_inference():
    rcfg, lcfg, p = _setup(32, 64, 4, 2, True, True)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 32))
    choice, gate_w, probs = rf.route(x, p["router"], rcfg, need_aux=False)
    assert probs is None                       # no softmax at inference
    y1, aux1 = rffn_ops.routed_ffn(x, p, rcfg, lcfg, interpret=True,
                                   need_aux=False)
    y0, aux0 = rffn_ops.routed_ffn(x, p, rcfg, lcfg, interpret=True)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y0))
    assert float(aux1["lb_loss"]) == 0.0 and float(aux0["lb_loss"]) > 0.0
    # jnp grouped path honors the same flag
    yg, auxg = rf.routed_ffn(x, p, rcfg, lcfg, impl="grouped",
                             need_aux=False)
    assert float(auxg["lb_loss"]) == 0.0
    np.testing.assert_array_equal(
        np.asarray(yg),
        np.asarray(rf.routed_ffn(x, p, rcfg, lcfg, impl="grouped")[0]))


def test_grouped_kernel_tile_padding_invariance():
    """Non-tile-multiple C and F zero-pad to the tile multiple (the old
    kernel silently fell back to whole-dimension tiles): capacity 48 with
    tile_c=32 pads to 64, F=16 with tile_f=12 pads to 24."""
    rcfg, lcfg, p = _setup(32, 64, 4, 2, True, True)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 24, 32))
    choice, gate_w, _ = rf.route(x, p["router"], rcfg, need_aux=False)
    cap = dispatch.capacity(24, 4, 2, 4.0)
    assert cap == 48
    plan = dispatch.make_plan(choice, gate_w, 4, cap)
    lp = {k: p[k] for k in ("lora_inner", "lora_gate", "lora_outer")}

    def run(tc, tf):
        return grouped_ffn_kernel(
            x, plan.index, p["w_inner"], p["w_outer"], p["w_gate"], lp,
            lcfg.scale, act=rcfg.activation, tile_c=tc, tile_f=tf,
            interpret=True)

    base = run(128, 256)                       # whole-dim tiles
    assert base.shape == (2, 4, cap, 32)
    ok = np.asarray(plan.slot_ok)[..., None]
    for tc, tf in [(32, 256), (128, 12), (32, 12)]:
        got = run(tc, tf)
        assert got.shape == base.shape         # padding sliced back off
        np.testing.assert_allclose(
            np.where(ok, np.asarray(got), 0.0),
            np.where(ok, np.asarray(base), 0.0), rtol=1e-4, atol=1e-4,
            err_msg=f"tc={tc} tf={tf}")


# ------------------------------------------------------------ decode path
@pytest.mark.parametrize("b,d,dff,g,gp,gated,lora_on,gate_out", [
    (4, 32, 64, 4, 2, False, False, False),
    (3, 32, 96, 8, 4, True, True, False),
    (2, 48, 96, 4, 3, True, True, True),
    (5, 64, 128, 4, 1, False, True, True),
])
def test_decode_kernel_matches_grouped_and_ref(b, d, dff, g, gp, gated,
                                               lora_on, gate_out):
    rcfg, lcfg, p = _setup(d, dff, g, gp, gated, lora_on,
                           gate_out=gate_out)
    x = jax.random.normal(jax.random.PRNGKey(4), (b, 1, d))
    yk, aux = rffn_ops.routed_ffn_decode(x, p, rcfg, lcfg, interpret=True)
    assert yk.shape == x.shape
    assert float(aux["lb_loss"]) == 0.0
    # vs the block-gather jnp oracle
    choice, gate_w, _ = rf.route(x, p["router"], rcfg, need_aux=False)
    lp = ({k: p[k] for k in ("lora_inner", "lora_gate", "lora_outer")
           if k in p} if lora_on else None)
    yr = decode_ffn_ref(x[:, 0], choice[:, 0], gate_w[:, 0], p["w_inner"],
                        p["w_outer"], p.get("w_gate"), lp, lcfg.scale,
                        act=rcfg.activation)
    np.testing.assert_allclose(np.asarray(yk[:, 0]), np.asarray(yr),
                               rtol=2e-3, atol=2e-3)
    # vs the grouped capacity path (no drops possible at S=1)
    yg, _ = rf.routed_ffn(x, p, rcfg, lcfg, impl="grouped", need_aux=False)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yg),
                               rtol=2e-3, atol=2e-3)


def test_decode_kernel_f_tile_padding_invariance():
    rcfg, lcfg, p = _setup(48, 96, 4, 3, True, True, gate_out=True)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 1, 48))
    choice, gate_w, _ = rf.route(x, p["router"], rcfg, need_aux=False)
    lp = {k: p[k] for k in ("lora_inner", "lora_gate", "lora_outer")}
    args = (x[:, 0], choice[:, 0], gate_w[:, 0], p["w_inner"],
            p["w_outer"], p["w_gate"], lp, lcfg.scale)
    a = decode_ffn_kernel(*args, act="gelu", tile_f=16, interpret=True)
    b_ = decode_ffn_kernel(*args, act="gelu", tile_f=256, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                               rtol=1e-4, atol=1e-4)


def test_decode_path_builds_no_dispatch_buffer():
    """The acceptance property: at (B, 1, d) the decode path must not
    materialize a (B, G, C, d) dispatch buffer — checked structurally on
    the jaxpr (no intermediate carries the G*C slot plan)."""
    rcfg, lcfg, p = _setup(32, 64, 8, 2, True, True)
    b = 4
    x = jnp.zeros((b, 1, 32))
    jaxpr = jax.make_jaxpr(
        lambda x: rffn_ops.routed_ffn_decode(x, p, rcfg, lcfg,
                                             interpret=True)[0])(x)
    # the same analysis helper python -m repro.analysis gates CI with:
    # one definition of "dispatch buffer", two enforcers — and it walks
    # nested jaxprs (pjit bodies), unlike the old top-level eqn loop
    assert jaxpr_audit.dispatch_buffer_violations(
        jaxpr, batch=b, groups=rcfg.num_groups,
        entry="routed_ffn.decode") == []
    assert jaxpr_audit.pallas_call_count(jaxpr) > 0


# ------------------------------------------------------- dispatch gating
def test_ffn_kernel_dispatch_switches(monkeypatch):
    cfg = configs.get_smoke("qwen3-0.6b").with_spt(ffn_impl="pallas")
    assert dispatch.use_routed_ffn_kernel(cfg)
    assert dispatch.use_decode_ffn_kernel(cfg)          # auto follows
    monkeypatch.setenv("REPRO_DISABLE_KERNELS", "1")
    assert not dispatch.use_routed_ffn_kernel(cfg)
    assert not dispatch.use_decode_ffn_kernel(cfg)
    monkeypatch.setenv("REPRO_DISABLE_KERNELS", "0")
    grouped = cfg.with_spt(ffn_impl="grouped")
    assert not dispatch.use_routed_ffn_kernel(grouped)
    assert not dispatch.use_decode_ffn_kernel(grouped)  # auto follows
    assert dispatch.use_decode_ffn_kernel(
        grouped.with_spt(decode_ffn_impl="kernel"))
    assert not dispatch.use_decode_ffn_kernel(
        cfg.with_spt(decode_ffn_impl="jnp"))


def test_decode_ffn_impl_jnp_overrides_pallas():
    """decode_ffn_impl="jnp" must force the grouped jnp path at decode
    even when ffn_impl="pallas" keeps the train/prefill kernel on — the
    per-path override exists so a suspected decode-kernel bug can be
    bisected without the global kill switch."""
    cfg = dataclasses.replace(
        configs.get_smoke("qwen3-0.6b"), num_layers=1, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256).with_spt(ffn_impl="pallas", decode_ffn_impl="jnp")
    assert not dispatch.use_decode_ffn_kernel(cfg)
    p = init_tree(ffn.ffn_defs(cfg), jax.random.PRNGKey(6))
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 1, 64))
    jaxpr = jax.make_jaxpr(
        lambda x: ffn.ffn_apply(p, x, cfg, mode="decode")[0])(x)
    assert jaxpr_audit.kernel_count_violations(
        jaxpr, "ffn.decode-jnp-override", "none") == [], \
        "decode still lowers via Pallas"
    y, _ = ffn.ffn_apply(p, x, cfg, mode="decode")
    yg, _ = ffn.ffn_apply(p, x, cfg.with_spt(ffn_impl="grouped"),
                          mode="decode")
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yg))


# ------------------------------------------------------------ engine e2e
def test_engine_greedy_identical_kernel_on_vs_off(monkeypatch):
    """ffn_impl="pallas" serves prefill through the fused grouped kernel
    and decode through the block-gather kernel (inside the compiled
    lax.while_loop chunk); greedy completions must be identical to the
    grouped jnp path, and REPRO_DISABLE_KERNELS=1 must reproduce them
    even with ffn_impl="pallas".  All-f32 keeps the accumulation-order
    difference inside float noise (same rationale as the sparse-decode
    engine test)."""
    base = dataclasses.replace(
        configs.get_smoke("qwen3-0.6b"), num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256, dtype=jnp.float32).with_spt(
            sparse_mha=False, ffn_capacity_factor=8.0)
    assert ffn.routed_applicable(base)
    params = jax.tree_util.tree_map(
        lambda a: a.astype(jnp.float32),
        init_tree(model_defs(base), jax.random.PRNGKey(0)))
    rng = np.random.default_rng(5)
    reqs = [Request(uid=i, tokens=rng.integers(0, 256, size=ln).tolist(),
                    max_new_tokens=3)
            for i, ln in enumerate([7, 11])]

    def run(impl, disable=False):
        monkeypatch.setenv("REPRO_DISABLE_KERNELS", "1" if disable else "0")
        cfg = base.with_spt(ffn_impl=impl)
        eng = Engine(cfg, params, max_len=24, num_slots=2, decode_chunk=4)
        try:
            return [c.tokens for c in eng.run(reqs)]
        finally:
            monkeypatch.setenv("REPRO_DISABLE_KERNELS", "0")

    want = run("grouped")
    assert run("pallas") == want
    assert run("pallas", disable=True) == want          # kill switch
