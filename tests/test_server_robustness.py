"""Long-lived serving-loop robustness tests (engine.serve + serving/chaos).

Covers: ArrivalSchedule/ManualClock determinism, priority preemption with
recompute re-admission, TTFT-deadline shedding, per-token streaming
callbacks, mid-stream + queued cancellation, rejection isolation, the SLO
percentile stats, and seeded chaos soaks across {contiguous, paged} x
{sparse decode kernel, jnp} with the invariant watchdog asserted after
every scheduling iteration — zero slot/page leaks, and never-preempted
greedy requests bit-identical to a burst-mode run() of the same workload.
A hypothesis sweep randomizes the arrival/fault schedule on top of the
fixed-seed soaks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.params import init_tree
from repro.serving import chaos
from repro.serving.engine import (ArrivalSchedule, Engine, ManualClock,
                                  Request)
from repro.train.state import model_defs

MAX_LEN, SLOTS, CHUNK, PS = 64, 4, 4, 16


def _tiny_cfg(**spt):
    cfg = dataclasses.replace(
        configs.get_smoke("qwen3-0.6b"), num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256)
    spt.setdefault("kv_page_size", PS)
    return cfg.with_spt(ffn_capacity_factor=8.0, **spt)


_params_cache = {}


def _params(cfg):
    key = (cfg.name, cfg.spt.sparse_mha, str(cfg.dtype))
    if key not in _params_cache:
        p = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
        if cfg.dtype == jnp.float32:
            p = jax.tree_util.tree_map(lambda a: a.astype(jnp.float32), p)
        _params_cache[key] = p
    return _params_cache[key]


def _reqs(cfg, n, seed=1, gen_lo=2, gen_hi=7, priorities=False):
    rng = np.random.default_rng(seed)
    return [Request(
        uid=i,
        tokens=rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(4, 17)),
                            dtype=np.int32).tolist(),
        max_new_tokens=int(rng.integers(gen_lo, gen_hi)),
        priority=int(rng.integers(0, 3)) if priorities else 0)
        for i in range(n)]


def _engine(cfg, **kw):
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("num_slots", SLOTS)
    kw.setdefault("decode_chunk", CHUNK)
    return Engine(cfg, _params(cfg), **kw)


# -------------------------------------------------- arrivals & clock units
def test_manual_clock_advances_per_iteration():
    clk = ManualClock(dt=0.5)
    assert clk() == 0.0
    clk.advance()
    clk.advance()
    assert clk() == 1.0


def test_arrival_schedule_poisson_seeded_and_ordered():
    reqs = [Request(uid=i, tokens=[1], max_new_tokens=1) for i in range(8)]
    a = ArrivalSchedule.poisson(reqs, rate_qps=2.0, seed=7)
    b = ArrivalSchedule.poisson(reqs, rate_qps=2.0, seed=7)
    c = ArrivalSchedule.poisson(reqs, rate_qps=2.0, seed=8)
    ta = [a.next_time() or 0.0]
    got_a = []
    while not a.exhausted:
        t = a.next_time()
        got_a.extend((t, r.uid) for r in a.due(t))
    assert [u for _, u in got_a] == list(range(8))      # FIFO per process
    assert sorted(t for t, _ in got_a) == [t for t, _ in got_a]
    assert b.next_time() == ta[0]
    assert c.next_time() != ta[0]


def test_arrival_schedule_due_trace_and_burst():
    r = [Request(uid=i, tokens=[1], max_new_tokens=1) for i in range(3)]
    tr = ArrivalSchedule.from_trace([(2.0, r[2]), (0.5, r[0]), (1.0, r[1])])
    assert [q.uid for q in tr.due(1.0)] == [0, 1]
    assert not tr.exhausted and tr.next_time() == 2.0
    assert [q.uid for q in tr.due(5.0)] == [2] and tr.exhausted
    bu = ArrivalSchedule.burst(r)
    assert [q.uid for q in bu.due(0.0)] == [0, 1, 2]


# ----------------------------------------------------- scheduling semantics
def test_priority_preemption_evicts_and_resumes():
    """A high-priority arrival on a full engine evicts the low-priority
    victim (pages + slot freed); the victim re-admits via recompute and
    still finishes its full budget, with the pre-eviction tokens intact."""
    cfg = dataclasses.replace(_tiny_cfg(kv_layout="paged"),
                              dtype=jnp.float32)
    eng = _engine(cfg, num_slots=1, decode_chunk=2)
    rng = np.random.default_rng(3)
    low = Request(uid=0, tokens=rng.integers(0, 256, 8).tolist(),
                  max_new_tokens=8, priority=0)
    high = Request(uid=1, tokens=rng.integers(0, 256, 6).tolist(),
                   max_new_tokens=4, priority=5)
    wd = chaos.Watchdog()
    out = eng.serve(ArrivalSchedule.from_trace([(0.0, low), (1.0, high)]),
                    clock=ManualClock(), on_iteration=wd)
    ref_low = _engine(cfg, num_slots=1).run([low])[0]
    ref_high = _engine(cfg, num_slots=1).run([high])[0]
    assert out[0].uid == 0 and out[0].preemptions >= 1
    assert out[0].finish_reason == "length" and len(out[0].tokens) == 8
    assert out[0].tokens == ref_low.tokens      # recompute resume is exact
    assert out[1].preemptions == 0 and out[1].tokens == ref_high.tokens
    assert eng.last_stats.preemptions >= 1
    assert wd.iterations > 0


def test_deadline_lapse_sheds_queued_request():
    """A queued request whose TTFT deadline lapses (and which cannot
    preempt the higher-priority occupant) is shed, not served late."""
    cfg = _tiny_cfg()
    eng = _engine(cfg, num_slots=1, decode_chunk=2)
    rng = np.random.default_rng(4)
    hog = Request(uid=0, tokens=rng.integers(0, 256, 8).tolist(),
                  max_new_tokens=16, priority=1)
    dl = Request(uid=1, tokens=rng.integers(0, 256, 8).tolist(),
                 max_new_tokens=4, priority=0, deadline_s=2.0)
    out = eng.serve(ArrivalSchedule.from_trace([(0.0, hog), (0.5, dl)]),
                    clock=ManualClock())
    assert out[0].finish_reason == "length" and len(out[0].tokens) == 16
    assert out[1].finish_reason == "shed" and out[1].tokens == []
    assert eng.last_stats.shed == 1


def test_deadline_urgency_preempts_deadline_free_peer():
    """At >= 50% of its TTFT deadline, a queued request may evict a
    deadline-free peer of EQUAL priority (strictly-lower priority is
    always evictable; this is the SLO tie-breaker)."""
    cfg = _tiny_cfg()
    eng = _engine(cfg, num_slots=1, decode_chunk=2)
    rng = np.random.default_rng(5)
    peer = Request(uid=0, tokens=rng.integers(0, 256, 8).tolist(),
                   max_new_tokens=16)
    dl = Request(uid=1, tokens=rng.integers(0, 256, 8).tolist(),
                 max_new_tokens=4, deadline_s=4.0)
    out = eng.serve(ArrivalSchedule.from_trace([(0.0, peer), (0.5, dl)]),
                    clock=ManualClock())
    assert out[1].finish_reason == "length"     # met: preempted the peer
    assert out[0].preemptions >= 1 and len(out[0].tokens) == 16
    assert eng.last_stats.preemptions >= 1


def test_streaming_callbacks_deliver_every_token():
    cfg = _tiny_cfg()
    events = []
    reqs = _reqs(cfg, 5, seed=6)
    for r in reqs:
        r.on_token = lambda uid, tok, done: events.append((uid, tok, done))
    eng = _engine(cfg)
    out = eng.run(reqs)
    for c in out:
        streamed = [t for u, t, _ in events if u == c.uid]
        flags = [d for u, _, d in events if u == c.uid]
        assert streamed == c.tokens
        assert flags[-1] and not any(flags[:-1])    # done exactly at last


def test_cancel_queued_and_midstream():
    cfg = _tiny_cfg()
    eng = _engine(cfg, num_slots=1, decode_chunk=2)
    reqs = _reqs(cfg, 2, seed=7, gen_lo=8, gen_hi=9)
    ref = _engine(cfg, num_slots=1).run([reqs[0]])[0]

    def hook(e, it):
        if it == 1:
            assert e.cancel(1)          # still queued (1 slot)
        if it == 2:
            assert e.cancel(0)          # mid-stream
        assert not e.cancel(99)         # unknown uid is a no-op

    out = eng.run(reqs, on_iteration=hook)
    assert out[1].finish_reason == "cancelled" and out[1].tokens == []
    assert out[0].finish_reason == "cancelled"
    assert 0 < len(out[0].tokens) < 8
    assert out[0].tokens == ref.tokens[:len(out[0].tokens)]
    assert eng.last_stats.cancelled == 2
    assert eng.last_stats.completed == 0


def test_rejection_isolation_and_slo_stats_keys():
    """Oversized + duplicate requests reject without touching the rest of
    the batch, and as_dict carries both the legacy keys and the new SLO
    percentiles/robustness counters."""
    cfg = _tiny_cfg()
    eng = _engine(cfg)
    good = _reqs(cfg, 3, seed=8)
    bad = [Request(uid=90, tokens=[1, 2], max_new_tokens=MAX_LEN + 1),
           Request(uid=1, tokens=[3, 4], max_new_tokens=2)]  # dup uid
    out = eng.run(good + bad)
    assert [c.finish_reason for c in out[:3]] == ["length"] * 3
    assert [c.finish_reason for c in out[3:]] == ["rejected"] * 2
    assert "max_len" in out[3].detail and "duplicate" in out[4].detail
    d = eng.last_stats.as_dict()
    for k in ("admitted", "completed", "prefill_s", "decode_s",
              "prefill_tok_s", "decode_tok_s", "prefill_batches",
              "prefill_batch_occupancy", "ttft_avg_s",
              "ttft_max_s"):                        # legacy keys intact
        assert k in d, k
    for k in ("ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "tpot_p99_s",
              "preemptions", "rejections", "cancelled", "shed"):
        assert k in d, k
    assert d["rejections"] == 2
    assert d["ttft_p50_s"] <= d["ttft_p99_s"] <= d["ttft_max_s"] + 1e-9


# ------------------------------------------------------------- chaos soaks
def _soak_and_check(cfg, *, kv_pages=None, seed=0, n=16,
                    requests=None, monkey=None):
    """Run a seeded chaos soak and check the acceptance contract: zero
    leaks (watchdog on every iteration), every submission reaches exactly
    one terminal completion, preempted requests still finish their full
    budget, and never-preempted greedy completions are bit-identical to a
    burst-mode run() of the same requests."""
    reqs = _reqs(cfg, n, seed=seed) if requests is None else requests
    n = len(reqs)
    eng = _engine(cfg, kv_pages=kv_pages)
    monkey = monkey or chaos.ChaosMonkey(
        seed, cancel_p=0.15, preempt_p=0.2, dup_p=0.1, oversized_p=0.1,
        hog_p=0.1, force_preempt_at=3)
    out, report = chaos.run_soak(eng, reqs, seed=seed, monkey=monkey)
    assert eng._live is None
    assert len(out) == eng.last_stats.submitted >= n
    assert all(c is not None for c in out)
    assert report["injected"].get("forced_preempt", 0) >= 1
    ref = {c.uid: c for c in _engine(cfg).run(reqs)}
    mine = {c.uid: c for c in out
            if c.uid < n and c.finish_reason != "rejected"}
    assert sorted(mine) == list(range(n))       # nothing lost or duped
    by_uid = {r.uid: r for r in reqs}
    for uid, c in mine.items():
        r = by_uid[uid]
        if c.finish_reason == "length":
            assert len(c.tokens) == r.max_new_tokens
            if c.preemptions == 0:
                assert c.tokens == ref[uid].tokens, uid
        elif c.finish_reason == "cancelled":
            if c.preemptions == 0:
                assert c.tokens == ref[uid].tokens[:len(c.tokens)], uid
        else:
            assert c.finish_reason == "shed" and c.tokens == []
    return out, report, eng


@pytest.mark.parametrize("layout,impl", [
    ("contiguous", "jnp"), ("contiguous", "kernel"),
    ("paged", "jnp"), ("paged", "kernel")])
def test_chaos_soak_layout_kernel_matrix(layout, impl):
    cfg = _tiny_cfg(kv_layout=layout, decode_attn_impl=impl)
    _soak_and_check(cfg, kv_pages=8 if layout == "paged" else None,
                    seed=11, n=12)


def test_chaos_soak_acceptance_64_requests():
    """The ISSUE-8 acceptance soak: >= 64 requests under Poisson arrivals
    on a constrained page pool, with injected exhaustion hogs, cancels,
    duplicate/oversized rejects, and forced preemption — zero slot/page
    leaks after every iteration and burst-identical unpreempted rows."""
    cfg = _tiny_cfg(kv_layout="paged")
    reqs = _reqs(cfg, 64, seed=13, priorities=True)
    out, report, eng = _soak_and_check(
        cfg, kv_pages=8, seed=13, requests=reqs,
        monkey=chaos.ChaosMonkey(13, cancel_p=0.1, preempt_p=0.15,
                                 dup_p=0.1, oversized_p=0.1, hog_p=0.15,
                                 force_preempt_at=4))
    assert eng.last_stats.preemptions >= 1
    assert eng.last_stats.rejections >= 1
    assert eng.last_stats.admission_stalls >= 1     # pool exhaustion hit
    assert report["iterations"] >= 8


def _random_soak(seed, rate, cancel_p, preempt_p):
    """Random arrival rates and cancel/preempt mixes must never leak
    slots/pages or lose a request (shared by the hypothesis sweep and the
    fixed-seed fallback when hypothesis is absent)."""
    cfg = _tiny_cfg(kv_layout="paged")
    reqs = _reqs(cfg, 8, seed=seed % 97, gen_lo=2, gen_hi=5)
    eng = _engine(cfg, kv_pages=8)
    monkey = chaos.ChaosMonkey(seed, cancel_p=cancel_p,
                               preempt_p=preempt_p, dup_p=0.05,
                               oversized_p=0.05, hog_p=0.05,
                               force_preempt_at=None)
    out, report = chaos.run_soak(eng, reqs, seed=seed, rate_qps=rate,
                                 monkey=monkey)
    assert eng._live is None
    assert len(out) == eng.last_stats.submitted >= 8
    assert all(c is not None for c in out)
    got = {c.uid for c in out if c.uid < 8 and c.finish_reason != "rejected"}
    assert got == set(range(8))


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2 ** 16), rate=st.floats(0.5, 8.0),
           cancel_p=st.floats(0.0, 0.3), preempt_p=st.floats(0.0, 0.3))
    def test_chaos_soak_randomized_schedules(seed, rate, cancel_p,
                                             preempt_p):
        _random_soak(seed, rate, cancel_p, preempt_p)
except ImportError:                      # image lacks hypothesis: pinned mix
    @pytest.mark.parametrize("seed,rate,cancel_p,preempt_p", [
        (101, 0.7, 0.0, 0.3), (202, 3.0, 0.3, 0.0), (303, 7.5, 0.2, 0.2)])
    def test_chaos_soak_randomized_schedules(seed, rate, cancel_p,
                                             preempt_p):
        _random_soak(seed, rate, cancel_p, preempt_p)
