"""Sharding-spec machinery + roofline parsers (unit level, 1 device)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from repro import configs
from repro.core.params import ParamDef, spec_tree, stack_defs
from repro.launch import roofline
from repro.sharding.context import spec_for


RULES = {"heads": "model", "ffn": "model", "embed": None,
         "batch": ("pod", "data"),
         "__sizes__": {"model": 16, "data": 16, "pod": 2}}


def test_spec_tree_divisibility_fallback():
    defs = {
        "ok": ParamDef((64, 32), axes=("embed", "heads")),     # 32 % 16 == 0
        "bad": ParamDef((64, 24), axes=("embed", "heads")),    # 24 % 16 != 0
    }
    specs = spec_tree(defs, RULES)
    assert specs["ok"] == PartitionSpec(None, "model")
    assert specs["bad"] == PartitionSpec(None, None)


def test_spec_tree_axis_used_once():
    defs = {"w": ParamDef((32, 32), axes=("heads", "ffn"))}
    spec = spec_tree(defs, RULES)["w"]
    # both logical axes map to "model"; only the first dim may take it
    assert spec == PartitionSpec("model", None)


def test_stacked_defs_get_layer_axis():
    defs = stack_defs({"w": ParamDef((8, 32), axes=(None, "ffn"))}, 4)
    assert defs["w"].shape == (4, 8, 32)
    assert spec_tree(defs, RULES)["w"] == PartitionSpec(None, None, "model")


def test_spec_for_batch_multi_axis():
    spec = spec_for((64, 128), ("batch", None), RULES)
    assert spec == PartitionSpec(("pod", "data"), None)
    # batch not divisible by pod*data => replicated
    assert spec_for((7, 128), ("batch", None), RULES) == \
        PartitionSpec(None, None)


# ------------------------------------------------------------- roofline
def test_shape_bytes():
    assert roofline.shape_bytes("bf16[16,4096,128]{2,1,0}") == \
        16 * 4096 * 128 * 2
    assert roofline.shape_bytes("(f32[8]{0}, s32[4]{0})") == 8 * 4 + 4 * 4
    assert roofline.shape_bytes("pred[]") == 1


def test_collective_bytes_parser():
    hlo = """
  %ag = f32[64,128]{1,0} all-gather(%x), replica_groups={{0,1}}
  %ar.1 = bf16[256]{0} all-reduce-start(%y), to_apply=%add
  %ar.d = bf16[256]{0} all-reduce-done(%ar.1)
  %rs = (f32[32]{0}, f32[32]{0}) reduce-scatter(%a, %b), dimensions={0}
  %cp = u8[1024]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    out = roofline.collective_bytes(hlo)
    assert out["all-gather"] == 64 * 128 * 4
    assert out["all-reduce"] == 256 * 2          # -start counted, -done not
    assert out["reduce-scatter"] == 32 * 4 * 2
    assert out["collective-permute"] == 1024


def test_hbm_traffic_counts_major_ops_only():
    hlo = """
ENTRY %main (p0: f32[128,64], p1: f32[64,32]) -> f32[128,32] {
  %p0 = f32[128,64]{1,0} parameter(0)
  %p1 = f32[64,32]{1,0} parameter(1)
  %t = f32[128,64]{1,0} tanh(%p0)
  ROOT %d = f32[128,32]{1,0} dot(%t, %p1), lhs_contracting_dims={1}
}
"""
    got = roofline.hbm_traffic(hlo)
    want = (128 * 64 + 64 * 32 + 128 * 32) * 4   # dot operands + result
    assert got == want


def test_roofline_terms_and_bottleneck():
    rl = roofline.Roofline(flops=197e12, hbm_bytes=819e9 * 2,
                           coll_bytes=50e9 * 0.5, coll_by_kind={})
    assert abs(rl.t_compute - 1.0) < 1e-9
    assert abs(rl.t_memory - 2.0) < 1e-9
    assert abs(rl.t_collective - 0.5) < 1e-9
    assert rl.bottleneck == "memory"
    assert rl.t_bound == 2.0


def test_model_flops_moe_counts_active_only():
    dense = configs.get_config("qwen3-0.6b")
    moe = configs.get_config("mixtral-8x22b")
    n_active = roofline.active_params(moe)
    # 8 experts top-2: active far below total
    from repro.core.params import count_params
    from repro.train.state import model_defs
    assert n_active < 0.5 * count_params(model_defs(moe))
    assert roofline.model_flops(dense, 1000) == \
        6.0 * roofline.active_params(dense) * 1000


def test_cell_supported_matrix():
    ok, _ = configs.cell_supported("mamba2-780m", "long_500k")
    assert ok
    ok, why = configs.cell_supported("gemma-7b", "long_500k")
    assert not ok and "full-attention" in why
    for arch in configs.ARCH_NAMES:
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            assert configs.cell_supported(arch, shape)[0]
