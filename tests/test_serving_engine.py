"""Continuous-batching engine tests: scanned-loop vs per-token greedy
equivalence, EOS early exit, ragged prompts, and slot recycling under
more requests than slots.

All tests share one Engine (module fixture) and one generation budget so
the compiled decode chunk is traced exactly once for the whole module."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.params import init_tree
from repro.models import transformer
from repro.serving.engine import Engine, Request
from repro.train.state import model_defs

MAX_LEN, SLOTS, GEN, CHUNK = 48, 3, 6, 4


def _tiny_cfg():
    # full dispatcher slack so capacity drops don't add noise to the
    # per-token-loop comparisons (cf. test_system's parity test)
    return dataclasses.replace(
        configs.get_smoke("qwen3-0.6b"), num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256).with_spt(ffn_capacity_factor=8.0)


@pytest.fixture(scope="module")
def tiny():
    cfg = _tiny_cfg()
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params, Engine(cfg, params, max_len=MAX_LEN,
                               num_slots=SLOTS, decode_chunk=CHUNK)


def _ref_steps(cfg, max_len):
    """Jitted prefill/decode exactly like the pre-refactor Engine built."""
    prefill = jax.jit(lambda params, toks: transformer.lm_prefill(
        params, cfg, {"tokens": toks}, max_len=max_len))
    decode = jax.jit(lambda params, caches, tok, pos:
                     transformer.lm_decode_step(params, cfg, caches, tok,
                                                pos))
    return prefill, decode


def _per_token_greedy(cfg, params, tokens, steps, max_len=MAX_LEN):
    """The pre-refactor Engine.generate loop: batched prefill + one Python
    decode call per token, scalar positions, greedy argmax."""
    key = (cfg.name, max_len)
    if key not in _per_token_greedy.cache:
        _per_token_greedy.cache[key] = _ref_steps(cfg, max_len)
    prefill, decode = _per_token_greedy.cache[key]
    caches, logits = prefill(params, tokens)
    pos0 = tokens.shape[1]
    outs = [jnp.argmax(logits[:, -1], -1).astype(jnp.int32)]
    for t in range(1, steps):
        caches, logits = decode(params, caches, outs[-1],
                                jnp.asarray(pos0 + t - 1, jnp.int32))
        outs.append(jnp.argmax(logits[:, -1], -1).astype(jnp.int32))
    return jnp.stack(outs, 1).tolist()


_per_token_greedy.cache = {}


def _prompts(cfg, lens, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=ln,
                         dtype=np.int32).tolist() for ln in lens]


def test_scanned_loop_matches_per_token_loop(tiny):
    cfg, params, eng = tiny
    toks = jnp.asarray(np.stack(_prompts(cfg, [16] * 4)))
    ref = _per_token_greedy(cfg, params, toks, steps=GEN)
    out = eng.run([Request(uid=i, tokens=np.asarray(toks)[i].tolist(),
                           max_new_tokens=GEN) for i in range(4)])
    assert [c.tokens for c in out] == ref
    # trace-once property: one compiled chunk serves the whole run
    assert len(eng._chunk_cache) == 1
    assert eng.last_stats.decode_tokens == 4 * (GEN - 1)  # 1st is prefill's


def test_slot_recycling_more_requests_than_slots(tiny):
    cfg, params, eng = tiny
    prompts = _prompts(cfg, [16] * 5, seed=2)
    out = eng.run([Request(uid=i, tokens=p, max_new_tokens=GEN)
                   for i, p in enumerate(prompts)])
    assert eng.last_stats.admitted == 5 and eng.last_stats.completed == 5
    for i, p in enumerate(prompts):                 # row-for-row vs solo run
        ref = _per_token_greedy(cfg, params, jnp.asarray([p]), GEN)
        assert out[i].tokens == ref[0], f"request {i}"


def test_ragged_prompt_lengths(tiny):
    cfg, params, eng = tiny
    # default SPT config (sparse MHA + routed FFN) is not pad-invariant,
    # so these ragged prompts take the exact-length prefill path
    assert not eng._pad_invariant()
    lens = [5, 9, 16, 11]
    prompts = _prompts(cfg, lens)
    out = eng.run([Request(uid=i, tokens=p, max_new_tokens=GEN)
                   for i, p in enumerate(prompts)])
    for i, p in enumerate(prompts):
        ref = _per_token_greedy(cfg, params, jnp.asarray([p]), GEN)
        assert out[i].tokens == ref[0], f"len={lens[i]}"
        assert out[i].prompt_len == lens[i]


def test_eos_early_exit(tiny):
    cfg, params, eng = tiny
    prompts = _prompts(cfg, [16, 16], seed=3)
    free = [c.tokens for c in eng.run(
        [Request(uid=i, tokens=p, max_new_tokens=GEN)
         for i, p in enumerate(prompts)])]
    eos = free[0][2]                      # greedy token 3 of request 0
    out = eng.run([Request(uid=i, tokens=p, max_new_tokens=GEN)
                   for i, p in enumerate(prompts)], eos_id=eos)
    assert out[0].tokens == free[0][:3]
    assert out[0].finish_reason == "eos"
    cut = free[1].index(eos) + 1 if eos in free[1] else len(free[1])
    assert out[1].tokens == free[1][:cut]
    assert eng.last_stats.decode_tokens < 2 * (GEN - 1)  # the exit saved work


def test_generate_legacy_api_matches_old_loop(tiny):
    cfg, params, eng = tiny
    toks = jnp.asarray(np.stack(_prompts(cfg, [16] * 3, seed=4)))
    ref = _per_token_greedy(cfg, params, toks, steps=GEN)
    got = eng.generate({"tokens": toks}, steps=GEN)
    assert got.tokens == ref and got.steps == GEN


def test_duplicate_request_uids_rejected(tiny):
    """Failure isolation: the duplicate uid is rejected as a Completion,
    the first occurrence (and the rest of the batch) still serves."""
    _, _, eng = tiny
    out = eng.run([Request(uid=0, tokens=[1, 2], max_new_tokens=2),
                   Request(uid=0, tokens=[3, 4], max_new_tokens=2)])
    assert out[0].finish_reason in ("eos", "length") and out[0].tokens
    assert out[1].finish_reason == "rejected" and not out[1].tokens
    assert "duplicate" in out[1].detail
    assert eng.last_stats.rejections == 1


def test_bucketed_padding_is_output_invariant():
    """Dense (SPT-off) stacks bucket ragged prompts to power-of-2 pads;
    the padding must not change real-token outputs vs exact-length
    prefill.  (Sparse-MHA / routed-FFN configs skip bucketing entirely:
    top-L budgets and capacity dispatch would see the pad tokens.)"""
    cfg = dataclasses.replace(_tiny_cfg(), name="tiny-dense").with_spt(
        sparse_mha=False, routed_ffn=False)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=MAX_LEN, num_slots=2, decode_chunk=4)
    assert eng._pad_invariant() and eng._pad_len(9) == 16
    prompts = _prompts(cfg, [5, 9, 11], seed=6)
    out = eng.run([Request(uid=i, tokens=p, max_new_tokens=4)
                   for i, p in enumerate(prompts)])
    for i, p in enumerate(prompts):
        ref = _per_token_greedy(cfg, params, jnp.asarray([p]), 4)
        assert out[i].tokens == ref[0], f"len={len(p)}"


def test_sliding_window_prompt_longer_than_window():
    """SWA ring caches hold only the last `window` positions, so the engine
    must prefill at exact length (right-padding would displace real KV out
    of the ring) — outputs must match the per-token loop."""
    cfg = dataclasses.replace(_tiny_cfg(), name="tiny-swa", window=8)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    assert transformer.supports_ragged_prefill(cfg)
    eng = Engine(cfg, params, max_len=MAX_LEN, num_slots=2, decode_chunk=4)
    prompts = _prompts(cfg, [12, 6], seed=5)     # 12 > window=8
    out = eng.run([Request(uid=i, tokens=p, max_new_tokens=4)
                   for i, p in enumerate(prompts)])
    for i, p in enumerate(prompts):
        ref = _per_token_greedy(cfg, params, jnp.asarray([p]), 4)
        assert out[i].tokens == ref[0], f"len={len(p)}"


def test_per_request_sampling_in_chunk(tiny):
    """Per-request temperature/top-k ride inside the compiled decode chunk:
    top_k=1 sampling is argmax (matches greedy exactly), a temp<=0 request
    in a sampled batch stays greedy, and a sampled request is reproducible
    under the same key."""
    cfg, params, eng = tiny
    prompts = _prompts(cfg, [16, 16, 16], seed=9)
    greedy = [c.tokens for c in eng.run(
        [Request(uid=i, tokens=p, max_new_tokens=GEN)
         for i, p in enumerate(prompts)])]
    key = jax.random.PRNGKey(11)
    reqs = [
        Request(uid=0, tokens=prompts[0], max_new_tokens=GEN,
                temperature=0.8, top_k=1),          # argmax sampling
        Request(uid=1, tokens=prompts[1], max_new_tokens=GEN,
                temperature=0.0),                   # greedy in mixed batch
        Request(uid=2, tokens=prompts[2], max_new_tokens=GEN,
                temperature=1.2, top_k=5),          # truly sampled
    ]
    out = eng.run(reqs, key=key)
    assert out[0].tokens == greedy[0]
    assert out[1].tokens == greedy[1]
    assert len(out[2].tokens) == GEN
    assert all(0 <= t < cfg.vocab_size for t in out[2].tokens)
    again = eng.run(reqs, key=key)
    assert [c.tokens for c in again] == [c.tokens for c in out]
    # different key moves the sampled request (overwhelmingly likely)
    moved = eng.run(reqs, key=jax.random.PRNGKey(12))
    assert moved[0].tokens == greedy[0]


@pytest.mark.slow
def test_recurrent_arch_exact_length_prefill():
    """Non-attention stacks can't right-pad prompts (state corruption);
    the engine prefills them at exact length — outputs must still match
    the per-token loop, including under slot recycling."""
    cfg = configs.get_smoke("mamba2-780m")
    assert not transformer.supports_ragged_prefill(cfg)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    prompts = _prompts(cfg, [7, 12])
    eng = Engine(cfg, params, max_len=32, num_slots=1, decode_chunk=4)
    out = eng.run([Request(uid=i, tokens=p, max_new_tokens=3)
                   for i, p in enumerate(prompts)])
    for i, p in enumerate(prompts):
        ref = _per_token_greedy(cfg, params, jnp.asarray([p]), 3, max_len=32)
        assert out[i].tokens == ref[0], f"request {i}"
