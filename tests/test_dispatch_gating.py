"""Exhaustive gating matrix for core/dispatch.py's execution-path
switches.

The four `use_*` switches are the single point deciding whether a layer
lowers through a Pallas kernel, a jnp fallback, or a paged cache layout.
The point-checks in the kernel suites each probe a few corners; here the
FULL cross product of (attn_impl x decode_attn_impl x ffn_impl x
decode_ffn_impl x kv_layout x REPRO_DISABLE_KERNELS) is asserted against
an independently-written model of the documented semantics:

  * decode_attn_impl / decode_ffn_impl: explicit "kernel"/"jnp" wins;
    "auto" follows the train/prefill impl ("pallas" -> kernel);
  * REPRO_DISABLE_KERNELS=1 forces every jnp fallback...
  * ...EXCEPT kv_layout: paging is a layout, not a kernel, so the kill
    switch must NOT flip it (the regression this test exists to catch —
    a refactor folding use_paged_kv under kernels_disabled() would make
    the kill switch silently change cache shapes).
"""
import itertools

import pytest

from repro import configs
from repro.core import dispatch

ATTN_IMPLS = ["sparse_jnp", "dense", "pallas"]
DECODE_ATTN_IMPLS = ["auto", "kernel", "jnp"]
FFN_IMPLS = ["grouped", "dense", "grouped_shmap", "pallas"]
DECODE_FFN_IMPLS = ["auto", "kernel", "jnp"]
KV_LAYOUTS = ["contiguous", "paged"]


def _cfg(**spt):
    return configs.get_smoke("qwen3-0.6b").with_spt(**spt)


# --------------------------------------------- independent semantic model
def want_sparse_decode(attn, decode_attn, disabled):
    if disabled:
        return False
    if decode_attn == "auto":
        return attn == "pallas"
    return decode_attn == "kernel"


def want_routed_kernel(ffn, disabled):
    return not disabled and ffn == "pallas"


def want_decode_ffn(ffn, decode_ffn, disabled):
    if disabled:
        return False
    if decode_ffn == "auto":
        return ffn == "pallas"
    return decode_ffn == "kernel"


def want_paged(kv_layout, disabled):
    del disabled                      # the kill switch must not apply
    return kv_layout == "paged"


# ------------------------------------------------------------ the matrix
@pytest.mark.parametrize("disabled", [False, True])
@pytest.mark.parametrize("attn,decode_attn", list(
    itertools.product(ATTN_IMPLS, DECODE_ATTN_IMPLS)))
def test_sparse_decode_matrix(monkeypatch, attn, decode_attn, disabled):
    monkeypatch.setenv("REPRO_DISABLE_KERNELS", "1" if disabled else "0")
    cfg = _cfg(attn_impl=attn, decode_attn_impl=decode_attn)
    assert dispatch.use_sparse_decode_kernel(cfg) \
        == want_sparse_decode(attn, decode_attn, disabled)


@pytest.mark.parametrize("disabled", [False, True])
@pytest.mark.parametrize("ffn,decode_ffn", list(
    itertools.product(FFN_IMPLS, DECODE_FFN_IMPLS)))
def test_ffn_matrix(monkeypatch, ffn, decode_ffn, disabled):
    monkeypatch.setenv("REPRO_DISABLE_KERNELS", "1" if disabled else "0")
    cfg = _cfg(ffn_impl=ffn, decode_ffn_impl=decode_ffn)
    assert dispatch.use_routed_ffn_kernel(cfg) \
        == want_routed_kernel(ffn, disabled)
    assert dispatch.use_decode_ffn_kernel(cfg) \
        == want_decode_ffn(ffn, decode_ffn, disabled)


@pytest.mark.parametrize("disabled", [False, True])
@pytest.mark.parametrize("kv_layout", KV_LAYOUTS)
def test_paged_kv_immune_to_kill_switch(monkeypatch, kv_layout, disabled):
    monkeypatch.setenv("REPRO_DISABLE_KERNELS", "1" if disabled else "0")
    cfg = _cfg(kv_layout=kv_layout)
    assert dispatch.use_paged_kv(cfg) == want_paged(kv_layout, disabled)


@pytest.mark.parametrize("value,expect", [
    ("", False), ("0", False), ("false", False), ("False", False),
    (" 0 ", False), ("1", True), ("true", True), ("yes", True),
    ("2", True),
])
def test_kill_switch_env_parsing(monkeypatch, value, expect):
    monkeypatch.setenv("REPRO_DISABLE_KERNELS", value)
    assert dispatch.kernels_disabled() is expect


def test_kill_switch_unset(monkeypatch):
    monkeypatch.delenv("REPRO_DISABLE_KERNELS", raising=False)
    assert dispatch.kernels_disabled() is False
