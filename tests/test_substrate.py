"""Training substrate: checkpointing, optimizer, straggler monitor, loss,
gradient compression, adapter function-preservation."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import adapter
from repro.core.params import init_tree, partition, combine, trainable_mask
from repro.optim.adamw import OptimizerConfig, adamw_init, adamw_update
from repro.optim.compress import (CompressionConfig, compress_tree,
                                  decompress_tree)
from repro.train import checkpoint
from repro.train.loss import lm_cross_entropy
from repro.train.state import init_state, model_defs
from repro.train.straggler import StepTimeMonitor, StragglerConfig


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    cfg = configs.get_smoke("qwen3-0.6b")
    state = init_state(cfg, jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    for step in (10, 20, 30, 40):
        checkpoint.save(state, step, d, keep=2)
    assert checkpoint.latest_step(d) == 40
    kept = sorted(os.listdir(d))
    assert kept == ["step_00000030", "step_00000040"]
    restored = checkpoint.restore(d)
    flat_a = jax.tree_util.tree_leaves(state)
    flat_b = jax.tree_util.tree_leaves(restored)
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_corruption(tmp_path):
    cfg = configs.get_smoke("mamba2-780m")
    state = init_state(cfg, jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    path = checkpoint.save(state, 1, d)
    npz = os.path.join(path, "arrays.npz")
    with open(npz, "r+b") as f:
        f.seek(100)
        f.write(b"\x00\x01\x02")
    with pytest.raises(IOError):
        checkpoint.restore(d)


def test_partition_combine_roundtrip():
    cfg = configs.get_smoke("gemma-7b")
    defs = model_defs(cfg)
    params = init_tree(defs, jax.random.PRNGKey(0))
    mask = trainable_mask(defs)
    train, frozen = partition(params, mask)
    back = combine(train, frozen)
    for (kp, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree_util.tree_leaves_with_path(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # frozen tree holds no LoRA; train tree holds no base weights
    train_paths = [jax.tree_util.keystr(kp) for kp, _ in
                   jax.tree_util.tree_leaves_with_path(train)]
    assert all(("lora" in p or "router" in p or "codebooks" in p)
               for p in train_paths)


# ------------------------------------------------------------- optimizer
def test_adamw_decreases_quadratic():
    w = {"a": jnp.array([3.0, -2.0]), "b": jnp.array([[1.5]])}
    opt = adamw_init(w)
    cfg = OptimizerConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=100, schedule="constant")

    def loss(w):
        return jnp.sum(w["a"] ** 2) + jnp.sum(w["b"] ** 2)

    l0 = float(loss(w))
    for i in range(50):
        g = jax.grad(loss)(w)
        w, opt, _ = adamw_update(w, g, opt, jnp.asarray(i), cfg)
    assert float(loss(w)) < l0 * 0.05


def test_grad_clip_caps_update_norm():
    w = {"a": jnp.array([1.0])}
    opt = adamw_init(w)
    cfg = OptimizerConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0,
                          warmup_steps=0, schedule="constant")
    g = {"a": jnp.array([1e6])}
    _, _, m = adamw_update(w, g, opt, jnp.asarray(0), cfg)
    assert float(m["grad_norm"]) > 1e5  # raw norm reported


# ------------------------------------------------------------ compression
@pytest.mark.parametrize("scheme", ["int8", "topk", "none"])
def test_compression_roundtrip(scheme):
    tree = {"x": jnp.asarray(np.random.default_rng(0).normal(
        size=(32, 16)).astype(np.float32))}
    cfg = CompressionConfig(scheme=scheme, topk_fraction=0.5)
    out = decompress_tree(compress_tree(tree, cfg), cfg)
    x, y = np.asarray(tree["x"]), np.asarray(out["x"])
    if scheme == "none":
        np.testing.assert_array_equal(x, y)
    elif scheme == "int8":
        assert np.abs(x - y).max() <= np.abs(x).max() / 127.0 + 1e-6
    else:  # topk keeps the largest half exactly
        kept = np.abs(x).ravel() >= np.median(np.abs(x))
        np.testing.assert_allclose(y.ravel()[kept], x.ravel()[kept],
                                   rtol=1e-6)


# ------------------------------------------------------------- straggler
def test_straggler_monitor_flags_outliers():
    mon = StepTimeMonitor(StragglerConfig(window=50, z_threshold=3.0,
                                          min_samples=10, act_density=0.15))
    for i in range(30):
        assert not mon.record(i, 0.10 + 0.001 * (i % 3))
    flagged = mon.record(31, 1.5)
    assert flagged and mon.events
    assert not mon.should_act()
    for i in range(10):
        mon.record(40 + i, 1.5 + 0.1 * i)
    assert mon.should_act()


# ------------------------------------------------------------------ loss
def test_chunked_loss_equals_direct():
    cfg = configs.get_smoke("qwen3-0.6b")
    from repro.models import transformer
    params = init_tree(transformer.lm_defs(cfg), jax.random.PRNGKey(0))
    hidden = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)
                               ).astype(jnp.bfloat16)
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                cfg.vocab_size)
    labels = labels.at[0, :3].set(-1)  # masked positions
    l_chunk, m = lm_cross_entropy(params, cfg, hidden, labels, chunk=4)
    l_full, _ = lm_cross_entropy(params, cfg, hidden, labels, chunk=16)
    np.testing.assert_allclose(float(l_chunk), float(l_full), rtol=1e-5)
    assert float(m["tokens"]) == 2 * 16 - 3


# ---------------------------------------------------------------- adapter
def test_adapter_preserves_function_at_identity_settings():
    """Dense model == adapted SPT model when sparsity is a no-op:
    top_fraction=1 (all keys kept) and active_groups == groups (all blocks
    active), LoRA zero-init.  This is the paper's Model Adapter contract."""
    from repro.launch.dryrun import apply_variant
    from repro.models import transformer
    base = configs.get_smoke("h2o-danube-1.8b")
    base = dataclasses.replace(base, window=None)
    spt_cfg = base.with_spt(attn_top_fraction=1.0, attn_min_l=1,
                            ffn_active_groups=base.spt.ffn_groups,
                            ffn_capacity_factor=8.0)
    dense_cfg = apply_variant(base, "full")
    dense_params = init_tree(transformer.lm_defs(dense_cfg),
                             jax.random.PRNGKey(0))
    adapted = adapter.adapt(dense_params, dense_cfg, spt_cfg,
                            jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                base.vocab_size)
    h_dense, _ = transformer.lm_hidden(dense_params, dense_cfg,
                                       {"tokens": tokens}, remat=False)
    h_spt, _ = transformer.lm_hidden(adapted, spt_cfg,
                                     {"tokens": tokens}, remat=False)
    np.testing.assert_allclose(np.asarray(h_dense, np.float32),
                               np.asarray(h_spt, np.float32),
                               rtol=5e-2, atol=5e-2)
