"""The analysis package's rules each fire on a deliberately-violating
fixture and stay quiet on a known-good one — so `python -m repro.analysis`
being green means the rules are alive, not vacuous.

Layer coverage: registry plumbing; lint (ast rules over synthetic
sources); jaxpr audits (dispatch buffer, cache repeat, byte budget,
forbidden primitives, accumulator dtype, kernel presence) on tiny traced
programs; pallas audits (VMEM budget, tile divisibility, scalar
prefetch) on toy pallas_calls traced but never run; trace guard (retrace
via weak-type flip, per-iteration jit rebuild) on tiny jitted fns.  The
full registry sweep over the real hot entrypoints is `slow` (ci_fast
runs the same sweep via scripts/analyze.sh anyway)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro.analysis import jaxpr_audit as ja
from repro.analysis import lint
from repro.analysis import pallas_audit as pa
from repro.analysis import registry
from repro.analysis import trace_guard as tg


def rules(violations):
    return sorted({v.rule for v in violations})


# -------------------------------------------------------------- registry
def test_registry_rejects_duplicates_and_unknown_names():
    with pytest.raises(ValueError):
        registry.audit("lint")(lambda: [])
    with pytest.raises(KeyError):
        registry.run_audits(["no-such-audit"])


def test_run_audits_streams_reports():
    seen = []
    registry.run_audits(["lint"], report=lambda n, vs: seen.append(n))
    assert seen == ["lint"]


# ------------------------------------------------------------------ lint
def test_lint_jnp_repeat_fires_in_serving_only():
    src = "import jax.numpy as jnp\ny = jnp.repeat(x, 4, axis=1)\n"
    assert rules(lint.lint_source(src, "serving/foo.py")) \
        == ["lint.jnp-repeat"]
    assert rules(lint.lint_source(src, "models/foo.py")) \
        == ["lint.jnp-repeat"]
    # core/ keeps its documented jnp fallback oracles
    assert lint.lint_source(src, "core/foo.py") == []


def test_lint_host_sync_fires_in_hot_modules():
    src = ("import numpy as np\n"
           "n = int(count.item())\n"
           "a = np.asarray(dev)\n")
    vs = lint.lint_source(src, "models/foo.py")
    assert rules(vs) == ["lint.host-sync"] and len(vs) == 2
    # the engine host scheduler is exempt by design
    assert lint.lint_source(src, "serving/engine.py") == []


def test_lint_interpret_default_must_be_none():
    bad = "def kernel_op(x, interpret=True):\n    return x\n"
    good = ("def kernel_op(x, interpret=None):\n    return x\n"
            "def _forward(x, interpret):\n    return x\n")
    assert rules(lint.lint_source(bad, "kernels/foo/ops.py")) \
        == ["lint.interpret-default"]
    assert lint.lint_source(good, "kernels/foo/ops.py") == []
    # kw-only defaults are checked too
    bad_kw = "def kernel_op(x, *, interpret=False):\n    return x\n"
    assert rules(lint.lint_source(bad_kw, "kernels/foo/ops.py")) \
        == ["lint.interpret-default"]


def test_lint_dispatch_routing():
    assert rules(lint.lint_source(
        "from jax.experimental import pallas as pl\n",
        "models/foo.py")) == ["lint.dispatch-routing"]
    assert rules(lint.lint_source(
        "import os\nflag = os.environ.get('REPRO_DISABLE_KERNELS')\n",
        "serving/foo.py")) == ["lint.dispatch-routing"]
    assert lint.lint_source(
        "from repro.core import dispatch\nok = dispatch.kernels_disabled()\n",
        "serving/foo.py") == []


def test_lint_repo_tree_clean():
    assert lint.run_lint() == []


# ---------------------------------------------------------- jaxpr audits
def test_dispatch_buffer_rule_fires_on_capacity_path():
    """The jnp grouped path at decode shape DOES build (B, G, C, d)
    buffers — the rule must see them (this is the violating twin of the
    clean ops.routed_ffn_decode entrypoint)."""
    from repro.core import lora as lora_mod
    from repro.core import routed_ffn as rf
    from repro.core.params import init_tree
    lcfg = lora_mod.LoRAConfig(rank=4, alpha=4.0, enabled=False)
    rcfg = rf.RoutedFFNConfig(d_model=64, d_ff=128, num_groups=8,
                              active_groups=2, capacity_factor=4.0,
                              gated=True, activation="gelu")
    p = jax.eval_shape(lambda: init_tree(rf.param_defs(rcfg, lcfg),
                                         jax.random.PRNGKey(0)))
    x = jax.ShapeDtypeStruct((4, 1, 64), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda p, x: rf.routed_ffn(x, p, rcfg, lcfg, impl="grouped")[0]
    )(p, x)
    assert rules(ja.dispatch_buffer_violations(jaxpr, 4, 8)) \
        == ["jaxpr.dispatch-buffer"]


def test_cache_repeat_rule_fires_on_gqa_expansion():
    def bad(q, k):                        # expands the cache to Hq
        kx = jnp.repeat(k, 4, axis=1)     # (B, Hk, S, d) -> (B, Hq, S, d)
        return jnp.einsum("bhqd,bhsd->bhqs", q, kx)

    jaxpr = jax.make_jaxpr(bad)(
        jax.ShapeDtypeStruct((2, 8, 1, 16), jnp.float32),
        jax.ShapeDtypeStruct((2, 2, 64, 16), jnp.float32))
    assert "jaxpr.cache-repeat" in rules(
        ja.cache_repeat_violations(jaxpr, num_q_heads=8, num_kv_heads=2,
                                   min_seq=64))
    # Hq == Hk (no GQA): nothing to expand, rule is inert
    assert ja.cache_repeat_violations(jaxpr, 8, 8, 64) == []


def test_intermediate_budget_rule_fires_on_big_broadcast():
    def bad(x):                           # materializes 4 MiB from 4 KiB
        return jnp.broadcast_to(x[:, None], (1024, 1024)) * 2.0

    jaxpr = jax.make_jaxpr(bad)(jax.ShapeDtypeStruct((1024,), jnp.float32))
    assert rules(ja.big_intermediate_violations(jaxpr, max_bytes=65536)) \
        == ["jaxpr.intermediate-budget"]
    assert ja.big_intermediate_violations(jaxpr, max_bytes=1 << 24) == []


def test_forbidden_primitive_rule_fires_on_debug_print():
    def bad(x):
        jax.debug.print("x={x}", x=x)
        return x * 2

    jaxpr = jax.make_jaxpr(bad)(jnp.ones(3))
    assert rules(ja.forbidden_primitive_violations(jaxpr)) \
        == ["jaxpr.forbidden-primitive"]


def test_kernel_count_rules():
    jaxpr = jax.make_jaxpr(lambda x: x + 1)(jnp.ones(3))
    assert rules(ja.kernel_count_violations(jaxpr, "e", "some")) \
        == ["jaxpr.kernel-missing"]
    assert ja.kernel_count_violations(jaxpr, "e", "none") == []
    assert rules(ja.kernel_count_violations(jaxpr, "e", "exact", exact=2)) \
        == ["jaxpr.kernel-missing"]


def _toy_pallas(block_shape, array_shape, dtype=jnp.float32,
                compute=None):
    """A minimal copy kernel traced (never run) for audit fixtures."""
    def kernel(x_ref, o_ref):
        val = x_ref[...]
        o_ref[...] = compute(val) if compute else val

    grid = tuple(-(-a // b) for a, b in zip(array_shape, block_shape))
    spec = pl.BlockSpec(block_shape, lambda i, j: (i, j))
    fn = pl.pallas_call(
        kernel, grid=grid, in_specs=[spec], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(array_shape, dtype),
        interpret=True)
    return jax.make_jaxpr(fn)(jax.ShapeDtypeStruct(array_shape, dtype))


def test_accum_dtype_rule_fires_on_bf16_dot():
    def kernel(a_ref, b_ref, o_ref):
        o_ref[...] = jnp.dot(a_ref[...], b_ref[...])   # bf16 accumulate

    shape = jax.ShapeDtypeStruct((128, 128), jnp.bfloat16)
    fn = pl.pallas_call(
        kernel, out_shape=shape, interpret=True)
    jaxpr = jax.make_jaxpr(fn)(shape, shape)
    assert rules(ja.accum_dtype_violations(jaxpr)) == ["jaxpr.accum-dtype"]


# --------------------------------------------------------- pallas audits
def test_vmem_budget_rule_fires_on_oversized_block():
    # one (4096, 4096) f32 block = 64 MiB; double-buffered in+out blows
    # any budget — traced only, never executed
    jaxpr = _toy_pallas((4096, 4096), (4096, 4096))
    calls = [c for c in _collect(jaxpr)]
    assert rules(pa.vmem_violations(calls, "toy")) == ["pallas.vmem-budget"]
    small = _collect(_toy_pallas((8, 128), (16, 256)))
    assert pa.vmem_violations(small, "toy") == []


def test_tile_divisibility_rule_fires_on_ragged_block():
    calls = _collect(_toy_pallas((32, 64), (48, 64)))    # 48 % 32 != 0
    assert rules(pa.tile_divisibility_violations(calls, "toy")) \
        == ["pallas.tile-divisibility"]
    ok = _collect(_toy_pallas((16, 64), (48, 64)))
    assert pa.tile_divisibility_violations(ok, "toy") == []


def test_scalar_prefetch_contract():
    """The real decode-FFN kernel prefetches 2 scalar operands; a
    contract of 0 (or a missing contract entry) must flag it."""
    from repro.core import lora as lora_mod
    from repro.core import routed_ffn as rf
    from repro.core.params import init_tree
    from repro.kernels.routed_ffn import ops as rffn_ops
    lcfg = lora_mod.LoRAConfig(rank=4, alpha=4.0, enabled=False)
    rcfg = rf.RoutedFFNConfig(d_model=64, d_ff=128, num_groups=8,
                              active_groups=2, capacity_factor=4.0,
                              gated=True, activation="gelu")
    p = jax.eval_shape(lambda: init_tree(rf.param_defs(rcfg, lcfg),
                                         jax.random.PRNGKey(0)))
    calls = pa.collect_pallas_calls(
        lambda p, x: rffn_ops.routed_ffn_decode(x, p, rcfg, lcfg,
                                                interpret=True)[0],
        p, jax.ShapeDtypeStruct((4, 1, 64), jnp.float32))
    assert [c.num_index_operands for c in calls] == [2]
    assert rules(pa.scalar_prefetch_violations(calls, "e", {})) \
        == ["pallas.scalar-prefetch"]
    assert pa.scalar_prefetch_violations(
        calls, "e", {"routed_ffn.py": 2}) == []


def test_audit_calls_flags_vacuous_entry():
    assert rules(pa.audit_calls([], "e")) == ["pallas.no-kernel"]


def _collect(jaxpr):
    out = []
    for eqn in ja.iter_eqns(jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        gm = eqn.params["grid_mapping"]
        blocks = tuple(
            pa.BlockInfo(block_shape=tuple(bm.block_shape),
                         array_shape=tuple(bm.array_shape_dtype.shape),
                         dtype=jnp.dtype(bm.array_shape_dtype.dtype).name,
                         itemsize=jnp.dtype(
                             bm.array_shape_dtype.dtype).itemsize,
                         any_space=False)
            for bm in gm.block_mappings)
        out.append(pa.PallasCallInfo(
            name=str(eqn.params.get("name_and_src_info", "?")),
            grid=tuple(int(g) for g in gm.grid),
            num_index_operands=int(gm.num_index_operands),
            num_scratch_operands=int(gm.num_scratch_operands),
            blocks=blocks, scratch_bytes=0))
    return out


# ------------------------------------------------------------ trace guard
def test_trace_guard_flags_weak_type_retrace():
    @jax.jit
    def f(x):
        return x * 2

    guard = tg.TraceGuard()
    wrapped = guard.track("decode_step", f)
    wrapped(jnp.float32(1.0))
    wrapped(2.0)                 # weak-type flip: same bucket, new trace
    assert rules(guard.violations()) == ["trace.retrace"]


def test_trace_guard_accepts_one_trace_per_shape_bucket():
    @jax.jit
    def f(x):
        return x * 2

    guard = tg.TraceGuard()
    wrapped = guard.track("decode_step", f)
    for s in (4, 8, 4, 8, 4):    # 2 buckets, 2 traces, 5 calls
        wrapped(jnp.ones(s, jnp.float32))
    assert guard.violations() == []


def test_trace_guard_flags_per_iteration_jit():
    guard = tg.TraceGuard()
    for _ in range(3):           # rebuilding jit each iteration
        wrapped = guard.track("chunk", jax.jit(lambda x: x + 1),
                              unique=True)
        wrapped(jnp.ones(2))
    assert "trace.per-iteration-jit" in rules(guard.violations())


def test_guard_engine_raises_on_injected_retrace():
    """End-to-end negative fixture: an engine whose chunk getter feeds a
    weak-type-flipping wrapper must raise at context exit."""
    @jax.jit
    def f(x):
        return x * 2

    class FakeEngine:
        def _get_chunk(self, *key):
            return f
        def _get_prefill(self):
            return f

    eng = FakeEngine()
    with pytest.raises(RuntimeError, match="trace.retrace"):
        with tg.guard_engine(eng):
            chunk = eng._get_chunk(2, 4)
            chunk(jnp.float32(1.0))
            chunk(2.0)
    assert eng._get_chunk(2, 4) is f          # hooks restored


# ------------------------------------------------- full registry (slow)
@pytest.mark.slow
def test_full_registry_clean_at_head():
    """Every registered audit over the real hot entrypoints is clean —
    the same sweep scripts/analyze.sh gates CI with."""
    assert registry.run_audits() == []


def test_fast_entrypoints_clean_at_head():
    """The cheap op-level entrypoints stay clean (sub-second each; the
    engine-tracing ones ride the slow sweep / analyze.sh)."""
    assert ja.ENTRYPOINTS["ops.routed_ffn_decode"]() == []
    assert pa.KERNEL_ENTRIES["routed_ffn.decode"]() == []
