"""Parity suite for the Pallas sparse-MHA decode path (interpret=True on
CPU — the same kernels lower to TPU): decode-threshold kernel vs its ref,
and the one-pass fused decode kernel vs the two-pass kernel pair vs the jnp
fallback oracle `sa.sparse_mha_decode`, across selection granularities, GQA
ratios, ring-buffer validity masks, and degenerate cases.  Every parity
case runs BOTH fuse modes against ONE oracle evaluation (`_assert_parity`):
fused and two-pass share their tile bodies so they must agree bit-exactly,
which means the expensive oracle is computed once per combo rather than per
mode.  Also covers: paged-native (page_id, offset) kernels vs the
gathered-view tier (bit-identical at equal tile size), dispatch gating for
the `decode_attn_fuse` / `kv_paged_native` switches, and an engine-level
check that greedy serving outputs are identical with the kernel path on vs
off.

These fast cases run in scripts/ci_fast.sh so the kernel path is exercised
on every iteration; the wide (S, L, dtype) sweep is marked `slow`.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import dispatch, pq
from repro.core import sparse_attention as sa
from repro.core.params import init_tree
from repro.kernels.sparse_attention.ops import (dense_mha_decode_paged,
                                                sparse_mha_decode_paged)
from repro.kernels.sparse_attention.ops import sparse_mha_decode as k_decode
from repro.kernels.topl_select.ops import decode_topl_thresholds
from repro.kernels.topl_select.ref import decode_thresholds_ref
from repro.models import transformer
from repro.serving import kv_pages as kvp
from repro.serving.engine import Engine, Request
from repro.train.state import model_defs


def _cb(head_dim, code_dim=8, e=16, seed=0):
    cfg = pq.PQConfig(head_dim=head_dim, code_dim=code_dim, num_codewords=e)
    return cfg, init_tree(pq.param_defs(cfg),
                          jax.random.PRNGKey(seed))["codebooks"]


def _decode_case(b, hq, hk, s, d, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, 1, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, hk, s, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, hk, s, d)).astype(dtype)
    return q, k, v


def _assert_parity(q, k, v, codes, cb, scfg, kv_valid, tol=2e-3, tile_k=512):
    """One oracle evaluation checks both kernel tiers: the one-pass fused
    kernel and the two-pass pair share `hist_reduce`/`_attend_tile`, so
    they must agree bit-exactly — only one of them needs the (expensive)
    jnp-oracle comparison."""
    d = q.shape[-1]
    out_f = k_decode(q, k, v, codes, cb, scfg, d ** -0.5, kv_valid,
                     tile_k=tile_k, interpret=True, fuse=True)
    out_t = k_decode(q, k, v, codes, cb, scfg, d ** -0.5, kv_valid,
                     tile_k=tile_k, interpret=True, fuse=False)
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_t))
    out_r = sa.sparse_mha_decode(q, k, v, codes, cb, scfg, d ** -0.5,
                                 kv_valid)
    np.testing.assert_allclose(np.asarray(out_f, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)


# ------------------------------------------------------ threshold kernel
@pytest.mark.parametrize("gran", ["qhead", "kvgroup"])
@pytest.mark.parametrize("hq,hk", [(4, 4), (4, 2), (4, 1)])
def test_decode_thresholds_kernel_matches_ref(gran, hq, hk):
    b, s, m = 2, 64, 4
    r = hq // hk
    key = jax.random.PRNGKey(1)
    cq = jax.random.randint(key, (b * hk, r, m), 0, 16)
    ck = jax.random.randint(jax.random.PRNGKey(2), (b * hk, s, m), 0, 16)
    kv_valid = jax.random.uniform(jax.random.PRNGKey(3), (b, s)) < 0.7
    sum_rows = gran == "kvgroup"
    kw = dict(l=12, max_score=m * (r if sum_rows else 1), sum_rows=sum_rows)
    got = decode_topl_thresholds(cq, ck, kv_valid, interpret=True,
                                 tile_k=16, heads_per_batch=hk, **kw)
    want = decode_thresholds_ref(cq, ck, kv_valid, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------- fused kernel vs oracle
@pytest.mark.parametrize("gran", ["qhead", "kvgroup"])
@pytest.mark.parametrize("hq,hk", [(4, 4), (4, 2), (4, 1)])
def test_decode_kernel_parity(gran, hq, hk):
    b, s, d = 2, 64, 32
    pcfg, cb = _cb(d)
    scfg = sa.SparseAttentionConfig(pq=pcfg, top_fraction=0.25, min_l=4,
                                    select_granularity=gran)
    q, k, v = _decode_case(b, hq, hk, s, d, seed=hq * 10 + hk)
    codes = pq.assign(k, cb).astype(jnp.int8)
    kv_valid = jnp.ones((b, s), bool)
    _assert_parity(q, k, v, codes, cb, scfg, kv_valid)


@pytest.mark.parametrize("gran", ["qhead", "kvgroup"])
def test_decode_kernel_ring_buffer_mask(gran):
    """Ring-buffer SWA caches reduce to an arbitrary (B, S) validity mask
    (the window can wrap, so the valid region need not be contiguous)."""
    b, hq, hk, s, d = 2, 4, 2, 48, 32
    pcfg, cb = _cb(d)
    scfg = sa.SparseAttentionConfig(pq=pcfg, top_fraction=0.25, min_l=4,
                                    select_granularity=gran)
    q, k, v = _decode_case(b, hq, hk, s, d, seed=7)
    codes = pq.assign(k, cb).astype(jnp.int8)
    wrap = np.zeros((b, s), bool)       # window wrapped around the ring
    wrap[0, :10] = True
    wrap[0, 40:] = True
    wrap[1, 13:29] = True               # window mid-buffer
    _assert_parity(q, k, v, codes, cb, scfg, jnp.asarray(wrap))


@pytest.mark.parametrize("gran", ["qhead", "kvgroup"])
def test_decode_kernel_degenerate(gran):
    """S below the L floor (selection saturates to every valid key), a
    single valid slot, and no valid slots at all (output must be zeros)."""
    b, hq, hk, d = 1, 4, 2, 32
    pcfg, cb = _cb(d)
    scfg = sa.SparseAttentionConfig(pq=pcfg, top_fraction=0.125, min_l=16,
                                    select_granularity=gran)
    s = 8                                # S < min_l => l == S
    assert sa.top_l(s, scfg, None) == s
    q, k, v = _decode_case(b, hq, hk, s, d, seed=11)
    codes = pq.assign(k, cb).astype(jnp.int8)
    _assert_parity(q, k, v, codes, cb, scfg, jnp.ones((b, s), bool))
    single = jnp.zeros((b, s), bool).at[:, 3].set(True)
    _assert_parity(q, k, v, codes, cb, scfg, single)
    for fuse in (True, False):
        out = k_decode(q, k, v, codes, cb, scfg, d ** -0.5,
                       jnp.zeros((b, s), bool), interpret=True, fuse=fuse)
        np.testing.assert_array_equal(np.asarray(out), 0.0)


@pytest.mark.parametrize("gran", ["qhead", "kvgroup"])
def test_decode_kernel_nondivisible_cache_len(gran):
    """Serving max_len is rarely a tile_k multiple (engine uses
    prompt+gen+8): the op must pad the key axis to keep Tk tiling (padded
    slots ride in as kv_valid=0) instead of widening the tile to S."""
    b, hq, hk, s, d = 2, 4, 2, 52, 32          # 52 = 3*16 + 4
    pcfg, cb = _cb(d)
    scfg = sa.SparseAttentionConfig(pq=pcfg, top_fraction=0.25, min_l=4,
                                    select_granularity=gran)
    q, k, v = _decode_case(b, hq, hk, s, d, seed=19)
    codes = pq.assign(k, cb).astype(jnp.int8)
    kv_valid = jax.random.uniform(jax.random.PRNGKey(9), (b, s)) < 0.8
    _assert_parity(q, k, v, codes, cb, scfg, kv_valid, tile_k=16)


def test_decode_kernel_tile_invariance():
    """Cross-tile tie-budget carry: results must not depend on Tk."""
    b, hq, hk, s, d = 1, 4, 2, 64, 32
    pcfg, cb = _cb(d)
    scfg = sa.SparseAttentionConfig(pq=pcfg, top_fraction=0.25, min_l=4)
    q, k, v = _decode_case(b, hq, hk, s, d, seed=13)
    codes = pq.assign(k, cb).astype(jnp.int8)
    kv_valid = jax.random.uniform(jax.random.PRNGKey(5), (b, s)) < 0.8
    a = k_decode(q, k, v, codes, cb, scfg, d ** -0.5, kv_valid,
                 tile_k=16, interpret=True)
    bb = k_decode(q, k, v, codes, cb, scfg, d ** -0.5, kv_valid,
                  tile_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                               rtol=1e-5, atol=1e-5)


def test_masked_decode_form_matches_fallback():
    """The fused-form jnp proxy (benchmark stand-in for the kernel) selects
    the identical set."""
    b, hq, hk, s, d = 2, 4, 2, 64, 32
    pcfg, cb = _cb(d)
    for gran in ("qhead", "kvgroup"):
        scfg = sa.SparseAttentionConfig(pq=pcfg, top_fraction=0.25, min_l=4,
                                        select_granularity=gran)
        q, k, v = _decode_case(b, hq, hk, s, d, seed=17)
        codes = pq.assign(k, cb).astype(jnp.int8)
        kv_valid = jax.random.uniform(jax.random.PRNGKey(6), (b, s)) < 0.7
        out_m = sa.sparse_mha_decode_masked(q, k, v, codes, cb, scfg,
                                            d ** -0.5, kv_valid)
        out_r = sa.sparse_mha_decode(q, k, v, codes, cb, scfg, d ** -0.5,
                                     kv_valid)
        np.testing.assert_allclose(np.asarray(out_m), np.asarray(out_r),
                                   rtol=2e-3, atol=2e-3, err_msg=gran)


# ---------------------------------------------- paged-native vs gathered
def _paged_case(ps, mp, seed=23):
    """A small paged pool with holes: 2 slots over an 8-page pool, slot 1
    page-table rows out of order (pages are allocated in admission order,
    not address order) and slot positions mid-page."""
    b, hq, hk, d, pool = 2, 4, 2, 32, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, 1, d))
    k_pool = jax.random.normal(ks[1], (pool, hk, ps, d))
    v_pool = jax.random.normal(ks[2], (pool, hk, ps, d))
    pt = jnp.asarray(np.asarray(
        [[2, 5, -1], [7, 0, 3]], np.int32)[:, :mp])
    pos = jnp.asarray([min(2 * ps - 3, mp * ps - 1), ps // 2 + 1])
    view = pt.shape[1] * ps
    kv_valid = ((jnp.arange(view)[None, :] < pos[:, None])
                & kvp.occupancy(pt, ps))
    return q, k_pool, v_pool, pt, kv_valid


@pytest.mark.parametrize("ps,tile_k", [(8, 8), (16, 16), (16, 8)])
def test_paged_native_sparse_matches_gathered_view(ps, tile_k):
    """Kernel-native (page_id, offset) addressing must be BIT-identical to
    the gathered-view fused kernel at equal tile size: same tile walk in
    the same order over the same data, just addressed through the
    scalar-prefetched page table instead of a materialized gather.
    Includes sub-page tiles (ps=16, tile_k=8 -> 2 tiles per page) and a
    page table with -1 holes (clamped page-0 reads masked by kv_valid)."""
    mp = 3
    pcfg, cb = _cb(32)
    scfg = sa.SparseAttentionConfig(pq=pcfg, top_fraction=0.25, min_l=4)
    q, k_pool, v_pool, pt, kv_valid = _paged_case(ps, mp)
    codes_pool = pq.assign(k_pool, cb).astype(jnp.int8)
    scale = q.shape[-1] ** -0.5
    out_p = sparse_mha_decode_paged(q, k_pool, v_pool, codes_pool, cb,
                                    scfg, scale, kv_valid, pt,
                                    tile_k=tile_k, interpret=True)
    k_view = kvp.gather_pages(k_pool, pt)
    v_view = kvp.gather_pages(v_pool, pt)
    codes_view = kvp.gather_pages(codes_pool, pt)
    out_g = k_decode(q, k_view, v_view, codes_view, cb, scfg, scale,
                     kv_valid, tile_k=tile_k, interpret=True, fuse=True)
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_g))


@pytest.mark.parametrize("ps", [8, 16])
def test_paged_native_dense_matches_jnp(ps):
    """The dense paged-native decode kernel (SPT-off route) vs the jnp
    dense oracle over the gathered view."""
    q, k_pool, v_pool, pt, kv_valid = _paged_case(ps, mp=3, seed=29)
    scale = q.shape[-1] ** -0.5
    out_p = dense_mha_decode_paged(q, k_pool, v_pool, scale, kv_valid, pt,
                                   tile_k=ps, interpret=True)
    out_r = sa.dense_attention(q, kvp.gather_pages(k_pool, pt),
                               kvp.gather_pages(v_pool, pt), scale,
                               causal=False, kv_valid=kv_valid, chunk_q=1)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------- dispatch gating
def test_disable_kernels_env(monkeypatch):
    cfg = configs.get_smoke("qwen3-0.6b").with_spt(decode_attn_impl="kernel")
    assert dispatch.use_sparse_decode_kernel(cfg)
    monkeypatch.setenv("REPRO_DISABLE_KERNELS", "1")
    assert dispatch.kernels_disabled()
    assert not dispatch.use_sparse_decode_kernel(cfg)
    monkeypatch.setenv("REPRO_DISABLE_KERNELS", "0")
    assert not dispatch.kernels_disabled()
    auto = cfg.with_spt(decode_attn_impl="auto")
    assert not dispatch.use_sparse_decode_kernel(auto)   # attn_impl=jnp
    assert dispatch.use_sparse_decode_kernel(
        auto.with_spt(attn_impl="pallas"))
    assert not dispatch.use_sparse_decode_kernel(
        cfg.with_spt(decode_attn_impl="jnp"))


def test_fuse_and_paged_native_dispatch(monkeypatch):
    """`decode_attn_fuse` picks the tier WITHIN the kernel path (one-pass
    fused by default, two-pass for bisection); `kv_paged_native` picks
    kernel-native page addressing vs the gathered-view fallback and honors
    the kill switch like every other kernel route."""
    jnp_cfg = configs.get_smoke("qwen3-0.6b")            # attn_impl="jnp"
    cfg = jnp_cfg.with_spt(attn_impl="pallas")
    assert dispatch.use_fused_decode_attn(cfg)           # auto -> fused
    assert dispatch.use_fused_decode_attn(cfg.with_spt(
        decode_attn_fuse="fused"))
    assert not dispatch.use_fused_decode_attn(cfg.with_spt(
        decode_attn_fuse="two_pass"))
    assert dispatch.use_paged_native_decode(cfg)         # auto + pallas
    assert not dispatch.use_paged_native_decode(jnp_cfg)  # auto + jnp
    assert dispatch.use_paged_native_decode(jnp_cfg.with_spt(
        kv_paged_native="kernel"))
    assert not dispatch.use_paged_native_decode(cfg.with_spt(
        kv_paged_native="gather"))
    monkeypatch.setenv("REPRO_DISABLE_KERNELS", "1")
    assert not dispatch.use_paged_native_decode(cfg.with_spt(
        kv_paged_native="kernel"))                       # kill switch wins


# ------------------------------------------------------------ engine e2e
def _replay_last_logits(params, cfg, tokens, max_len):
    """f32 logits after `tokens` via a batch-1 exact-length ragged
    prefill (the decode paths under test are not involved)."""
    batch = {"tokens": jnp.asarray(np.asarray(tokens, np.int32)[None, :])}
    lengths = jnp.asarray([len(tokens)], jnp.int32)
    _, logits = transformer.lm_prefill_ragged(params, cfg, batch, lengths,
                                              max_len)
    return np.asarray(logits[0, -1], np.float32)


def test_engine_greedy_identical_kernel_on_vs_off():
    """The compiled lax.while_loop decode chunk traces the fused kernel
    (per-slot positions + engine-tracked validity); greedy completions must
    be identical to the jnp decode path — except across a genuine argmax
    near-tie, where either token is a correct greedy output."""
    # fp32 model AND params: the kernel and the jnp gather path accumulate
    # in different orders (~1e-6 apart in f32); bf16 weights amplify that
    # to a full bf16 ulp per layer, which can legitimately flip a
    # near-tied greedy argmax.  All-f32 keeps the paths within float noise
    # so the token streams must match exactly — unless the top-2 logits
    # are themselves within float noise of each other.  That near-tie is
    # data-dependent (it moves with jax's per-version RNG streams), so at
    # the first divergence we replay the context and accept EITHER token
    # iff both logits sit within tolerance of the max; the rest of that
    # row's stream is then conditioned on a different prefix and is not
    # comparable.
    base = dataclasses.replace(
        configs.get_smoke("qwen3-0.6b"), num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256, dtype=jnp.float32).with_spt(ffn_capacity_factor=8.0)
    params = jax.tree_util.tree_map(
        lambda a: a.astype(jnp.float32),
        init_tree(model_defs(base), jax.random.PRNGKey(0)))
    rng = np.random.default_rng(3)
    reqs = [Request(uid=i,
                    tokens=rng.integers(0, 256, size=ln).tolist(),
                    max_new_tokens=4)
            for i, ln in enumerate([9, 14])]
    outs = {}
    for impl in ("jnp", "kernel"):
        cfg = base.with_spt(decode_attn_impl=impl)
        assert dispatch.use_sparse_decode_kernel(cfg) == (impl == "kernel")
        eng = Engine(cfg, params, max_len=32, num_slots=2, decode_chunk=4)
        outs[impl] = [c.tokens for c in eng.run(reqs)]
    for row, (req, got_k, got_j) in enumerate(
            zip(reqs, outs["kernel"], outs["jnp"])):
        if got_k == got_j:
            continue
        t = next(i for i, (a, b) in enumerate(zip(got_k, got_j)) if a != b)
        ctx = list(req.tokens) + got_j[:t]    # common prefix by choice of t
        lg = _replay_last_logits(params, base, ctx, max_len=32)
        top = float(lg.max())
        gap = max(top - float(lg[got_k[t]]), top - float(lg[got_j[t]]))
        assert gap <= 1e-3, (
            f"row {row} diverged at step {t} with a real logit gap "
            f"{gap:.3e} (tokens {got_k[t]} vs {got_j[t]}): kernel path "
            "disagrees with the jnp oracle beyond a near-tie")


# ------------------------------------------------------------ slow sweep
@pytest.mark.slow
@pytest.mark.parametrize("gran", ["qhead", "kvgroup"])
@pytest.mark.parametrize("s,frac,dtype", [
    (64, 0.125, jnp.float32),
    (96, 0.5, jnp.float32),
    (128, 0.125, jnp.bfloat16),
    (256, 0.25, jnp.float32),
])
def test_decode_kernel_sweep(gran, s, frac, dtype):
    b, hq, hk, d = 2, 8, 2, 64
    pcfg, cb = _cb(d)
    scfg = sa.SparseAttentionConfig(pq=pcfg, top_fraction=frac, min_l=8,
                                    select_granularity=gran)
    q, k, v = _decode_case(b, hq, hk, s, d, seed=s, dtype=dtype)
    codes = pq.assign(k, cb).astype(jnp.int8)
    kv_valid = jax.random.uniform(jax.random.PRNGKey(s), (b, s)) < 0.9
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    _assert_parity(q, k, v, codes, cb, scfg, kv_valid, tol=tol, tile_k=64)
