"""Paged KV-cache subsystem tests (serving/kv_pages.py + engine wiring).

Covers: allocator unit behavior (alloc/free/exhaustion/reuse), engine-level
paged == contiguous greedy row-identity (default sparse-MHA jnp, dense,
bucketed-padding, sparse decode *kernel* on/off, ragged prompts, EOS slot
recycling), lazy in-loop page growth across page boundaries, the
page-exhaustion admission stall, and the memory accounting helpers.  The
wide (page_size x variant) sweep is `slow`; everything else runs in
scripts/ci_fast.sh."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.params import init_tree
from repro.serving import kv_pages as kvp
from repro.serving.engine import Engine, Request
from repro.train.state import model_defs

MAX_LEN, SLOTS, GEN, CHUNK, PS = 48, 3, 6, 4, 16


def _tiny_cfg(**spt):
    cfg = dataclasses.replace(
        configs.get_smoke("qwen3-0.6b"), num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256)
    spt.setdefault("kv_page_size", PS)
    return cfg.with_spt(ffn_capacity_factor=8.0, **spt)


_params_cache = {}


def _params(cfg):
    key = (cfg.name, cfg.spt.sparse_mha, str(cfg.dtype))
    if key not in _params_cache:
        p = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
        if cfg.dtype == jnp.float32:
            p = jax.tree_util.tree_map(
                lambda a: a.astype(jnp.float32), p)
        _params_cache[key] = p
    return _params_cache[key]


def _reqs(cfg, lens, gen=GEN, seed=1):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, tokens=rng.integers(
        0, cfg.vocab_size, size=ln, dtype=np.int32).tolist(),
        max_new_tokens=gen) for i, ln in enumerate(lens)]


def _run_both(cfg, reqs, eos_id=None, kv_pages=None, max_len=MAX_LEN,
              slots=SLOTS):
    params = _params(cfg)
    eng_c = Engine(cfg, params, max_len=max_len, num_slots=slots,
                   decode_chunk=CHUNK)
    eng_p = Engine(cfg.with_spt(kv_layout="paged"), params, max_len=max_len,
                   num_slots=slots, decode_chunk=CHUNK, kv_pages=kv_pages)
    out_c = eng_c.run(reqs, eos_id=eos_id)
    out_p = eng_p.run(reqs, eos_id=eos_id)
    return out_c, out_p, eng_c, eng_p


# --------------------------------------------------------------- allocator
def test_allocator_alloc_free_exhaustion_reuse():
    st = kvp.init_state(4)
    st, pid, ok = kvp.alloc_masked(st, jnp.asarray([True, False, True, True]))
    pid = np.asarray(pid)
    assert np.asarray(ok).tolist() == [True, False, True, True]
    assert pid[1] == -1 and len({pid[0], pid[2], pid[3]}) == 3
    assert int(kvp.pages_in_use(st)) == 3
    # exhaustion: 1 page left, 2 wanted -> second alloc fails cleanly
    st, pid2, ok2 = kvp.alloc_masked(st, jnp.asarray([True, True]))
    assert np.asarray(ok2).tolist() == [True, False]
    assert int(np.asarray(pid2)[1]) == -1
    assert int(kvp.pages_in_use(st)) == 4
    # free + reuse: freed ids come back
    pt = kvp.init_page_table(1, 4)
    pt = pt.at[0, 0].set(int(np.asarray(pid2)[0]))
    st, pt = kvp.free_slot_pages(st, pt, jnp.int32(0))
    assert int(kvp.pages_in_use(st)) == 3
    assert np.asarray(pt[0]).tolist() == [-1, -1, -1, -1]
    st, pid3, ok3 = kvp.alloc_masked(st, jnp.asarray([True]))
    assert bool(np.asarray(ok3)[0])
    assert int(np.asarray(pid3)[0]) == int(np.asarray(pid2)[0])  # recycled


def test_alloc_slot_pages_partial_row():
    st = kvp.init_state(8)
    pt = kvp.init_page_table(2, 3)
    st, pt = kvp.alloc_slot_pages(st, pt, jnp.int32(1), jnp.int32(2))
    row = np.asarray(pt[1])
    assert (row[:2] >= 0).all() and row[2] == -1 and row[0] != row[1]
    assert np.asarray(pt[0]).tolist() == [-1, -1, -1]
    assert int(kvp.pages_in_use(st)) == 2
    # replacing a slot's row starts from a clean slate (recycling)
    st, pt = kvp.free_slot_pages(st, pt, jnp.int32(1))
    st, pt = kvp.alloc_slot_pages(st, pt, jnp.int32(1), jnp.int32(3))
    assert (np.asarray(pt[1]) >= 0).all()
    assert int(kvp.pages_in_use(st)) == 3


def test_gather_scatter_round_trip():
    pool = jnp.zeros((4, 2, PS, 8))                      # (P, Hk, ps, d)
    pt = jnp.asarray([[2, 0], [3, -1]])                  # slot 1: 1 page
    val = jnp.ones((2, 2, 8))
    pool = kvp.scatter_row(pool, pt, jnp.asarray([0, PS + 1]), val, PS)
    view = kvp.gather_pages(pool, pt)                    # (2, 2, 2*PS, 8)
    assert view.shape == (2, 2, 2 * PS, 8)
    assert float(view[0, :, 0].sum()) == 16.0            # slot 0 row 0
    # slot 1 position PS+1 -> logical page 1 = unallocated -> dropped
    assert float(view[1].sum()) == 0.0
    occ = kvp.occupancy(pt, PS)
    assert occ.shape == (2, 2 * PS)
    assert bool(occ[0].all()) and not bool(occ[1, PS:].any())


# ----------------------------------------------------------- engine parity
def test_paged_matches_contiguous_with_recycling():
    """Default SPT config (sparse-MHA jnp decode + routed FFN), ragged
    exact-length prompts, more requests than slots (slot + page
    recycling): greedy completions must be row-identical."""
    cfg = _tiny_cfg()
    reqs = _reqs(cfg, [16, 9, 23, 5, 12])
    out_c, out_p, _, eng_p = _run_both(cfg, reqs)
    assert [c.tokens for c in out_p] == [c.tokens for c in out_c]
    assert [c.finish_reason for c in out_p] == \
        [c.finish_reason for c in out_c]
    s = eng_p.last_stats
    assert s.page_size == PS and s.kv_pages_total == SLOTS * (MAX_LEN // PS)
    assert 0 < s.kv_pages_peak <= s.kv_pages_total
    assert len(eng_p._chunk_cache) == 1                  # still traces once


def test_paged_matches_contiguous_dense_bucketed():
    """SPT-off dense stack takes the bucketed right-padding prefill path;
    the pad overhang scatters into -1 page ids (dropped) and must not
    change outputs."""
    cfg = dataclasses.replace(_tiny_cfg(), name="tiny-dense").with_spt(
        sparse_mha=False, routed_ffn=False)
    eng = Engine(cfg.with_spt(kv_layout="paged"), _params(cfg),
                 max_len=MAX_LEN, num_slots=2, decode_chunk=CHUNK)
    assert eng._pad_invariant() and eng._pad_len(9) == 16
    reqs = _reqs(cfg, [5, 9, 11], gen=4, seed=6)
    out_c, out_p, _, _ = _run_both(cfg, reqs, slots=2)
    assert [c.tokens for c in out_p] == [c.tokens for c in out_c]


def test_paged_bucketed_overhang_dropped():
    """Bucketed padding can overshoot the allocated pages (len 17 pads to
    32 but only ceil(17/8)=3 pages are allocated at ps=8): the overhang
    scatters into -1 page ids and is dropped without corrupting the pool."""
    cfg = dataclasses.replace(
        _tiny_cfg(kv_page_size=8), name="tiny-dense8").with_spt(
        sparse_mha=False, routed_ffn=False)
    eng = Engine(cfg.with_spt(kv_layout="paged"), _params(cfg),
                 max_len=MAX_LEN, num_slots=2, decode_chunk=CHUNK)
    assert eng._pad_invariant() and eng._pad_len(17) == 32
    reqs = _reqs(cfg, [17, 5], gen=4, seed=8)
    out_c, out_p, _, _ = _run_both(cfg, reqs, slots=2)
    assert [c.tokens for c in out_p] == [c.tokens for c in out_c]


def test_paged_eos_recycling():
    cfg = _tiny_cfg()
    reqs = _reqs(cfg, [16, 16, 16, 16], seed=3)
    free = [c.tokens for c in Engine(
        cfg, _params(cfg), max_len=MAX_LEN, num_slots=SLOTS,
        decode_chunk=CHUNK).run(reqs)]
    eos = free[0][2]
    out_c, out_p, _, eng_p = _run_both(cfg, reqs, eos_id=eos)
    assert [c.tokens for c in out_p] == [c.tokens for c in out_c]
    assert out_p[0].finish_reason == "eos"
    assert eng_p.last_stats.completed == 4


def test_page_exhaustion_admission_stall():
    """A pool sized for one request at a time serializes admission: every
    request still completes (row-identical), the engine reports stalls,
    and the measured peak never exceeds the pool."""
    cfg = _tiny_cfg()
    reqs = _reqs(cfg, [16, 12, 16], seed=2)
    ws = kvp.num_pages(16 + GEN - 1, PS)                 # largest request
    out_c, out_p, _, eng_p = _run_both(cfg, reqs, kv_pages=ws)
    assert [c.tokens for c in out_p] == [c.tokens for c in out_c]
    s = eng_p.last_stats
    assert s.admission_stalls > 0
    assert 0 < s.kv_pages_peak <= ws
    assert s.completed == 3


def test_request_larger_than_pool_rejected():
    cfg = _tiny_cfg().with_spt(kv_layout="paged")
    eng = Engine(cfg, _params(_tiny_cfg()), max_len=MAX_LEN,
                 num_slots=SLOTS, decode_chunk=CHUNK, kv_pages=1)
    out = eng.run(_reqs(_tiny_cfg(), [32]))
    assert out[0].finish_reason == "rejected"
    assert "KV pages" in out[0].detail
    assert eng.last_stats.rejections == 1


def test_lazy_page_growth_across_boundary():
    """A generation that crosses a page boundary allocates its next page
    inside the compiled while_loop (prompt 15 + first token fill page 0 of
    ps=16; decode then pops page 1 in-loop)."""
    cfg = _tiny_cfg()
    reqs = _reqs(cfg, [15], gen=8, seed=4)
    out_c, out_p, _, eng_p = _run_both(cfg, reqs, slots=1)
    assert out_p[0].tokens == out_c[0].tokens
    assert eng_p.last_stats.kv_pages_peak == 2           # grew by one page


# ----------------------------------------------------- sparse decode kernel
def test_paged_sparse_decode_kernel_on_off(monkeypatch):
    """Paged greedy decode through the kernel-native route (page table
    scalar-prefetched into the fused Pallas decode kernel, interpret
    off-TPU) == the explicit gathered-view kernel tier == the jnp fallback
    == the kill switch, and all of them == the contiguous layout.  All-f32
    keeps accumulation order inside float noise (same rationale as
    test_sparse_decode)."""
    base = dataclasses.replace(_tiny_cfg(), dtype=jnp.float32).with_spt(
        routed_ffn=False)
    reqs = _reqs(base, [9, 14], gen=3, seed=5)

    def run(layout, impl, disable=False, native="auto"):
        monkeypatch.setenv("REPRO_DISABLE_KERNELS", "1" if disable else "0")
        cfg = base.with_spt(kv_layout=layout, decode_attn_impl=impl,
                            kv_paged_native=native)
        try:
            eng = Engine(cfg, _params(base), max_len=32, num_slots=2,
                         decode_chunk=CHUNK)
            return [c.tokens for c in eng.run(reqs)]
        finally:
            monkeypatch.setenv("REPRO_DISABLE_KERNELS", "0")

    want = run("contiguous", "jnp")
    assert run("paged", "jnp") == want
    assert run("paged", "kernel") == want                # kernel-native
    assert run("paged", "kernel", native="gather") == want  # gathered tier
    assert run("paged", "kernel", disable=True) == want  # kill switch


# --------------------------------------------------------- accounting/misc
def test_kv_row_bytes_accounting():
    sparse = _tiny_cfg()
    dense = sparse.with_spt(sparse_mha=False)
    rb_s, rb_d = kvp.kv_row_bytes(sparse), kvp.kv_row_bytes(dense)
    # 2 layers x (K+V bf16 + slot_pos), + PQ codes only when sparse
    assert rb_d == 2 * (2 * 2 * 16 * 2 + 4)
    assert rb_s == rb_d + 2 * 2 * (16 // sparse.spt.pq_code_dim)
    swa = dataclasses.replace(sparse, window=8)
    assert kvp.kv_row_bytes(swa) == 0                    # rings aren't paged


def test_paged_noop_for_windowed_and_recurrent():
    """kv_layout="paged" on stacks with nothing to page (SWA ring bounds
    every attention cache) silently keeps the contiguous engine."""
    cfg = dataclasses.replace(_tiny_cfg(), window=8).with_spt(
        kv_layout="paged")
    eng = Engine(cfg, _params(_tiny_cfg()), max_len=MAX_LEN,
                 num_slots=2, decode_chunk=CHUNK)
    assert not eng._paged and eng.kv_pages == 0


# ------------------------------------------------------------- wide sweep
@pytest.mark.slow
@pytest.mark.parametrize("ps", [8, 24])                  # 24 !| MAX_LEN
@pytest.mark.parametrize("sparse", [False, True])
def test_paged_parity_sweep(ps, sparse):
    cfg = dataclasses.replace(
        _tiny_cfg(kv_page_size=ps), dtype=jnp.float32,
        name=f"tiny-sweep-{ps}-{sparse}")
    if not sparse:
        cfg = cfg.with_spt(sparse_mha=False)
    reqs = _reqs(cfg, [16, 7, 21, 11], seed=7)
    out_c, out_p, _, _ = _run_both(cfg, reqs)
    assert [c.tokens for c in out_p] == [c.tokens for c in out_c]
