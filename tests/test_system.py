"""End-to-end system tests: fine-tune -> checkpoint -> resume -> serve,
decode/train parity, and paper-claims validation at CPU scale."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import pq
from repro.core import sparse_attention as sa
from repro.core.params import init_tree
from repro.data.pipeline import DataConfig, synthetic_dataset
from repro.models import transformer
from repro.optim.adamw import OptimizerConfig
from repro.serving.engine import Engine
from repro.train.state import model_defs
from repro.train.trainer import Trainer, TrainerConfig


def _tiny_cfg():
    return dataclasses.replace(
        configs.get_smoke("qwen3-0.6b"), num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256)


@pytest.mark.slow
def test_training_reduces_loss():
    cfg = _tiny_cfg()
    steps = 60
    data = synthetic_dataset(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                   branching=2, seed=3), steps=steps + 1)
    t = Trainer(cfg, OptimizerConfig(lr=5e-3, warmup_steps=5,
                                     total_steps=steps),
                TrainerConfig(total_steps=steps, log_interval=1))
    rep = t.run(data)
    losses = [m["loss"] for m in rep["metrics"]]
    assert losses[-1] < losses[0] - 0.1, (losses[0], losses[-1])
    assert all(np.isfinite(l) for l in losses)


@pytest.mark.slow
def test_resume_continues_from_checkpoint(tmp_path):
    cfg = _tiny_cfg()
    d = str(tmp_path / "ck")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    t1 = Trainer(cfg, OptimizerConfig(total_steps=20),
                 TrainerConfig(total_steps=10, ckpt_dir=d, ckpt_interval=5))
    t1.run(synthetic_dataset(dcfg, steps=11))
    t2 = Trainer(cfg, OptimizerConfig(total_steps=20),
                 TrainerConfig(total_steps=20, ckpt_dir=d, ckpt_interval=5))
    assert t2.start_step == 10
    rep = t2.run(synthetic_dataset(dcfg, steps=11))
    assert rep["final_step"] == 20


def test_serve_after_training_deterministic():
    cfg = _tiny_cfg()
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_len=48, jit=True)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab_size, dtype=jnp.int32)}
    a = engine.generate(batch, steps=4)
    engine2 = Engine(cfg, params, max_len=48, jit=True)
    b = engine2.generate(batch, steps=4)
    assert a.tokens == b.tokens


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-780m",
                                  "recurrentgemma-9b"])
def test_decode_matches_train_forward(arch):
    """Logits from prefill+decode equal the full-sequence forward at the
    same positions (teacher-forcing parity) — the serving-path contract."""
    cfg = configs.get_smoke(arch)
    if cfg.window is not None:
        cfg = dataclasses.replace(cfg, window=None)
    # capacity drops are train-path-only (decode always fits): give the
    # dispatcher full slack so the parity check isolates the serving path
    cfg = cfg.with_spt(ffn_capacity_factor=8.0)
    params = init_tree(transformer.lm_defs(cfg), jax.random.PRNGKey(0))
    s = 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, s), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    hidden, _ = transformer.lm_hidden(params, cfg, {"tokens": tokens},
                                      remat=False)
    full_logits = transformer.logits_of(params, cfg, hidden)
    caches, logits_p = transformer.lm_prefill(
        params, cfg, {"tokens": tokens[:, :s - 2]}, max_len=s + 4)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1], np.float32),
        np.asarray(full_logits[:, s - 3], np.float32), rtol=6e-2, atol=6e-2)
    caches, logits_d = transformer.lm_decode_step(
        params, cfg, caches, tokens[:, s - 2],
        jnp.asarray(s - 2, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits_d[:, -1], np.float32),
        np.asarray(full_logits[:, s - 2], np.float32), rtol=6e-2, atol=6e-2)


# ------------------------------------------------- paper-claims validation
def test_paper_claim_attention_weight_concentration():
    """Fig. 3 analogue: top-15% softmax weights carry >> 50% of the mass
    for trained-ish (correlated) q/k; we check the skew exists even with
    random data at low temperature."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (64, 32)) * 2.0
    k = q + 0.5 * jax.random.normal(jax.random.fold_in(key, 1), (64, 32))
    w = jax.nn.softmax(q @ k.T / np.sqrt(32), axis=-1)
    ws = np.sort(np.asarray(w), axis=-1)[:, ::-1]
    top15 = ws[:, :int(0.15 * 64)].sum(-1).mean()
    assert top15 > 0.5, top15


def test_paper_claim_pq_recall_with_trained_codebooks():
    """§4.1: PQ top-L recall ~90% with codebooks matched to the data.
    We EMA-train codebooks on the key distribution and require >=60%
    recall at top-1/4 on correlated data (untrained floor is ~35%)."""
    key = jax.random.PRNGKey(0)
    pcfg = pq.PQConfig(head_dim=32, code_dim=8, num_codewords=16)
    base = jax.random.normal(key, (8, 32))        # 8 latent clusters
    assign_idx = jax.random.randint(jax.random.fold_in(key, 1), (1, 2, 128),
                                    0, 8)
    noise = 0.3 * jax.random.normal(jax.random.fold_in(key, 2),
                                    (1, 2, 128, 32))
    k = base[assign_idx] + noise
    q = base[assign_idx] + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 3), (1, 2, 128, 32))
    cb = pq.init_codebooks_from_data(k, pcfg, jax.random.fold_in(key, 4))
    for _ in range(20):
        cb = pq.ema_update(cb, k.reshape(-1, 32), ema=0.3)
    scfg = sa.SparseAttentionConfig(pq=pcfg, top_fraction=0.25, min_l=4)
    rec = float(sa.selection_recall(q, k, cb, scfg, causal=True))
    assert rec >= 0.6, rec


def test_paper_claim_routed_ffn_flop_fraction():
    """§4.2/Table 4: routed FFN computes ~beta of the dense FFN FLOPs.
    Verified structurally: exactly G' of G blocks active per token."""
    from repro.core import routed_ffn as rf
    from repro.core import lora as lora_mod
    rcfg = rf.RoutedFFNConfig(d_model=32, d_ff=64, num_groups=8,
                              active_groups=3, capacity_factor=8.0)
    p = init_tree(rf.param_defs(rcfg, lora_mod.LoRAConfig(enabled=False)),
                  jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32))
    choice, gate, probs = rf.route(x, p["router"], rcfg)
    assert choice.shape == (1, 16, 3)
    # distinct blocks per token
    c = np.asarray(choice)
    for tkn in c.reshape(-1, 3):
        assert len(set(tkn.tolist())) == 3
    plan_tokens = 16 * 3
    from repro.core import dispatch
    cap = dispatch.capacity(16, 8, 3, 8.0)
    plan = dispatch.make_plan(choice, gate, 8, cap)
    assert int(np.asarray(plan.slot_ok).sum()) == plan_tokens


def test_paper_claim_sparse_mha_memory_scaling():
    """§4.1: attention state scales O(nL), not O(n^2): the selection output
    is exactly (B, H, n, L) indices — 8x smaller at top-1/8."""
    n, frac = 256, 0.125
    pcfg = pq.PQConfig(head_dim=16, code_dim=8, num_codewords=16)
    scfg = sa.SparseAttentionConfig(pq=pcfg, top_fraction=frac, min_l=1)
    assert sa.top_l(n, scfg, None) == int(n * frac)
