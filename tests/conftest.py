import os

# Tests run on the single real CPU device (the 512-device override belongs
# to launch/dryrun.py ONLY).  Force-set nothing here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
