"""Serving telemetry tests: reservoir sampling, snapshot/legacy dict
parity, request lifecycle timelines per finish reason, Chrome-trace
schema + uid coverage, device-counter drains against a jnp oracle, and
bit-identical greedy streams across telemetry off/counters/trace.

Engines here are tiny (2 layers, d=64) and jit-compiled once per mode;
the counter oracle replays the decode loop through the model-level
``lm_decode_step(return_counters=True)`` path the compiled chunk calls.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.params import init_tree
from repro.models import transformer
from repro.serving import trace_export
from repro.serving.engine import (ArrivalSchedule, Engine, ManualClock,
                                  Request, ServeStats)
from repro.serving.telemetry import (MetricsSnapshot, Reservoir,
                                     TelemetryRecorder)
from repro.train.state import model_defs

MAX_LEN, GEN, CHUNK = 48, 6, 4


def _tiny_cfg(**spt):
    return dataclasses.replace(
        configs.get_smoke("qwen3-0.6b"), num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256).with_spt(ffn_capacity_factor=8.0, **spt)


@pytest.fixture(scope="module")
def tiny_params():
    cfg = _tiny_cfg()
    return cfg, init_tree(model_defs(cfg), jax.random.PRNGKey(0))


def _prompts(cfg, lens, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=ln,
                         dtype=np.int32).tolist() for ln in lens]


# --------------------------------------------------------------- reservoir
def test_reservoir_deterministic_bounded_exact_mean():
    xs = np.random.default_rng(3).random(5000)
    a, b = Reservoir(cap=256, seed=7), Reservoir(cap=256, seed=7)
    a.extend(xs)
    b.extend(xs)
    assert a.values == b.values                 # same seed, same sample
    assert len(a) == 256 and a.n_seen == 5000
    assert a.mean == pytest.approx(float(xs.mean()), rel=1e-12)
    # the retained sample is uniform over the stream: percentiles land
    # near the full-stream truth (256-sample error band)
    assert abs(a.percentile(50) - float(np.percentile(xs, 50))) < 0.12
    assert abs(a.percentile(99) - float(np.percentile(xs, 99))) < 0.12
    c = Reservoir(cap=256, seed=8)
    c.extend(xs)
    assert c.values != a.values                 # different seed, different

    short = Reservoir(cap=16, seed=0)
    short.extend([1.0, 2.0, 3.0])
    assert list(short) == [1.0, 2.0, 3.0] and bool(short)
    assert not Reservoir(cap=4, seed=0)
    assert Reservoir(cap=4, seed=0).percentile(50) == 0.0


# ------------------------------------------------------ snapshot / as_dict
def test_snapshot_matches_legacy_as_dict_exactly():
    st = ServeStats(page_size=16, kv_pages_total=12)
    st.prefill_s, st.decode_s = 0.1234567, 2.5
    st.prefill_tokens, st.decode_tokens, st.decode_steps = 100, 50, 10
    st.admitted, st.completed, st.prefill_batches = 6, 6, 3
    st.prefill_rows = 8
    st.ttft_samples.extend([0.01, 0.02, 0.05])
    st.ttft_s_sum, st.ttft_s_max = 0.08, 0.05
    st.tpot_samples.extend([0.001, 0.002])
    st.preemptions, st.rejections, st.cancelled, st.shed = 1, 2, 1, 1
    st.kv_pages_peak, st.admission_stalls = 9, 4
    d = st.as_dict()
    assert list(d) == list(ServeStats.LEGACY_ORDER)
    assert d["ttft_avg_s"] == round(0.08 / 3, 4)
    assert d["ttft_max_s"] == 0.05
    # no paging -> the paged tail keys are absent, order still legacy
    d2 = ServeStats().as_dict()
    assert list(d2) == [k for k in ServeStats.LEGACY_ORDER
                        if k not in ("page_size", "kv_pages_total",
                                     "kv_pages_peak", "admission_stalls")]
    # device aggregates (telemetry on) append AFTER the legacy keys
    st.device.update({"keep_rate": 0.5, "expert_load_imbalance": 1.2})
    d3 = st.as_dict()
    assert list(d3)[:len(ServeStats.LEGACY_ORDER)] == \
        list(ServeStats.LEGACY_ORDER)
    assert list(d3)[len(ServeStats.LEGACY_ORDER):] == [
        "expert_load_imbalance", "keep_rate"]

    flat = MetricsSnapshot(histograms={"ttft": {"p50_s": 1.0}}).flat()
    assert flat == {"ttft_p50_s": 1.0}


# ----------------------------------------------------- recorder micro-unit
def test_drain_counters_micro_oracle():
    rec = TelemetryRecorder(mode="counters")
    assert not rec.trace
    rec.drain_counters({
        "tel_attn_kept": np.array([[3.0, 4.0], [1.0, 2.0]]),
        "tel_attn_elig": np.array([[6.0, 8.0], [2.0, 4.0]]),
        "tel_expert_load": np.array([[[1.0, 0.0], [2.0, 1.0]]]),
        "tel_expert_drop": np.array([2.0]),
        "decode_tokens": np.array(7.0),
    })
    rec.drain_counters({"tel_expert_load": np.array([[[0.0, 3.0]]])})
    assert rec.attn_kept == 10.0 and rec.attn_elig == 20.0
    assert rec.expert_load_vector() == [3.0, 4.0]
    agg = rec.device_aggregates()
    assert agg["keep_rate"] == 0.5
    # max/mean over per-expert loads: max 4, mean 3.5
    assert agg["expert_load_imbalance"] == round(4.0 / 3.5, 3)
    assert agg["expert_tokens_routed"] == 7.0
    assert agg["expert_dropped"] == 2.0
    assert agg["counted_decode_tokens"] == 7.0
    assert rec.counter_drains == 2
    rec.drain_counters(None)                    # no-op, not a crash
    assert rec.counter_drains == 2

    # counters mode drops lifecycle recording entirely
    rec.event(1, "submit", 0.0)
    rec.span("x", 0.0, 1.0, 0)
    rec.gauge("g", 0.0, 1.0)
    assert not rec.timelines and not rec.spans and not rec.gauge_tracks


# ------------------------------------------- engine counters vs jnp oracle
def test_engine_counter_drain_matches_stepwise_oracle(tiny_params):
    """Seeded single-slot greedy run: the totals the compiled chunk
    accumulates in-carry (and the engine drains once per chunk) must
    equal a host-side replay through lm_prefill_ragged/lm_decode_step
    with return_counters=True — the jnp oracle for every tel_* key."""
    cfg, params = tiny_params
    ctel = cfg.with_spt(telemetry="counters")
    prompt = _prompts(cfg, [8])[0]
    eng = Engine(ctel, params, max_len=MAX_LEN, num_slots=1,
                 decode_chunk=CHUNK)
    eng.run([Request(uid=0, tokens=prompt, max_new_tokens=GEN)])
    rec = eng.last_recorder
    assert rec is not None and rec.counter_drains >= 2  # prefill + chunks

    # oracle prefill: the exact ragged call the engine made (bucket of 1)
    toks = np.zeros((1, 8), np.int32)
    toks[0] = prompt
    _, logits, telp = jax.jit(
        lambda p, b, ln: transformer.lm_prefill_ragged(
            p, ctel, b, ln, MAX_LEN, return_counters=True)
    )(params, {"tokens": jnp.asarray(toks)}, jnp.asarray([8], jnp.int32))
    telp = jax.device_get(telp)
    assert set(telp) == {"tel_expert_load", "tel_expert_drop"}

    # oracle decode: contiguous per-token replay with the engine's own
    # kv_valid construction (slot j live iff j <= pos)
    decode = jax.jit(
        lambda p, c, t, q, m: transformer.lm_decode_step(
            p, ctel, c, t, q, kv_valid=m, return_counters=True))
    caches, lg = jax.jit(
        lambda p, b: transformer.lm_prefill(p, ctel, b, max_len=MAX_LEN)
    )(params, {"tokens": jnp.asarray(toks)})
    tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)
    kept = elig = 0.0
    load = None
    for i in range(GEN - 1):
        pos = jnp.asarray([8 + i], jnp.int32)
        kv_valid = (jnp.arange(MAX_LEN, dtype=jnp.int32)[None, :]
                    <= pos[:, None])
        caches, lg, tel = decode(params, caches, tok, pos, kv_valid)
        tel = jax.device_get(tel)
        kept += float(np.array(tel["tel_attn_kept"]).sum())
        elig += float(np.array(tel["tel_attn_elig"]).sum())
        step_load = np.array(tel["tel_expert_load"], np.float64)
        step_load = step_load.reshape(-1, step_load.shape[-1]).sum(0)
        load = step_load if load is None else load + step_load
        tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)

    assert rec.attn_kept == kept
    assert rec.attn_elig == elig
    assert rec.counted_decode_tokens == GEN - 1
    prefill_load = np.array(telp["tel_expert_load"], np.float64)
    prefill_load = prefill_load.reshape(-1, prefill_load.shape[-1]).sum(0)
    np.testing.assert_array_equal(rec.expert_load, prefill_load + load)
    # greedy run, decode chunk compiled with no sampling counters
    assert rec.sampled_tokens == 0.0 and rec.pages_allocated == 0.0
    assert eng.last_stats.decode_tokens == GEN - 1


# ------------------------------------- bit-identical streams across modes
def test_streams_bit_identical_across_modes(tiny_params):
    cfg, params = tiny_params
    reqs = [Request(uid=i, tokens=t, max_new_tokens=GEN)
            for i, t in enumerate(_prompts(cfg, [8, 11, 6, 9]))]
    outs, dicts = {}, {}
    for mode in ("off", "counters", "trace"):
        eng = Engine(cfg.with_spt(telemetry=mode), params,
                     max_len=MAX_LEN, num_slots=2, decode_chunk=CHUNK)
        res = eng.run(list(reqs))
        outs[mode] = [(c.uid, c.tokens, c.finish_reason) for c in res]
        dicts[mode] = eng.last_stats.as_dict()
    assert outs["off"] == outs["counters"] == outs["trace"]
    # the off-mode dict is exactly the legacy key set — telemetry keys
    # appear only when telemetry is on
    assert set(dicts["off"]) <= set(ServeStats.LEGACY_ORDER)
    assert "keep_rate" in dicts["counters"]
    assert "keep_rate" in dicts["trace"]


# ----------------------------------------- lifecycle timelines + the trace
@pytest.fixture(scope="module")
def traced_run(tiny_params):
    """One deterministic serve() exercising every finish reason: uid 0 is
    force-preempted mid-stream and resumed, uid 1 sheds on a lapsed TTFT
    deadline, uid 2 is cancelled while queued, uid 3 retires normally,
    uid 99 (oversized, injected mid-run) is rejected."""
    cfg, params = tiny_params
    eng = Engine(cfg.with_spt(telemetry="trace"), params,
                 max_len=MAX_LEN, num_slots=1, decode_chunk=CHUNK)
    pr = _prompts(cfg, [8, 8, 8, 8])
    reqs = [Request(uid=0, tokens=pr[0], max_new_tokens=10),
            Request(uid=1, tokens=pr[1], max_new_tokens=4,
                    deadline_s=0.5),
            Request(uid=2, tokens=pr[2], max_new_tokens=4),
            Request(uid=3, tokens=pr[3], max_new_tokens=4)]
    fired = []

    def hook(e, iteration):
        if iteration == 2 and not fired:
            fired.append(iteration)
            assert e.preempt()                  # evicts uid 0 (active)
            assert e.cancel(2)                  # uid 2 still queued
            e.submit(Request(uid=99, tokens=[1] * 4,
                             max_new_tokens=MAX_LEN + 1))  # must reject
    out = eng.serve(ArrivalSchedule.burst(reqs), clock=ManualClock(dt=1.0),
                    on_iteration=hook)
    return eng, {c.uid: c for c in out}


def test_timelines_per_finish_reason(traced_run):
    eng, by_uid = traced_run
    rec = eng.last_recorder
    assert by_uid[0].finish_reason == "length"
    assert by_uid[1].finish_reason == "shed"
    assert by_uid[2].finish_reason == "cancelled"
    assert by_uid[3].finish_reason == "length"
    assert by_uid[99].finish_reason == "rejected"

    ev = {uid: [e["event"] for e in rec.timeline(uid)]
          for uid in (0, 1, 2, 3, 99)}
    assert ev[0][:4] == ["submit", "queued", "admitted", "first_token"]
    assert "preempted" in ev[0] and "resumed" in ev[0]
    assert ev[0].index("preempted") < ev[0].index("resumed")
    assert ev[0][-1] == "retired"
    assert ev[1] == ["submit", "queued", "shed"]
    assert ev[2] == ["submit", "queued", "cancelled"]
    assert ev[3] == ["submit", "queued", "admitted", "first_token",
                     "retired"]
    assert ev[99] == ["submit", "rejected"]
    # timestamps are monotone within each timeline
    for uid in (0, 1, 2, 3, 99):
        ts = [e["t"] for e in rec.timeline(uid)]
        assert ts == sorted(ts)
    # resumed tokens are never re-counted: the retired n_gen equals the
    # completion's token count
    retired0 = rec.timeline(0)[-1]
    assert retired0["n_gen"] == len(by_uid[0].tokens)


def test_chrome_trace_schema_and_uid_coverage(traced_run, tmp_path):
    eng, by_uid = traced_run
    rec = eng.last_recorder
    path = tmp_path / "trace.json"
    trace = trace_export.write_trace(rec, str(path))
    assert trace_export.validate_chrome_trace(trace) == []
    import json
    with open(path) as f:
        on_disk = json.load(f)
    assert trace_export.validate_chrome_trace(on_disk) == []
    # every submitted uid owns a request lane
    assert set(by_uid) <= trace_export.trace_uids(on_disk)
    names = {e["name"] for e in on_disk["traceEvents"]}
    # scheduler spans + derived request-phase spans are present
    assert {"decode_chunk", "prefill_batch", "queued", "generate",
            "queue_depth", "active_slots"} <= names
    # the preempted request's lane carries TWO queued waits (initial +
    # re-queued after eviction)
    q0 = [e for e in on_disk["traceEvents"]
          if e.get("tid") == 0 and e.get("pid") == trace_export.REQ_PID
          and e.get("name") == "queued" and e.get("ph") == "X"]
    assert len(q0) == 2
    # events JSONL round-trips line-per-event
    jl = tmp_path / "events.jsonl"
    n = trace_export.write_events_jsonl(rec, str(jl))
    lines = [json.loads(x) for x in jl.read_text().splitlines()]
    assert len(lines) == n == len(rec.events)

    # the validator actually rejects malformed events
    assert trace_export.validate_chrome_trace({"traceEvents": [
        {"ph": "Z", "pid": 1, "tid": 0, "name": "x", "ts": 0}]})
    assert trace_export.validate_chrome_trace({"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 0, "name": "x", "ts": -1.0}]})
    assert trace_export.validate_chrome_trace(["not-a-dict"])


def test_watchdog_dump_on_invariant_failure(tiny_params, capsys):
    """A tripped invariant dumps the metrics snapshot and recent events
    before raising — the postmortem flight recorder."""
    from repro.serving.chaos import Watchdog
    cfg, params = tiny_params
    eng = Engine(cfg.with_spt(telemetry="trace"), params,
                 max_len=MAX_LEN, num_slots=1, decode_chunk=CHUNK)
    wd = Watchdog(dump_events=5)

    def corrupt_then_check(e, iteration):
        st = e._live
        if st.slot_item[0] is not None:
            st.active[0] = True
            st.slot_item[0] = None              # fabricate a slot leak
        wd(e, iteration)

    req = Request(uid=0, tokens=_prompts(cfg, [8])[0], max_new_tokens=10)
    with pytest.raises(AssertionError, match="slot leak"):
        eng.run([req], on_iteration=corrupt_then_check)
    eng._live = None                            # unwind the aborted run
    err = capsys.readouterr().err
    assert "WATCHDOG DUMP" in err and "violations" in err
