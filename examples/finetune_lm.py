"""End-to-end driver (deliverable b): fine-tune a ~100M-param LM with SPT
for a few hundred steps on the synthetic corpus, with checkpoint/restart.

    PYTHONPATH=src python examples/finetune_lm.py --steps 300

The model is a 12-layer qwen3-family config (~100M params with its
embedding table) running sparse MHA + routed FFN + LoRA — the paper's full
pipeline at CPU scale.
"""
import argparse
import dataclasses
import json
import pathlib
import tempfile

import jax

from repro import configs
from repro.core.params import count_params
from repro.data.pipeline import DataConfig, synthetic_dataset
from repro.optim.adamw import OptimizerConfig
from repro.train.state import model_defs
from repro.train.trainer import Trainer, TrainerConfig


def model_100m() -> configs.ModelConfig:
    return dataclasses.replace(
        configs.get_config("qwen3-0.6b"), name="qwen3-100m",
        num_layers=12, d_model=512, num_heads=8, num_kv_heads=4,
        head_dim=64, d_ff=1536, vocab_size=32000,
    ).with_spt(attn_min_l=16, chunk_q=128)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume-demo", action="store_true",
                    help="kill/restart mid-run to exercise restart")
    args = ap.parse_args()

    cfg = model_100m()
    print(f"{cfg.name}: {count_params(model_defs(cfg))/1e6:.1f}M params, "
          f"{count_params(model_defs(cfg), True)/1e6:.2f}M trainable")
    ckpt = args.ckpt or tempfile.mkdtemp(prefix="spt_ckpt_")
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)

    if args.resume_demo:
        half = args.steps // 2
        t1 = Trainer(cfg, ocfg, TrainerConfig(
            total_steps=half, ckpt_dir=ckpt, ckpt_interval=25))
        t1.run(synthetic_dataset(dcfg, steps=half + 1))
        print(f"-- simulated preemption at step {half}; restarting --")

    trainer = Trainer(cfg, ocfg, TrainerConfig(
        total_steps=args.steps, ckpt_dir=ckpt, ckpt_interval=50))
    print(f"starting from step {trainer.start_step} (ckpt dir {ckpt})")
    report = trainer.run(synthetic_dataset(dcfg, steps=args.steps + 1))
    print(json.dumps({"final_step": report["final_step"],
                      "first": report["metrics"][0] if report["metrics"] else None,
                      "last": report["metrics"][-1] if report["metrics"] else None,
                      "straggler": report["straggler"]}, indent=1))


if __name__ == "__main__":
    main()
