"""Quickstart: build a small SPT model, run the Model Adapter workflow,
fine-tune a few steps, and compare Full / LoRA / SPT step costs.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import adapter
from repro.core.params import count_params, init_tree, trainable_mask
from repro.data.pipeline import DataConfig, synthetic_dataset
from repro.launch.dryrun import apply_variant
from repro.models import transformer
from repro.optim.adamw import OptimizerConfig
from repro.train.state import model_defs
from repro.train.trainer import Trainer, TrainerConfig


def main():
    cfg = configs.get_smoke("qwen3-0.6b")
    print(f"arch: {cfg.name} (reduced)  layers={cfg.num_layers} "
          f"d={cfg.d_model} heads={cfg.num_heads}/{cfg.num_kv_heads}")

    # --- the paper's Model Adapter workflow: dense -> SPT ------------
    dense_cfg = apply_variant(cfg, "full")
    dense_params = init_tree(transformer.lm_defs(dense_cfg),
                             jax.random.PRNGKey(0))
    spt_params = adapter.adapt(dense_params, dense_cfg, cfg,
                               jax.random.PRNGKey(1))
    print(adapter.upgrade_report(dense_params, spt_params)[:400], "...")

    # --- parameter accounting ----------------------------------------
    defs = model_defs(cfg)
    total = count_params(defs)
    trainable = count_params(defs, only_trainable=True)
    print(f"params: total={total/1e6:.2f}M  trainable (LoRA/router/PQ)="
          f"{trainable/1e6:.3f}M  ({100*trainable/total:.2f}%)")

    # --- short fine-tune on the synthetic corpus ---------------------
    steps = 30
    data = synthetic_dataset(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8),
        steps=steps + 1)
    trainer = Trainer(cfg, OptimizerConfig(lr=3e-3, total_steps=steps),
                      TrainerConfig(total_steps=steps, log_interval=10))
    report = trainer.run(data)
    for m in report["metrics"]:
        print(f"  step {m['step']:>3}  loss={m['loss']:.3f} "
              f"acc={m['accuracy']:.3f}")

    # --- Full vs LoRA vs SPT one-step wall time (CPU, compiled) ------
    for variant in ("full", "lora", "spt"):
        vcfg = apply_variant(cfg, variant)
        t = Trainer(vcfg, OptimizerConfig(), TrainerConfig(total_steps=3))
        d = synthetic_dataset(
            DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                       global_batch=8), steps=4)
        batches = list(d)
        t.run(iter(batches[:1]))        # compile
        t0 = time.time()
        t.run(iter(batches[1:3]))
        print(f"  {variant:>5}: {(time.time()-t0)/2*1e3:.0f} ms/step (CPU)")


if __name__ == "__main__":
    main()
