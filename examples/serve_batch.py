"""Continuous-batching serving example: ragged prompts stream through a
small pool of decode slots, with the SPT sparse-MHA decode path (top-L
selection over the PQ-coded KV cache) and EOS-based early exit.

    PYTHONPATH=src python examples/serve_batch.py --requests 8 --slots 4
"""
import argparse
import json

import jax

from repro import configs
from repro.core.params import init_tree
from repro.launch.serve import build_requests
from repro.serving.engine import Engine
from repro.train.state import model_defs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_len=args.prompt_len + args.gen + 8,
                    num_slots=args.slots, eos_id=args.eos_id)

    requests = build_requests(cfg, args.requests, args.prompt_len, args.gen,
                              ragged=True)

    out = engine.run(requests, temperature=args.temperature,
                     key=jax.random.PRNGKey(3))
    print(json.dumps({
        "arch": cfg.name, "requests": args.requests, "slots": args.slots,
        **engine.last_stats.as_dict(),
        "completions": [{"uid": c.uid, "prompt_len": c.prompt_len,
                         "reason": c.finish_reason, "tokens": c.tokens[:10]}
                        for c in out],
    }, indent=1))


if __name__ == "__main__":
    main()
