"""Batched serving example: prefill + decode with the SPT sparse-MHA
decode path (top-L selection over the PQ-coded KV cache).

    PYTHONPATH=src python examples/serve_batch.py --requests 4 --gen 16
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.params import init_tree
from repro.serving.engine import Engine
from repro.train.state import model_defs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_len=args.prompt_len + args.gen + 8)
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (args.requests, args.prompt_len), 0,
        cfg.vocab_size, dtype=jnp.int32)}
    if cfg.frontend:
        batch["frontend_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.requests, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    t0 = time.time()
    out = engine.generate(batch, steps=args.gen, temperature=0.8,
                          key=jax.random.PRNGKey(3))
    dt = time.time() - t0
    print(json.dumps({
        "arch": cfg.name, "requests": args.requests,
        "tokens_per_s": round(args.requests * args.gen / dt, 1),
        "generations": [t[:10] for t in out.tokens],
    }, indent=1))


if __name__ == "__main__":
    main()
