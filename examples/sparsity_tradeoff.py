"""Sparsity-strength sweep (paper Figure 10 analogue): fine-tune the same
small LM at several sparse-MHA / routed-FFN strengths and report loss.

    PYTHONPATH=src python examples/sparsity_tradeoff.py --steps 120
"""
import argparse
import dataclasses
import json

import jax

from repro import configs
from repro.data.pipeline import DataConfig, synthetic_dataset
from repro.optim.adamw import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    base = configs.get_smoke("qwen3-0.6b")
    base = dataclasses.replace(base, num_layers=4, d_model=128,
                               num_heads=4, num_kv_heads=2, head_dim=32,
                               d_ff=256, vocab_size=512)
    rows = []
    grid = [
        ("dense (LoRA)", dict(sparse_mha=False, routed_ffn=False)),
        ("mha 1/4", dict(attn_top_fraction=0.25, routed_ffn=False)),
        ("mha 1/8", dict(attn_top_fraction=0.125, routed_ffn=False)),
        ("ffn 3/4", dict(sparse_mha=False, ffn_active_groups=6)),
        ("ffn 1/2", dict(sparse_mha=False, ffn_active_groups=4)),
        ("spt (1/8 + 1/2)", dict(attn_top_fraction=0.125,
                                 ffn_active_groups=4)),
    ]
    for name, spt_kw in grid:
        cfg = base.with_spt(**spt_kw)
        data = synthetic_dataset(
            DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                       global_batch=8, seed=7), steps=args.steps + 1)
        t = Trainer(cfg, OptimizerConfig(lr=3e-3, total_steps=args.steps),
                    TrainerConfig(total_steps=args.steps, log_interval=20))
        rep = t.run(data)
        last = rep["metrics"][-1]
        rows.append({"setting": name, "loss": round(last["loss"], 4),
                     "ppl": round(2.718281828 ** last["lm_loss"], 2),
                     "acc": round(last["accuracy"], 4)})
        print(rows[-1])
    print(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
